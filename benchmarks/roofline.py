"""Roofline benchmark: aggregate the dry-run JSONs into the §Roofline table.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and emits
one CSV row per (arch, shape, mesh) plus a markdown table for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_row


def load_records(dirname: str = "experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs) -> str:
    hdr = ("| arch | shape | mesh | status | t_comp (s) | t_mem (s) | "
           "t_coll (s) | bottleneck | useful FLOPs | fits HBM |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in recs:
        if r.get("roofline"):
            t = r["roofline"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} "
                f"| {t['t_compute_s']:.3f} | {t['t_memory_s']:.3f} "
                f"| {t['t_collective_s']:.3f} | {t['bottleneck']} "
                f"| {t['useful_flops_fraction']:.1%} | {r['fits_hbm']} |")
        else:
            reason = r.get("reason", r.get("error", ""))[:60]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} "
                f"| - | - | - | - | - | {reason} |")
    return hdr + "\n".join(lines)


def main(fast: bool = True, out_json: str | None = None):
    recs = load_records()
    rows = []
    for r in recs:
        if r.get("roofline"):
            t = r["roofline"]
            rows.append(csv_row(
                f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                max(t["t_compute_s"], t["t_memory_s"],
                    t["t_collective_s"]) * 1e6,
                f"bottleneck={t['bottleneck']};"
                f"useful={t['useful_flops_fraction']:.3f};"
                f"fits={r['fits_hbm']}"))
        else:
            rows.append(csv_row(
                f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
                f"status={r['status']}"))
    if not rows:
        rows.append(csv_row("roofline_no_dryruns_found", 0.0,
                            "run repro.launch.dryrun --all first"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
    print()
    print(markdown_table(load_records()))

"""Figure 5: accuracy vs privacy budget eps_th at fixed resource budgets."""
from __future__ import annotations

import json
import time

from benchmarks.common import (
    estimate_constants, make_cases, run_dp_pasgd, csv_row,
    BATCH, C1, C2, CLIP, DELTA,
)
from repro.core.design import DesignProblem, ResourceModel

EPS_GRID = (1.0, 2.0, 4.0, 10.0)
C_GRID = (500.0, 1000.0)


def main(fast: bool = True, out_json: str | None = None):
    rows, blob = [], {}
    for case in make_cases(fast):
        consts = estimate_constants(case)
        for c_th in C_GRID:
            accs = []
            t0 = time.time()
            for eps in EPS_GRID:
                prob = DesignProblem(
                    consts=consts, resource=ResourceModel(C1, C2),
                    clip_norm=CLIP, batch_sizes=case.fed.batch_sizes(BATCH),
                    delta=DELTA, eps_th=eps, c_th=c_th)
                sol = prob.solve()
                out = run_dp_pasgd(case, tau=sol.tau, c_th=c_th, eps_th=eps,
                                   k_budget=sol.k)
                accs.append(out["best"].get("eval_acc", 0.0))
            dt = time.time() - t0
            key = f"{case.name}_C{int(c_th)}"
            blob[key] = dict(zip(map(float, EPS_GRID), accs))
            monotone = accs[-1] >= accs[0] - 0.02
            rows.append(csv_row(
                f"fig5_{key}", dt * 1e6 / len(EPS_GRID),
                ";".join(f"eps{e:g}={a:.4f}"
                         for e, a in zip(EPS_GRID, accs))
                + f";higher_eps_helps={monotone}"))
    if out_json:
        with open(out_json, "w") as f:
            json.dump(blob, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)

"""Figure 4: accuracy vs resource budget C_th at fixed privacy budgets.

Uses the solver-configured DP-PASGD at each budget point."""
from __future__ import annotations

import json
import time

from benchmarks.common import (
    estimate_constants, make_cases, run_dp_pasgd, csv_row,
    BATCH, C1, C2, CLIP, DELTA,
)
from repro.core.design import DesignProblem, ResourceModel

C_GRID = (200.0, 500.0, 1000.0)
EPS_GRID = (1.0, 10.0)


def main(fast: bool = True, out_json: str | None = None):
    rows, blob = [], {}
    for case in make_cases(fast):
        consts = estimate_constants(case)
        for eps in EPS_GRID:
            accs = []
            t0 = time.time()
            for c_th in C_GRID:
                prob = DesignProblem(
                    consts=consts, resource=ResourceModel(C1, C2),
                    clip_norm=CLIP, batch_sizes=case.fed.batch_sizes(BATCH),
                    delta=DELTA, eps_th=eps, c_th=c_th)
                sol = prob.solve()
                out = run_dp_pasgd(case, tau=sol.tau, c_th=c_th, eps_th=eps,
                                   k_budget=sol.k)
                accs.append(out["best"].get("eval_acc", 0.0))
            dt = time.time() - t0
            key = f"{case.name}_eps{eps:g}"
            blob[key] = dict(zip(map(int, C_GRID), accs))
            monotone = accs[-1] >= accs[0] - 0.02
            rows.append(csv_row(
                f"fig4_{key}", dt * 1e6 / len(C_GRID),
                ";".join(f"C{int(c)}={a:.4f}" for c, a in zip(C_GRID, accs))
                + f";higher_C_helps={monotone}"))
    if out_json:
        with open(out_json, "w") as f:
            json.dump(blob, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)

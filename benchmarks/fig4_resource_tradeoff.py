"""Figure 4: accuracy vs resource budget C_th at fixed privacy budgets.

Uses the solver-configured DP-PASGD at each budget point. The
beyond-paper ``comm_sweep`` compares the aggregation-pipeline settings
(partial participation x compressed updates) against the paper's dense
full-cohort protocol at a *matched iteration budget*, so the Eq.-8
resource saving shows up directly in ``resource_spent`` at comparable
accuracy."""
from __future__ import annotations

import json
import time

from benchmarks.common import (
    estimate_constants, make_cases, run_dp_pasgd, csv_row,
    BATCH, C1, C2, CLIP, DELTA,
)
from repro.core.design import DesignProblem, ResourceModel

C_GRID = (200.0, 500.0, 1000.0)
EPS_GRID = (1.0, 10.0)

# (label, participation q, compressor, ratio) — the comm sweep grid
PIPELINES = (
    ("dense_q100", 1.0, "none", 1.0),
    ("topk25_q100", 1.0, "topk", 0.25),
    ("topk25_q50", 0.5, "topk", 0.25),
    ("qsgd8_q50", 0.5, "qsgd", 0.25),
)


def comm_sweep(fast: bool = True, eps: float = 10.0, tau: int = 5,
               rounds: int = 20):
    """Pipeline sweep on one synthetic case at a fixed (tau, K, eps).

    All settings train the same K = rounds * tau iterations under a
    non-binding C_th; the derived column reports accuracy and the Eq.-8
    cost each setting actually spent (comm term scaled by wire_ratio * q).
    """
    case = make_cases(fast)[1]          # Adult-2 (iid synthetic, logreg)
    k = rounds * tau
    c_th = 10 * k * (C1 / tau + C2)     # never binds: K fixes the run length
    rows, blob = [], {}
    base_cost = None
    for label, q, comp, ratio in PIPELINES:
        t0 = time.time()
        out = run_dp_pasgd(case, tau=tau, c_th=c_th, eps_th=eps,
                           k_budget=k, participation=q, compressor=comp,
                           compression_ratio=ratio)
        dt = time.time() - t0
        acc = out["best"].get("eval_acc", 0.0)
        cost = out["resource_spent"]
        base_cost = cost if base_cost is None else base_cost
        blob[label] = {"eval_acc": acc, "resource_spent": cost,
                       "cost_vs_dense": cost / base_cost}
        rows.append(csv_row(
            f"fig4_comm_{label}", dt * 1e6,
            f"acc={acc:.4f};cost={cost:.0f};"
            f"cost_vs_dense={cost / base_cost:.3f}"))
    return rows, blob


def main(fast: bool = True, out_json: str | None = None):
    rows, blob = [], {}
    for case in make_cases(fast):
        consts = estimate_constants(case)
        for eps in EPS_GRID:
            accs = []
            t0 = time.time()
            for c_th in C_GRID:
                prob = DesignProblem(
                    consts=consts, resource=ResourceModel(C1, C2),
                    clip_norm=CLIP, batch_sizes=case.fed.batch_sizes(BATCH),
                    delta=DELTA, eps_th=eps, c_th=c_th)
                sol = prob.solve()
                out = run_dp_pasgd(case, tau=sol.tau, c_th=c_th, eps_th=eps,
                                   k_budget=sol.k)
                accs.append(out["best"].get("eval_acc", 0.0))
            dt = time.time() - t0
            key = f"{case.name}_eps{eps:g}"
            blob[key] = dict(zip(map(int, C_GRID), accs))
            monotone = accs[-1] >= accs[0] - 0.02
            rows.append(csv_row(
                f"fig4_{key}", dt * 1e6 / len(C_GRID),
                ";".join(f"C{int(c)}={a:.4f}" for c, a in zip(C_GRID, accs))
                + f";higher_C_helps={monotone}"))
    sweep_rows, sweep_blob = comm_sweep(fast)
    rows.extend(sweep_rows)
    blob["comm_sweep"] = sweep_blob
    if out_json:
        with open(out_json, "w") as f:
            json.dump(blob, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)

"""Figure 6: the solver's optimal tau over the (C_th, eps_th) grid.

Paper §8.5 claim: tau* decreases with the resource budget and increases
with the privacy budget. Pure solver evaluation (no training)."""
from __future__ import annotations

import json
import time

from benchmarks.common import (
    estimate_constants, make_cases, csv_row, BATCH, C1, C2, CLIP, DELTA,
)
from repro.core.design import DesignProblem, ResourceModel

C_GRID = (200.0, 400.0, 600.0, 800.0, 1000.0)
EPS_GRID = (1.0, 2.0, 4.0, 7.0, 10.0)


def main(fast: bool = True, out_json: str | None = None):
    rows, blob = [], {}
    case = make_cases(fast)[0]          # Adult-1 representative
    consts = estimate_constants(case)
    t0 = time.time()
    grid = {}
    for c_th in C_GRID:
        for eps in EPS_GRID:
            prob = DesignProblem(
                consts=consts, resource=ResourceModel(C1, C2),
                clip_norm=CLIP, batch_sizes=case.fed.batch_sizes(BATCH),
                delta=DELTA, eps_th=eps, c_th=c_th)
            grid[f"C{int(c_th)}_eps{eps:g}"] = prob.solve().tau
    dt = time.time() - t0
    blob["grid"] = grid
    # monotonicity checks of the paper's §8.5 claims
    tau_low_c = grid[f"C{int(C_GRID[0])}_eps4"]
    tau_high_c = grid[f"C{int(C_GRID[-1])}_eps4"]
    tau_low_e = grid[f"C600_eps{EPS_GRID[0]:g}"]
    tau_high_e = grid[f"C600_eps{EPS_GRID[-1]:g}"]
    rows.append(csv_row(
        "fig6_optimal_tau", dt * 1e6 / (len(C_GRID) * len(EPS_GRID)),
        f"tau(C{int(C_GRID[0])})={tau_low_c};tau(C{int(C_GRID[-1])})={tau_high_c};"
        f"dec_with_C={tau_low_c >= tau_high_c};"
        f"tau(eps1)={tau_low_e};tau(eps10)={tau_high_e};"
        f"inc_with_eps={tau_high_e >= tau_low_e}"))
    if out_json:
        with open(out_json, "w") as f:
            json.dump(blob, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)

"""Serving-plane benchmark: tokens/s and per-token latency vs offered load.

Open-loop Poisson arrivals (the ``asyncfl/clock.py`` determinism idiom:
every request a pure function of ``(seed, rid)``) drive the
continuous-batching :class:`repro.serve.SlotEngine` and the static-batch
baseline over the SAME workload, on a :class:`WallClock` — simulated time
advances by the measured host seconds of each prefill/decode and jumps
idle gaps, so tokens/s is real engine speed and latency percentiles
include real queueing at the offered load.

Offered load is calibrated, not absolute: a saturated probe measures this
host's aggregate decode capacity (tokens/s with all slots busy), then
each scenario offers ``load x capacity`` tokens/s of Poisson demand.
``load=2.0`` is the backpressure regime the queue-depth stats exist for.

    PYTHONPATH=src python benchmarks/serve.py --smoke --check

``--check`` gates (CI serve leg): continuous batching strictly above the
static baseline on aggregate tokens/s at the mixed-length scenario, and
byte-identical per-request tokens between the two modes (greedy).
Writes BENCH_serve.json.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.models.transformer import Transformer
from repro.serve import (SlotEngine, WallClock, poisson_workload,
                         serve_continuous, serve_static)

PROMPT_LENS = (5, 8, 12)
GEN_LENS = (4, 9)
LOADS = (0.5, 1.0, 2.0)


def _build(arch: str, smoke: bool, n_slots: int, max_len: int,
           block_size: int):
    cfg = get_arch(arch)
    if smoke:
        cfg = smoke_variant(cfg)
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = SlotEngine(model, params, n_slots=n_slots, max_len=max_len,
                        block_size=block_size)
    return model, params, engine


def _calibrate(engine, vocab: int) -> float:
    """Aggregate decode capacity (tokens/s) with every slot busy: serve a
    zero-arrival-gap probe and take the steady throughput."""
    probe = poisson_workload(2 * engine.n_slots, 1e9, vocab, seed=99,
                             prompt_lens=PROMPT_LENS, gen_lens=GEN_LENS)
    report = serve_continuous(engine, probe)
    return report.tokens_per_s


def _row(mode: str, load: float, offered: float, report) -> dict:
    s = report.summary()
    return {
        "mode": mode, "load": load,
        "offered_tokens_per_s": round(offered, 1),
        "tokens_per_s": s["tokens_per_s"],
        "p50_latency_ms": round(s["p50_latency_s"] * 1e3, 3),
        "p99_latency_ms": round(s["p99_latency_s"] * 1e3, 3),
        "requests": s["requests"], "tokens_out": s["tokens_out"],
        "max_queue_depth": s["max_queue_depth"],
        "occupancy_mean": s["occupancy_mean"],
    }


def run(arch: str, smoke: bool, n_slots: int, block_size: int,
        n_requests: int) -> dict:
    max_len = max(PROMPT_LENS) + max(GEN_LENS)
    model, params, engine = _build(arch, smoke, n_slots, max_len,
                                   block_size)
    vocab = model.cfg.vocab
    engine.warmup(buckets=PROMPT_LENS)
    capacity = _calibrate(engine, vocab)
    mean_gen = float(np.mean(GEN_LENS))
    # warm the static path's per-length prefill compiles off the clock
    serve_static(model, params, poisson_workload(
        3, 1e9, vocab, seed=98, prompt_lens=PROMPT_LENS,
        gen_lens=GEN_LENS), batch=n_slots, max_len=max_len)

    rows = []
    token_match = True
    for load in LOADS:
        offered = load * capacity
        rate = offered / mean_gen
        wl_c = poisson_workload(n_requests, rate, vocab, seed=7,
                                prompt_lens=PROMPT_LENS, gen_lens=GEN_LENS)
        wl_s = poisson_workload(n_requests, rate, vocab, seed=7,
                                prompt_lens=PROMPT_LENS, gen_lens=GEN_LENS)
        rep_c = serve_continuous(engine, wl_c, clock=WallClock())
        rep_s = serve_static(model, params, wl_s, clock=WallClock(),
                             batch=n_slots, max_len=max_len)
        rows.append(_row("continuous", load, offered, rep_c))
        rows.append(_row("static", load, offered, rep_s))
        token_match &= all(a.out == b.out for a, b in
                           zip(rep_c.requests, rep_s.requests))
        print(f"load={load:<4} continuous {rows[-2]['tokens_per_s']:>8.1f} "
              f"tok/s p99={rows[-2]['p99_latency_ms']:>8.2f} ms | "
              f"static {rows[-1]['tokens_per_s']:>8.1f} tok/s "
              f"p99={rows[-1]['p99_latency_ms']:>8.2f} ms")

    return {
        "bench": "serve",
        "config": {"arch": model.cfg.name, "smoke": smoke,
                   "n_slots": n_slots, "block_size": block_size or max_len,
                   "max_len": max_len, "n_requests": n_requests,
                   "prompt_lens": list(PROMPT_LENS),
                   "gen_lens": list(GEN_LENS),
                   "capacity_tokens_per_s": round(capacity, 1),
                   "compile_s": engine.stats()["compile_s"]},
        "jax": jax.__version__,
        "device": str(jax.devices()[0]),
        "results": rows,
        "tokens_byte_identical": bool(token_match),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke model variant + reduced workload for CI")
    ap.add_argument("--check", action="store_true",
                    help="fail unless continuous batching beats the "
                         "static baseline on aggregate tokens/s at every "
                         "mixed-length load, with byte-identical tokens")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--requests", type=int, default=0,
                    help="workload size per load point (default 10 smoke, "
                         "32 full)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    n_requests = args.requests or (10 if args.smoke else 32)
    report = run(args.arch, args.smoke, args.slots, args.block_size,
                 n_requests)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")

    if args.check:
        if not report["tokens_byte_identical"]:
            print("REGRESSION: continuous and static emitted different "
                  "tokens for the same greedy workload")
            return 1
        by_load = {}
        for r in report["results"]:
            by_load.setdefault(r["load"], {})[r["mode"]] = r
        slow = {load: (m["continuous"]["tokens_per_s"],
                       m["static"]["tokens_per_s"])
                for load, m in by_load.items()
                if m["continuous"]["tokens_per_s"]
                <= m["static"]["tokens_per_s"]}
        if slow:
            print(f"REGRESSION: continuous batching not above the static "
                  f"baseline (load -> (cont, static) tok/s): {slow}")
            return 1
        print("serve gate passed: continuous > static at every load, "
              "tokens byte-identical")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Attack-resilience benchmark: final accuracy vs byzantine fraction.

Trains the fixed CPU reference federation (logistic regression on a
separable synthetic task) under a byzantine update attack at a sweep of
byzantine fractions, for every aggregator
(``mean | median | trimmed_mean | norm_bound``), and emits
``BENCH_attack.json`` — the accuracy-vs-fraction trajectory every future
PR's robust-aggregation change has to beat.

Reading the numbers: at fraction 0.0 every aggregator trains to the same
clean accuracy (the robust reductions cost a little statistical
efficiency, nothing more). As the fraction grows, the ``mean`` column is
dragged by the boosted byzantine updates while the robust columns hold.
``--check`` gates exactly the ISSUE acceptance criterion at fraction 0.25:
every robust aggregator's post-attack accuracy stays within
``GATE_POINTS`` (5 points) of its own no-attack accuracy, AND the mean
degrades by strictly more than the worst robust aggregator. The runs are
seed-deterministic, so the gate is not flaky.

    PYTHONPATH=src python benchmarks/attack_resilience.py           # full
    PYTHONPATH=src python benchmarks/attack_resilience.py --smoke --check
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.api import FederationSpec, eval_params, init_state, train
from repro.models.linear import init_linear, logits, logreg_loss
from repro.optim import sgd

# fixed reference federation: big enough that the attacked mean visibly
# diverges, small enough for a CI smoke leg
C, TAU, DIM, BATCH = 8, 2, 16, 8
# sigma is deliberately small: the robust reductions' residual bias under
# attack scales with the honest-row spread (order statistics of noisy
# rows), and the benchmark isolates BYZANTINE damage, not DP damage
SIGMA, LR, CLIP = 0.02, 0.3, 1.0
# negative scale = boosted sign-flip (model-replacement poison): the one
# attack that durably breaks the mean at fractions < 0.5 — plain sign_flip
# only halves the mean step, and a positive boost still points the honest
# way, so both wash out over a longer round budget
ATTACK, ATTACK_SCALE = "scale", -25.0
GATE_FRACTION = 0.25            # the ISSUE acceptance point: 2 of 8 clients
GATE_POINTS = 0.05              # robust post-attack accuracy within 5 points

AGGREGATORS = [
    ("mean", {}),
    ("median", {}),
    ("trimmed_mean", dict(trim_fraction=0.25)),
    ("norm_bound", dict(norm_bound_factor=2.0)),
]


def make_task(seed: int = 0):
    """A separable logistic task shared by all runs: fixed true weights,
    unit-ball features. Returns (sampler, eval_batch)."""
    root = np.random.default_rng(seed)
    w_true = root.normal(size=DIM)
    w_true /= np.linalg.norm(w_true)

    def draw(rng, n):
        x = rng.normal(size=(n, DIM))
        x /= np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1.0)
        y = (x @ w_true > 0).astype(np.int32)
        return x.astype(np.float32), y

    def sampler(m, tau, rng):
        x, y = draw(rng, tau * BATCH)
        return {"x": x.reshape(tau, BATCH, DIM), "y": y.reshape(tau, BATCH)}

    ex, ey = draw(np.random.default_rng(seed + 1), 2048)
    return sampler, {"x": ex, "y": ey}


def accuracy(params, eval_batch) -> float:
    z = np.asarray(logits(params, eval_batch["x"]))
    return float((z.argmax(axis=-1) == np.asarray(eval_batch["y"])).mean())


def attack_spec(aggregator: str, fraction: float, **agg_kw) -> FederationSpec:
    return FederationSpec(
        n_clients=C, tau=TAU, loss_fn=logreg_loss, optimizer=sgd(LR),
        dp=True, clip_norm=CLIP, kernel_backend="ref",
        sigmas=(SIGMA,) * C, batch_sizes=(BATCH,) * C,
        aggregator=aggregator,
        # fraction 0 -> attack "none": identical spec shape, no byzantine
        # set (and the clean runs double as every aggregator's baseline)
        attack=(ATTACK if fraction > 0 else "none"),
        byzantine_fraction=fraction, attack_scale=ATTACK_SCALE,
        # a compressor-free pipeline is forced by the aggregator on the
        # robust rows; the mean rows get it from the participation field
        # staying at 1.0 only when adversarial — use identity topk so ALL
        # rows (mean included) share the pipeline PRNG schedule
        compressor="topk", compression_ratio=1.0,
        **agg_kw)


def run_matrix(fractions, rounds: int) -> list[dict]:
    sampler, eval_batch = make_task()
    rows = []
    for agg, kw in AGGREGATORS:
        for frac in fractions:
            spec = attack_spec(agg, frac, **kw)
            state = init_state(spec, init_linear(DIM))
            state, out = train(spec, state, sampler, max_rounds=rounds)
            acc = accuracy(eval_params(spec, state), eval_batch)
            rows.append({
                "aggregator": agg, "byzantine_fraction": frac,
                "attack": ATTACK if frac > 0 else "none",
                "attack_scale": ATTACK_SCALE, "rounds": out["rounds"],
                "final_loss": out["history"][-1]["loss"],
                "accuracy": round(acc, 4),
            })
            print(f"{agg:13s} byz={frac:<6} acc={acc:.3f} "
                  f"loss={out['history'][-1]['loss']:.4f}")
    return rows


def check_gate(rows) -> int:
    """The ISSUE acceptance gate at GATE_FRACTION (deterministic runs)."""
    acc = {(r["aggregator"], r["byzantine_fraction"]): r["accuracy"]
           for r in rows}
    drops = {agg: acc[(agg, 0.0)] - acc[(agg, GATE_FRACTION)]
             for agg, _ in AGGREGATORS}
    robust = {a: d for a, d in drops.items() if a != "mean"}
    print(f"accuracy drops at byz={GATE_FRACTION}: "
          f"{ {a: round(d, 4) for a, d in drops.items()} }")
    bad = {a: d for a, d in robust.items() if d > GATE_POINTS}
    if bad:
        print(f"REGRESSION: robust aggregator(s) lost more than "
              f"{GATE_POINTS * 100:.0f} accuracy points under attack: {bad}")
        return 1
    worst_robust = max(robust.values())
    if drops["mean"] <= worst_robust:
        print(f"REGRESSION: mean ({drops['mean']:.4f}) no longer degrades "
              f"more than the worst robust aggregator ({worst_robust:.4f}) "
              f"— the attack matrix lost its contrast")
        return 1
    print(f"attack gate passed: robust drops <= {GATE_POINTS}, mean drops "
          f"{drops['mean']:.3f}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep for CI (gate fractions only)")
    ap.add_argument("--check", action="store_true",
                    help="fail unless every robust aggregator holds within "
                         f"{GATE_POINTS * 100:.0f} accuracy points at "
                         f"byzantine fraction {GATE_FRACTION} while the "
                         "mean degrades more")
    ap.add_argument("--out", default="BENCH_attack.json")
    args = ap.parse_args(argv)

    # the round budget is part of the gate's calibration (the robust
    # reductions' bias transient is larger early in training), so smoke
    # trims the fraction sweep, never the rounds
    if args.smoke:
        fractions, rounds = [0.0, GATE_FRACTION], 20
    else:
        fractions, rounds = [0.0, 0.125, GATE_FRACTION, 0.375], 20

    rows = run_matrix(fractions, rounds)
    report = {
        "bench": "attack_resilience",
        "config": {"n_clients": C, "tau": TAU, "dim": DIM, "batch": BATCH,
                   "sigma": SIGMA, "lr": LR, "attack": ATTACK,
                   "attack_scale": ATTACK_SCALE, "rounds": rounds,
                   "smoke": args.smoke},
        "jax": jax.__version__,
        "device": str(jax.devices()[0]),
        "results": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    if args.check:
        return check_gate(rows)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Driver-throughput benchmark: per-round vs fused multi-round training.

Measures end-to-end ``repro.api.train`` throughput (rounds/s and
local-steps/s, batch building + prefetch + ledger included) on a fixed
small CPU reference federation, across engine x chunk_rounds x compressor,
and emits ``BENCH_throughput.json`` so every future PR has a perf
trajectory to beat. ``chunk_rounds=1`` is the per-round driver (one XLA
dispatch and >=1 blocking host sync per round); ``chunk_rounds=R`` lowers R
rounds into one ``lax.scan`` dispatch with at most one blocking sync per
chunk (``host_syncs_per_round`` reports that driver-structural count: the
materialize/mask fetch for the per-round driver, 1/R for the fused one).

Reading the numbers: the PIPELINE configs (compressor / partial
participation — the paper's resource-constrained IoT setting) are where
the fusion is structural: the per-round driver must block on the realized
participation mask every round, the fused driver once per chunk, giving a
stable ~2-4x. The dense full-participation protocol has no forced
per-round sync left (this PR's lazy records + cached ledger constants
removed them), so jax async dispatch already pipelines it and its fused
gain is whatever python/dispatch overhead remains on the host — real but
machine-dependent. ``--check`` therefore gates only the sync-bound
pipeline configs (threshold 0.8 for CI-runner noise; healthy margin is
>= 2x) and reports the dense rows informationally.

A second scenario tracks **cohort scaling** (repro.population): rounds/s
and the device-resident block bytes of a K = 8 cohort as the virtual
population M grows 10^3 -> 10^6. Both must be flat in M — the population
drivers gather only the sampled cohort, so M buys scenario scale, not
device memory or dispatch cost. ``--check`` gates the byte-flatness
exactly and the rounds/s within a noise margin. Cohort rows now carry an
honest ``host_syncs_per_round``: the chunk-boundary path is NOT the dense
fused driver's 1/chunk — each chunk pays the stacked-mask fetch plus the
ClientStore residual gather and scatter-back materialize, i.e. 3/chunk
under a pipeline spec. A companion **resident-cohort** scenario times the
same M = 10^5 workload through ``train_population(...,
resident_cache=S)`` (PR 8): sticky state and stationary data shards live
on device, cohorts are drawn per round inside the scan, and the
steady-state chunk makes zero blocking host syncs. ``--check`` pins the
resident row's ``host_syncs_per_round == 0`` and its rounds/s against the
chunk-boundary baseline (noise margin; the committed full-grid JSON shows
it strictly ahead).

A **kernel roofline** section projects the measured kernels onto the TPU
v5e roofline of :mod:`repro.utils.roofline`: for the qsgd
``quantize_decompress`` kernel and the PR-8 ``cohort_gather_scatter``
kernel, each probe-available backend is timed on a fixed shape and the
row reports analytic FLOPs/bytes, achieved GFLOP/s and GB/s on this
host, the v5e roofline bound (t_compute vs t_memory, bottleneck term),
and the headroom factor ``wall / v5e_bound`` — how far this backend on
this host sits above what the target part's roofline admits. Both
kernels are streaming (O(1) flops/byte), so ``--check`` gates that every
row's projected bottleneck is the memory term — a compute-bound verdict
means the analytic model (or the kernel) regressed.

A **mesh-plane** section (needs >= 8 devices; rows are skipped with a
reason otherwise, so the committed full-grid JSON must come from an
``--xla_force_host_platform_device_count=8`` run) times the 2D client x
model engine (repro.mesh) against the 1D shard_map plane on the same
federation: the degenerate ``(8, 1)`` mesh — bitwise the shard_map
protocol, so its throughput gap is pure engine overhead (padding plumbing
+ partial-auto lowering) — and the true ``(4, 2)`` mesh, which halves the
client axis to buy a model axis. On this single-host CPU benchmark the
(4, 2) row pays real cost (half the client parallelism, no memory win to
show for it); the row exists to track that cost, not to win. A
**too-big-model** companion pins the placement story end to end: a
replica footprint hint that exceeds a (tiny, env-injected) per-device
budget must route ``engine="auto"`` onto mesh_2d with enough model shards
that the per-device slice fits, and the round must actually run.
``--check`` gates the degenerate row within a noise margin of shard_map
and the too-big row's ``per_device_bytes <= budget < replica_bytes``
invariant plus a finite loss.

A third scenario tracks **buffered-async federation** (repro.asyncfl) on
a heterogeneous straggler fleet: the simulated seconds to land a target
amount of zCDP (equivalently, R sync rounds' worth of client updates) for
the sync barrier driver vs the B-of-K buffered-async driver under the
same :class:`HeteroLatency` clock. The async side runs the real
``train_async`` driver (fused flush+dispatch programs, chunked schedule
projection), so the row also reports host flushes/s. ``--check`` gates
``async_sim_seconds < sync_sim_seconds`` strictly — on a fleet whose
slowest device is ~7x the fastest, losing that gap means the buffer
semantics regressed to a barrier.

    PYTHONPATH=src python benchmarks/throughput.py            # full grid
    PYTHONPATH=src python benchmarks/throughput.py --smoke --check
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import FederationSpec, init_state, train
from repro.api.state import round_rho_charges
from repro.asyncfl import (
    HeteroLatency,
    init_async_state,
    sync_round_duration,
    train_async,
)
from repro.kernels.dispatch import backend_works, get_kernel
from repro.models.linear import init_linear, logreg_loss
from repro.optim import sgd
from repro.population import (
    UniformCohort,
    cohort_batch,
    device_block_bytes,
    init_population_state,
    synthetic_population,
    train_population,
)
from repro.utils.roofline import HBM_BW, RooflineTerms

# fixed CPU reference federation: small enough that driver overhead (the
# thing this benchmark tracks) dominates — per-round host cost is fixed
# while device compute scales with tau*dim*batch, so keep all three small,
# but big enough to do real math
C, TAU, DIM, BATCH = 8, 2, 32, 8
SIGMA, LR, CLIP = 0.5, 0.3, 1.0


def reference_spec(engine: str, compressor: str, participation: float,
                   **kw) -> FederationSpec:
    extra = {}
    if compressor != "none":
        extra["compression_ratio"] = 0.25
    # kernel_backend pinned to the jnp oracle so the measurement is
    # identical on every platform ("auto" now resolves to ref off-TPU
    # anyway — this benchmark is where the ~100x interpret-vs-oracle gap
    # was measured and the auto ranking fixed)
    extra.update(kw)
    extra.setdefault("kernel_backend", "ref")
    return FederationSpec(
        n_clients=C, tau=TAU, loss_fn=logreg_loss, optimizer=sgd(LR),
        engine=engine, dp=True, clip_norm=CLIP,
        participation=participation, compressor=compressor,
        sigmas=(SIGMA,) * C, batch_sizes=(BATCH,) * C, **extra)


def make_sampler(dim: int = DIM, batch: int = BATCH):
    def sampler(m, tau, rng):
        return {"x": rng.normal(size=(tau, batch, dim)).astype(np.float32),
                "y": rng.integers(0, 2, size=(tau, batch)).astype(np.int32)}
    return sampler


def time_driver(spec: FederationSpec, rounds: int, chunk_rounds: int,
                repeats: int) -> dict:
    """Best-of-``repeats`` wall time of ``train(..., chunk_rounds=...)``,
    after one untimed warm-up run that pays all XLA compiles (min filters
    scheduler noise; both drivers get the same treatment)."""
    sampler = make_sampler()

    def one_run(n_rounds: int) -> float:
        state = init_state(spec, init_linear(DIM))
        t0 = time.perf_counter()
        state, out = train(spec, state, sampler, max_rounds=n_rounds,
                           chunk_rounds=chunk_rounds)
        jax.block_until_ready(state.params)
        assert out["rounds"] == n_rounds
        return time.perf_counter() - t0

    one_run(min(rounds, max(1, chunk_rounds)))          # compile warm-up
    wall = min(one_run(rounds) for _ in range(repeats))
    # blocking syncs per round, from the driver structure: the per-round
    # driver materializes each record (plus the mask fetch under a
    # pipeline spec); the fused driver blocks once per chunk
    syncs = ((1.0 + (1.0 if spec.has_pipeline() else 0.0))
             if chunk_rounds <= 1 else 1.0 / chunk_rounds)
    return {
        "engine": spec.engine, "compressor": spec.compressor,
        "participation": spec.participation_fraction(),
        "chunk_rounds": chunk_rounds, "rounds": rounds,
        "wall_s": round(wall, 4),
        "rounds_per_s": round(rounds / wall, 2),
        "local_steps_per_s": round(rounds * TAU / wall, 2),
        "host_syncs_per_round": syncs,
    }


def time_cohort_driver(m: int, rounds: int, chunk_rounds: int,
                       repeats: int, resident: int = 0) -> dict:
    """Cohort-scaling row: train a K = C cohort drawn from M virtual
    clients (fused chunks, topk pipeline so the ClientStore residual path
    is on the clock) and record rounds/s plus the device-resident block
    bytes — both must be independent of M.

    ``resident=S`` routes the same workload through the PR-8
    device-resident driver (``resident_cache=S``, stationary population so
    the data shards cache on device too): per-round cohorts inside the
    fused scan, zero blocking host syncs per steady-state chunk.
    """
    spec, pop = _cohort_workload(m, resident)
    _cohort_run(spec, pop, max(1, chunk_rounds), chunk_rounds,
                resident)                       # compile warm-up
    wall = min(_cohort_run(spec, pop, rounds, chunk_rounds, resident)
               for _ in range(repeats))
    return _cohort_row(spec, pop, m, rounds, chunk_rounds, resident, wall)


def _cohort_workload(m: int, resident: int):
    spec = reference_spec("vmap", "topk", 1.0).replace(population=m,
                                                       cohort_size=C)
    pop = synthetic_population(m, dim=DIM, batch_size=BATCH, seed=0,
                               stationary=bool(resident))
    return spec, pop


def _cohort_run(spec, pop, n_rounds: int, chunk_rounds: int,
                resident: int) -> float:
    ps = init_population_state(spec, init_linear(DIM))
    t0 = time.perf_counter()
    ps, out = train_population(spec, ps, pop, max_rounds=n_rounds,
                               chunk_rounds=chunk_rounds,
                               resident_cache=resident)
    jax.block_until_ready(ps.fl.params)
    assert out["rounds"] == n_rounds
    return time.perf_counter() - t0


def _cohort_row(spec, pop, m: int, rounds: int, chunk_rounds: int,
                resident: int, wall: float) -> dict:
    ps = init_population_state(spec, init_linear(DIM))
    batch = cohort_batch(spec, pop, UniformCohort(spec.seed)(0, m, C),
                         np.random.default_rng(0))
    # honest driver-structural sync count. The chunk-boundary cohort path
    # is NOT the dense fused driver's 1/chunk: each chunk blocks on the
    # stacked participation-mask fetch (pipeline spec) AND pays the
    # ClientStore hop — residual gather when building the device block,
    # residual materialize at scatter-back — so 3 per chunk. The resident
    # driver keeps residuals/rho/data on device and, under full
    # within-cohort participation, the mask is the deterministic all-ones
    # constant (never fetched): zero forced syncs per steady-state chunk.
    # Partial participation would reintroduce the 1/chunk mask fetch.
    if resident:
        syncs = (0.0 if spec.participation_fraction() >= 1.0
                 else 1.0 / chunk_rounds)
    else:
        syncs = ((1.0 if spec.has_pipeline() else 0.0) + 2.0) / chunk_rounds
    row = {
        "mode": "resident" if resident else "chunk_boundary",
        "population": m, "cohort_size": C, "chunk_rounds": chunk_rounds,
        "rounds": rounds, "wall_s": round(wall, 4),
        "rounds_per_s": round(rounds / wall, 2),
        "host_syncs_per_round": round(syncs, 4),
        "device_block_bytes": device_block_bytes(ps, batch),
    }
    if resident:
        row["resident_cache"] = resident
    return row


def run_cohort_scaling(smoke: bool) -> list[dict]:
    if smoke:
        ms, rounds, chunk, repeats = [1_000, 100_000], 16, 8, 2
    else:
        ms, rounds, chunk, repeats = [1_000, 100_000, 1_000_000], 32, 8, 3
    rows = []
    for m in ms:
        r = time_cohort_driver(m, rounds, chunk, repeats)
        rows.append(r)
        print(f"population M={m:<9,} K={C} chunk={chunk:<3} "
              f"{r['rounds_per_s']:>8.1f} rounds/s "
              f"({r['host_syncs_per_round']:.3f} syncs/round, "
              f"{r['device_block_bytes']:,} device bytes)")
    return rows


def run_resident_cohort(smoke: bool) -> dict:
    """Resident-vs-chunk-boundary head-to-head at M = 10^5, K = 8.

    Both drivers are timed with INTERLEAVED repeats (best-of each) on the
    same round count (longer than the scaling rows — the ratio is the
    deliverable, so per-run fixed costs must not dominate). The baseline
    fixes ONE cohort per chunk and pays the 3 ClientStore syncs at every
    boundary; the resident row runs ``resident_cache=S`` with a
    stationary population so sticky state AND data rows are
    device-resident, cohorts are drawn per round INSIDE the fused scan,
    and the steady-state chunk makes no blocking host sync at all.

    chunk_rounds is deliberately asymmetric: for the resident driver it is
    a pure execution detail — the realized cohort schedule is per-round at
    ANY chunk_rounds (finer than the baseline offers at any setting) — so
    it runs at its natural larger chunk, which the zero-sync property is
    exactly what makes safe. The baseline stays at the scaling rows'
    chunk: raising it would coarsen its cohort schedule further, trading
    fidelity for speed rather than comparing drivers.
    """
    m = 100_000
    rounds, repeats = (32, 3) if smoke else (64, 5)
    chunk_base, chunk_res = 8, 32
    cache = chunk_res * C               # S = 256: one full chunk of warm slots
    spec_b, pop_b = _cohort_workload(m, 0)
    spec_r, pop_r = _cohort_workload(m, cache)
    _cohort_run(spec_b, pop_b, chunk_base, chunk_base, 0)       # compile
    _cohort_run(spec_r, pop_r, chunk_res, chunk_res, cache)
    # INTERLEAVED repeats, best-of each: machine-state noise (scheduler,
    # allocator phase) lands on both drivers alike instead of biasing
    # whichever ran second — the ratio is the deliverable here
    walls_b, walls_r = [], []
    for _ in range(repeats):
        walls_b.append(_cohort_run(spec_b, pop_b, rounds, chunk_base, 0))
        walls_r.append(_cohort_run(spec_r, pop_r, rounds, chunk_res, cache))
    base = _cohort_row(spec_b, pop_b, m, rounds, chunk_base, 0,
                       min(walls_b))
    res = _cohort_row(spec_r, pop_r, m, rounds, chunk_res, cache,
                      min(walls_r))
    speedup = res["rounds_per_s"] / base["rounds_per_s"]
    print(f"resident   M={m:<9,} K={C} S={cache:<4} "
          f"{res['rounds_per_s']:>8.1f} rounds/s "
          f"({res['host_syncs_per_round']:.3f} syncs/round, "
          f"{speedup:.2f}x chunk-boundary)")
    return {"baseline": base, "resident": res,
            "speedup_resident_vs_chunk": round(speedup, 2)}


# analytic per-call roofline terms for the streamed kernels. Coarse HLO-level
# op counts, deliberately simple: quantize_decompress reads x and u and
# writes the dequantized y (3 f32 arrays, 12N bytes) and spends ~6 flops per
# element (abs-max reduction, normalize, scale, jitter-add, floor, dequant);
# cohort gather is a pure row copy — K*D read + K*D write, zero flops — so
# its roofline position is memory-bound by construction; dp_clip_noise (the
# per-round DP hot path: square+reduce for the norm, clip scale, fused
# noise multiply-add, ~5 flops/element) reads g and noise and writes y —
# 12N bytes, streaming like the others.
def _kernel_scenarios(smoke: bool) -> list[dict]:
    n = 1 << 16 if smoke else 1 << 20
    s_rows, d = (128, 256) if smoke else (512, 4096)
    key = jax.random.PRNGKey(0)
    kx, ku, kc, kn = jax.random.split(key, 4)
    x = jax.random.normal(kx, (n,), jnp.float32)
    u = jax.random.uniform(ku, (n,), jnp.float32)
    noise = jax.random.normal(kn, (n,), jnp.float32)
    cachemat = jax.random.normal(kc, (s_rows, d), jnp.float32)
    slots = jnp.asarray(np.arange(0, s_rows, s_rows // C)[:C], jnp.int32)
    return [
        {"kernel": "quantize_decompress",
         "shape": f"N={n}", "args": (x, u),
         "call": lambda impl: (lambda x_, u_: impl(x_, u_, 4)),
         "flops": 6.0 * n, "hbm_bytes": 12.0 * n},
        {"kernel": "cohort_gather_scatter",
         "shape": f"S={s_rows} K={C} D={d}", "args": (cachemat, slots),
         "call": lambda impl: (lambda c_, s_: impl(c_, s_)),
         "flops": 0.0, "hbm_bytes": 2.0 * C * d * 4.0},
        {"kernel": "dp_clip_noise",
         "shape": f"N={n}", "args": (x, noise),
         "call": lambda impl: (lambda g_, n_: impl(g_, n_, 1.0, 0.5)),
         "flops": 5.0 * n, "hbm_bytes": 12.0 * n},
    ]


def run_kernel_roofline(smoke: bool) -> dict:
    """Achieved-vs-peak per kernel backend, projected on the v5e roofline.

    Each probe-available backend is timed (best-of-repeats, many calls per
    timing to amortize dispatch) on a fixed shape; the row pairs the
    achieved GFLOP/s / GB/s on THIS host with the v5e roofline bound for
    the same analytic FLOPs/bytes. ``headroom_vs_v5e`` = measured wall /
    roofline bound: how many times slower this backend+host runs than the
    target part's roofline admits (1.0 would be a roofline-saturating
    kernel on real hardware).
    """
    iters, repeats = (5, 2) if smoke else (20, 3)
    rows = []
    for sc in _kernel_scenarios(smoke):
        for backend in ("pallas", "interpret", "ref"):
            if not backend_works(sc["kernel"], backend):
                continue
            if backend == "interpret" and not smoke:
                # interpret mode executes the kernel body block-by-block as
                # jax ops (~100x the oracle on CPU); time it at the smoke
                # shape only so the full grid stays minutes, not hours
                continue
            fn = jax.jit(sc["call"](get_kernel(sc["kernel"], backend)))
            out = fn(*sc["args"])
            jax.block_until_ready(out)          # compile warm-up
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                for _ in range(iters):
                    out = fn(*sc["args"])
                jax.block_until_ready(out)
                best = min(best, (time.perf_counter() - t0) / iters)
            terms = RooflineTerms(flops=sc["flops"],
                                  hbm_bytes=sc["hbm_bytes"], coll_bytes=0.0)
            bound = max(terms.t_compute, terms.t_memory)
            row = {
                "kernel": sc["kernel"], "backend": backend,
                "shape": sc["shape"],
                "flops": sc["flops"], "hbm_bytes": sc["hbm_bytes"],
                "wall_us": round(best * 1e6, 2),
                "achieved_gflop_s": round(sc["flops"] / best / 1e9, 2),
                "achieved_gb_s": round(sc["hbm_bytes"] / best / 1e9, 2),
                "fraction_of_v5e_hbm_bw": round(
                    sc["hbm_bytes"] / best / HBM_BW, 6),
                "v5e_bound_us": round(bound * 1e6, 4),
                "v5e_bottleneck": ("compute" if terms.t_compute
                                   > terms.t_memory else "memory"),
                "headroom_vs_v5e": round(best / bound, 1),
                "v5e_roofline": terms.as_dict(),
            }
            rows.append(row)
            print(f"roofline {sc['kernel']:22s} {backend:9s} {sc['shape']:18s}"
                  f" {row['wall_us']:>10.1f} us  {row['achieved_gb_s']:>8.2f}"
                  f" GB/s ({row['headroom_vs_v5e']}x off v5e "
                  f"{row['v5e_bottleneck']} roof)")
    return {"iters": iters, "repeats": repeats, "rows": rows}


def run_mesh_plane(smoke: bool) -> dict:
    """2D mesh engine vs the 1D shard_map plane, plus the too-big-model
    placement gate. See the module docstring for what each row means."""
    n_dev = jax.device_count()
    if n_dev < 8:
        return {"skipped": True,
                "reason": f"needs 8 devices for the (4,2) mesh, have {n_dev}"
                          " — run under "
                          "XLA_FLAGS=--xla_force_host_platform_device_count=8"}
    rounds, repeats = (16, 2) if smoke else (48, 3)
    chunk = 8
    rows = []
    for engine, shape in [("shard_map", None), ("mesh_2d", (8, 1)),
                          ("mesh_2d", (4, 2))]:
        kw = {"mesh_shape": shape} if shape else {}
        spec = reference_spec(engine, "none", 1.0, **kw)
        r = time_driver(spec, rounds, chunk, repeats)
        r["mesh_shape"] = list(shape) if shape else None
        rows.append(r)
        label = f"{engine}{list(shape) if shape else ''}"
        print(f"mesh {label:16s} chunk={chunk:<3} "
              f"{r['rounds_per_s']:>8.1f} rounds/s "
              f"({r['local_steps_per_s']:.0f} steps/s)")
    return {"rows": rows, "too_big_model": _run_mesh_too_big()}


def _run_mesh_too_big() -> dict:
    """Placement gate: a replica-footprint hint over the per-device budget
    must steer ``engine="auto"`` onto mesh_2d with enough model shards to
    fit, and the resulting program must train. The budget is injected via
    ``REPRO_DEVICE_MEM_BYTES`` (restored afterwards) and sized so a
    4-shard slice fits but the whole replica does not."""
    import os

    from repro.api import resolve_engine
    from repro.mesh.placement import (
        ENV_DEVICE_MEM,
        default_mesh_shape,
        device_memory_budget,
    )

    replica = 100 * DIM * 4                       # 12.8 KB synthetic hint
    budget = 4 * 1024                             # fits at dm=4, not at dm=1
    prev = os.environ.get(ENV_DEVICE_MEM)
    os.environ[ENV_DEVICE_MEM] = str(budget)
    try:
        spec = reference_spec("auto", "none", 1.0, replica_bytes=replica)
        engine = resolve_engine(spec)
        shape = default_mesh_shape(C, jax.device_count(),
                                   replica_bytes=replica)
        per_device = -(-replica // shape[1])
        sampler = make_sampler()
        state = init_state(spec, init_linear(DIM))
        state, out = train(spec, state, sampler, max_rounds=2)
        loss = float(out["history"][-1]["loss"])
        row = {
            "replica_bytes": replica,
            "budget_bytes": device_memory_budget(),
            "resolved_engine": engine,
            "mesh_shape": list(shape),
            "per_device_bytes": per_device,
            "final_loss": round(loss, 6),
        }
        print(f"mesh too-big     replica={replica} budget={budget} -> "
              f"{engine} {shape} ({per_device} B/device, "
              f"loss {loss:.4f})")
        return row
    finally:
        if prev is None:
            del os.environ[ENV_DEVICE_MEM]
        else:
            os.environ[ENV_DEVICE_MEM] = prev


def run_async_hetero(smoke: bool) -> dict:
    """Simulated-seconds-to-target-rho on a straggler fleet.

    The target is the total landed zCDP of ``rounds_sync`` full sync
    rounds (every client charged the Lemma-2 round rho each round). Sync
    reaches it in ``sum(max-over-fleet latency)`` simulated seconds; the
    async driver lands the same total after ``rounds_sync * K / B``
    flushes (dense spec: every arrival participates and carries the same
    charge), and its clock only ever waits for the B-th earliest arrival.
    """
    rounds_sync, buffer_size = (6, 2) if smoke else (12, 2)
    flushes = rounds_sync * C // buffer_size
    spec = reference_spec("async_buffered", "none", 1.0,
                          buffer_size=buffer_size, staleness_alpha=0.5,
                          eps_th=1e9, c_th=1e9)
    lat = HeteroLatency(0, fleet=C, slow_factor=6.0)
    target_rho = rounds_sync * float(round_rho_charges(spec).sum())
    sync_sim = sum(sync_round_duration(lat, C, r)
                   for r in range(rounds_sync))
    sampler = make_sampler()
    rng = np.random.default_rng(0)
    st = init_async_state(spec, init_linear(DIM), sampler, rng=rng,
                          latency_model=lat)
    t0 = time.perf_counter()
    st, out = train_async(spec, st, sampler, max_rounds=flushes, rng=rng,
                          chunk_rounds=8, latency_model=lat)
    jax.block_until_ready(st.global_params)
    wall = time.perf_counter() - t0
    assert out["rounds"] == flushes
    landed = float(np.sum(st.fl.rho))
    assert landed >= target_rho * (1 - 1e-9), (landed, target_rho)
    row = {
        "fleet": C, "buffer_size": buffer_size,
        "rounds_sync": rounds_sync, "flushes": flushes,
        "target_rho_landed": round(target_rho, 6),
        "sync_sim_seconds": round(sync_sim, 4),
        "async_sim_seconds": round(out["sim_seconds"], 4),
        "sim_speedup": round(sync_sim / out["sim_seconds"], 2),
        "wall_s": round(wall, 4),
        "flushes_per_s": round(flushes / wall, 2),
    }
    print(f"async hetero  K={C} B={buffer_size} target_rho="
          f"{row['target_rho_landed']:.3f}: sync {row['sync_sim_seconds']}s "
          f"vs async {row['async_sim_seconds']}s simulated "
          f"({row['sim_speedup']}x, {row['flushes_per_s']:.1f} flushes/s)")
    return row


def run_grid(smoke: bool) -> dict:
    if smoke:
        grid = [("vmap", "none", 1.0), ("vmap", "topk", 0.5)]
        chunks, rounds, repeats = (1, 8), 24, 3
    else:
        grid = [("vmap", "none", 1.0), ("vmap", "topk", 0.5),
                ("vmap", "qsgd", 1.0), ("map", "none", 1.0),
                ("shard_map", "none", 1.0), ("shard_map", "topk", 0.5)]
        chunks, rounds, repeats = (1, 2, 8), 64, 5
    results = []
    for engine, compressor, participation in grid:
        spec = reference_spec(engine, compressor, participation)
        for chunk in chunks:
            r = time_driver(spec, rounds, chunk, repeats)
            results.append(r)
            print(f"{engine:10s} {compressor:5s} q={participation:<4} "
                  f"chunk={chunk:<3} {r['rounds_per_s']:>8.1f} rounds/s "
                  f"({r['local_steps_per_s']:.0f} steps/s, "
                  f"{r['host_syncs_per_round']:.3f} syncs/round)")
    speedups = {}
    for engine, compressor, participation in grid:
        sel = {r["chunk_rounds"]: r["rounds_per_s"] for r in results
               if (r["engine"], r["compressor"], r["participation"])
               == (engine, compressor, float(participation))}
        base = sel[1]
        top = max(k for k in sel if k > 1)
        speedups[f"{engine}/{compressor}/q{participation}"] = round(
            sel[top] / base, 2)
    cohort_rows = run_cohort_scaling(smoke)
    return {
        "bench": "throughput",
        "config": {"n_clients": C, "tau": TAU, "dim": DIM, "batch": BATCH,
                   "sigma": SIGMA, "rounds": rounds, "smoke": smoke},
        "jax": jax.__version__,
        "device": str(jax.devices()[0]),
        "results": results,
        "speedup_fused_vs_per_round": speedups,
        "cohort_scaling": cohort_rows,
        "resident_cohort": run_resident_cohort(smoke),
        "kernel_roofline": run_kernel_roofline(smoke),
        "async_hetero": run_async_hetero(smoke),
        "mesh_plane": run_mesh_plane(smoke),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid for CI (vmap only, 24 rounds)")
    ap.add_argument("--check", action="store_true",
                    help="fail if any fused config regresses below the "
                         "per-round driver (with a noise margin: speedup "
                         "< 0.8 fails — a real regression lands far below, "
                         "the healthy margin is >= 2x)")
    ap.add_argument("--out", default="BENCH_throughput.json")
    args = ap.parse_args(argv)

    report = run_grid(args.smoke)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    if args.check:
        # gate only the pipeline configs, where the fused speedup is
        # structural (per-round mask sync vs 1/chunk) rather than
        # machine-dependent; 0.8 not 1.0 because the smoke walls are
        # sub-second and a scheduler stall on a shared CI runner can shave
        # tens of percent — a genuine chunking regression collapses the
        # ~3x margin entirely
        slow = {k: v for k, v in
                report["speedup_fused_vs_per_round"].items()
                if "/none/q1.0" not in k and v < 0.8}
        if slow:
            print(f"REGRESSION: fused driver slower than per-round: {slow}")
            return 1
        # cohort scaling: device bytes must be EXACTLY flat in M (the
        # K-block is the same program regardless of population), and
        # rounds/s flat within noise (0.5: the biggest M must not halve
        # throughput — a leak of M into the hot path collapses this)
        rows = report["cohort_scaling"]
        bytes_set = {r["device_block_bytes"] for r in rows}
        if len(bytes_set) != 1:
            print(f"REGRESSION: device block bytes vary with M: "
                  f"{[(r['population'], r['device_block_bytes']) for r in rows]}")
            return 1
        base_rps = rows[0]["rounds_per_s"]
        slow_pop = [r for r in rows if r["rounds_per_s"] < 0.5 * base_rps]
        if slow_pop:
            print(f"REGRESSION: cohort rounds/s degrades with M: {slow_pop}")
            return 1
        # resident cohort: the sync count is exact (0 is the whole point of
        # the device-resident driver — any nonzero means a forced fetch
        # crept back into the steady-state chunk). Throughput: the full
        # grid demands resident >= chunk-boundary outright (interleaved
        # best-of-5 timing makes that stable locally); the CI smoke run on
        # shared runners keeps a noise margin like the fused gate above
        rc = report["resident_cohort"]
        if rc["resident"]["host_syncs_per_round"] != 0:
            print(f"REGRESSION: resident driver reports host syncs: "
                  f"{rc['resident']}")
            return 1
        rc_margin = 0.85 if report["config"]["smoke"] else 1.0
        if (rc["resident"]["rounds_per_s"]
                < rc_margin * rc["baseline"]["rounds_per_s"]):
            print(f"REGRESSION: resident driver slower than the "
                  f"chunk-boundary path: {rc}")
            return 1
        # kernel roofline: all three streamed kernels must be covered and
        # every row must project memory-bound on v5e — these kernels do
        # O(1) flops per byte, so a compute-bound verdict means the
        # analytic model (or the kernel itself) regressed
        kr = report["kernel_roofline"]["rows"]
        covered = {r["kernel"] for r in kr}
        if not {"quantize_decompress", "cohort_gather_scatter",
                "dp_clip_noise"} <= covered:
            print(f"REGRESSION: kernel roofline rows missing: {covered}")
            return 1
        off_roof = [r for r in kr if r["v5e_bottleneck"] != "memory"]
        if off_roof:
            print(f"REGRESSION: streamed kernel projects compute-bound: "
                  f"{off_roof}")
            return 1
        # mesh plane (only when the device count admitted it): the
        # degenerate (8,1) mesh runs the shard_map protocol through the
        # mesh engine — large noise margin (0.5) because the padding
        # plumbing + partial-auto lowering cost is real and the walls are
        # sub-second, but a collapsed engine lands far below. The too-big
        # row's fit invariant is exact: per-device slice within the budget
        # the full replica exceeds, and the placed program trained.
        mp = report["mesh_plane"]
        if not mp.get("skipped"):
            by = {(r["engine"], tuple(r["mesh_shape"] or ())):
                  r["rounds_per_s"] for r in mp["rows"]}
            degen = by[("mesh_2d", (8, 1))]
            if degen < 0.5 * by[("shard_map", ())]:
                print(f"REGRESSION: degenerate mesh far below shard_map: "
                      f"{mp['rows']}")
                return 1
            tb = mp["too_big_model"]
            fit_ok = (tb["per_device_bytes"] <= tb["budget_bytes"]
                      < tb["replica_bytes"])
            if (tb["resolved_engine"] != "mesh_2d" or not fit_ok
                    or not np.isfinite(tb["final_loss"])):
                print(f"REGRESSION: too-big-model placement gate: {tb}")
                return 1
        # async vs sync simulated time: strict — the event schedule is
        # deterministic (no wall-clock noise), and on a ~7x-spread fleet
        # the buffered driver must beat the barrier outright
        ah = report["async_hetero"]
        if ah["async_sim_seconds"] >= ah["sync_sim_seconds"]:
            print(f"REGRESSION: buffered-async no faster than the sync "
                  f"barrier in simulated time: {ah}")
            return 1
        print("throughput gate passed: fused driver within margin "
              f"(speedups: {report['speedup_fused_vs_per_round']}); "
              f"cohort scaling flat over M "
              f"({[r['population'] for r in rows]}); "
              f"resident cohort 0 syncs/round at "
              f"{rc['speedup_resident_vs_chunk']}x chunk-boundary; "
              f"roofline memory-bound for {sorted(covered)}; "
              f"async {ah['sim_speedup']}x sync in simulated seconds; "
              + ("mesh plane skipped (device count)" if mp.get("skipped")
                 else "mesh plane placed + within margin"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

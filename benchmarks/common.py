"""Shared benchmark setup: the paper's four data cases on the synthetic
surrogates, problem-constant estimation (paper §8.1 'estimated beforehand'),
and a budget-driven training runner."""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (
    FederationSpec,
    eval_params,
    init_state,
    round_batch,
    run_round,
    train,
)
from repro.core.convergence import ProblemConstants
from repro.core.fl import design_sigmas
from repro.data import (
    adult_like,
    split_by_group,
    split_iid,
    vehicle_like,
)
from repro.models.linear import (
    init_linear,
    logreg_loss,
    make_eval_fn,
    svm_loss,
)
from repro.optim import sgd

BATCH = 32
DELTA = 1e-4
C1, C2 = 100.0, 1.0          # paper §8.1 resource-cost setting
LR = 0.3
CLIP = 1.0


@dataclass
class Case:
    name: str
    fed: object
    loss_fn: object
    dim: int
    eval_fn: object


def make_cases(fast: bool = True):
    """Adult-1/2 (logreg) and Vehicle-1/2 (SVM), as in paper §8.1."""
    if fast:
        adult = adult_like(n=6_000, dim=40, seed=0)
        vehicle = vehicle_like(n_sensors=23, per_sensor=300, dim=50, seed=1)
    else:
        adult = adult_like(seed=0)
        vehicle = vehicle_like(seed=1)
    cases = []
    for name, fed, loss in [
        ("Adult-1", split_by_group(adult), logreg_loss),
        ("Adult-2", split_iid(adult, 16), logreg_loss),
        ("Vehicle-1", split_by_group(vehicle), svm_loss),
        ("Vehicle-2", split_iid(vehicle, 23), svm_loss),
    ]:
        xt, yt = fed.eval_arrays("test")
        cases.append(Case(name=name, fed=fed, loss_fn=loss,
                          dim=fed.clients[0].x_train.shape[1],
                          eval_fn=make_eval_fn(loss, xt, yt)))
    return cases


def estimate_constants(case: Case, probe_rounds: int = 30) -> ProblemConstants:
    """Estimate (L, lambda, alpha, xi^2) as the paper does (§8.1)."""
    fed = case.fed
    d = case.dim
    params0 = init_linear(d)
    # L: top eigenvalue of the (regularized) logistic Hessian bound
    x, _ = fed.eval_arrays("train")
    n = min(len(x), 4000)
    xs = x[:n]
    v = np.random.default_rng(0).normal(size=d)
    for _ in range(20):
        v = xs.T @ (xs @ v) / n
        v /= np.linalg.norm(v) + 1e-12
    lip = 0.25 * float(v @ (xs.T @ (xs @ v)) / n) + 1e-4

    # xi^2: minibatch-gradient variance at params0
    g_fn = jax.jit(jax.grad(case.loss_fn))
    rng = np.random.default_rng(1)
    sampler = fed.make_sampler(BATCH)
    grads = []
    for m in range(min(fed.n_clients, 8)):
        b = sampler(m, 1, rng)
        g = g_fn(params0, {k: jnp.asarray(val[0]) for k, val in b.items()})
        grads.append(np.concatenate([np.ravel(l) for l in jax.tree.leaves(g)]))
    grads = np.stack(grads)
    xi2 = float(np.mean(np.var(grads, axis=0)) * grads.shape[1])

    # alpha and lambda: cheap non-private probe run
    spec = FederationSpec(n_clients=fed.n_clients, tau=5, dp=False,
                          loss_fn=case.loss_fn, optimizer=sgd(LR),
                          sigmas=(0.0,) * fed.n_clients,
                          batch_sizes=tuple(fed.batch_sizes(BATCH)))
    state = init_state(spec, params0)
    probe_rng = np.random.default_rng(spec.seed)
    losses = []
    for _ in range(probe_rounds):
        batch = round_batch(spec, sampler, probe_rng)
        state, rec = run_round(spec, state, batch, check_budgets=False)
        losses.append(float(rec["loss"]))   # records are lazy device scalars
    l0, lstar = losses[0], min(losses)
    alpha = max(l0 - lstar, 1e-3) + 0.05
    # strong convexity: fit exponential decay rate of the loss gap
    gaps = np.maximum(np.asarray(losses) - lstar + 1e-4, 1e-6)
    k = np.arange(len(gaps)) * spec.tau
    slope = np.polyfit(k, np.log(gaps), 1)[0]
    lam = min(max(-slope / LR, 1e-3), 1.0 / LR * 0.99)
    return ProblemConstants(eta=LR, lam=float(lam), lip=float(lip),
                            alpha=float(alpha), xi2=float(xi2), dim=2 * d + 2,
                            n_clients=fed.n_clients)


def run_dp_pasgd(case: Case, tau: int, c_th: float, eps_th: float,
                 k_budget: int | None = None, seed: int = 0,
                 participation: float = 1.0, compressor: str = "none",
                 compression_ratio: float = 0.1, compression_bits: int = 8,
                 proportional_batches: bool = False):
    """Train DP-PASGD at a given tau until the budgets bind (paper's Eq. 8/9
    schedule: K chosen by the budgets; sigma by Eq. 23).

    The aggregation-pipeline knobs (participation / compressor) and the
    paper's per-client X_m (``proportional_batches``) pass straight through
    to the FederationSpec; the k_max estimate keeps the dense cost so runs
    at different pipeline settings plan the same K and the Eq.-8 savings
    show up in ``resource_spent``.
    """
    fed = case.fed
    k_max = int(c_th / (C1 / tau + C2) // tau * tau)
    k = k_budget or max(tau, k_max)
    # FederatedData.batch_sizes enforces the X_m <= executed-batch cap
    # itself (an X_m above the sampled batch would under-claim sensitivity)
    x_m = fed.batch_sizes(BATCH, proportional=proportional_batches)
    sig = design_sigmas(k, CLIP, x_m, eps_th, DELTA)
    spec = FederationSpec(n_clients=fed.n_clients, tau=tau,
                          loss_fn=case.loss_fn, optimizer=sgd(LR),
                          clip_norm=CLIP, dp=True,
                          participation=participation, compressor=compressor,
                          compression_ratio=compression_ratio,
                          compression_bits=compression_bits,
                          sigmas=tuple(float(s) for s in sig),
                          batch_sizes=tuple(x_m),
                          eps_th=eps_th, delta=DELTA,
                          c_th=c_th, c1=C1, c2=C2, seed=seed)
    state = init_state(spec, init_linear(case.dim))
    t0 = time.time()
    state, out = train(spec, state, fed.make_sampler(BATCH),
                       max_rounds=max(1, k // tau),
                       eval_fn=case.eval_fn, eval_every=1)
    if "eval_acc" not in out["best"]:
        # budgets bound before any evaluated round: score the current model
        out["best"] = {**out["best"], **case.eval_fn(eval_params(spec, state))}
    out["wall_s"] = time.time() - t0
    out["sigma"] = float(sig[0])
    out["k_planned"] = k
    return out


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"

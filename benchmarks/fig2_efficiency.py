"""Figure 2: resource efficiency of DP-PASGD (tau=10) vs DP-SGD (tau=1).

Paper setting: run both until resource cost C=1000 and privacy loss eps=10;
DP-PASGD should reach higher accuracy at every resource level."""
from __future__ import annotations

import json
import time

from benchmarks.common import make_cases, run_dp_pasgd, csv_row

C_TH, EPS = 1000.0, 10.0


def main(fast: bool = True, out_json: str | None = None):
    rows, blob = [], {}
    for case in make_cases(fast):
        t0 = time.time()
        pasgd = run_dp_pasgd(case, tau=10, c_th=C_TH, eps_th=EPS)
        dpsgd = run_dp_pasgd(case, tau=1, c_th=C_TH, eps_th=EPS)
        dt = time.time() - t0
        acc_p = pasgd["best"].get("eval_acc", 0.0)
        acc_s = dpsgd["best"].get("eval_acc", 0.0)
        blob[case.name] = {
            "dp_pasgd": {"acc": acc_p, "rounds": pasgd["rounds"],
                         "curve": [(h.get("resource_spent"),
                                    h.get("eval_acc"))
                                   for h in pasgd["history"]]},
            "dp_sgd": {"acc": acc_s, "rounds": dpsgd["rounds"],
                       "curve": [(h.get("resource_spent"),
                                  h.get("eval_acc"))
                                 for h in dpsgd["history"]]},
        }
        rows.append(csv_row(
            f"fig2_{case.name}", dt * 1e6 / max(1, pasgd["rounds"]),
            f"acc_pasgd={acc_p:.4f};acc_dpsgd={acc_s:.4f};"
            f"pasgd_wins={acc_p > acc_s}"))
    if out_json:
        with open(out_json, "w") as f:
            json.dump(blob, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)

"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--full`` uses the paper-scale
dataset sizes (slower); the default FAST mode uses statistically matched
reduced sizes so the whole suite runs on one CPU core in minutes.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2,fig6]
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import (  # noqa: E402
    fig2_efficiency,
    fig3_tau_sweep,
    fig4_resource_tradeoff,
    fig5_privacy_tradeoff,
    fig6_optimal_tau,
    roofline,
)

SUITES = {
    "fig2": fig2_efficiency.main,
    "fig3": fig3_tau_sweep.main,
    "fig4": fig4_resource_tradeoff.main,
    "fig5": fig5_privacy_tradeoff.main,
    "fig6": fig6_optimal_tau.main,
    "roofline": roofline.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig2,fig6")
    ap.add_argument("--out-dir", default="experiments/bench")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = (args.only.split(",") if args.only else list(SUITES))
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            rows = SUITES[name](
                fast=not args.full,
                out_json=os.path.join(args.out_dir, f"{name}.json"))
            for r in rows:
                print(r, flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Figure 3: accuracy vs tau, with the optimal-design solver's tau* marker.

Grid-searches tau (paper: 1..20) under (C_th, eps_th) budgets and compares
the solver's tau* (paper §7) against the empirical best."""
from __future__ import annotations

import json
import time

from benchmarks.common import (
    estimate_constants,
    make_cases,
    run_dp_pasgd,
    csv_row,
    BATCH, C1, C2, CLIP, DELTA,
)
from repro.core.design import DesignProblem, ResourceModel

TAUS = (1, 2, 3, 5, 8, 10, 14, 20)


def main(fast: bool = True, out_json: str | None = None,
         budgets=((1000.0, 4.0),)):
    rows, blob = [], {}
    cases = make_cases(fast)
    for case in cases:
        consts = estimate_constants(case)
        for c_th, eps_th in budgets:
            t0 = time.time()
            accs = {}
            for tau in TAUS:
                out = run_dp_pasgd(case, tau=tau, c_th=c_th, eps_th=eps_th)
                accs[tau] = out["best"].get("eval_acc", 0.0)
            prob = DesignProblem(
                consts=consts, resource=ResourceModel(C1, C2),
                clip_norm=CLIP,
                batch_sizes=case.fed.batch_sizes(BATCH),
                delta=DELTA, eps_th=eps_th, c_th=c_th)
            sol = prob.solve()
            best_tau = max(accs, key=accs.get)
            # accuracy at the solver's tau vs the empirical best
            tau_near = min(TAUS, key=lambda t: abs(t - sol.tau))
            gap = accs[best_tau] - accs[tau_near]
            dt = time.time() - t0
            key = f"{case.name}_C{int(c_th)}_eps{eps_th:g}"
            blob[key] = {"accs": accs, "tau_star_solver": sol.tau,
                         "tau_star_grid": best_tau, "acc_gap": gap}
            rows.append(csv_row(
                f"fig3_{key}", dt * 1e6 / len(TAUS),
                f"tau_solver={sol.tau};tau_grid={best_tau};"
                f"acc_at_solver={accs[tau_near]:.4f};"
                f"acc_at_grid={accs[best_tau]:.4f};gap={gap:.4f}"))
    if out_json:
        with open(out_json, "w") as f:
            json.dump(blob, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)

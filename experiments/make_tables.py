"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run JSONs."""
import glob
import json
import os
import re

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
ARCH_ORDER = ["internvl2-76b", "musicgen-large", "mistral-large-123b",
              "codeqwen1.5-7b", "rwkv6-1.6b", "zamba2-7b", "gemma3-4b",
              "phi3.5-moe-42b-a6.6b", "granite-20b",
              "llama4-maverick-400b-a17b"]


def load(mesh_tag, opt=False):
    recs = {}
    for f in glob.glob("experiments/dryrun/*.json"):
        base = os.path.basename(f)[:-5]
        is_opt = "_opt" in base
        if is_opt != opt:
            continue
        d = json.load(open(f))
        if d["mesh"] != mesh_tag:
            continue
        key = (d["arch"], d["shape"])
        # prefer the latest write (os.path.getmtime)
        if key not in recs or os.path.getmtime(f) > recs[key][1]:
            recs[key] = (d, os.path.getmtime(f))
    return {k: v[0] for k, v in recs.items()}


def fmt_bytes(b):
    return f"{b/2**30:.1f}"


def dryrun_table():
    single = load("16x16")
    multi = load("2x16x16")
    hdr = ("| arch | shape | 16x16 | live GiB/dev | fits | 2x16x16 | coll GB/dev (1 pod) |\n"
           "|---|---|---|---|---|---|---|\n")
    rows = []
    for a in ARCH_ORDER:
        for s in sorted(SHAPE_ORDER, key=SHAPE_ORDER.get):
            d1 = single.get((a, s))
            d2 = multi.get((a, s))
            if d1 is None:
                continue
            if d1["status"] == "skipped":
                rows.append(f"| {a} | {s} | skipped (sub-quadratic gate) | - | - | "
                            f"{'skipped' if d2 and d2['status']=='skipped' else '?'} | - |")
                continue
            live = d1.get("live_bytes_per_device", 0)
            coll = d1.get("roofline", {}).get("coll_bytes_per_device", 0)
            rows.append(
                f"| {a} | {s} | {d1['status']} | {fmt_bytes(live)} "
                f"| {'✅' if d1.get('fits_hbm') else '✗'} "
                f"| {d2['status'] if d2 else 'n/a'} | {coll/1e9:.1f} |")
    return hdr + "\n".join(rows)


def roofline_table():
    single = load("16x16")
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bottleneck "
           "| MODEL_FLOPS | useful | one-line lever |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    levers = {
        "collective": "overlap/shrink grad + dispatch collectives (reduce-scatter, fewer microbatch reductions)",
        "memory": "raise arithmetic intensity (fuse scans into kernels, wider microbatches, bf16 accum)",
        "compute": "cut redundant flops (remat policy, causal block-skip)",
    }
    rows = []
    for a in ARCH_ORDER:
        for s in sorted(SHAPE_ORDER, key=SHAPE_ORDER.get):
            d = single.get((a, s))
            if d is None or d["status"] != "compiled":
                continue
            r = d["roofline"]
            rows.append(
                f"| {a} | {s} | {r['t_compute_s']:.2f} | {r['t_memory_s']:.2f} "
                f"| {r['t_collective_s']:.2f} | **{r['bottleneck']}** "
                f"| {r['model_flops']:.2e} | {r['useful_flops_fraction']:.1%} "
                f"| {levers[r['bottleneck']]} |")
    return hdr + "\n".join(rows)


def _between(src, tag, content):
    return re.sub(rf"<!-- {tag} -->.*?<!-- /{tag} -->",
                  f"<!-- {tag} -->\n{content}\n<!-- /{tag} -->",
                  src, flags=re.S)


def patch(md_path="EXPERIMENTS.md"):
    src = open(md_path).read()
    src = _between(src, "DRYRUN_TABLE", dryrun_table())
    src = _between(src, "ROOFLINE_TABLE", roofline_table())
    open(md_path, "w").write(src)
    print("patched", md_path)


if __name__ == "__main__":
    patch()

"""Recompute n_params / MODEL_FLOPS / useful%% for existing dry-run JSONs.

The original sweep computed param counts with jnp.prod (int32 overflow for
multi-billion-param archs). The HLO-derived terms are unaffected; only the
analytic MODEL_FLOPS needed fixing, which we can do without recompiling.
"""
import glob
import json
import math
import sys

sys.path.insert(0, "src")
import jax  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.models.transformer import Transformer  # noqa: E402
from repro.utils.roofline import active_params, model_flops_estimate  # noqa: E402

counts = {}
for f in sorted(glob.glob("experiments/dryrun/*.json")):
    d = json.load(open(f))
    if d["status"] != "compiled":
        continue
    arch = d["arch"]
    if arch not in counts:
        cfg = get_arch(arch)
        params = jax.eval_shape(Transformer(cfg).init, jax.random.PRNGKey(0))
        counts[arch] = sum(math.prod(x.shape) if x.shape else 1
                           for x in jax.tree.leaves(params))
    n = counts[arch]
    cfg = get_arch(arch)
    n_active = active_params(cfg, float(n))
    kind = d["kind"]
    mf = model_flops_estimate(n_active, d["tokens_per_step"], kind)
    r = d["roofline"]
    total_hlo = r["flops_per_device"] * r["chips"]
    old = (d["n_params"], r["useful_flops_fraction"])
    d["n_params"] = n
    d["active_params"] = n_active
    r["model_flops"] = mf
    r["useful_flops_fraction"] = mf / total_hlo if total_hlo else 0.0
    json.dump(d, open(f, "w"), indent=2)
    if abs(old[1] - r["useful_flops_fraction"]) > 1e-6:
        print(f"{f}: params {old[0]/1e9:.2f}B -> {n/1e9:.2f}B, "
              f"useful {old[1]:.3f} -> {r['useful_flops_fraction']:.3f}")

"""Suite-wide setup: make `from hypothesis import ...` work with or without
the real package installed (see tests/_hypothesis_compat.py)."""
import _hypothesis_compat

_hypothesis_compat.install()

"""Suite-wide setup: make `from hypothesis import ...` work with or without
the real package installed (see tests/_hypothesis_compat.py), and the
fresh-buffer fixture that keeps donated-state tests order-independent."""
import _hypothesis_compat

import jax
import jax.numpy as jnp
import pytest

_hypothesis_compat.install()


@pytest.fixture
def fresh_buffers():
    """Factory copying a pytree onto FRESH device buffers.

    ``run_round`` / ``run_rounds`` donate their state operands (params,
    opt_state, residual): after the call, the buffers the caller passed in
    are deleted. A test that wants to feed the same state to a second
    jitted call must hand that call its own copy — do it through this
    fixture instead of ordering the calls around the donation, so no test
    carries a hidden execution-order dependency.
    """
    def copy(tree):
        return jax.tree.map(jnp.copy, tree)
    return copy

"""Adversarial-fleet tests: the attack-matrix harness pinning PR 7.

Four gates, per ISSUE:

1. **Identity** — with every adversarial knob at its default the pipeline
   is bit-for-bit the PR-3 code path: same engine key, same collective
   schedule (the shard_map jaxpr stays psum-only, no all_gather), and a
   zero-fraction attack spec reproduces the plain run exactly.
2. **Secure aggregation** — the in-engine masked modular sum equals the
   unmasked fixed-point sum EXACTLY (integer domain), including under
   dropout (non-participants are the dropped set); the full secure round
   matches the plain mean round to quantization precision.
3. **Attack matrix** — engine x aggregator x attack x byzantine-fraction:
   every robust aggregator's attack-induced perturbation is strictly
   below the mean's, and the mean demonstrably diverges under the boosted
   attack. All runs share seeds, so the margins are deterministic.
4. **Accounting** — robust/secure knobs leave the local rho ledger
   byte-identical; ``dp_accounting="central"`` scales every charge by
   exactly 1/P and stays out of the engine key.

Satellite property tests (hypothesis, or the tests/_hypothesis_compat
shim): robust aggregators are permutation-invariant and coordinate-wise
bounded by their inputs.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import FederationSpec, init_state, run_round
from repro.core.robust import (
    CoordinateMedian,
    NormBound,
    TrimmedMean,
    UpdateAttack,
    byzantine_flags,
    make_aggregator,
    make_attack,
    participant_rows,
)
from repro.core.secureagg import SecureMaskedSum
from repro.models.linear import init_linear, logreg_loss
from repro.optim import sgd

C, TAU, DIM, B = 8, 3, 8, 4
ROUNDS = 3
BYZ = 0.25                       # 2 of the 8 clients
OPT = sgd(0.2)                   # one optimizer instance -> shared engine keys


def _spec(**kw):
    base = dict(n_clients=C, tau=TAU, loss_fn=logreg_loss, optimizer=OPT,
                clip_norm=1.0, dp=True, sigmas=(0.3,) * C,
                batch_sizes=(B,) * C)
    base.update(kw)
    return FederationSpec(**base)


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    return {"x": jnp.asarray(rng.normal(size=(C, TAU, B, DIM)), jnp.float32),
            "y": jnp.asarray(rng.integers(0, 2, size=(C, TAU, B)), jnp.int32)}


def _run(spec, rounds=ROUNDS):
    state = init_state(spec, init_linear(DIM))
    for r in range(rounds):
        state, _ = run_round(spec, state, _batch(r), check_budgets=False)
    return state


def _global_vec(state):
    """Client 0's replica flattened (full_average keeps replicas equal)."""
    return np.concatenate([np.asarray(l)[0].ravel()
                           for l in jax.tree.leaves(state.params)])


# the matrix axes: every robust aggregator (trim/factor sized so the 25%
# byzantine minority is actually inside the trimmed/rejected region), every
# update attack (scale boosted so the mean visibly diverges)
AGGREGATORS = [
    ("mean", {}),
    ("median", {}),
    ("trimmed_mean", dict(trim_fraction=0.25)),
    ("norm_bound", dict(norm_bound_factor=2.0)),
]
ATTACKS = [
    ("sign_flip", {}),
    ("scale", dict(attack_scale=25.0)),
]

# final params are deterministic per spec (shared seeds), so the matrix
# reuses each clean/attacked endpoint across assertions
_PARAMS_CACHE = {}


def _final_params(**kw):
    key = tuple(sorted(kw.items()))
    if key not in _PARAMS_CACHE:
        _PARAMS_CACHE[key] = _global_vec(_run(_spec(**kw)))
    return _PARAMS_CACHE[key]


# ---------------------------------------------------------------------------
# gate 1: identity — default adversarial knobs are bit-for-bit inert
# ---------------------------------------------------------------------------

def test_adversarial_defaults_do_not_change_engine_key():
    """Spelling out every adversarial default produces the PR-3 engine key:
    cached compiled rounds survive the field additions unchanged."""
    plain = _spec(participation=0.5)
    explicit = _spec(participation=0.5, aggregator="mean",
                     trim_fraction=0.1, norm_bound_factor=3.0,
                     secure_agg=False, secure_frac_bits=16,
                     dp_accounting="local", attack="none",
                     byzantine_fraction=0.0, attack_scale=10.0)
    assert explicit.engine_key() == plain.engine_key()
    assert not plain.is_adversarial()
    # the q-sweep reuse contract of the mean path survives too...
    assert plain.replace(participation=0.75).engine_key() == plain.engine_key()
    # ...while a robust aggregator bakes the static P in
    rob = _spec(participation=0.5, aggregator="median")
    assert rob.replace(participation=0.75).engine_key() != rob.engine_key()


@pytest.mark.parametrize("name,kw", [("q50", dict(participation=0.5)),
                                     ("topk25", dict(compressor="topk",
                                                     compression_ratio=0.25))],
                         ids=["q50", "topk25"])
def test_default_pipeline_keeps_psum_only_schedule(name, kw):
    """The shard_map pipeline round of a NON-adversarial spec contains no
    all_gather: the PR-3 psum-of-block-sums collective schedule is intact,
    byte for byte. The adversarial variant of the same spec does gather —
    the full-view reduction is pay-for-use."""
    from repro.api import get_engine

    def jaxpr_of(spec):
        state = init_state(spec, init_linear(DIM))
        fn = get_engine("shard_map")(spec)
        _, sub = jax.random.split(state.key)
        sig = jnp.asarray(spec.resolved_sigmas(), jnp.float32)
        mask = jnp.ones((C,), jnp.float32)
        residual = (jnp.zeros_like(state.residual)
                    if state.residual is not None else
                    jnp.zeros((C, 1), jnp.float32))
        if spec.has_pipeline() and state.residual is None:
            # participation-only pipelines carry residual=None
            return str(jax.make_jaxpr(fn)(
                state.params, state.opt_state, _batch(), sub, sig, mask,
                None))
        return str(jax.make_jaxpr(fn)(
            state.params, state.opt_state, _batch(), sub, sig, mask,
            residual))

    assert "all_gather" not in jaxpr_of(_spec(engine="shard_map", **kw))
    assert "all_gather" in jaxpr_of(_spec(engine="shard_map",
                                          aggregator="median", **kw))


def test_zero_fraction_attack_is_bitwise_noop():
    """byzantine_fraction=0 resolves to attack=None inside the pipeline:
    the run is bit-identical to the plain spec's (the corruption is a
    select over an empty set, and make_attack drops it entirely)."""
    plain = _spec(participation=0.5)
    armed = _spec(participation=0.5, attack="sign_flip",
                  byzantine_fraction=0.0)
    assert armed.aggregation_pipeline().attack is None
    s_p, s_a = _run(plain), _run(armed)
    for a, b in zip(jax.tree.leaves(s_p.params), jax.tree.leaves(s_a.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(s_p.rho, s_a.rho)


# ---------------------------------------------------------------------------
# gate 2: secure aggregation — masked == unmasked, exactly
# ---------------------------------------------------------------------------

def test_masked_mean_exact_on_fixed_point_grid():
    """Updates already on the 2^-frac_bits grid survive the full masked
    protocol EXACTLY — encode, pairwise masking, dropout recovery for the
    non-participants, decode — equal to the plain masked mean with zero
    tolerance. (Quantization is the only lossy step; on-grid inputs have
    none, so any discrepancy here is a protocol bug, not rounding.)"""
    sec = SecureMaskedSum(n_clients=C, frac_bits=10)
    rng = np.random.default_rng(0)
    grid = rng.integers(-4000, 4000, size=(C, 17)) / float(1 << 10)
    updates = jnp.asarray(grid, jnp.float32)
    base_key = jax.random.PRNGKey(3)
    for dropped in (0, 3):
        mask = np.ones((C,), np.float32)
        if dropped:
            mask[rng.choice(C, size=dropped, replace=False)] = 0.0
        got = np.asarray(sec.masked_mean(updates, jnp.asarray(mask),
                                         base_key))
        # the reference decodes the plain integer survivor sum with the
        # identical float32 arithmetic: bitwise equality then pins that
        # masking + dropout recovery added ZERO error in the field
        int_sum = (grid * (1 << 10)).astype(np.int64)[mask > 0].sum(axis=0)
        want = (int_sum.astype(np.int32).astype(np.float32)
                / np.float32(1 << 10)) / np.float32(mask.sum())
        np.testing.assert_array_equal(got, want)


def test_masked_uploads_are_not_the_plaintext():
    """Sanity on the simulation's point: an individual masked upload is
    garbage (mask-dominated), even though the sum is exact."""
    from repro.core.secureagg import masked_update, fp_decode, fp_encode
    upd = np.full((32,), 0.125)
    up = masked_update(upd, vid=0, cohort=range(C), seed=0, round_idx=0)
    assert not np.array_equal(up, fp_encode(upd))
    # decoded garbage is nowhere near the tiny true value
    assert np.max(np.abs(fp_decode(up))) > 1.0


@pytest.mark.parametrize("engine", ["vmap", "map", "shard_map"])
@pytest.mark.parametrize("name,kw", [
    # dense via the identity codec: both sides on the pipeline key
    # schedule (a non-pipeline dense spec draws different DP noise)
    ("dense", dict(compressor="topk", compression_ratio=1.0)),
    ("q50-dropout", dict(participation=0.5)),
], ids=["dense", "q50-dropout"])
def test_secure_round_matches_mean_round(engine, name, kw):
    """A secure_agg federation trains within quantization distance of the
    plain-mean federation — same participant draws, same DP noise, the
    masked sum replacing the plain sum. The q50 case runs the dropout
    recovery every round (non-participants ARE the dropped set). The rho
    ledger is byte-identical: secure aggregation changes who SEES the
    updates, not the executed mechanism."""
    plain = _run(_spec(engine=engine, **kw))
    sec = _run(_spec(engine=engine, secure_agg=True, **kw))
    # 3 rounds of <= C pooled quantization errors each, generously bounded
    np.testing.assert_allclose(_global_vec(sec), _global_vec(plain),
                               atol=1e-3)
    np.testing.assert_array_equal(plain.rho, sec.rho)


# ---------------------------------------------------------------------------
# gate 3: the attack matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("attack,akw", ATTACKS, ids=[a for a, _ in ATTACKS])
def test_robust_aggregators_bound_attack_perturbation(attack, akw):
    """The matrix centerpiece: at byzantine fraction 0.25, every robust
    aggregator's perturbation (distance between its attacked and clean
    endpoints, all seeds shared) is strictly below the mean's — and under
    the boosted scale attack the mean diverges by an order of magnitude
    while every robust endpoint stays put."""
    devs = {}
    for agg, kw in AGGREGATORS:
        clean = _final_params(aggregator=agg, **kw)
        dirty = _final_params(aggregator=agg, attack=attack,
                              byzantine_fraction=BYZ, **kw, **akw)
        devs[agg] = float(np.linalg.norm(dirty - clean))
    assert devs["mean"] > 0.1            # the attack actually bites
    for agg in ("median", "trimmed_mean", "norm_bound"):
        assert devs[agg] < 0.9 * devs["mean"], (attack, agg, devs)
    if attack == "scale":
        # model-replacement-style boost: mean diverges, robust holds
        assert devs["mean"] > 1.0
        for agg in ("median", "trimmed_mean", "norm_bound"):
            assert devs[agg] < 0.25 * devs["mean"], (agg, devs)


def test_attack_corrupts_only_byzantine_rows():
    """Honest rows pass through the attack select bit-unchanged; the
    flagged rows carry exactly the advertised corruption."""
    flags = byzantine_flags(C, BYZ, seed=0)
    assert sum(flags) == round(BYZ * C)
    u = jnp.asarray(np.random.default_rng(1).normal(size=(C, 5)), jnp.float32)
    flipped = np.asarray(UpdateAttack("sign_flip", flags)(u))
    scaled = np.asarray(UpdateAttack("scale", flags, scale=25.0)(u))
    for i, f in enumerate(flags):
        if f:
            np.testing.assert_array_equal(flipped[i], -np.asarray(u)[i])
            np.testing.assert_array_equal(scaled[i], 25.0 * np.asarray(u)[i])
        else:
            np.testing.assert_array_equal(flipped[i], np.asarray(u)[i])
            np.testing.assert_array_equal(scaled[i], np.asarray(u)[i])
    # deterministic per (seed, fraction); different seeds move the set
    assert byzantine_flags(C, BYZ, seed=0) == flags
    assert any(byzantine_flags(C, BYZ, seed=s) != flags for s in range(1, 8))


@pytest.mark.parametrize("engine", ["map", "shard_map"])
@pytest.mark.parametrize("name,kw", [
    ("median-q75", dict(aggregator="median", participation=0.75)),
    ("trimmed-topk", dict(aggregator="trimmed_mean", trim_fraction=0.25,
                          compressor="topk", compression_ratio=0.25)),
    ("normbound", dict(aggregator="norm_bound", norm_bound_factor=2.0)),
    ("secure-q50", dict(secure_agg=True, participation=0.5)),
    ("signflip", dict(attack="sign_flip", byzantine_fraction=BYZ)),
], ids=["median-q75", "trimmed-topk", "normbound", "secure-q50", "signflip"])
def test_engine_parity_under_adversarial_settings(engine, name, kw):
    """vmap / map / shard_map agree under every adversarial setting — the
    shard_map all_gather full-view path computes the same reduction as the
    single-device engines (same participant sets, same masks, same
    byzantine rows)."""
    ref = _run(_spec(engine="vmap", **kw), rounds=2)
    got = _run(_spec(engine=engine, **kw), rounds=2)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(got.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(ref.rho, got.rho)


# ---------------------------------------------------------------------------
# gate 4: accounting soundness
# ---------------------------------------------------------------------------

def test_adversarial_knobs_leave_local_ledger_unchanged():
    """Robust aggregation and secure masking change the aggregate, not the
    executed per-client mechanism: the rho ledger is byte-identical to the
    plain spec's under the same participation draw."""
    base = _run(_spec(participation=0.5))
    for kw in (dict(aggregator="median"), dict(secure_agg=True),
               dict(attack="sign_flip", byzantine_fraction=BYZ)):
        got = _run(_spec(participation=0.5, **kw))
        np.testing.assert_array_equal(base.rho, got.rho)


def test_central_accounting_scales_rho_by_exactly_one_over_p():
    """dp_accounting='central' divides every realized per-step charge by
    the participant count P — engine key unchanged (accounting-only), all
    four ledger surfaces consistent because they share accounting_q()."""
    from repro.api import round_rho_charges
    local = _spec(secure_agg=True, participation=0.5)
    central = local.replace(dp_accounting="central")
    assert central.engine_key() == local.engine_key()
    p = local.participants_per_round()
    assert central.accounting_q() == pytest.approx(local.accounting_q() / p)
    np.testing.assert_allclose(round_rho_charges(central),
                               round_rho_charges(local) / p, rtol=1e-12)
    s_l, s_c = _run(local), _run(central)
    np.testing.assert_allclose(s_c.rho, s_l.rho / p, rtol=1e-12)
    # composes multiplicatively with participation amplification
    amp = central.replace(amplify_participation=True)
    assert amp.accounting_q() == pytest.approx(
        local.replace(amplify_participation=True).accounting_q() / p)


def test_central_accounting_requires_secure_agg():
    with pytest.raises(ValueError):
        _spec(dp_accounting="central")


# ---------------------------------------------------------------------------
# spec surface
# ---------------------------------------------------------------------------

def test_adversarial_spec_validation():
    with pytest.raises(ValueError):
        _spec(aggregator="krum")
    with pytest.raises(ValueError):
        _spec(aggregator="trimmed_mean", trim_fraction=0.5)
    with pytest.raises(ValueError):
        _spec(aggregator="norm_bound", norm_bound_factor=0.0)
    with pytest.raises(ValueError):
        _spec(secure_agg=True, secure_frac_bits=0)
    with pytest.raises(ValueError):        # median of a sum it never sees
        _spec(secure_agg=True, aggregator="median")
    with pytest.raises(ValueError):
        _spec(attack="gradient_theft")
    with pytest.raises(ValueError):        # zero scale silently drops rows
        _spec(attack="scale", byzantine_fraction=BYZ, attack_scale=0.0)
    with pytest.raises(ValueError):
        _spec(attack="sign_flip", byzantine_fraction=1.0)
    with pytest.raises(ValueError):        # update attacks are resident-only
        _spec(attack="sign_flip", byzantine_fraction=BYZ,
              population=64, cohort_size=C)
    with pytest.raises(ValueError):        # async bypasses the pipeline seam
        _spec(aggregator="median", engine="async_buffered")
    with pytest.raises(ValueError):
        _spec(aggregator="median", topology="local_only")
    # adversarial knobs alone switch the pipeline on
    assert _spec(aggregator="median").has_pipeline()
    assert _spec(secure_agg=True).has_pipeline()
    assert not _spec().has_pipeline()


def test_make_aggregator_and_attack_factories():
    assert make_aggregator("mean") is None
    assert isinstance(make_aggregator("median"), CoordinateMedian)
    assert isinstance(make_aggregator("trimmed_mean", 0.2), TrimmedMean)
    assert isinstance(make_aggregator("norm_bound", 0.1, 2.0), NormBound)
    assert make_attack("none", (1, 1)) is None
    assert make_attack("sign_flip", (0,) * C) is None       # all honest
    assert isinstance(make_attack("sign_flip", (1, 0)), UpdateAttack)


# ---------------------------------------------------------------------------
# satellite: property tests on the robust reductions
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), p=st.integers(3, 9), d=st.integers(1, 6),
       trim=st.floats(0.0, 0.45))
def test_robust_aggregators_permutation_invariant_and_bounded(seed, p, d,
                                                              trim):
    """For ANY participant matrix: shuffling the rows never changes a
    robust aggregate (client order is protocol noise), and every output
    coordinate stays inside [min, max] of that coordinate's inputs — the
    boundedness that caps what a byzantine minority can inject."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(scale=rng.uniform(0.1, 10.0), size=(p, d)),
                    jnp.float32)
    perm = jnp.asarray(rng.permutation(p))
    for agg in (CoordinateMedian(), TrimmedMean(trim), NormBound(2.0)):
        out = np.asarray(agg(u))
        out_perm = np.asarray(agg(u[perm]))
        np.testing.assert_allclose(out_perm, out, rtol=1e-5, atol=1e-6)
        lo = np.min(np.asarray(u), axis=0) - 1e-6
        hi = np.max(np.asarray(u), axis=0) + 1e-6
        assert np.all(out >= lo) and np.all(out <= hi), type(agg).__name__


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), p=st.integers(2, 7))
def test_participant_rows_gathers_exactly_the_masked_rows(seed, p):
    """participant_rows extracts precisely the mask's P participant rows
    (any P-subset, any order), on which every aggregator then operates."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(C, 4)), jnp.float32)
    chosen = np.sort(rng.choice(C, size=min(p, C), replace=False))
    mask = np.zeros((C,), np.float32)
    mask[chosen] = 1.0
    rows = np.asarray(participant_rows(u, jnp.asarray(mask), len(chosen)))
    np.testing.assert_array_equal(rows, np.asarray(u)[chosen])


# ---------------------------------------------------------------------------
# population-mode poisoning: malicious vids, data-level label flip
# ---------------------------------------------------------------------------

def test_malicious_population_poisons_only_byzantine_vids():
    """The wrapper flips exactly the byzantine vids' labels, leaves
    features bit-unchanged everywhere, is deterministic per (vid, seed),
    and is the identity at fraction zero."""
    from repro.population import (
        POPULATION_ATTACKS, is_byzantine_vid, malicious_population,
        synthetic_population)
    m, frac, seed = 64, 0.25, 5
    base = synthetic_population(m, dim=DIM, batch_size=B)
    mal = malicious_population(base, byzantine_fraction=frac, seed=seed)
    ident = malicious_population(base, byzantine_fraction=0.0, seed=seed)
    assert mal.n_clients == base.n_clients
    assert "label_flip" in mal.name
    flags = [is_byzantine_vid(v, frac, seed) for v in range(m)]
    assert any(flags) and not all(flags)
    # membership is a pure function of (vid, fraction, seed)
    assert flags == [is_byzantine_vid(v, frac, seed) for v in range(m)]
    for vid in range(0, m, 7):
        want = base.sampler(vid, TAU, np.random.default_rng((1, vid)))
        got = mal.sampler(vid, TAU, np.random.default_rng((1, vid)))
        same = ident.sampler(vid, TAU, np.random.default_rng((1, vid)))
        np.testing.assert_array_equal(got["x"], want["x"])
        np.testing.assert_array_equal(same["y"], want["y"])
        if flags[vid]:
            np.testing.assert_array_equal(got["y"], 1 - want["y"])
        else:
            np.testing.assert_array_equal(got["y"], want["y"])
    assert POPULATION_ATTACKS == ("label_flip",)
    with pytest.raises(ValueError):           # update attacks are resident-only
        malicious_population(base, attack="sign_flip")
    with pytest.raises(ValueError):
        malicious_population(base, n_classes=1)


def test_malicious_population_composes_with_cohort_round():
    """A cohort round over the poisoned population runs end to end and
    differs from the clean round only through the poisoned shards (same
    rho ledger: data poisoning never touches the privacy accounting)."""
    from repro.population import (
        init_population_state, malicious_population, run_cohort_round,
        synthetic_population)
    m = 32
    pspec = _spec(n_clients=4, sigmas=(0.3,) * 4, batch_sizes=(B,) * 4,
                  population=m, cohort_size=4)
    base = synthetic_population(m, dim=DIM, batch_size=B)
    mal = malicious_population(base, byzantine_fraction=0.5, seed=1)
    outs = {}
    for tag, pop in [("clean", base), ("poisoned", mal)]:
        st_p = init_population_state(pspec, init_linear(DIM))
        rng = np.random.default_rng(0)
        for _ in range(2):
            st_p, rec = run_cohort_round(pspec, st_p, pop, rng,
                                         check_budgets=False)
        outs[tag] = (st_p, rec)
    clean, poisoned = outs["clean"][0], outs["poisoned"][0]
    assert float(outs["clean"][1]["loss"]) != float(outs["poisoned"][1]["loss"])
    np.testing.assert_array_equal(np.asarray(clean.store.rho),
                                  np.asarray(poisoned.store.rho))


# ---------------------------------------------------------------------------
# CI smoke leg (REPRO_SMOKE_ATTACK): the benchmark's robust-beats-mean gate
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not os.environ.get("REPRO_SMOKE_ATTACK"),
                    reason="set REPRO_SMOKE_ATTACK=1 to run the attack-"
                           "resilience benchmark smoke gate in this env")
def test_attack_resilience_benchmark_smoke(tmp_path):
    """benchmarks/attack_resilience.py --smoke --check passes: on the
    reduced config, every robust aggregator's post-attack accuracy stays
    within the gate of its clean run while the mean degrades more."""
    out = tmp_path / "BENCH_attack.json"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src"))
    proc = subprocess.run(
        [sys.executable, os.path.join(repo, "benchmarks",
                                      "attack_resilience.py"),
         "--smoke", "--check", "--out", str(out)],
        capture_output=True, text=True, env=env, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert out.exists()

"""Direct unit tests for repro.launch.env (backfill satellite): the pure
profile computation, the user-flags-win XLA merge, and the re-exec guard
of apply_env_profile — everything testable without actually exec'ing."""
import os

import pytest

from repro.launch.env import (
    ENV_PROFILES,
    _APPLIED_VAR,
    _merge_xla_flags,
    apply_env_profile,
    find_tcmalloc,
    profile_env,
)


# ---------------------------------------------------------------------------
# _merge_xla_flags: profile defaults never override user flags
# ---------------------------------------------------------------------------

def test_merge_appends_to_empty_and_existing():
    assert _merge_xla_flags("", ["--xla_a=1"]) == "--xla_a=1"
    assert _merge_xla_flags("--xla_b=2", ["--xla_a=1"]) == \
        "--xla_b=2 --xla_a=1"


def test_merge_user_flags_win():
    """A flag NAME already present is skipped entirely — the user's value
    survives, no duplicate is appended."""
    merged = _merge_xla_flags(
        "--xla_force_host_platform_device_count=16",
        ["--xla_force_host_platform_device_count=4",
         "--xla_step_marker_location=1"])
    assert merged.count("--xla_force_host_platform_device_count") == 1
    assert "--xla_force_host_platform_device_count=16" in merged
    assert "--xla_step_marker_location=1" in merged


def test_merge_handles_whitespace_and_valueless_flags():
    assert _merge_xla_flags("  --xla_a  ", ["--xla_a=9", "--xla_b"]) == \
        "--xla_a --xla_b"


# ---------------------------------------------------------------------------
# profile_env: pure computation of the delta
# ---------------------------------------------------------------------------

def test_profile_env_validates_inputs():
    with pytest.raises(ValueError):
        profile_env("gpu-turbo")
    with pytest.raises(ValueError):
        profile_env("cpu-mesh", host_devices=0)
    assert set(ENV_PROFILES) == {"none", "host", "cpu-mesh"}


def test_profile_none_is_empty_delta():
    assert profile_env("none", base={}) == {}


def test_profile_host_sets_log_level_and_optional_tcmalloc():
    env = profile_env("host", base={})
    assert env["TF_CPP_MIN_LOG_LEVEL"] == "4"
    assert "XLA_FLAGS" not in env
    lib = find_tcmalloc()
    if lib is None:
        assert "LD_PRELOAD" not in env
    else:
        assert lib in env["LD_PRELOAD"].split(":")
        assert env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"]


def test_profile_cpu_mesh_adds_host_platform_flags():
    env = profile_env("cpu-mesh", host_devices=8, base={})
    assert "--xla_force_host_platform_device_count=8" in env["XLA_FLAGS"]
    assert "--xla_step_marker_location=1" in env["XLA_FLAGS"]


def test_profile_cpu_mesh_respects_user_xla_flags():
    base = {"XLA_FLAGS": "--xla_force_host_platform_device_count=32"}
    env = profile_env("cpu-mesh", host_devices=4, base=base)
    assert "--xla_force_host_platform_device_count=32" in env["XLA_FLAGS"]
    assert "--xla_force_host_platform_device_count=4" not in env["XLA_FLAGS"]
    assert "--xla_step_marker_location=1" in env["XLA_FLAGS"]


def test_profile_env_does_not_mutate_process_env():
    before = dict(os.environ)
    profile_env("cpu-mesh", host_devices=2)
    assert dict(os.environ) == before


def test_tcmalloc_preload_not_duplicated():
    lib = find_tcmalloc()
    if lib is None:
        pytest.skip("no tcmalloc installed in this environment")
    env = profile_env("host", base={"LD_PRELOAD": lib})
    # already preloaded by the user: the profile adds nothing
    assert "LD_PRELOAD" not in env


def test_find_tcmalloc_prefers_listed_order(tmp_path):
    a, b = tmp_path / "full.so", tmp_path / "minimal.so"
    a.write_bytes(b"")
    b.write_bytes(b"")
    assert find_tcmalloc((str(a), str(b))) == str(a)
    assert find_tcmalloc((str(tmp_path / "nope.so"),)) is None


# ---------------------------------------------------------------------------
# apply_env_profile: the re-exec guard
# ---------------------------------------------------------------------------

def test_apply_none_profile_never_reexecs(monkeypatch):
    monkeypatch.delenv(_APPLIED_VAR, raising=False)
    assert apply_env_profile(None) is False
    assert apply_env_profile("none") is False


def test_apply_guard_blocks_second_exec(monkeypatch):
    """After the re-exec set REPRO_ENV_PROFILE_APPLIED=1, a second call is
    a no-op returning False — the guard is what makes the exec happen
    exactly once."""
    monkeypatch.setenv(_APPLIED_VAR, "1")
    called = []
    monkeypatch.setattr(os, "execvpe",
                        lambda *a, **kw: called.append(a))
    assert apply_env_profile("cpu-mesh", host_devices=4) is False
    assert not called


def test_apply_execs_with_guard_and_profile_env(monkeypatch):
    """First application: execvpe is invoked with the same argv, the
    profile's delta, and the guard variable set for the child."""
    monkeypatch.delenv(_APPLIED_VAR, raising=False)
    # user XLA_FLAGS win over the profile's, so a flag inherited from the
    # surrounding environment (CI exports one) would mask the profile value
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    captured = {}

    def fake_exec(exe, argv, env):
        captured.update(exe=exe, argv=argv, env=env)
        raise SystemExit(0)                  # stand-in for "does not return"

    monkeypatch.setattr(os, "execvpe", fake_exec)
    with pytest.raises(SystemExit):
        apply_env_profile("cpu-mesh", host_devices=2)
    import sys
    assert captured["exe"] == sys.executable
    assert captured["argv"] == [sys.executable] + sys.argv
    assert captured["env"][_APPLIED_VAR] == "1"
    assert "--xla_force_host_platform_device_count=2" in \
        captured["env"]["XLA_FLAGS"]
    assert captured["env"]["TF_CPP_MIN_LOG_LEVEL"] == "4"

"""Equivalence tests for the §Perf optimizations: every optimized lowering
must compute the same function as the paper-faithful baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clipping import make_dp_grad_fn
from repro.models.attention import blocked_causal_attention
from repro.models.layers import embed, init_embed
from repro.models.moe import init_moe, moe_dense, moe_scatter
from repro.models.rwkv import wkv6_chunked, wkv6_scan


def test_scan_accum_equals_stack():
    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (6, 3))}
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (8, 6)),
             "y": jax.random.normal(jax.random.PRNGKey(2), (8, 3))}
    key = jax.random.PRNGKey(3)
    g_stack, m1 = make_dp_grad_fn(loss, 1.0, 4, vmap_microbatches=False,
                                  accumulate="stack")(params, batch, key, 0.3)
    g_scan, m2 = make_dp_grad_fn(loss, 1.0, 4, vmap_microbatches=False,
                                 accumulate="scan")(params, batch, key, 0.3)
    np.testing.assert_allclose(np.asarray(g_stack["w"]),
                               np.asarray(g_scan["w"]), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)


def test_onehot_embed_equals_gather():
    params, _ = init_embed(jax.random.PRNGKey(0), 64, 16)
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 5), 0, 64)
    a = embed(params, toks, "gather")
    b = embed(params, toks, "one_hot")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("sq", [64, 96, 128])
def test_bucketed_causal_equals_full_grid(sq):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (2, sq, 4, 16)) for kk in ks)
    base = blocked_causal_attention(q, k, v, block_q=16)
    opt = blocked_causal_attention(q, k, v, block_q=16, causal_buckets=True)
    np.testing.assert_allclose(np.asarray(opt), np.asarray(base), rtol=2e-5,
                               atol=2e-6)


def test_wkv6_chunked_equals_scan_gradients():
    """Forward AND gradients match (the chunked form is used in training)."""
    b, s, h, hd = 1, 32, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = (jax.random.normal(kk, (b, s, h, hd)) for kk in ks[:3])
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) - 2)
    u = jax.random.normal(ks[4], (h, hd))

    def f_scan(r):
        y, _ = wkv6_scan(r, k, v, jnp.exp(logw), u)
        return jnp.sum(y ** 2)

    def f_chunk(r):
        y, _ = wkv6_chunked(r, k, v, logw, u, chunk=8)
        return jnp.sum(y ** 2)

    np.testing.assert_allclose(float(f_scan(r)), float(f_chunk(r)),
                               rtol=1e-4)
    g1 = jax.grad(f_scan)(r)
    g2 = jax.grad(f_chunk)(r)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3,
                               atol=1e-4)


def test_moe_scatter_equals_dense_train_and_decode():
    p, _ = init_moe(jax.random.PRNGKey(0), 16, 32, n_experts=4, top_k=2,
                    shared_expert=True)
    for shape in ((2, 16, 16), (8, 1, 16)):      # train-ish and decode
        x = jax.random.normal(jax.random.PRNGKey(1), shape)
        y1, a1 = moe_scatter(p, x, top_k=2, capacity_factor=4.0)
        y2, a2 = moe_dense(p, x, top_k=2, capacity_factor=4.0)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_moe_decode_grouping_no_waste():
    """decode (S=1) groups the whole batch: capacity ~ B*K/E, not 8 per row."""
    from repro.models.moe import _regroup, capacity
    x = jnp.zeros((128, 1, 16))
    g = _regroup(x)
    assert g.shape == (1, 128, 16)
    assert capacity(128, 16, 2, 1.25) < 128    # vs 128 rows x cap 8 = 1024

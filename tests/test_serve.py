"""Serving-plane tests: paged-vs-dense cache exactness, the
continuous-batching engine's byte-identity with the static ``generate``
path, federated checkpoint flavors, and the hot-swap boundary."""
import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_variant
from repro.launch.serve import generate, load_federated_params
from repro.models.transformer import Transformer
from repro.serve import (Request, SlotEngine, StepClock, model_pads_ok,
                         poisson_workload, serve_continuous, serve_static)


@functools.lru_cache(maxsize=None)
def _model(arch):
    cfg = smoke_variant(get_arch(arch))
    model = Transformer(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _engine(arch, params=None, **kw):
    model, p0 = _model(arch)
    kw.setdefault("n_slots", 3)
    kw.setdefault("max_len", 32)
    return model, SlotEngine(model, params if params is not None else p0,
                             **kw)


def _mixed_workload(arch, n=7, rate=2.0, seed=5, prompt_lens=(5, 8, 12),
                    gen_lens=(4, 9)):
    model, _ = _model(arch)
    return poisson_workload(n, rate, model.cfg.vocab, seed=seed,
                            prompt_lens=prompt_lens, gen_lens=gen_lens)


def _reference_tokens(arch, params, req):
    model, _ = _model(arch)
    out = generate(model, params, jnp.asarray(req.tokens)[None], req.max_gen)
    return np.asarray(out)[0].tolist()


# ------------------------- paged cache vs dense -----------------------------

def test_paged_matches_dense_one_block():
    """One block spanning max_len with an identity table IS the dense
    cache: prefill logits and every decode step must match bitwise."""
    model, params = _model("gemma3-4b")
    B, S, ML = 3, 6, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              model.cfg.vocab)
    logits_d, caches_d, pos_d = model.prefill(params, toks, max_len=ML)
    paged = model.init_paged_cache(B, B + 1, ML)
    table = jnp.arange(B, dtype=jnp.int32)[:, None]
    lengths = jnp.full((B,), S, jnp.int32)
    logits_p, pre, pos_p = model.prefill_at(params, toks, lengths,
                                            max_len=ML)
    np.testing.assert_array_equal(np.asarray(logits_p),
                                  np.asarray(logits_d))
    paged = model.insert_prefill(paged, pre, table,
                                 jnp.arange(B, dtype=jnp.int32))
    ld, lp = logits_d, logits_p
    for i in range(3):
        tok = jnp.argmax(ld, -1).astype(jnp.int32)
        ld, caches_d = model.decode_step(params, caches_d, tok, pos_d + i)
        lp, paged = model.decode_step(params, paged, tok, pos_p + i, table)
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))


def test_paged_matches_dense_shuffled_multiblock():
    """Real paging: 4 blocks per slot, physical blocks assigned in
    shuffled order — the block-table indirection must still reproduce
    dense reads bitwise (positions gather in logical order)."""
    model, params = _model("gemma3-4b")
    B, S, ML, bs = 3, 6, 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              model.cfg.vocab)
    logits_d, caches_d, pos_d = model.prefill(params, toks, max_len=ML)
    bps = ML // bs
    perm = np.random.default_rng(3).permutation(B * bps)
    table = jnp.asarray(perm.reshape(B, bps), jnp.int32)
    paged = model.init_paged_cache(B, B * bps + 1, bs)
    logits_p, pre, pos_p = model.prefill_at(
        params, toks, jnp.full((B,), S, jnp.int32), max_len=ML)
    paged = model.insert_prefill(paged, pre, table,
                                 jnp.arange(B, dtype=jnp.int32))
    ld, lp = logits_d, logits_p
    for i in range(8):  # crosses two block boundaries
        tok = jnp.argmax(ld, -1).astype(jnp.int32)
        ld, caches_d = model.decode_step(params, caches_d, tok, pos_d + i)
        lp, paged = model.decode_step(params, paged, tok, pos_p + i, table)
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))


def test_right_padded_prefill_exact_for_attention():
    """Bucketed prefill right-pads prompts; for pure-attention archs the
    pad garbage sits behind the visibility mask, so each row's logits
    equal an exact-length single-row prefill bitwise."""
    model, params = _model("gemma3-4b")
    assert model_pads_ok(model)
    B, S = 3, 6
    lens = jnp.asarray([3, 6, 4], jnp.int32)
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                              model.cfg.vocab)
    toks = jnp.where(jnp.arange(S)[None, :] < lens[:, None], toks, 0)
    logits, _, next_pos = model.prefill_at(params, toks, lens)
    np.testing.assert_array_equal(np.asarray(next_pos), np.asarray(lens))
    for r in range(B):
        row, _, _ = model.prefill(params, toks[r:r + 1, :int(lens[r])])
        np.testing.assert_array_equal(np.asarray(logits[r]),
                                      np.asarray(row[0]))


def test_recurrent_archs_reject_padding():
    """mamba2 / rwkv6 state consumes pad tokens — the engine must demand
    exact-length prefill groups there (pad_ok False -> bucket == length,
    and mixing lengths in one admit group raises)."""
    model, engine = _engine("rwkv6-1.6b")
    assert not engine.pad_ok
    assert engine.bucket_len(5) == 5
    reqs = [Request(0, 0.0, np.zeros(5, np.int32), 2),
            Request(1, 0.0, np.zeros(7, np.int32), 2)]
    with pytest.raises(ValueError, match="mixed prefill buckets"):
        engine.admit(reqs)


# ------------------------- engine == generate gate --------------------------

def test_engine_byte_identical_to_generate_mixed_lengths():
    """THE exactness gate: the continuous-batching engine emits
    byte-identical tokens to the static generate path for every request
    in a mixed-length workload — including requests admitted mid-stream
    into recycled slots (workload > slots forces churn)."""
    model, engine = _engine("gemma3-4b", block_size=8)
    wl = _mixed_workload("gemma3-4b")
    engine.warmup(buckets=[r.prompt_len for r in wl])
    report = serve_continuous(engine, wl, clock=StepClock())
    assert len(report.requests) == len(wl)
    _, params = _model("gemma3-4b")
    for r in report.requests:
        assert len(r.out) == r.max_gen
        assert r.out == _reference_tokens("gemma3-4b", params, r), r.rid


def test_engine_byte_identical_recurrent_arch():
    """Same gate for a recurrent arch (rwkv6): exact-length prefill
    groups, per-slot state rows instead of paged blocks."""
    model, engine = _engine("rwkv6-1.6b", max_len=24, block_size=8)
    wl = _mixed_workload("rwkv6-1.6b", n=6, seed=3, prompt_lens=(5, 9),
                         gen_lens=(4, 7))
    engine.warmup(buckets=[r.prompt_len for r in wl])
    report = serve_continuous(engine, wl, clock=StepClock())
    _, params = _model("rwkv6-1.6b")
    for r in report.requests:
        assert r.out == _reference_tokens("rwkv6-1.6b", params, r), r.rid


def test_static_baseline_matches_engine_tokens():
    """serve_static shares generate's fused step — same tokens per
    request as the engine, only the schedule (convoy) differs."""
    model, params = _model("gemma3-4b")
    wl_a = _mixed_workload("gemma3-4b")
    wl_b = _mixed_workload("gemma3-4b")
    _, engine = _engine("gemma3-4b", block_size=8)
    engine.warmup(buckets=[r.prompt_len for r in wl_a])
    rep_a = serve_continuous(engine, wl_a, clock=StepClock())
    rep_b = serve_static(model, params, wl_b, clock=StepClock(), batch=3)
    assert len(rep_b.requests) == len(wl_b)
    for ra, rb in zip(rep_a.requests, rep_b.requests):
        assert ra.rid == rb.rid and ra.out == rb.out


# ------------------------- sampling / dispatch ------------------------------

def test_greedy_is_argmax_invariance():
    """The fused sample+decode step at temperature 0 must reproduce an
    explicit host-side argmax loop token for token."""
    model, params = _model("gemma3-4b")
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 5), 0,
                                 model.cfg.vocab)
    fused = np.asarray(generate(model, params, prompts, 6))
    logits, caches, pos = model.prefill(params, prompts, max_len=11)
    outs = []
    for i in range(6):
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(np.asarray(tok))
        logits, caches = model.decode_step(params, caches, tok, pos + i)
    np.testing.assert_array_equal(fused, np.stack(outs, axis=1))


def test_sampled_generate_deterministic_per_seed():
    """Sampling lives inside the jitted step now; same seed -> same
    stream, different seed -> (almost surely) different."""
    model, params = _model("gemma3-4b")
    prompts = jax.random.randint(jax.random.PRNGKey(8), (2, 4), 0,
                                 model.cfg.vocab)
    a = np.asarray(generate(model, params, prompts, 8, temperature=1.0,
                            seed=1))
    b = np.asarray(generate(model, params, prompts, 8, temperature=1.0,
                            seed=1))
    c = np.asarray(generate(model, params, prompts, 8, temperature=1.0,
                            seed=2))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


# ------------------------- scheduler behavior -------------------------------

def test_slot_recycle_and_eos_early_stop():
    """An EOS engine frees the slot the step the token appears; the
    request keeps the EOS token as its last output."""
    model, params = _model("gemma3-4b")
    probe = _mixed_workload("gemma3-4b", n=1, seed=9, prompt_lens=(6,),
                            gen_lens=(8,))
    ref = _reference_tokens("gemma3-4b", params, probe[0])
    eos = ref[2]  # stop at the first occurrence of this token
    stop = ref.index(eos) + 1
    _, engine = _engine("gemma3-4b", eos=eos)
    engine.warmup(buckets=[6])
    report = serve_continuous(engine, probe, clock=StepClock())
    r = report.requests[0]
    assert r.out == ref[:stop] and r.out[-1] == eos
    assert engine.free_slots == engine.n_slots


def test_backpressure_stats_and_occupancy():
    """High offered load must show up in the stats: nonzero queue depth,
    high slot occupancy, slots all recycled at drain."""
    _, engine = _engine("gemma3-4b", block_size=8)
    wl = _mixed_workload("gemma3-4b", n=9, rate=50.0, seed=13)
    engine.warmup(buckets=[r.prompt_len for r in wl])
    report = serve_continuous(engine, wl, clock=StepClock())
    s = report.summary()
    assert s["max_queue_depth"] > 0
    assert s["occupancy_mean"] > 0.5
    assert s["tokens_out"] == sum(r.max_gen for r in wl)
    assert engine.free_slots == engine.n_slots
    assert s["p99_latency_s"] >= s["p50_latency_s"] > 0


def test_workload_deterministic_per_seed():
    a = poisson_workload(5, 2.0, 64, seed=4)
    b = poisson_workload(5, 2.0, 64, seed=4)
    for ra, rb in zip(a, b):
        assert ra.arrival == rb.arrival and ra.max_gen == rb.max_gen
        np.testing.assert_array_equal(ra.tokens, rb.tokens)
    # suffix of a longer workload regenerates the same requests
    c = poisson_workload(3, 2.0, 64, seed=4)
    for ra, rc in zip(a, c):
        np.testing.assert_array_equal(ra.tokens, rc.tokens)


def test_admission_guards():
    _, engine = _engine("gemma3-4b", n_slots=2, max_len=16)
    too_long = [Request(0, 0.0, np.zeros(12, np.int32), 8)]
    with pytest.raises(ValueError, match="exceed max_len"):
        engine.admit(too_long)
    three = [Request(i, 0.0, np.zeros(4, np.int32), 2) for i in range(3)]
    with pytest.raises(ValueError, match="free slots"):
        engine.admit(three)
    with pytest.raises(ValueError, match="max_len"):
        serve_continuous(engine, too_long, clock=StepClock())


# ------------------------- federated checkpoints ----------------------------

def _toy_loss(params, batch):
    # differentiable on ANY params tree (async init dispatches a real
    # local round, so the loss must accept transformer params)
    return sum(jnp.sum(jnp.square(leaf)) for leaf in jax.tree.leaves(params))


def _transformer_spec(model, n_clients=2, **kw):
    from repro.api import FederationSpec
    from repro.optim import sgd
    base = dict(n_clients=n_clients, tau=1, loss_fn=_toy_loss,
                optimizer=sgd(0.1), clip_norm=1.0, dp=True,
                sigmas=(0.5,) * n_clients, batch_sizes=(2,) * n_clients)
    base.update(kw)
    return FederationSpec(**base)


def _stacked_params(model, n_clients):
    inits = [model.init(jax.random.PRNGKey(10 + i))
             for i in range(n_clients)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *inits)


@pytest.mark.parametrize("topology", ["full_average", "local_only"])
def test_dense_checkpoint_serves_eval_params(tmp_path, topology):
    """save_state checkpoints serve bit-identically to eval_params under
    both collapse topologies (distinct per-client replicas make the
    collapse rule observable)."""
    from repro.api import eval_params, init_state, save_state
    from repro.launch.train import federation_meta
    model, _ = _model("gemma3-4b")
    spec = _transformer_spec(model, topology=topology)
    state = init_state(spec, model.init(jax.random.PRNGKey(3)))
    state = dataclasses.replace(state,
                                params=_stacked_params(model, spec.n_clients))
    save_state(str(tmp_path), state, extra=federation_meta(spec))
    served = load_federated_params(model, str(tmp_path))
    want = eval_params(spec, state)
    for a, b in zip(jax.tree.leaves(served), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_population_checkpoint_serves_eval_params(tmp_path):
    """save_population_state wraps save_state — the serving loader must
    see through the store sidecar and collapse the K-block params."""
    from repro.api import eval_params
    from repro.launch.train import federation_meta
    from repro.population import (init_population_state,
                                  save_population_state)
    model, _ = _model("gemma3-4b")
    spec = _transformer_spec(model, population=6, cohort_size=2)
    pstate = init_population_state(spec, model.init(jax.random.PRNGKey(3)))
    pstate = dataclasses.replace(
        pstate, fl=dataclasses.replace(
            pstate.fl, params=_stacked_params(model, spec.n_clients)))
    save_population_state(str(tmp_path), pstate,
                          extra=federation_meta(spec))
    served = load_federated_params(model, str(tmp_path))
    want = eval_params(spec, pstate.fl)
    for a, b in zip(jax.tree.leaves(served), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint_serves_global_params(tmp_path):
    """Async checkpoints store the already-collapsed server model under
    global_params; the loader must serve that, never the K in-flight
    slot storages."""
    from repro.asyncfl import init_async_state, save_async_state
    from repro.launch.train import federation_meta

    def sampler(vid, tau, rng):
        return {"x": rng.normal(size=(tau, 2, 4)).astype(np.float32),
                "y": rng.integers(0, 2, size=(tau, 2)).astype(np.int32)}

    model, _ = _model("gemma3-4b")
    spec = _transformer_spec(model, engine="async_buffered")
    params0 = model.init(jax.random.PRNGKey(3))
    state = init_async_state(spec, params0, sampler, check_budgets=False)
    # make the slot storages visibly different from the server model
    state = dataclasses.replace(
        state, fl=dataclasses.replace(
            state.fl, params=_stacked_params(model, spec.n_clients)))
    save_async_state(str(tmp_path), state, extra=federation_meta(spec))
    served = load_federated_params(model, str(tmp_path))
    for a, b in zip(jax.tree.leaves(served),
                    jax.tree.leaves(state.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------- hot-swap gate ------------------------------------

def test_hot_swap_mid_decode():
    """The hot-swap gate: swapping checkpoints mid-decode (a) completes
    every in-flight request without error, (b) leaves tokens emitted
    before the boundary byte-identical to the old checkpoint's reference,
    and (c) admissions after the swap serve the new checkpoint exactly."""
    model, pA = _model("gemma3-4b")
    pB = model.init(jax.random.PRNGKey(7))
    wl = _mixed_workload("gemma3-4b", n=6, rate=1.0, seed=11,
                         prompt_lens=(6, 10), gen_lens=(8,))
    _, engine = _engine("gemma3-4b", params=pA, block_size=8)
    engine.warmup(buckets=[r.prompt_len for r in wl])
    swap_at = 6.0
    report = serve_continuous(engine, wl, clock=StepClock(),
                              swap_at=swap_at, swap_params=pB)
    assert engine.swaps == 1
    assert len(report.requests) == len(wl)
    saw_boundary = False
    for r in report.requests:
        assert len(r.out) == r.max_gen
        refA = _reference_tokens("gemma3-4b", pA, r)
        n_pre = sum(1 for t in r.emit_times if t <= swap_at)
        assert r.out[:n_pre] == refA[:n_pre], r.rid
        saw_boundary |= 0 < n_pre < r.max_gen
    assert saw_boundary  # the workload actually straddled the swap

    # post-swap admissions serve the new checkpoint byte-identically
    wl2 = _mixed_workload("gemma3-4b", n=3, rate=2.0, seed=21,
                          prompt_lens=(6, 10), gen_lens=(8,))
    rep2 = serve_continuous(engine, wl2, clock=StepClock())
    for r in rep2.requests:
        assert r.out == _reference_tokens("gemma3-4b", pB, r), r.rid


def test_hot_swap_rejects_mismatched_tree():
    model, engine = _engine("gemma3-4b")
    with pytest.raises(ValueError, match="tree mismatch"):
        engine.swap_params({"not": jnp.zeros(3)})


# ------------------- CI smoke leg (REPRO_SMOKE_SERVE) -----------------------

@pytest.mark.skipif(not os.environ.get("REPRO_SMOKE_SERVE"),
                    reason="set REPRO_SMOKE_SERVE=1 to smoke the serving "
                           "plane on a hybrid arch")
def test_serve_smoke_hybrid_arch():
    """CI serve leg: the exactness gate on zamba2 (attention + mamba2
    hybrid — paged blocks and per-slot recurrent state in one model).
    Prompt lengths are multiples of the mamba2 SSD chunk (prefill
    constraint, same as the dense path)."""
    model, engine = _engine("zamba2-7b", max_len=24, block_size=8)
    wl = _mixed_workload("zamba2-7b", n=5, seed=17, prompt_lens=(8, 16),
                         gen_lens=(4, 6))
    engine.warmup(buckets=[r.prompt_len for r in wl])
    report = serve_continuous(engine, wl, clock=StepClock())
    _, params = _model("zamba2-7b")
    for r in report.requests:
        assert r.out == _reference_tokens("zamba2-7b", params, r), r.rid

"""Tests for the Theorem-1 convergence bound."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.convergence import (
    ProblemConstants,
    bound_b,
    reduces_to_distributed_sgd,
    theorem1_bound,
)

CONSTS = ProblemConstants(eta=0.05, lam=0.5, lip=2.0, alpha=1.0, xi2=0.5,
                          dim=50, n_clients=8)


def test_tau1_sigma0_reduces_to_dsgd():
    b = theorem1_bound(CONSTS, 100, tau=1.0, sigmas2=[0.0] * 8)
    assert b == pytest.approx(reduces_to_distributed_sgd(CONSTS, 100))
    # with tau=1, sigma=0 the floor only carries the minibatch variance term
    assert bound_b(CONSTS, 1.0, [0.0] * 8) == pytest.approx(
        CONSTS.eta * CONSTS.lip * CONSTS.xi2 / (2 * CONSTS.lam * CONSTS.n_clients))


@settings(max_examples=100, deadline=None)
@given(k=st.integers(1, 5000), tau=st.integers(1, 20),
       sig=st.floats(0.0, 5.0))
def test_bound_monotonicity(k, tau, sig):
    """Paper's discussion after Thm 1: bound grows with tau and sigma,
    shrinks with K (for the decaying term)."""
    s2 = [sig ** 2] * CONSTS.n_clients
    b = theorem1_bound(CONSTS, k, tau, s2)
    assert b >= 0 or CONSTS.alpha < bound_b(CONSTS, tau, s2)
    assert theorem1_bound(CONSTS, k, tau + 1, s2) >= b - 1e-12
    s2_hi = [(sig + 1.0) ** 2] * CONSTS.n_clients
    assert theorem1_bound(CONSTS, k, tau, s2_hi) >= b - 1e-12


def test_bound_decreases_with_k_before_floor():
    s2 = [0.01] * CONSTS.n_clients
    vals = [theorem1_bound(CONSTS, k, 5, s2) for k in (1, 5, 25, 125)]
    assert vals[0] > vals[-1]


def test_lr_constraint_eq21e():
    assert CONSTS.lr_constraint_ok(1.0)
    tmax = CONSTS.tau_max()
    assert CONSTS.lr_constraint_ok(tmax)
    assert not CONSTS.lr_constraint_ok(tmax + 1.0)

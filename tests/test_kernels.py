"""Backend-parity harness: every registered kernel × every available backend
× a shape/dtype sweep, checked against the ref.py pure-jnp oracles.

The parametrization is driven by the dispatch registry itself
(``kernel_names()`` × ``available_backends(name)``), so on a jax whose
Pallas API has drifted the probes exclude "interpret"/"pallas" and the
suite still runs (and passes) on the "ref" oracle — green degradation
instead of collection errors. Property tests run under real hypothesis or
the ``_hypothesis_compat`` replay shim (installed by conftest).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ref
from repro.kernels.dispatch import (
    available_backends,
    get_kernel,
    kernel_names,
)
from repro.kernels.ops import dp_clip_noise_tree


def _assert_trees_close(got, want, tol):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=tol, atol=tol)


# --------------------------------------------------------------------------
# case sweep per kernel: (case_id, build) where build() -> (args, kwargs,
# {dtype: tol}). args/kwargs are passed identically to every backend; the
# oracle adapters swallow the tuning kwargs.
# --------------------------------------------------------------------------

def _dp_case(n, dtype, scale):
    def build():
        g = jax.random.normal(jax.random.PRNGKey(0), (n,), dtype) * scale
        noise = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        return (g, noise, 1.0, 0.5), {"block": 4096}, tol
    return build


def _dp_clip_only_case(n):
    def build():
        g = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32) * 50
        # noise=None selects the clip-only lowering (microbatch clip path)
        return (g, None, 1.0, 0.0), {"block": 4096}, 1e-5
    return build


def _quant_case(n, bits, dtype, scale):
    def build():
        x = jax.random.normal(jax.random.PRNGKey(0), (n,), dtype) * scale
        u = jax.random.uniform(jax.random.PRNGKey(1), (n,), jnp.float32)
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
        return (x, u, bits), {"block": 4096}, tol
    return build


def _flash_case(s, bq, bk, dtype, window=0):
    def build():
        b, h, hd = 2, 3, 64
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q, k, v = (jax.random.normal(kk, (b, h, s, hd), dtype) for kk in ks)
        tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
        return (q, k, v), {"window": window, "block_q": bq,
                           "block_k": bk}, tol
    return build


def _rwkv_case(s, dtype):
    def build():
        b, h, hd = 2, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        r, k, v = (jax.random.normal(kk, (b, h, s, hd), dtype)
                   for kk in ks[:3])
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, s, hd))
                           ).astype(dtype)
        u = jax.random.normal(ks[4], (h, hd), jnp.float32)
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
        return (r, k, v, w, u), {}, tol
    return build


def _rwkv_state_case():
    def build():
        b, h, s, hd = 1, 1, 5, 8
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        r, k, v = (jax.random.normal(kk, (b, h, s, hd)) for kk in ks[:3])
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, s, hd)))
        u = jax.random.normal(ks[4], (h, hd))
        s0 = jnp.ones((b, h, hd, hd), jnp.float32) * 0.3
        return (r, k, v, w, u, s0), {}, 1e-4
    return build


def _mamba_case(s, chunk, dtype):
    def build():
        b, h, p, n = 2, 3, 8, 4
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(ks[0], (b, s, h, p), dtype)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))
                             ).astype(jnp.float32)
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        b_in = jax.random.normal(ks[3], (b, s, n), dtype)
        c_in = jax.random.normal(jax.random.PRNGKey(9), (b, s, n), dtype)
        tol = 6e-2 if dtype == jnp.bfloat16 else 1e-3
        return (x, dt, a, b_in, c_in), {"chunk": chunk}, tol
    return build


def _cohort_case(s, k, d, dtype, scatter=False):
    def build():
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        cache = jax.random.normal(ks[0], (s, d), dtype)
        slots = jax.random.permutation(ks[1], s)[:k].astype(jnp.int32)
        if scatter:
            rows = jax.random.normal(ks[2], (k, d), dtype)
            # pure row copy: exact on every backend, no tolerance
            return (cache, slots, rows), {}, 0.0
        return (cache, slots), {}, 0.0
    return build


CASES = {
    "dp_clip_noise": [
        (f"n{n}-{np.dtype(d).name if d != jnp.bfloat16 else 'bf16'}-x{s}",
         _dp_case(n, d, s))
        for n in (17, 1024, 64 * 1024 + 3)
        for d in (jnp.float32, jnp.bfloat16)
        for s in (100.0, 1e-3)
    ] + [
        ("clip-only-n1000", _dp_clip_only_case(1000)),
    ],
    "quantize_decompress": [
        (f"n{n}-b{bits}-{np.dtype(d).name if d != jnp.bfloat16 else 'bf16'}",
         _quant_case(n, bits, d, s))
        for n, bits, d, s in [
            (17, 8, jnp.float32, 1.0),
            (1024, 4, jnp.float32, 50.0),
            (64 * 1024 + 3, 8, jnp.float32, 1e-3),
            (1024, 8, jnp.bfloat16, 1.0),
            (255, 1, jnp.float32, 1.0),
        ]
    ],
    "flash_attention": [
        ("s128-b64", _flash_case(128, 64, 64, jnp.float32)),
        ("s256-b128.64", _flash_case(256, 128, 64, jnp.float32)),
        ("s64-b64", _flash_case(64, 64, 64, jnp.float32)),
        ("s128-bf16", _flash_case(128, 64, 64, jnp.bfloat16)),
        ("window32", _flash_case(256, 64, 64, jnp.float32, window=32)),
        ("window100", _flash_case(256, 64, 64, jnp.float32, window=100)),
    ],
    "rwkv6_scan": [
        ("s1", _rwkv_case(1, jnp.float32)),
        ("s7", _rwkv_case(7, jnp.float32)),
        ("s64", _rwkv_case(64, jnp.float32)),
        ("s7-bf16", _rwkv_case(7, jnp.bfloat16)),
        ("init-state", _rwkv_state_case()),
    ],
    "mamba2_ssd": [
        ("s32-c8", _mamba_case(32, 8, jnp.float32)),
        ("s64-c16", _mamba_case(64, 16, jnp.float32)),
        ("s16-c16", _mamba_case(16, 16, jnp.float32)),
        ("s32-c8-bf16", _mamba_case(32, 8, jnp.bfloat16)),
    ],
    "cohort_gather_scatter": [
        ("gather-s9-d5", _cohort_case(9, 3, 5, jnp.float32)),
        ("scatter-s9-d5", _cohort_case(9, 3, 5, jnp.float32, scatter=True)),
        # d > 128 exercises the lane-padding path of the Pallas kernel
        ("gather-s64-d130", _cohort_case(64, 8, 130, jnp.float32)),
        ("scatter-s64-d130", _cohort_case(64, 8, 130, jnp.float32,
                                          scatter=True)),
        ("gather-s16-d33-bf16", _cohort_case(16, 4, 33, jnp.bfloat16)),
        ("scatter-s16-d33-bf16", _cohort_case(16, 4, 33, jnp.bfloat16,
                                              scatter=True)),
    ],
}


def _parity_params():
    assert set(CASES) == set(kernel_names()), \
        "case sweep drifted from the dispatch registry"
    for name in kernel_names():
        for backend in available_backends(name):
            if backend == "ref":
                continue   # ref IS the oracle; ref-vs-ref proves nothing
            for case_id, build in CASES[name]:
                yield pytest.param(name, backend, build,
                                   id=f"{name}-{backend}-{case_id}")


@pytest.mark.parametrize(
    "name,backend,build",
    list(_parity_params())
    # oracle-only env (all pallas probes failed / disabled): nothing to
    # compare — parametrize an explicit skip instead of an empty set
    or [pytest.param(None, None, None,
                     id="oracle-only-env",
                     marks=pytest.mark.skip("no non-ref backend available"))])
def test_kernel_backend_parity(name, backend, build):
    args, kwargs, tol = build()
    got = get_kernel(name, backend)(*args, **kwargs)
    # oracle adapters take the same kwargs: semantic ones (window, causal)
    # apply, tuning ones (block sizes) are swallowed
    want = get_kernel(name, "ref")(*args, **kwargs)
    _assert_trees_close(got, want, tol)


def test_every_kernel_has_ref_backend():
    for name in kernel_names():
        assert "ref" in available_backends(name)


# ------------------------- dp_clip_noise properties ------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5000), clip=st.floats(0.01, 10.0),
       seed=st.integers(0, 2**30))
def test_dp_clip_noise_norm_bound(n, clip, seed):
    """sigma=0: output norm <= clip_norm for ALL inputs (Eq. 7a sensitivity
    bound), on every available backend."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32) * 10.0
    noise = jnp.zeros((n,), jnp.float32)
    for backend in available_backends("dp_clip_noise"):
        got, norm = get_kernel("dp_clip_noise", backend)(
            g, noise, clip, 0.0, block=1024)
        out_norm = float(jnp.linalg.norm(got.astype(jnp.float32)))
        assert out_norm <= min(clip, float(norm)) * (1 + 1e-4)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 2000), seed=st.integers(0, 2**30))
def test_dp_clip_noise_passthrough_below_clip(n, seed):
    """Gradients already inside the clip ball pass through untouched."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)
    g = g / jnp.maximum(jnp.linalg.norm(g), 1e-12) * 0.5   # norm 0.5 < 1
    noise = jnp.zeros((n,), jnp.float32)
    for backend in available_backends("dp_clip_noise"):
        got, norm = get_kernel("dp_clip_noise", backend)(
            g, noise, 1.0, 0.0, block=1024)
        np.testing.assert_allclose(np.asarray(got), np.asarray(g),
                                   rtol=1e-6, atol=1e-7)
        assert float(norm) <= 0.5 * (1 + 1e-5)


def test_dp_clip_noise_tree_dtype_preservation():
    """Mixed bf16/f32 trees keep every leaf's dtype through the fused path."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (9, 4),
                                   jnp.bfloat16) * 10,
            "b": jax.random.normal(jax.random.PRNGKey(1), (7,), jnp.float32)}
    for backend in available_backends("dp_clip_noise"):
        out, norm = dp_clip_noise_tree(tree, jax.random.PRNGKey(2), 1.0, 0.3,
                                       backend=backend)
        assert jax.tree.map(lambda x: x.dtype, out) == \
            jax.tree.map(lambda x: x.dtype, tree)
        assert float(norm) > 0


def test_dp_clip_noise_tree_matches_core():
    from repro.core.clipping import clip_tree
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (37, 5)) * 8,
            "b": {"c": jax.random.normal(jax.random.PRNGKey(1), (11,))}}
    key = jax.random.PRNGKey(2)
    want, wnorm = clip_tree(tree, 1.0)
    for backend in available_backends("dp_clip_noise"):
        got, norm = dp_clip_noise_tree(tree, key, 1.0, 0.0, backend=backend)
        _assert_trees_close(got, want, 1e-5)
        np.testing.assert_allclose(float(norm), float(wnorm), rtol=1e-5)


def test_dp_clip_noise_tree_noise_matches_tree_add_noise():
    """The fused path draws the SAME noise stream as the legacy
    clip_tree + tree_add_noise path (per-leaf split keys)."""
    from repro.core.clipping import clip_tree
    from repro.utils.tree import tree_add_noise
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (13, 3)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (21,))}
    key = jax.random.PRNGKey(5)
    clipped, _ = clip_tree(tree, 1.0)
    want = tree_add_noise(key, clipped, 0.7)
    got, _ = dp_clip_noise_tree(tree, key, 1.0, 0.7, backend="ref")
    _assert_trees_close(got, want, 1e-6)


# ------------------------- kernels vs model baselines -----------------------

def test_flash_attention_matches_model_blocked_attention():
    """Dispatch kernel == the lax blockwise attention used in the model."""
    from repro.models.attention import blocked_causal_attention
    from repro.kernels.ops import flash_attention
    b, h, s, hd = 1, 4, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (jax.random.normal(kk, (b, s, h, hd), jnp.float32)
               for kk in ks)
    lax_out = blocked_causal_attention(q, k, v, block_q=32)
    disp_out = flash_attention(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(jnp.moveaxis(disp_out, 1, 2)),
                               np.asarray(lax_out), rtol=2e-4, atol=2e-5)


def test_rwkv6_kernel_matches_model_scan():
    """Dispatch kernel == models.rwkv.wkv6_scan (the lax baseline)."""
    from repro.models.rwkv import wkv6_scan
    from repro.kernels.ops import rwkv6_scan
    b, h, s, hd = 2, 3, 12, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    # model layout (B, S, H, hd)
    r, k, v = (jax.random.normal(kk, (b, s, h, hd)) for kk in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, hd)))
    u = jax.random.normal(ks[4], (h, hd))
    y_model, s_model = wkv6_scan(r, k, v, w, u)
    perm = lambda t: jnp.moveaxis(t, 2, 1)  # -> (B, H, S, hd)
    y_k, s_k = rwkv6_scan(perm(r), perm(k), perm(v), perm(w), u)
    np.testing.assert_allclose(np.asarray(jnp.moveaxis(y_k, 1, 2)),
                               np.asarray(y_model), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_model),
                               rtol=1e-4, atol=1e-5)


def test_ssd_chunked_model_matches_sequential_ref():
    """models.ssm.ssd_chunked (lax baseline) == sequential oracle."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 2, 32, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b_in = jax.random.normal(ks[3], (b, s, n))
    c_in = jax.random.normal(ks[4], (b, s, n))
    got_y, got_s = ssd_chunked(x, dt, a, b_in, c_in, chunk=8)
    want_y, want_s = ref.mamba2_ssd_ref(x, dt, a, b_in, c_in)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=1e-3, atol=1e-4)

"""Per-kernel allclose sweeps vs the ref.py pure-jnp oracles.

Every Pallas kernel runs in interpret=True on CPU (kernel body executed in
Python) and is compared against the oracle over a sweep of shapes/dtypes
(pytest params + hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ref
from repro.kernels.dp_clip_noise import dp_clip_noise
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_ssd import mamba2_ssd
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.ops import dp_clip_noise_tree


# ------------------------- dp_clip_noise ----------------------------------

@pytest.mark.parametrize("n", [17, 1024, 64 * 1024 + 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("scale_big", [True, False])
def test_dp_clip_noise_matches_ref(n, dtype, scale_big):
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (n,), dtype) * (100.0 if scale_big else 1e-3)
    noise = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    got, gnorm = dp_clip_noise(g, noise, 1.0, 0.5, block=4096,
                               interpret=True)
    want, wnorm = ref.dp_clip_noise_ref(g, noise, 1.0, 0.5)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(float(gnorm), float(wnorm), rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5000), clip=st.floats(0.01, 10.0),
       sigma=st.floats(0.0, 5.0), seed=st.integers(0, 2**30))
def test_dp_clip_noise_property(n, clip, sigma, seed):
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (n,), jnp.float32) * 10.0
    noise = jnp.zeros((n,), jnp.float32)
    got, norm = dp_clip_noise(g, noise, clip, sigma, block=1024,
                              interpret=True)
    # with zero noise, output norm is min(norm, clip)
    out_norm = float(jnp.linalg.norm(got.astype(jnp.float32)))
    assert out_norm <= clip * (1 + 1e-4) or out_norm <= float(norm) * (1 + 1e-4)
    want, _ = ref.dp_clip_noise_ref(g, noise, clip, sigma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


def test_dp_clip_noise_tree_matches_core():
    from repro.core.clipping import clip_tree
    from repro.utils.tree import tree_add_noise
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (37, 5)) * 8,
            "b": {"c": jax.random.normal(jax.random.PRNGKey(1), (11,))}}
    key = jax.random.PRNGKey(2)
    got, norm = dp_clip_noise_tree(tree, key, 1.0, 0.0)
    want, wnorm = clip_tree(tree, 1.0)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)
    np.testing.assert_allclose(float(norm), float(wnorm), rtol=1e-5)


# ------------------------- flash attention --------------------------------

@pytest.mark.parametrize("s,bq,bk", [(128, 64, 64), (256, 128, 64),
                                     (64, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(s, bq, bk, dtype):
    b, h, hd = 2, 3, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), dtype)
    k = jax.random.normal(ks[1], (b, h, s, hd), dtype)
    v = jax.random.normal(ks[2], (b, h, s, hd), dtype)
    got = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 100])
def test_flash_attention_window(window):
    b, h, s, hd = 1, 2, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, hd), jnp.float32)
               for kk in ks)
    got = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_blocked_attention():
    """Pallas kernel == the lax blockwise attention used in the model."""
    from repro.models.attention import blocked_causal_attention
    b, h, s, hd = 1, 4, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (jax.random.normal(kk, (b, s, h, hd), jnp.float32)
               for kk in ks)
    lax_out = blocked_causal_attention(q, k, v, block_q=32)
    pallas_out = flash_attention(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.moveaxis(pallas_out, 1, 2)),
                               np.asarray(lax_out), rtol=2e-4, atol=2e-5)


# ------------------------- rwkv6 scan --------------------------------------

@pytest.mark.parametrize("s", [1, 7, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_matches_ref(s, dtype):
    b, h, hd = 2, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (b, h, s, hd), dtype)
    k = jax.random.normal(ks[1], (b, h, s, hd), dtype)
    v = jax.random.normal(ks[2], (b, h, s, hd), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, s, hd))).astype(dtype)
    u = jax.random.normal(ks[4], (h, hd), jnp.float32)
    got_y, got_s = rwkv6_scan(r, k, v, w, u, interpret=True)
    want_y, want_s = ref.rwkv6_scan_ref(r, k, v, w, u)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got_y, np.float32),
                               np.asarray(want_y, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=tol, atol=tol)


def test_rwkv6_scan_with_initial_state():
    b, h, s, hd = 1, 1, 5, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r, k, v = (jax.random.normal(kk, (b, h, s, hd)) for kk in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, s, hd)))
    u = jax.random.normal(ks[4], (h, hd))
    s0 = jnp.ones((b, h, hd, hd), jnp.float32) * 0.3
    got_y, got_s = rwkv6_scan(r, k, v, w, u, s0, interpret=True)
    want_y, want_s = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=1e-4, atol=1e-5)


def test_rwkv6_kernel_matches_model_scan():
    """Pallas kernel == models.rwkv.wkv6_scan (the lax baseline)."""
    from repro.models.rwkv import wkv6_scan
    b, h, s, hd = 2, 3, 12, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    # model layout (B, S, H, hd)
    r, k, v = (jax.random.normal(kk, (b, s, h, hd)) for kk in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, hd)))
    u = jax.random.normal(ks[4], (h, hd))
    y_model, s_model = wkv6_scan(r, k, v, w, u)
    perm = lambda t: jnp.moveaxis(t, 2, 1)  # -> (B, H, S, hd)
    y_k, s_k = rwkv6_scan(perm(r), perm(k), perm(v), perm(w), u,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.moveaxis(y_k, 1, 2)),
                               np.asarray(y_model), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_model),
                               rtol=1e-4, atol=1e-5)


# ------------------------- mamba2 ssd --------------------------------------

@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (16, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba2_ssd_matches_ref(s, chunk, dtype):
    b, h, p, n = 2, 3, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b_in = jax.random.normal(ks[3], (b, s, n), dtype)
    c_in = jax.random.normal(jax.random.PRNGKey(9), (b, s, n), dtype)
    got_y, got_s = mamba2_ssd(x, dt, a, b_in, c_in, chunk=chunk,
                              interpret=True)
    want_y, want_s = ref.mamba2_ssd_ref(x, dt, a, b_in, c_in)
    tol = 6e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(got_y, np.float32),
                               np.asarray(want_y, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=tol, atol=tol)


def test_ssd_chunked_model_matches_sequential_ref():
    """models.ssm.ssd_chunked (lax baseline) == sequential oracle."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 2, 32, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b_in = jax.random.normal(ks[3], (b, s, n))
    c_in = jax.random.normal(ks[4], (b, s, n))
    got_y, got_s = ssd_chunked(x, dt, a, b_in, c_in, chunk=8)
    want_y, want_s = ref.mamba2_ssd_ref(x, dt, a, b_in, c_in)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=1e-3, atol=1e-4)

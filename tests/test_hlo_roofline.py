"""Tests for the loop-aware HLO cost analyzer and the roofline model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.hlo import analyze_hlo, cost_analysis_dict, count_ops
from repro.utils.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    RooflineTerms,
    active_params,
    model_flops_estimate,
)


def test_scan_flops_exact():
    """XLA cost_analysis counts while bodies once; analyze_hlo multiplies by
    the trip count and recovers the exact matmul flops."""
    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    sds = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = jax.jit(f).lower(sds, sds).compile()
    # raw cost_analysis: body counted once (dict or [dict] across versions)
    raw = cost_analysis_dict(compiled)["flops"]
    assert raw == pytest.approx(2 * 256 ** 3, rel=0.05)
    m = analyze_hlo(compiled.as_text())
    assert m.flops == pytest.approx(10 * 2 * 256 ** 3, rel=0.01)


def test_nested_scan_flops_exact():
    def g(w, x):
        def inner(c, _):
            return c @ w, None
        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return jnp.tanh(c2), None
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return c

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    m = analyze_hlo(jax.jit(g).lower(sds, sds).compile().as_text())
    assert m.flops == pytest.approx(15 * 2 * 128 ** 3, rel=0.01)


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((128, 32), jnp.bfloat16)
    m = analyze_hlo(jax.jit(lambda a, b: a @ b).lower(a, b).compile().as_text())
    assert m.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_collective_parse_fake_hlo():
    text = """
ENTRY %main.1 (p0: f32[16]) -> f32[16] {
  %p0 = f32[16]{0} parameter(0)
  ROOT %all-reduce.1 = f32[16]{0} all-reduce(%p0), replica_groups={}
}
"""
    m = analyze_hlo(text)
    assert m.coll_breakdown.get("all-reduce") == 64.0
    assert count_ops(text, "all-reduce") >= 1


def test_roofline_terms_and_bottleneck():
    t = RooflineTerms(flops=197e12, hbm_bytes=819e9 / 2,
                      coll_bytes=50e9 * 3, model_flops=197e12 * 256 * 0.5,
                      chips=256)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(0.5)
    assert t.t_collective == pytest.approx(3.0)
    assert t.bottleneck == "collective"
    assert t.useful_flops_fraction == pytest.approx(0.5)
    assert PEAK_FLOPS_BF16 == 197e12 and HBM_BW == 819e9 and ICI_BW == 50e9


def test_model_flops_and_active_params():
    from repro.configs import get_arch
    assert model_flops_estimate(1e9, 1e6, "train") == 6e15
    assert model_flops_estimate(1e9, 1e6, "decode") == 2e15
    moe = get_arch("phi3.5-moe-42b-a6.6b")
    dense = get_arch("granite-20b")
    n = 42e9
    assert active_params(moe, n) < n          # top-2 of 16 experts
    assert active_params(dense, 20e9) == 20e9
    # phi3.5: expert params = 3*4096*6400*16*32 = 40.2B of 42B; active = 1/8
    expert_total = 3 * 4096 * 6400 * 16 * 32
    expect = n - expert_total + expert_total * 2 / 16
    assert active_params(moe, n) == pytest.approx(expect)

"""Aggregation-pipeline tests: compressors, error feedback, partial
participation, engine parity under every pipeline setting, and the
refactor guard (default spec == pre-pipeline engines, bit for bit)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FederationSpec, init_state, run_round, train
from repro.core.aggregation import (
    AggregationPipeline,
    QSGD,
    RandK,
    TopK,
    flatten_tree,
    make_compressor,
    participation_mask,
    unflatten_like,
)
from repro.core.fl import make_round_step
from repro.models.linear import init_linear, logreg_loss
from repro.optim import sgd

C, TAU, DIM, B = 4, 3, 8, 4


def _spec(**kw):
    base = dict(n_clients=C, tau=TAU, loss_fn=logreg_loss, optimizer=sgd(0.2),
                clip_norm=1.0, dp=True, sigmas=(0.5,) * C,
                batch_sizes=(B,) * C)
    base.update(kw)
    return FederationSpec(**base)


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    return {"x": jnp.asarray(rng.normal(size=(C, TAU, B, DIM)), jnp.float32),
            "y": jnp.asarray(rng.integers(0, 2, size=(C, TAU, B)), jnp.int32)}


def _run(spec, rounds=2, seed=0):
    state = init_state(spec, init_linear(DIM))
    recs = []
    for r in range(rounds):
        state, rec = run_round(spec, state, _batch(seed + r),
                               check_budgets=False)
        recs.append(rec)
    return state, recs


# Every non-default pipeline setting the parity gate covers; qsgd exercises
# the fused quantize_decompress kernel on the spec's (auto) backend.
PIPELINE_SETTINGS = [
    ("q50-dense", dict(participation=0.5)),
    ("q1client-dense", dict(participation=1)),      # int count
    ("topk25", dict(compressor="topk", compression_ratio=0.25)),
    ("randk25-q50", dict(compressor="randk", compression_ratio=0.25,
                         participation=0.5)),
    ("qsgd4", dict(compressor="qsgd", compression_bits=4)),
    ("qsgd8-q75", dict(compressor="qsgd", compression_bits=8,
                       participation=0.75)),
]


# ---------------------------- compressors -----------------------------------

def test_flatten_unflatten_roundtrip():
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.ones((4,), jnp.bfloat16)}
    flat = flatten_tree(tree)
    assert flat.shape == (10,) and flat.dtype == jnp.float32
    back = unflatten_like(flat, tree)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_topk_keeps_largest_coordinates():
    x = jnp.asarray([0.1, -5.0, 0.3, 2.0, -0.2, 0.05, 1.0, -0.4])
    y = TopK(0.25)(x, None)                       # k = 2 of 8
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray([0, -5.0, 0, 2.0, 0, 0, 0, 0], np.float32))


def test_randk_keeps_exactly_k_unscaled():
    x = jnp.arange(1.0, 101.0)
    y = RandK(0.1)(x, jax.random.PRNGKey(0))
    nz = np.flatnonzero(np.asarray(y))
    assert len(nz) == 10
    np.testing.assert_array_equal(np.asarray(y)[nz], np.asarray(x)[nz])


def test_qsgd_error_bounded_by_one_level():
    """|x - Q(x)| < scale = max|x| / (2^bits - 1) elementwise (stochastic
    rounding moves at most one level), and signs/zeros are preserved."""
    x = jax.random.normal(jax.random.PRNGKey(0), (512,)) * 3.0
    x = x.at[7].set(0.0)
    for bits in (2, 4, 8):
        comp = QSGD(bits)
        y = comp(x, jax.random.PRNGKey(1))
        scale = float(jnp.max(jnp.abs(x))) / (2 ** bits - 1)
        assert float(jnp.max(jnp.abs(y - x))) <= scale * (1 + 1e-6)
        assert float(y[7]) == 0.0
        assert comp.wire_ratio() == bits / 32.0


def test_make_compressor_validation():
    assert make_compressor("none") is None
    assert isinstance(make_compressor("topk", ratio=0.5), TopK)
    with pytest.raises(ValueError):
        make_compressor("gzip")
    with pytest.raises(ValueError):
        make_compressor("topk", ratio=0.0)
    with pytest.raises(ValueError):
        make_compressor("qsgd", bits=0)


def test_participation_mask_fixed_size():
    seen = set()
    for s in range(20):
        m = participation_mask(jax.random.PRNGKey(s), 8, 3)
        assert m.shape == (8,) and float(m.sum()) == 3.0
        seen.add(tuple(np.flatnonzero(np.asarray(m))))
    assert len(seen) > 5        # actually random across rounds


# ------------------------ refactor guard (satellite) ------------------------

@pytest.mark.parametrize("engine", ["vmap", "map", "shard_map"])
def test_default_spec_bitwise_identical_to_pre_refactor(engine,
                                                        fresh_buffers):
    """participation=1.0, compressor='none' routes through the exact seed
    code path: the engine builds the legacy 5-arg round_step (not the
    pipeline variant) and its jaxpr is IDENTICAL to a directly-built
    pre-pipeline round_step — same program, hence bit-for-bit rounds.
    (The runtime check is ULP-tolerance: two separately-jitted copies of
    one jaxpr may differ by 1 ULP in XLA:CPU instruction scheduling.)"""
    from repro.api import get_engine

    spec = _spec(engine=engine)
    assert not spec.has_pipeline()
    explicit = spec.replace(participation=1.0, compressor="none")
    assert explicit.engine_key() == spec.engine_key()
    assert not explicit.has_pipeline()

    state = init_state(spec, init_linear(DIM))

    engine_fn = get_engine(engine)(spec)
    assert engine_fn.__name__ == "round_step"       # not round_step_pipeline
    if engine == "shard_map":
        from jax.sharding import Mesh
        from repro.api.engines import _n_client_shards
        from repro.core.fl_shard_map import make_shard_map_round
        # the same client mesh the engine derives (the process may run with
        # a forced multi-device host platform, e.g. after importing dryrun)
        n_shards = _n_client_shards(C, len(jax.devices()))
        mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("client",))
        rs = make_shard_map_round(logreg_loss, sgd(0.2),
                                  spec.fl_config(vmap_clients=True), mesh)
    else:
        rs = make_round_step(logreg_loss, sgd(0.2),
                             spec.fl_config(vmap_clients=(engine == "vmap")))
    _, sub = jax.random.split(state.key)
    sig = jnp.asarray(spec.resolved_sigmas(), jnp.float32)
    # run_round donates state's buffers, so the reference call gets copies
    args = (fresh_buffers(state.params), fresh_buffers(state.opt_state),
            _batch(), sub, sig)
    assert str(jax.make_jaxpr(engine_fn)(*args)) == \
        str(jax.make_jaxpr(rs)(*args))

    nxt, _ = run_round(spec, state, _batch(), check_budgets=False)
    want_p, _, _ = jax.jit(rs)(*args)
    for a, b in zip(jax.tree.leaves(nxt.params), jax.tree.leaves(want_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-8)


# ---------------------------- engine parity ---------------------------------

@pytest.mark.parametrize("engine", ["map", "shard_map"])
@pytest.mark.parametrize("name,kw", PIPELINE_SETTINGS,
                         ids=[n for n, _ in PIPELINE_SETTINGS])
def test_engine_parity_under_pipeline(engine, name, kw):
    """vmap / map / shard_map run the identical pipeline protocol: same
    participant sets, same compressor streams, matching params + residual
    (atol 1e-5) for every compressor x participation setting."""
    ref_state, ref_recs = _run(_spec(engine="vmap", **kw))
    got_state, got_recs = _run(_spec(engine=engine, **kw))
    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(got_state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    if ref_state.residual is not None:
        np.testing.assert_allclose(np.asarray(ref_state.residual),
                                   np.asarray(got_state.residual),
                                   rtol=1e-5, atol=1e-5)
    for ra, rb in zip(ref_recs, got_recs):
        assert rb["loss"] == pytest.approx(ra["loss"], rel=1e-4)
        assert rb["participants"] == ra["participants"]
        assert rb["max_epsilon"] == pytest.approx(ra["max_epsilon"])


# ---------------------------- pipeline semantics ----------------------------

def test_full_average_sync_survives_compression():
    """Whatever the codec drops, every client ends the round on the same
    global model (Eq. 7b still broadcasts one average)."""
    for name, kw in PIPELINE_SETTINGS:
        state, _ = _run(_spec(**kw), rounds=1)
        w = np.asarray(state.params["w"])
        for c in range(1, C):
            np.testing.assert_allclose(w[0], w[c], rtol=1e-6,
                                       err_msg=f"setting {name}")


def test_error_feedback_residual_carries_dropped_mass():
    """One full-participation topk round: residual == (delta + 0) - sent,
    i.e. exactly the coordinates the codec dropped; and it is non-zero for
    an aggressive ratio."""
    spec = _spec(compressor="topk", compression_ratio=0.1)
    state0 = init_state(spec, init_linear(DIM))
    assert state0.residual is not None
    np.testing.assert_array_equal(np.asarray(state0.residual), 0.0)
    state1, _ = run_round(spec, state0, _batch(), check_budgets=False)
    res = np.asarray(state1.residual)
    assert res.shape == state0.residual.shape
    assert (np.abs(res) > 0).any()
    # round 2 re-sends the residual: with ratio 1.0 nothing is dropped
    dense = _spec(compressor="topk", compression_ratio=1.0)
    sdense, _ = run_round(dense, init_state(dense, init_linear(DIM)),
                          _batch(), check_budgets=False)
    np.testing.assert_allclose(np.asarray(sdense.residual), 0.0, atol=1e-7)


def test_nonparticipants_spend_no_privacy():
    """Default (conservative) ledger: realized participants pay the full
    Lemma-2 per-step rho; non-participants pay nothing."""
    spec = _spec(participation=1)         # exactly one client per round
    state, recs = _run(spec, rounds=3)
    assert all(r["participants"] == 1.0 for r in recs)
    # 3 rounds, 1 participant each: at most 3 clients have nonzero rho
    assert (state.rho > 0).sum() <= 3
    from repro.core.privacy import gaussian_zcdp, grad_sensitivity
    per_round = TAU * gaussian_zcdp(grad_sensitivity(1.0, B), 0.5)
    assert state.rho.sum() == pytest.approx(3 * per_round, rel=1e-12)


def test_participation_amplification_strictly_tightens_epsilon():
    """Opted-in amplification: same rounds, same sigmas, q < 1 gives
    strictly lower max_epsilon than q = 1 (fewer realized steps AND the
    q-amplified per-step rho)."""
    _, recs_full = _run(_spec(), rounds=3)
    _, recs_half = _run(_spec(participation=0.5,
                              amplify_participation=True), rounds=3)
    assert recs_half[-1]["max_epsilon"] < recs_full[-1]["max_epsilon"]


def test_amplified_ledger_is_opt_in():
    """amplify_participation=True divides the realized per-step charge by
    exactly 1/q vs the sound default ledger — accounting-only toggle, same
    engine key (no recompile)."""
    conservative = _spec(participation=1)
    amplified = conservative.replace(amplify_participation=True)
    assert amplified.engine_key() == conservative.engine_key()
    assert conservative.accounting_q() == 1.0
    assert amplified.accounting_q() == pytest.approx(1 / C)
    s_con, _ = _run(conservative, rounds=3)
    s_amp, _ = _run(amplified, rounds=3)
    # same seed -> same participant draw; ledgers differ exactly by q
    np.testing.assert_allclose(s_amp.rho, s_con.rho / C, rtol=1e-12)
    assert s_con.rho.sum() > s_amp.rho.sum()


def test_round_cost_scales_with_pipeline():
    base = _spec()
    assert base.comm_scale() == 1.0
    assert base.round_cost() == pytest.approx(100.0 + TAU)
    s = _spec(participation=0.5, compressor="topk", compression_ratio=0.25)
    assert s.wire_ratio() == 0.25
    assert s.comm_scale() == pytest.approx(0.125)
    assert s.round_cost() == pytest.approx(100.0 * 0.125 + TAU)
    q = _spec(compressor="qsgd", compression_bits=8)
    assert q.comm_scale() == pytest.approx(0.25)
    # run_round charges the scaled cost
    state, recs = _run(s, rounds=2)
    assert state.resource_spent == pytest.approx(2 * s.round_cost())


def test_budget_driven_train_does_more_rounds_when_compressed():
    """Under the same C_th, the compressed/subsampled federation affords
    strictly more rounds than the dense one (the whole point of Eq. 8)."""
    def sampler(m, tau, rng):
        return {"x": rng.normal(size=(tau, B, DIM)).astype(np.float32),
                "y": rng.integers(0, 2, size=(tau, B)).astype(np.int32)}

    c_th = 5 * (100.0 + TAU)          # 5 dense rounds
    dense = _spec(c_th=c_th, eps_th=1e9)
    sd, outd = train(dense, init_state(dense, init_linear(DIM)), sampler,
                     max_rounds=100)
    comp = _spec(c_th=c_th, eps_th=1e9, participation=0.5,
                 compressor="topk", compression_ratio=0.25)
    sc, outc = train(comp, init_state(comp, init_linear(DIM)), sampler,
                     max_rounds=100)
    assert outd["rounds"] == 5
    assert outc["rounds"] > outd["rounds"]
    assert sc.resource_spent <= c_th


def test_spec_pipeline_validation():
    with pytest.raises(ValueError):
        _spec(compressor="gzip")
    with pytest.raises(ValueError):
        _spec(participation=0.0)
    with pytest.raises(ValueError):
        _spec(participation=C + 1)
    with pytest.raises(ValueError):
        _spec(compression_ratio=1.5)
    with pytest.raises(ValueError):
        _spec(participation=0.5, topology="local_only")
    # pipeline knobs are part of the engine key; budget edits are not
    s = _spec(compressor="topk")
    assert s.engine_key() != _spec().engine_key()
    assert s.replace(eps_th=3.0).engine_key() == s.engine_key()
    # the participant COUNT is a runtime operand (the mask): q sweeps at a
    # fixed has_pipeline() reuse one compiled round
    q = _spec(participation=0.5)
    assert q.replace(participation=0.75).engine_key() == q.engine_key()
    assert q.engine_key() != _spec().engine_key()   # pipeline vs seed path


# ------------------- proportional X_m (satellite) ---------------------------

def test_federated_batch_sizes_proportional():
    from repro.data import adult_like, split_dirichlet
    fed = split_dirichlet(adult_like(n=2000, dim=6, seed=0), 5, alpha=0.3,
                          seed=0)
    uniform = fed.batch_sizes(16)
    assert uniform == [16] * 5
    prop = fed.batch_sizes(16, proportional=True)
    assert len(prop) == 5 and all(1 <= x <= 16 for x in prop)
    # capped at the total budget (big clients saturate at batch_size),
    # ordered like the client sizes up to the cap
    assert sum(prop) <= 16 * 5
    sizes = np.asarray([c.n_train for c in fed.clients])
    assert prop[int(np.argmax(sizes))] == max(prop)
    assert prop != uniform


def test_federated_batch_sizes_cap_is_enforced():
    """Satellite pin: the X_m <= executed-batch cap lives INSIDE
    batch_sizes, not in callers. A client owning almost all the data would
    proportionally claim ~C*batch_size — an X_m above the sampled batch
    claims a 2G/X_m sensitivity smaller than the executed mechanism's,
    so batch_sizes must clamp it to batch_size."""
    from repro.data import adult_like, split_dirichlet
    # extreme skew: near-degenerate Dirichlet gives one dominant client
    fed = split_dirichlet(adult_like(n=4000, dim=6, seed=1), 4, alpha=0.02,
                          seed=3)
    sizes = [c.n_train for c in fed.clients]
    assert max(sizes) > sum(sizes) // 2          # the skew is real
    prop = fed.batch_sizes(8, proportional=True)
    uncapped = round(8 * 4 * max(sizes) / sum(sizes))
    assert uncapped > 8                          # cap actually binds
    assert max(prop) == 8                        # ...and is enforced
    assert all(1 <= x <= 8 for x in prop)


# ------------------- CI smoke leg (REPRO_SMOKE_COMPRESSOR) ------------------

@pytest.mark.skipif(not os.environ.get("REPRO_SMOKE_COMPRESSOR"),
                    reason="set REPRO_SMOKE_COMPRESSOR=topk|randk|qsgd to "
                           "smoke the compressed pipeline in this env")
def test_env_selected_compressor_smoke():
    """CI's oracle-only leg sets REPRO_SMOKE_COMPRESSOR so the compressed
    round (incl. the quantize_decompress kernel path for qsgd) is exercised
    on whatever kernel backend this environment resolves."""
    name = os.environ["REPRO_SMOKE_COMPRESSOR"]
    state, recs = _run(_spec(compressor=name, participation=0.5), rounds=2)
    assert np.isfinite(recs[-1]["loss"])
    assert state.residual is not None
    w = np.asarray(state.params["w"])
    for c in range(1, C):
        np.testing.assert_allclose(w[0], w[c], rtol=1e-6)

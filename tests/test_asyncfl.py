"""Buffered-async federation tests (repro.asyncfl).

The two load-bearing pins:

* **Sync-equivalence identity gate** — with ``buffer_size == n_clients``,
  a zero-spread latency model and ``staleness_alpha=0``, the async engine
  must be bit-for-bit the sync vmap engine on global params, optimizer
  state, the rho ledger and resource_spent, across dense / partial
  participation / top-k / QSGD specs.
* **Dispatch-ledger soundness** — the dispatched privacy view
  (``fl.rho + pending_rho``) equals the hand-computed Lemma-2 composition
  of every dispatch ever issued, and therefore can never under-count while
  uploads are still in flight.
"""
import os

import jax
import numpy as np
import pytest

from repro.api import FederationSpec, init_state, run_round
from repro.api.state import round_batch, round_rho_charges
from repro.asyncfl import (
    AsyncState,
    EventView,
    HeteroLatency,
    LognormalLatency,
    UniformLatency,
    async_accountant_view,
    async_eval_params,
    async_flush_cost,
    dispatched_epsilon,
    dispatched_rho,
    earliest_arrivals,
    exceeds_async_budgets,
    flushes_within_budgets,
    init_async_state,
    latency_profile,
    load_async_state,
    polynomial_staleness,
    run_async_cycle,
    save_async_state,
    sync_round_duration,
    train_async,
)
from repro.core.privacy import (
    PrivacyAccountant,
    gaussian_zcdp,
    grad_sensitivity,
    zcdp_to_dp,
)
from repro.models.linear import init_linear, logreg_loss
from repro.optim import sgd

C, TAU, DIM, B = 4, 3, 8, 4

# every dispatch takes exactly 1.1 simulated seconds: the degenerate clock
# of the identity gate (all C uploads arrive together, the flush is a
# barrier)
FLAT_CLOCK = UniformLatency(0, compute=(1.0, 1.0), upload=(0.1, 0.1))


def _spec(engine="async_buffered", **kw):
    base = dict(n_clients=C, tau=TAU, loss_fn=logreg_loss, optimizer=sgd(0.2),
                clip_norm=1.0, dp=True, sigmas=(0.5,) * C,
                batch_sizes=(B,) * C, engine=engine)
    base.update(kw)
    return FederationSpec(**base)


def _sampler(m, tau, rng):
    return {"x": rng.normal(size=(tau, B, DIM)).astype(np.float32),
            "y": rng.integers(0, 2, size=(tau, B)).astype(np.int32)}


def _fixed_sampler(m, tau, rng):
    """rng-free sampler (pure in the client id): resume tests replay the
    exact data stream without checkpointing a numpy Generator."""
    r = np.random.default_rng((7, int(m)))
    return {"x": r.normal(size=(tau, B, DIM)).astype(np.float32),
            "y": r.integers(0, 2, size=(tau, B)).astype(np.int32)}


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# the identity gate: degenerate async == sync vmap, bit for bit
# ---------------------------------------------------------------------------

GATE_SETTINGS = [
    ("dense", dict()),
    ("q50", dict(participation=0.5)),
    ("topk25", dict(compressor="topk", compression_ratio=0.25)),
    ("qsgd4", dict(compressor="qsgd", compression_bits=4)),
]


@pytest.mark.parametrize("name,extra", GATE_SETTINGS,
                         ids=[n for n, _ in GATE_SETTINGS])
def test_sync_identity_gate(name, extra):
    """B == C + zero latency spread + alpha=0 reduces the buffered-async
    engine to the sync barrier: global params, optimizer state, the rho
    ledger and resource_spent match ``run_round`` bit for bit, round for
    round, under dense, partial-participation and compressed specs."""
    ss = _spec("vmap", **extra)
    sa = _spec("async_buffered", **extra)
    rng_s, rng_a = np.random.default_rng(0), np.random.default_rng(0)
    st_s = init_state(ss, init_linear(DIM))
    st_a = init_async_state(sa, init_linear(DIM), _sampler, rng=rng_a,
                            latency_model=FLAT_CLOCK)
    for r in range(4):
        st_s, _ = run_round(ss, st_s, round_batch(ss, _sampler, rng_s),
                            check_budgets=False)
        st_a, rec = run_async_cycle(sa, st_a, _sampler, rng_a,
                                    latency_model=FLAT_CLOCK,
                                    check_budgets=False)
        _leaves_equal(jax.tree.map(lambda x: x[0], st_s.params),
                      st_a.global_params)
        _leaves_equal(jax.tree.map(lambda x: x[0], st_s.opt_state),
                      st_a.global_opt)
        np.testing.assert_array_equal(st_s.rho, st_a.fl.rho)
        assert st_s.resource_spent == st_a.fl.resource_spent
        assert rec["staleness_max"] == 0.0


def test_degenerate_matches_train_eval_model():
    """async_eval_params serves the global model (already collapsed)."""
    spec = _spec()
    st = init_async_state(spec, init_linear(DIM), _sampler,
                          rng=np.random.default_rng(0),
                          latency_model=FLAT_CLOCK)
    _leaves_equal(async_eval_params(spec, st), st.global_params)


# ---------------------------------------------------------------------------
# dispatch-ledger soundness (the staleness-aware accounting pin)
# ---------------------------------------------------------------------------

def test_dispatch_ledger_soundness():
    """The dispatched view equals the hand-computed Lemma-2 composition of
    every dispatch ever issued — computed independently of the runtime from
    first principles (tau * gaussian_zcdp(sens_m, sigma_m) per dispatch) —
    and the landed ledger lags it by exactly the in-flight uploads. A
    budget probe reading the dispatched view therefore can never be
    under-counted by a straggler."""
    spec = _spec(buffer_size=2, eps_th=1e9, c_th=1e9)
    lat = LognormalLatency(3, median=1.0, sigma=0.9)
    rng = np.random.default_rng(0)
    st = init_async_state(spec, init_linear(DIM), _sampler, rng=rng,
                          latency_model=lat)
    # hand Lemma-2: per-dispatch rho of client m, from the paper's
    # formulas only (never round_rho_charges)
    per_dispatch = np.asarray(
        [TAU * gaussian_zcdp(grad_sensitivity(spec.clip_norm, B), 0.5)
         for _ in range(C)], np.float64)
    dispatches = np.ones(C)            # generation 0 dispatched everyone
    arrived = np.zeros(C)
    for _ in range(6):
        np.testing.assert_allclose(dispatched_rho(st),
                                   dispatches * per_dispatch, rtol=1e-12)
        np.testing.assert_allclose(st.fl.rho, arrived * per_dispatch,
                                   rtol=1e-12)
        # soundness: dispatched >= landed, gap is exactly the in-flight set
        assert np.all(st.pending_rho >= 0.0)
        assert np.all(dispatched_rho(st) >= st.fl.rho)
        in_flight = dispatches - arrived
        np.testing.assert_allclose(st.pending_rho,
                                   in_flight * per_dispatch, rtol=1e-12)
        view = EventView(st.arrival_time, st.slot_seq, st.next_seq, st.clock)
        idx, _, _, _ = view.copy().pop(2, lat)
        st, _ = run_async_cycle(spec, st, _sampler, rng, latency_model=lat,
                                check_budgets=False)
        arrived[idx] += 1
        dispatches[idx] += 1
    # the accountant view restores the same split
    acc = async_accountant_view(spec, st)
    for m in range(C):
        assert acc.rho(m) == pytest.approx(float(dispatched_rho(st)[m]))
        assert acc.pending_rho(m) == pytest.approx(float(st.pending_rho[m]))
        assert acc.landed_rho(m) == pytest.approx(float(st.fl.rho[m]))


def test_accountant_charge_at_dispatch():
    """PrivacyAccountant dispatch/arrival split: pre-charge shows up in rho
    immediately, arrival moves pending to landed without changing totals."""
    acc = PrivacyAccountant(clip_norm=1.0, delta=1e-5)
    acc.register_client(0, 16, 0.7)
    inc = TAU * gaussian_zcdp(grad_sensitivity(1.0, 16), 0.7)
    acc.charge_at_dispatch(TAU, [0])
    assert acc.rho(0) == pytest.approx(inc)
    assert acc.pending_rho(0) == pytest.approx(inc)
    assert acc.landed_rho(0) == pytest.approx(0.0)
    eps_before = acc.epsilon(0)
    acc.note_arrival([0])
    assert acc.rho(0) == pytest.approx(inc)          # totals unchanged
    assert acc.pending_rho(0) == 0.0
    assert acc.landed_rho(0) == pytest.approx(inc)
    assert acc.epsilon(0) == eps_before
    with pytest.raises(ValueError):
        acc.charge_at_dispatch(-1, [0])


def test_budget_probe_counts_in_flight():
    """The privacy probe trips on dispatched (not landed) rho: a state
    whose pending charges already exhaust the budget refuses the next
    flush even though nothing has landed."""
    spec = _spec(buffer_size=2, eps_th=1e9, c_th=1e9)
    st = init_async_state(spec, init_linear(DIM), _sampler,
                          rng=np.random.default_rng(0),
                          latency_model=FLAT_CLOCK)
    assert float(np.max(st.fl.rho)) == 0.0          # nothing landed
    eps_now = dispatched_epsilon(spec, st)
    assert eps_now > 0.0
    tight = _spec(buffer_size=2, eps_th=eps_now * 1.0001, c_th=1e9)
    assert exceeds_async_budgets(tight, st) == "privacy"
    n, why = flushes_within_budgets(tight, st, 10)
    assert (n, why) == (0, "privacy")
    with pytest.raises(Exception):
        run_async_cycle(tight, st, _sampler, np.random.default_rng(1),
                        latency_model=FLAT_CLOCK)


def test_flush_cost_degenerates_to_round_cost():
    spec = _spec()
    assert async_flush_cost(spec, C, spec.participants_per_round()) == \
        spec.round_cost()
    half = _spec(buffer_size=2)
    assert async_flush_cost(half, 2, 2) < half.round_cost()


# ---------------------------------------------------------------------------
# clocks: determinism, hetero composition, event loop
# ---------------------------------------------------------------------------

def test_latency_determinism():
    """Draws depend only on (seed, vid, seq): fresh instances replay the
    stream, different seqs re-randomize, zero spread is exact."""
    vids, seqs = np.arange(6), np.arange(6) + 10
    a = UniformLatency(5)(vids, seqs)
    b = UniformLatency(5)(vids, seqs)
    np.testing.assert_array_equal(a, b)
    c = UniformLatency(5)(vids, seqs + 1)
    assert not np.array_equal(a, c)
    flat = FLAT_CLOCK(vids, seqs)
    np.testing.assert_allclose(flat, np.full(6, 1.1), rtol=1e-12)
    log = LognormalLatency(5)(vids, seqs)
    np.testing.assert_array_equal(log, LognormalLatency(5)(vids, seqs))
    assert np.all(log > 0)


def test_latency_profile_factory():
    assert isinstance(latency_profile("uniform", seed=1), UniformLatency)
    assert isinstance(latency_profile("lognormal", scale=2.0),
                      LognormalLatency)
    h = latency_profile("hetero", fleet=8, scale=0.5)
    assert isinstance(h, HeteroLatency) and h.fleet == 8
    with pytest.raises(ValueError):
        latency_profile("nope")


def test_hetero_cohort_latency_composition():
    """The pinned composition: HeteroLatency shares HeterogeneousCohort's
    availability rates, so high-unreliability vids have strictly higher
    mean simulated latency AND land strictly fewer buffer arrivals."""
    from repro.population.samplers import HeterogeneousCohort
    k = 16
    cohort = HeterogeneousCohort(seed=11, availability=(2.0, 2.0))
    lat = HeteroLatency(11, fleet=k, cohort=cohort, jitter=0.1)
    rates = lat.rates()
    np.testing.assert_array_equal(rates, cohort.rates(k))
    flaky = np.argsort(rates)[: k // 4]         # least available quartile
    solid = np.argsort(rates)[-k // 4:]
    assert float(lat.mean_latency(flaky).mean()) > \
        float(lat.mean_latency(solid).mean())
    # strict monotonicity vid-by-vid: lower rate -> higher mean
    order = np.argsort(rates)
    means = lat.mean_latency(order)
    assert np.all(np.diff(means) <= 0)
    assert means[0] > means[-1]
    # arrival rates: drive the pure event loop (no training needed) and
    # count how often each slot makes a B-of-K buffer
    view = EventView(lat(np.arange(k), np.arange(k)),
                     np.arange(k), k, 0.0)
    arrivals = np.zeros(k, np.int64)
    for _ in range(200):
        idx, _, _, _ = view.pop(4, lat)
        arrivals[idx] += 1
    assert arrivals[flaky].mean() < arrivals[solid].mean()
    assert arrivals[flaky].max() < arrivals[solid].min()


def test_event_view_pop_semantics():
    at = np.asarray([3.0, 1.0, 2.0, 1.0])
    seq = np.asarray([0, 3, 2, 1])
    # tie at t=1.0 broken by seq: slot 3 (seq 1) before slot 1 (seq 3)
    np.testing.assert_array_equal(earliest_arrivals(at, seq, 3), [3, 1, 2])
    view = EventView(at, seq, next_seq=4, clock=0.0)
    twin = view.copy()
    idx, t, new_seqs, latency = view.pop(2, FLAT_CLOCK)
    np.testing.assert_array_equal(idx, [3, 1])
    assert t == 1.0 and view.clock == 1.0
    np.testing.assert_array_equal(new_seqs, [4, 5])
    # replacement arrivals rescheduled from the flush time
    np.testing.assert_allclose(view.arrival_time[[3, 1]], t + latency)
    # the copy was untouched
    assert twin.clock == 0.0 and twin.next_seq == 4
    with pytest.raises(ValueError):
        view.pop(5, FLAT_CLOCK)


def test_polynomial_staleness():
    s = np.asarray([0, 1, 3])
    np.testing.assert_array_equal(polynomial_staleness(0.0)(s),
                                  np.ones(3, np.float32))
    w = polynomial_staleness(1.0)(s)
    np.testing.assert_allclose(w, [1.0, 0.5, 0.25], rtol=1e-6)


def test_staleness_observed_with_small_buffer():
    """B < C leaves slow slots training on old versions: the cycle record
    reports nonzero staleness once versions diverge, and alpha > 0 changes
    the aggregate (weights actually applied)."""
    spec = _spec(buffer_size=1, eps_th=1e9, c_th=1e9)
    lat = LognormalLatency(1, sigma=1.0)
    rng = np.random.default_rng(0)
    st = init_async_state(spec, init_linear(DIM), _sampler, rng=rng,
                          latency_model=lat)
    seen = 0.0
    for _ in range(6):
        st, rec = run_async_cycle(spec, st, _sampler, rng,
                                  latency_model=lat, check_budgets=False)
        seen = max(seen, rec["staleness_max"])
    assert seen > 0.0

    def run(alpha):
        sp = _spec(buffer_size=1, staleness_alpha=alpha, eps_th=1e9,
                   c_th=1e9)
        r = np.random.default_rng(0)
        s = init_async_state(sp, init_linear(DIM), _sampler, rng=r,
                             latency_model=lat)
        for _ in range(6):
            s, _ = run_async_cycle(sp, s, _sampler, r, latency_model=lat,
                                   check_budgets=False)
        return np.concatenate([np.asarray(x).ravel()
                               for x in jax.tree.leaves(s.global_params)])

    assert not np.array_equal(run(0.0), run(2.0))


# ---------------------------------------------------------------------------
# driver: budgets, chunking, resume
# ---------------------------------------------------------------------------

def test_train_async_budget_stop():
    spec = _spec(buffer_size=2, eps_th=25.0, c_th=1e9, delta=1e-5)
    rng = np.random.default_rng(0)
    st = init_async_state(spec, init_linear(DIM), _sampler, rng=rng,
                          latency_model=FLAT_CLOCK)
    st, out = train_async(spec, st, _sampler, max_rounds=10_000, rng=rng,
                          latency_model=FLAT_CLOCK)
    assert 0 < out["rounds"] < 10_000
    assert exceeds_async_budgets(spec, st) is not None
    # never exceeded: even the conservative dispatched view stayed inside
    assert dispatched_epsilon(spec, st) <= spec.eps_th
    assert out["sim_seconds"] > 0.0


def test_train_async_chunked_equals_per_cycle():
    """chunk_rounds > 1 (pre-projected schedule + device_put batches) is
    bit-for-bit the per-cycle driver."""
    lat = LognormalLatency(2, sigma=0.8)

    def run(chunk):
        spec = _spec(buffer_size=2, eps_th=1e9, c_th=1e9,
                     compressor="topk", compression_ratio=0.25)
        rng = np.random.default_rng(0)
        st = init_async_state(spec, init_linear(DIM), _sampler, rng=rng,
                              latency_model=lat)
        st, out = train_async(spec, st, _sampler, max_rounds=6, rng=rng,
                              chunk_rounds=chunk, latency_model=lat)
        return st, out

    s1, o1 = run(1)
    s3, o3 = run(3)
    assert o1["rounds"] == o3["rounds"] == 6
    _leaves_equal(s1.global_params, s3.global_params)
    np.testing.assert_array_equal(s1.fl.rho, s3.fl.rho)
    np.testing.assert_array_equal(s1.arrival_time, s3.arrival_time)
    assert s1.clock == s3.clock


def test_save_load_resume_identity(tmp_path):
    """Checkpoint mid-run, restore, continue: identical to the
    uninterrupted run (model, ledgers, schedule, clock)."""
    lat = LognormalLatency(4, sigma=0.7)
    spec = _spec(buffer_size=2, eps_th=1e9, c_th=1e9)
    rng = np.random.default_rng(0)  # _fixed_sampler ignores it

    def fresh():
        return init_async_state(spec, init_linear(DIM), _fixed_sampler,
                                rng=np.random.default_rng(0),
                                latency_model=lat)

    st_a = fresh()
    for _ in range(4):
        st_a, _ = run_async_cycle(spec, st_a, _fixed_sampler, rng,
                                  latency_model=lat, check_budgets=False)
    st_b = fresh()
    for _ in range(2):
        st_b, _ = run_async_cycle(spec, st_b, _fixed_sampler, rng,
                                  latency_model=lat, check_budgets=False)
    save_async_state(str(tmp_path / "ck"), st_b, extra={"tag": 7})
    st_c, extra = load_async_state(str(tmp_path / "ck"), like=fresh())
    assert extra["tag"] == 7
    assert st_c.clock == st_b.clock and st_c.next_seq == st_b.next_seq
    for _ in range(2):
        st_c, _ = run_async_cycle(spec, st_c, _fixed_sampler, rng,
                                  latency_model=lat, check_budgets=False)
    _leaves_equal(st_a.global_params, st_c.global_params)
    _leaves_equal(st_a.global_opt, st_c.global_opt)
    np.testing.assert_array_equal(st_a.fl.rho, st_c.fl.rho)
    np.testing.assert_array_equal(st_a.pending_rho, st_c.pending_rho)
    np.testing.assert_array_equal(st_a.arrival_time, st_c.arrival_time)
    np.testing.assert_array_equal(st_a.slot_version, st_c.slot_version)
    assert st_a.clock == st_c.clock


# ---------------------------------------------------------------------------
# spec / engine seams
# ---------------------------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError, match="buffer_size"):
        _spec("vmap", buffer_size=2)
    with pytest.raises(ValueError, match="staleness_alpha"):
        _spec("vmap", staleness_alpha=0.5)
    with pytest.raises(ValueError, match="buffer_size"):
        _spec(buffer_size=C + 1)
    with pytest.raises(ValueError, match="staleness_alpha"):
        _spec(staleness_alpha=-1.0)
    spec = _spec()                      # defaults to B == n_clients
    assert spec.resolved_buffer_size() == C and spec.is_async()
    assert not _spec("vmap").is_async()
    # buffer size shapes the dispatched program: distinct executor keys
    assert _spec(buffer_size=2).engine_key() != spec.engine_key()


def test_sync_round_fns_refuse_async_specs():
    from repro.api.engines import chunked_round_fn_for, round_fn_for
    spec = _spec()
    with pytest.raises(ValueError, match="async"):
        round_fn_for(spec)
    with pytest.raises(ValueError, match="async"):
        chunked_round_fn_for(spec)
    with pytest.raises(ValueError, match="async"):
        init_async_state(_spec("vmap"), init_linear(DIM), _sampler)


# ---------------------------------------------------------------------------
# async-beats-sync on a heterogeneous fleet (simulated time)
# ---------------------------------------------------------------------------

def test_async_beats_sync_simulated_time():
    """On a straggler fleet, processing the same number of client updates
    takes strictly less simulated time buffered-async (B of K per flush)
    than with a sync barrier (max over all K per round)."""
    k, b, rounds = 8, 2, 10
    lat = HeteroLatency(3, fleet=k, slow_factor=6.0)
    sync_time = sum(sync_round_duration(lat, k, r) for r in range(rounds))
    view = EventView(lat(np.arange(k), np.arange(k)), np.arange(k), k, 0.0)
    flushes = rounds * k // b           # same update count as sync
    for _ in range(flushes):
        view.pop(b, lat)
    assert view.clock < sync_time


# ---------------------------------------------------------------------------
# launch CLI + env profiles
# ---------------------------------------------------------------------------

def test_launch_train_async_cli(tmp_path, capsys):
    from repro.launch.train import main
    save = str(tmp_path / "ckpt")
    rc = main(["--arch", "gemma3-4b", "--smoke", "--rounds", "2",
               "--clients", "4", "--tau", "1", "--batch", "2", "--seq",
               "16", "--async-buffer", "2", "--latency-profile", "hetero",
               "--staleness-alpha", "0.5", "--eps", "1e9", "--cth", "1e9",
               "--save", save])
    assert rc == 0
    out = capsys.readouterr().out
    assert '"buffer_size": 2' in out and '"sim_seconds"' in out
    assert os.path.exists(os.path.join(save, "meta.json"))


def test_launch_train_async_population_rejected():
    from repro.launch.train import main
    with pytest.raises(SystemExit):
        main(["--arch", "gemma3-4b", "--smoke", "--rounds", "1",
              "--population", "100", "--cohort-size", "2",
              "--async-buffer", "2"])


def test_env_profiles():
    from repro.launch.env import (
        ENV_PROFILES,
        _merge_xla_flags,
        apply_env_profile,
        profile_env,
    )
    assert profile_env("none") == {}
    host = profile_env("host", base={})
    assert host["TF_CPP_MIN_LOG_LEVEL"] == "4"
    mesh = profile_env("cpu-mesh", host_devices=4,
                       base={"XLA_FLAGS": "--xla_step_marker_location=0"})
    # user flags win, profile flags append
    assert "--xla_step_marker_location=0" in mesh["XLA_FLAGS"]
    assert "--xla_step_marker_location=1" not in mesh["XLA_FLAGS"]
    assert "--xla_force_host_platform_device_count=4" in mesh["XLA_FLAGS"]
    assert _merge_xla_flags("", ["--a=1"]) == "--a=1"
    with pytest.raises(ValueError):
        profile_env("gpu-mesh")
    with pytest.raises(ValueError):
        profile_env("cpu-mesh", host_devices=0)
    # apply is a no-op for "none" and for already-applied processes
    assert apply_env_profile("none") is False
    assert apply_env_profile(None) is False
    os.environ["REPRO_ENV_PROFILE_APPLIED"] = "1"
    try:
        assert apply_env_profile("host") is False
    finally:
        del os.environ["REPRO_ENV_PROFILE_APPLIED"]
    assert set(ENV_PROFILES) == {"none", "host", "cpu-mesh"}


# ------------------- CI smoke leg (REPRO_SMOKE_ASYNC) -----------------------

@pytest.mark.skipif(not os.environ.get("REPRO_SMOKE_ASYNC"),
                    reason="set REPRO_SMOKE_ASYNC=1 to smoke buffered-async "
                           "federation in this env")
def test_env_async_smoke():
    """CI's async leg: K=8 hetero straggler fleet, B=4 buffer, top-k
    compressed uploads, staleness damping, chunked driver — trains, the
    virtual clock advances monotonically, arrivals skew toward reliable
    devices, and the dispatched ledger stays ahead of the landed one."""
    k = 8
    spec = FederationSpec(
        n_clients=k, tau=TAU, loss_fn=logreg_loss, optimizer=sgd(0.2),
        clip_norm=1.0, dp=True, sigmas=(0.5,) * k, batch_sizes=(B,) * k,
        engine="async_buffered", buffer_size=4, staleness_alpha=0.5,
        compressor="topk", compression_ratio=0.25, eps_th=1e9, c_th=1e9)
    lat = HeteroLatency(0, fleet=k, slow_factor=6.0)
    rng = np.random.default_rng(0)
    st = init_async_state(spec, init_linear(DIM), _sampler, rng=rng,
                          latency_model=lat)
    st, out = train_async(spec, st, _sampler, max_rounds=8, rng=rng,
                          chunk_rounds=4, latency_model=lat)
    assert out["rounds"] == 8
    assert np.isfinite(out["history"][-1]["loss"])
    clocks = [r["sim_seconds"] for r in out["history"]]
    assert all(b >= a for a, b in zip(clocks, clocks[1:]))
    assert st.arrivals.sum() == 8 * 4
    assert np.all(dispatched_rho(st) >= st.fl.rho)
    assert zcdp_to_dp(float(np.max(dispatched_rho(st))),
                      spec.delta) == out["max_epsilon"]

"""Launch-layer tests on tiny in-process meshes (the 512-device production
meshes are exercised by repro.launch.dryrun itself; here we validate the
mesh derivations and the lowering builders end-to-end on 1 device)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.configs import get_arch, get_shape, input_specs, smoke_variant, supports_shape
from repro.configs.shapes import InputShape
from repro.launch.mesh import (
    default_n_clients,
    make_federated_mesh,
    make_serving_mesh,
)
from repro.launch import dryrun as dr


def _mini_mesh():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_federated_mesh_regrouping():
    mesh = _mini_mesh()
    fed = make_federated_mesh(mesh, 1)
    assert fed.axis_names == ("client", "replica", "model")
    assert fed.shape["client"] == 1
    with pytest.raises(ValueError):
        make_federated_mesh(mesh, 3)
    srv = make_serving_mesh(mesh)
    assert srv.axis_names == ("data", "model")


def test_default_n_clients_scales_with_pods():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    assert default_n_clients(Mesh(dev, ("data", "model"))) == 4
    dev3 = np.asarray(jax.devices()[:1]).reshape(1, 1, 1)
    assert default_n_clients(Mesh(dev3, ("pod", "data", "model"))) == 4
    assert default_n_clients(Mesh(dev, ("data", "model")), requested=7) == 7


def test_input_specs_shapes():
    cfg = get_arch("gemma3-4b")
    tr = input_specs(cfg, get_shape("train_4k"), n_clients=4, tau=2)
    assert tr["tokens"].shape == (4, 2, 64, 4096)
    pf = input_specs(cfg, get_shape("prefill_32k"))
    assert pf["tokens"].shape == (32, 32768)
    dc = input_specs(cfg, get_shape("decode_32k"))
    assert dc["tokens"].shape == (128,) and dc["pos"].shape == ()
    vlm = input_specs(get_arch("internvl2-76b"), get_shape("train_4k"),
                      n_clients=4, tau=1)
    assert vlm["prefix"].shape == (4, 1, 64, 256, 8192)


def test_long500k_applicability():
    long = get_shape("long_500k")
    ok, _ = supports_shape(get_arch("rwkv6-1.6b"), long)
    assert ok
    for dense in ("mistral-large-123b", "granite-20b", "musicgen-large",
                  "internvl2-76b", "codeqwen1.5-7b", "phi3.5-moe-42b-a6.6b"):
        ok, why = supports_shape(get_arch(dense), long)
        assert not ok and "skip" in why
    for sub in ("zamba2-7b", "gemma3-4b", "llama4-maverick-400b-a17b"):
        ok, _ = supports_shape(get_arch(sub), long)
        assert ok


def test_lower_train_on_mini_mesh():
    """The full train-lowering builder works on a 1-device mesh with a smoke
    config and a reduced shape (no compile; structure only)."""
    cfg = smoke_variant(get_arch("musicgen-large"))
    shape = InputShape("mini_train", seq_len=16, global_batch=2, kind="train")
    mesh = _mini_mesh()
    lowered, n_params, tokens, n_mb = dr.lower_train(cfg, shape, mesh,
                                                     n_clients=1, tau=2)
    assert n_params > 0 and tokens == 2 * 16 * 2 and n_mb >= 1
    text = lowered.as_text()
    assert "while" in text or "func" in text


def test_lower_decode_on_mini_mesh():
    cfg = smoke_variant(get_arch("zamba2-7b"))
    shape = InputShape("mini_dec", seq_len=32, global_batch=2, kind="decode")
    lowered, n_params, tokens = dr.lower_decode(cfg, shape, _mini_mesh())
    assert tokens == 2
    compiled = lowered.compile()           # tiny: compile for real
    assert compiled.cost_analysis() is not None


def test_lower_prefill_on_mini_mesh():
    cfg = smoke_variant(get_arch("codeqwen1.5-7b"))
    shape = InputShape("mini_pf", seq_len=16, global_batch=2, kind="prefill")
    lowered, _, tokens = dr.lower_prefill(cfg, shape, _mini_mesh())
    assert tokens == 32
    lowered.compile()


def test_auto_microbatches_divides_batch():
    cfg = get_arch("mistral-large-123b")
    shape = get_shape("train_4k")
    n_mb = dr._auto_microbatches(cfg, shape, n_clients=4, replica=4)
    per_client = shape.global_batch // 4
    assert per_client % n_mb == 0
    assert n_mb >= 8      # 88 layers x 12288 wide needs heavy microbatching

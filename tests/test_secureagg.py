"""Host-level secure-aggregation protocol tests (repro.core.secureagg).

Property tests (hypothesis, or the tests/_hypothesis_compat shim) pin the
protocol's load-bearing algebra for ARBITRARY cohorts and dropout sets:

* pairwise masks are antisymmetric and telescope to zero over any cohort;
* the server's masked survivor sum equals the plain fixed-point survivor
  sum EXACTLY — under any dropout subset, any vid numbering, any round;
* quantization (the one lossy step) is bounded by the grid pitch.

Plus the composition the tentpole promises: a secure round over a
:class:`repro.population.HeterogeneousCohort` draw, with the sampler's
mid-round dropouts as the protocol's dropped set.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.secureagg import (
    MODULUS,
    central_rho_scale,
    dropout_correction,
    fp_decode,
    fp_encode,
    masked_update,
    pairwise_mask,
    secure_aggregate,
    unmasked_fixed_point_sum,
    validate_secure,
)
from repro.population import HeterogeneousCohort


def _updates(vids, dim, seed, scale=4.0):
    rng = np.random.default_rng(seed)
    return {int(v): rng.normal(scale=scale, size=dim) for v in vids}


# ---------------------------------------------------------------------------
# fixed-point codec
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), frac_bits=st.integers(1, 24))
def test_fp_codec_roundtrip_error_bounded_by_grid(seed, frac_bits):
    """encode->decode moves a value by at most half the grid pitch
    2^-frac_bits — quantization is the protocol's entire error budget."""
    rng = np.random.default_rng(seed)
    x = rng.normal(scale=10.0, size=64)
    back = fp_decode(fp_encode(x, frac_bits), frac_bits)
    assert np.max(np.abs(back - x)) <= 0.5 / (1 << frac_bits) + 1e-12
    # on-grid values roundtrip exactly
    grid = np.round(x * (1 << frac_bits)) / (1 << frac_bits)
    np.testing.assert_array_equal(
        fp_decode(fp_encode(grid, frac_bits), frac_bits), grid)


def test_validate_secure_bounds():
    validate_secure(1)
    validate_secure(24)
    for bad in (0, 25, -3):
        with pytest.raises(ValueError):
            validate_secure(bad)


# ---------------------------------------------------------------------------
# mask algebra
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), vi=st.integers(0, 500),
       vj=st.integers(0, 500), rnd=st.integers(0, 100))
def test_pairwise_masks_antisymmetric(seed, vi, vj, rnd):
    """m_ij + m_ji == 0 (mod 2^32) for every pair, seed and round — the
    single identity the whole telescoping cancellation rests on; and masks
    are fresh per round."""
    if vi == vj:
        with pytest.raises(ValueError):
            pairwise_mask(seed, vi, vj, rnd, 8)
        return
    a = pairwise_mask(seed, vi, vj, rnd, 8).astype(np.int64)
    b = pairwise_mask(seed, vj, vi, rnd, 8).astype(np.int64)
    np.testing.assert_array_equal((a + b) % MODULUS, 0)
    assert not np.array_equal(a, pairwise_mask(seed, vi, vj, rnd + 1, 8)
                              .astype(np.int64))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(2, 40),
       k=st.integers(2, 12), n_drop=st.integers(0, 10),
       rnd=st.integers(0, 50), dim=st.integers(1, 16))
def test_masked_sum_equals_unmasked_sum_any_cohort_any_dropout(
        seed, m, k, n_drop, rnd, dim):
    """THE protocol identity, quantified over arbitrary vid subsets of an
    M-population, arbitrary dropout subsets (all but one survivor), and
    arbitrary rounds: the server's masked survivor sum — dropout-recovery
    correction included — equals the plain fixed-point survivor sum with
    ZERO tolerance."""
    rng = np.random.default_rng((seed, 0xC0))
    k = min(k, m)
    cohort = np.sort(rng.choice(m, size=k, replace=False))
    dropped = rng.permutation(cohort)[:min(n_drop, k - 1)]
    updates = _updates(cohort, dim, seed)
    survivors = [v for v in cohort if v not in set(int(d) for d in dropped)]
    got = secure_aggregate(updates, cohort, seed, rnd, dropped=dropped)
    want = unmasked_fixed_point_sum(updates, survivors)
    np.testing.assert_array_equal(got, want)
    # ...and the decoded sum is the true float sum up to k quantizations
    true = np.sum([updates[v] for v in survivors], axis=0)
    assert np.max(np.abs(got - true)) <= len(survivors) * 0.5 / (1 << 16)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 10),
       dim=st.integers(1, 12))
def test_dropout_correction_is_exactly_the_mask_residue(seed, k, dim):
    """The reconstructed correction equals, term by term, the pair masks
    the survivors carried against the dropped — and vanishes when nothing
    dropped."""
    cohort = list(range(k))
    dropped = cohort[: k // 2]
    survivors = cohort[k // 2:]
    corr = dropout_correction(survivors, dropped, seed, 0, dim)
    want = np.zeros(dim, np.int64)
    for i in survivors:
        for j in dropped:
            want = (want + pairwise_mask(seed, i, j, 0, dim)) % MODULUS
    np.testing.assert_array_equal(corr.astype(np.int64), want)
    np.testing.assert_array_equal(
        dropout_correction(survivors, (), seed, 0, dim), 0)


def test_masked_upload_hides_the_plaintext():
    """A single client's upload with >= 1 partner is mask-dominated: it
    differs from the plain encoding, changes when the partner set changes,
    and two rounds' uploads of the SAME update are unrelated."""
    u = np.full((64,), 0.25)
    plain = fp_encode(u)
    a = masked_update(u, 0, (0, 1, 2), seed=7, round_idx=0)
    b = masked_update(u, 0, (0, 1, 3), seed=7, round_idx=0)
    c = masked_update(u, 0, (0, 1, 2), seed=7, round_idx=1)
    assert not np.array_equal(a, plain)
    assert not np.array_equal(a, b)
    assert not np.array_equal(a, c)


def test_secure_aggregate_validates_membership():
    updates = _updates(range(4), 8, 0)
    with pytest.raises(ValueError):          # dropped outside the cohort
        secure_aggregate(updates, range(4), 0, 0, dropped=(9,))
    with pytest.raises(ValueError):          # everyone dropped
        secure_aggregate(updates, range(4), 0, 0, dropped=range(4))
    with pytest.raises(ValueError):
        central_rho_scale(0)
    assert central_rho_scale(8) == pytest.approx(1 / 8)


# ---------------------------------------------------------------------------
# composition with the PR-5 heterogeneous-fleet model
# ---------------------------------------------------------------------------

def test_secure_round_over_heterogeneous_cohort_draw():
    """End-to-end fleet round: HeterogeneousCohort picks the round's K
    vids under Beta-availability; its dropout model (clients lost
    mid-round) supplies the protocol's dropped set; the masked sum equals
    the unmasked survivor sum exactly, every round."""
    m, k, dim, seed = 40, 8, 12, 3
    sampler = HeterogeneousCohort(seed=seed, dropout=0.3)
    rng = np.random.default_rng(seed)
    saw_dropout = False
    for rnd in range(6):
        cohort = sampler(rnd, m, k)
        assert len(cohort) == k
        # the sampler backfills dropped slots to keep K static; re-derive
        # a mid-round dropout set over the realized cohort for the uplink
        # loss the protocol must absorb
        dropped = cohort[rng.random(k) < 0.3][: k - 1]
        saw_dropout = saw_dropout or len(dropped) > 0
        updates = _updates(cohort, dim, (seed, rnd))
        got = secure_aggregate(updates, cohort, seed, rnd, dropped=dropped)
        survivors = [v for v in cohort
                     if v not in set(int(d) for d in dropped)]
        np.testing.assert_array_equal(
            got, unmasked_fixed_point_sum(updates, survivors))
    assert saw_dropout                       # the recovery path really ran

"""repro.population tests: cohort-path identity vs the dense participation
path (M == C, engine x compressor matrix), ClientStore sparse-residual
checkpoint-resume identity, population accounting (K/M monotonicity,
conditional-ledger soundness), cohort samplers, and the device-memory
boundedness gate (block bytes independent of M)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    FederationSpec,
    init_state,
    round_batch,
    round_rho_charges,
    run_round,
    train,
)
from repro.core.privacy import (
    composed_subsampling_q,
    gaussian_zcdp,
    grad_sensitivity,
    zcdp_to_dp,
)
from repro.data import adult_like, split_iid
from repro.models.linear import init_linear, logreg_loss
from repro.optim import sgd
from repro.population import (
    ClientStore,
    HeterogeneousCohort,
    UniformCohort,
    device_block_bytes,
    exceeds_population_budgets,
    init_population_state,
    init_resident_cache,
    load_population_state,
    peek_population_epsilon,
    population_from_federated,
    population_from_sampler,
    run_cohort_round,
    run_cohort_rounds,
    run_resident_rounds,
    save_population_state,
    synthetic_population,
    train_population,
)

C, TAU, DIM, B = 4, 2, 8, 4
OPT = sgd(0.2)          # one optimizer instance -> engine caches shared


def _spec(**kw):
    base = dict(n_clients=C, tau=TAU, loss_fn=logreg_loss, optimizer=OPT,
                clip_norm=1.0, dp=True, sigmas=(0.5,) * C,
                batch_sizes=(B,) * C, kernel_backend="ref")
    base.update(kw)
    return FederationSpec(**base)


def _pop_spec(population, n_clients=C, **kw):
    return _spec(n_clients=n_clients, population=population,
                 cohort_size=n_clients,
                 sigmas=(0.5,) * n_clients, batch_sizes=(B,) * n_clients,
                 **kw)


@pytest.fixture(scope="module")
def fed():
    return split_iid(adult_like(n=400, dim=DIM, seed=0), C)


# ---------------------------- spec surface ----------------------------------

def test_population_spec_validation():
    s = _pop_spec(1000)
    assert s.is_population() and s.cohort_size == C
    assert s.cohort_fraction() == pytest.approx(C / 1000)
    assert not _spec().is_population() and _spec().cohort_fraction() == 1.0
    with pytest.raises(ValueError):        # cohort_size without population
        _spec(cohort_size=C)
    with pytest.raises(ValueError):        # cohort must fit the population
        _spec(population=C - 1)
    with pytest.raises(ValueError):        # K is the device block
        _spec(population=100, cohort_size=C + 1)
    with pytest.raises(ValueError):        # slots host changing clients
        _spec(population=100, sigmas=(0.5, 0.5, 0.5, 0.6))
    with pytest.raises(ValueError):
        _spec(population=100, batch_sizes=(B, B, B, B + 1))
    with pytest.raises(ValueError):        # cohorts need the re-broadcast
        _spec(population=100, topology="local_only")


def test_population_not_in_engine_key():
    """Sweeping M at fixed K must reuse one compiled round (and the M == C
    identity gate runs literally the same executable)."""
    assert _pop_spec(100).engine_key() == _pop_spec(100_000).engine_key()
    assert _pop_spec(100).engine_key() == _spec().engine_key()
    assert _pop_spec(100).ledger_key() == _spec().ledger_key()


def test_accounting_q_composes_cohort_and_participation():
    assert _pop_spec(1000).accounting_q() == 1.0     # sound default
    amp = _pop_spec(1000, amplify_participation=True)
    assert amp.accounting_q() == pytest.approx(C / 1000)
    both = _pop_spec(1000, participation=0.5, amplify_participation=True)
    assert both.accounting_q() == pytest.approx((C / 1000) * 0.5)
    assert composed_subsampling_q(0.5, 0.25) == pytest.approx(0.125)
    assert composed_subsampling_q() == 1.0
    with pytest.raises(ValueError):
        composed_subsampling_q(0.5, 1.5)
    with pytest.raises(ValueError):
        composed_subsampling_q(0.0)


# ---------------------------- populations -----------------------------------

def test_synthetic_population_is_lazy_and_deterministic():
    pop = synthetic_population(1_000_000, dim=DIM, batch_size=B, alpha=0.3,
                               seed=7)
    a = pop.sampler(123_456, TAU, np.random.default_rng(5))
    b = pop.sampler(123_456, TAU, np.random.default_rng(5))
    np.testing.assert_array_equal(a["x"], b["x"])
    np.testing.assert_array_equal(a["y"], b["y"])
    assert a["x"].shape == (TAU, B, DIM) and a["x"].dtype == np.float32
    assert set(np.unique(a["y"])) <= {0, 1}
    # unit-ball features (paper §4), different clients differ
    assert float(np.linalg.norm(a["x"], axis=-1).max()) <= 1.0 + 1e-5
    other = pop.sampler(7, TAU, np.random.default_rng(5))
    assert np.abs(a["x"] - other["x"]).max() > 0


def test_synthetic_population_label_skew_scales_with_alpha():
    """Small alpha -> most clients dominated by one class; large alpha ->
    balanced. Measured over per-client label rates."""
    def dominance(alpha):
        pop = synthetic_population(500, dim=4, batch_size=64, alpha=alpha,
                                   seed=0)
        rng = np.random.default_rng(0)
        rates = [pop.sampler(v, 1, rng)["y"].mean() for v in range(40)]
        return np.mean([max(r, 1 - r) for r in rates])

    assert dominance(0.05) > 0.9
    assert dominance(100.0) < 0.65


# ---------------------------- cohort samplers -------------------------------

def test_uniform_cohort_sorted_unique_deterministic():
    s = UniformCohort(seed=3)
    a = s(5, 10_000, 16)
    assert a.shape == (16,) and np.all(np.diff(a) > 0)   # sorted, unique
    np.testing.assert_array_equal(a, s(5, 10_000, 16))   # stateless replay
    assert np.any(a != s(6, 10_000, 16))                 # varies per round
    # full cohort is canonical arange (the identity-gate anchor)
    np.testing.assert_array_equal(s(0, C, C), np.arange(C))
    # rejection path (K << M) stays within range and exact-size
    big = s(0, 5_000_000, 8)
    assert big.shape == (8,) and big.min() >= 0 and big.max() < 5_000_000
    with pytest.raises(ValueError):
        s(0, 10, 11)


def test_heterogeneous_cohort_availability_bias_and_dropout():
    model = HeterogeneousCohort(seed=1, availability=(8.0, 2.0), dropout=0.2)
    m = 2_000
    counts = np.zeros(m)
    for r in range(150):
        cohort = model(r, m, 32)
        assert cohort.shape == (32,) and np.unique(cohort).size == 32
        counts[cohort] += 1
    rates = model.rates(m)
    lo, hi = rates < np.quantile(rates, 0.2), rates > np.quantile(rates, 0.8)
    # rarely-available devices are sampled measurably less often
    assert counts[hi].mean() > 1.5 * counts[lo].mean()
    np.testing.assert_array_equal(model(3, m, 32), model(3, m, 32))
    with pytest.raises(ValueError):
        HeterogeneousCohort(dropout=1.0)
    with pytest.raises(ValueError):
        HeterogeneousCohort(availability=(0.0, 1.0))


def test_heterogeneous_dropout_is_observable_selection_bias():
    """Dropout must CHANGE the realized cohort distribution (an
    identity-blind drop + backfill from the same uniform order would be a
    distributional no-op): unreliability-weighted dropout skews selection
    toward reliable devices beyond availability alone."""
    m, k, rounds = 500, 16, 300

    def quintile_means(dropout):
        model = HeterogeneousCohort(seed=2, availability=(2.0, 2.0),
                                    dropout=dropout)
        counts = np.zeros(m)
        for r in range(rounds):
            counts[model(r, m, k)] += 1
        rates = model.rates(m)
        return (counts[rates < np.quantile(rates, 0.2)].mean(),
                counts[rates > np.quantile(rates, 0.8)].mean())

    base_lo, base_hi = quintile_means(0.0)
    drop_lo, drop_hi = quintile_means(0.8)
    assert drop_lo < 0.5 * base_lo      # flaky devices squeezed out...
    assert drop_hi > 1.5 * base_hi      # ...reliable ones over-selected


# ------------------- identity gate: cohort path == dense path ---------------

IDENTITY_SETTINGS = [
    ("dense", dict()),
    ("q50", dict(participation=0.5)),
    ("topk25", dict(compressor="topk", compression_ratio=0.25)),
    ("qsgd4-q50", dict(compressor="qsgd", compression_bits=4,
                       participation=0.5)),
]


@pytest.mark.parametrize("engine", ["vmap", "map", "shard_map"])
@pytest.mark.parametrize("name,kw", IDENTITY_SETTINGS,
                         ids=[n for n, _ in IDENTITY_SETTINGS])
def test_cohort_path_identity_with_full_population(engine, name, kw, fed):
    """M == C with cohort == population is bit-for-bit the dense
    participation path: same compiled round (population is not in the
    engine key), same RNG streams, same ledger — across every engine and
    pipeline setting."""
    dense = _spec(engine=engine, **kw)
    pspec = _spec(engine=engine, population=C, cohort_size=C, **kw)
    pop = population_from_federated(fed, B)
    s_d = init_state(dense, init_linear(DIM))
    s_p = init_population_state(pspec, init_linear(DIM))
    rng_d, rng_p = np.random.default_rng(0), np.random.default_rng(0)
    sampler = fed.make_sampler(B)
    for _ in range(3):
        s_d, rec_d = run_round(dense, s_d, round_batch(dense, sampler, rng_d),
                               check_budgets=False)
        s_p, rec_p = run_cohort_round(pspec, s_p, pop, rng_p,
                                      check_budgets=False)
        assert float(rec_p["loss"]) == float(rec_d["loss"])
        assert rec_p["max_epsilon"] == rec_d["max_epsilon"]
        assert rec_p["participants"] == rec_d["participants"]
    for a, b in zip(jax.tree.leaves(s_d.params),
                    jax.tree.leaves(s_p.fl.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(s_d.rho, s_p.store.rho)
    assert s_d.resource_spent == s_p.fl.resource_spent
    if s_d.residual is not None:
        np.testing.assert_array_equal(
            np.asarray(s_d.residual),
            s_p.store.gather_residual(np.arange(C)))


def test_chunked_cohort_train_identity_with_full_population(fed):
    """train_population(chunk_rounds=R) over cohort == population matches
    dense train(chunk_rounds=R) exactly (the fused run_rounds driver works
    under cohort execution, cohorts resampled at chunk boundaries)."""
    kw = dict(compressor="topk", compression_ratio=0.25, participation=0.5,
              eps_th=1e9, c_th=1e9)
    dense, pspec = _spec(**kw), _spec(population=C, cohort_size=C, **kw)
    pop = population_from_federated(fed, B)
    s_d, out_d = train(dense, init_state(dense, init_linear(DIM)),
                       fed.make_sampler(B), max_rounds=5, chunk_rounds=2)
    s_p, out_p = train_population(
        pspec, init_population_state(pspec, init_linear(DIM)), pop,
        max_rounds=5, chunk_rounds=2)
    assert out_d["rounds"] == out_p["rounds"] == 5
    for a, b in zip(jax.tree.leaves(s_d.params),
                    jax.tree.leaves(s_p.fl.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(s_d.rho, s_p.store.rho)
    for rd, rp in zip(out_d["history"], out_p["history"]):
        assert rd["loss"] == rp["loss"]
        assert rd["max_epsilon"] == rp["max_epsilon"]


# ------------------- cohort execution over a real population ----------------

def test_device_block_bounded_by_cohort_not_population():
    """The tentpole memory gate: the device-resident block (params,
    opt_state, residual, batch) is byte-identical across M = 100 and
    M = 100_000 at fixed K — device memory is O(K), independent of M."""
    sizes = {}
    for m in (100, 100_000):
        spec = _pop_spec(m, compressor="topk", compression_ratio=0.25)
        pop = synthetic_population(m, dim=DIM, batch_size=B, seed=1)
        ps = init_population_state(spec, init_linear(DIM))
        rng = np.random.default_rng(0)
        cohort = UniformCohort(0)(0, m, C)
        from repro.population import cohort_batch
        batch = cohort_batch(spec, pop, cohort, rng)
        sizes[m] = device_block_bytes(ps, batch)
        for leaf in jax.tree.leaves(batch):
            assert leaf.shape[0] == C          # K rows, never M
    assert sizes[100] == sizes[100_000] > 0


def test_cohort_round_charges_only_sampled_clients():
    m = 1_000
    spec = _pop_spec(m)
    pop = synthetic_population(m, dim=DIM, batch_size=B, seed=2)
    ps = init_population_state(spec, init_linear(DIM))
    seen = set()
    for r in range(4):
        ps, rec = run_cohort_round(spec, ps, pop, np.random.default_rng(r),
                                   check_budgets=False)
        seen |= set(np.flatnonzero(ps.store.rounds_participated).tolist())
    charged = np.flatnonzero(ps.store.rho)
    assert 0 < charged.size <= 4 * C
    assert set(charged.tolist()) <= seen
    # conditional-ledger soundness: every realized participant pays the
    # FULL Lemma-2 per-step rho for exactly the rounds it ran
    per_round = TAU * gaussian_zcdp(grad_sensitivity(1.0, B), 0.5)
    np.testing.assert_allclose(
        ps.store.rho[charged],
        ps.store.rounds_participated[charged] * per_round, rtol=1e-12)
    assert rec["max_epsilon"] == pytest.approx(
        zcdp_to_dp(ps.store.max_rho(), spec.delta))


def test_population_amplification_monotone_in_m():
    """K/M accounting: at fixed K, growing the population strictly tightens
    the amplified per-step charge (and the sound default is unaffected)."""
    qs = [_pop_spec(m, amplify_participation=True).accounting_q()
          for m in (10, 100, 10_000, 1_000_000)]
    assert all(a > b for a, b in zip(qs, qs[1:]))
    assert _pop_spec(1_000_000).accounting_q() == 1.0
    # the charge vector the drivers use scales exactly by q
    amp = round_rho_charges(_pop_spec(1000, amplify_participation=True))
    full = round_rho_charges(_pop_spec(1000))
    np.testing.assert_allclose(amp, full * (C / 1000), rtol=1e-12)


def test_population_budget_probe_and_train_stop():
    m = 200
    c_th = 3 * (100.0 + TAU)       # exactly 3 rounds of resource
    spec = _pop_spec(m, c_th=c_th, eps_th=1e9)
    pop = synthetic_population(m, dim=DIM, batch_size=B, seed=3)
    ps = init_population_state(spec, init_linear(DIM))
    assert exceeds_population_budgets(spec, ps) is None
    ps, out = train_population(spec, ps, pop, max_rounds=50)
    assert out["rounds"] == 3
    assert exceeds_population_budgets(spec, ps) == "resource"
    # privacy probe: conservative (assumes the worst client is resampled)
    eps_next = peek_population_epsilon(spec, ps, 1)
    assert eps_next > out["max_epsilon"] > 0
    from repro.api import BudgetExceeded
    with pytest.raises(BudgetExceeded):
        run_cohort_round(spec, ps, pop, np.random.default_rng(0))


def test_amplified_accounting_requires_uniform_cohorts():
    """amplify_participation=True charges q_eff = K/M per realized step —
    a bound stated for UNIFORM cohorts. The drivers must refuse it under
    an availability-skewed sampler (a high-rate device realizes more than
    K/M of the rounds, so the reported epsilon would understate its true
    loss) instead of silently under-reporting."""
    m = 100
    spec = _pop_spec(m, amplify_participation=True)
    pop = synthetic_population(m, dim=DIM, batch_size=B, seed=8)
    ps = init_population_state(spec, init_linear(DIM))
    hetero = HeterogeneousCohort(seed=0)
    with pytest.raises(ValueError, match="uniform"):
        run_cohort_round(spec, ps, pop, np.random.default_rng(0),
                         cohort_sampler=hetero, check_budgets=False)
    with pytest.raises(ValueError, match="uniform"):
        train_population(spec, ps, pop, cohort_sampler=hetero, max_rounds=1)
    # uniform cohorts (and the skewed sampler under the sound default
    # conditional ledger) stay allowed
    ps, _ = run_cohort_round(spec, ps, pop, np.random.default_rng(0),
                             check_budgets=False)
    sound = _pop_spec(m)
    ps2 = init_population_state(sound, init_linear(DIM))
    run_cohort_round(sound, ps2, pop, np.random.default_rng(0),
                     cohort_sampler=hetero, check_budgets=False)


def test_heterogeneous_cohort_trains_and_skews_ledger():
    m = 500
    spec = _pop_spec(m, eps_th=1e9, c_th=1e9)
    pop = synthetic_population(m, dim=DIM, batch_size=B, seed=4)
    ps = init_population_state(spec, init_linear(DIM))
    hetero = HeterogeneousCohort(seed=9, availability=(2.0, 2.0),
                                 dropout=0.1)
    ps, out = train_population(spec, ps, pop, cohort_sampler=hetero,
                               max_rounds=6, chunk_rounds=3)
    assert out["rounds"] == 6
    assert np.isfinite(out["history"][-1]["loss"])
    part = ps.store.rounds_participated
    assert part.sum() == 6 * C and (part > 0).sum() <= 6 * C


# ------------------- ClientStore -------------------------------------------

def test_client_store_sparse_residual_gather_scatter():
    store = ClientStore(1000, residual_dim=5)
    cohort = np.asarray([3, 500, 999])
    np.testing.assert_array_equal(store.gather_residual(cohort),
                                  np.zeros((3, 5), np.float32))
    block = np.asarray([[1, 0, 0, 0, 0],
                        [0, 0, 0, 0, 0],
                        [0, 2, 0, 0, 3]], np.float32)
    store.scatter_residual(cohort, block)
    assert store.residual_rows() == 2           # all-zero row not stored
    np.testing.assert_array_equal(store.gather_residual(cohort), block)
    # zeroing a row prunes it
    store.scatter_residual(np.asarray([3]), np.zeros((1, 5), np.float32))
    assert store.residual_rows() == 1
    with pytest.raises(ValueError):
        store.scatter_residual(cohort, np.zeros((2, 5), np.float32))
    with pytest.raises(ValueError):
        ClientStore(1000).gather_residual(cohort)   # built without residual


def test_client_store_save_load_roundtrip(tmp_path):
    store = ClientStore(50, residual_dim=4)
    store.rho[7] = 0.25
    store.rho[11] = np.inf
    store.rounds_participated[7] = 3
    store.scatter_residual(np.asarray([7, 20]),
                           np.asarray([[1., 2, 3, 4], [0, 0, 5, 0]],
                                      np.float32))
    path = str(tmp_path / "store.npz")
    store.save(path)
    back = ClientStore.load(path)
    assert back.population == 50 and back.residual_dim == 4
    np.testing.assert_array_equal(back.rho, store.rho)
    np.testing.assert_array_equal(back.rounds_participated,
                                  store.rounds_participated)
    assert back.residual_rows() == 2
    np.testing.assert_array_equal(back.gather_residual(np.asarray([7, 20])),
                                  store.gather_residual(np.asarray([7, 20])))


def test_population_checkpoint_resume_identity(tmp_path):
    """Save mid-run, resume, continue — bit-identical to the uninterrupted
    run (params, per-vid rho ledger, sparse residual rows). The cohort
    schedule is stateless per round index and the per-round data rng is
    re-derived per round, so resume needs no sampler state."""
    m = 300
    spec = _pop_spec(m, compressor="topk", compression_ratio=0.25,
                     participation=0.5)
    pop = synthetic_population(m, dim=DIM, batch_size=B, seed=5)

    def drive(ps, start, n):
        for r in range(start, start + n):
            ps, _ = run_cohort_round(spec, ps, pop,
                                     np.random.default_rng(10_000 + r),
                                     check_budgets=False)
        return ps

    straight = drive(init_population_state(spec, init_linear(DIM)), 0, 5)

    ps = drive(init_population_state(spec, init_linear(DIM)), 0, 2)
    save_population_state(str(tmp_path), ps, extra={"note": "mid"})
    like = init_population_state(spec, init_linear(DIM))
    resumed, extra = load_population_state(str(tmp_path), like)
    assert extra["note"] == "mid" and extra["population"] == m
    assert resumed.fl.rounds_done == 2
    assert resumed.store.residual_rows() == ps.store.residual_rows()
    resumed = drive(resumed, 2, 3)

    for a, b in zip(jax.tree.leaves(straight.fl.params),
                    jax.tree.leaves(resumed.fl.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(straight.store.rho, resumed.store.rho)
    np.testing.assert_array_equal(straight.store.rounds_participated,
                                  resumed.store.rounds_participated)
    assert (straight.store.residual_rows()
            == resumed.store.residual_rows() > 0)
    vids = np.flatnonzero(straight.store.rounds_participated)
    np.testing.assert_array_equal(
        straight.store.gather_residual(vids),
        resumed.store.gather_residual(vids))


def test_population_geometry_mismatch_rejected(tmp_path):
    spec = _pop_spec(100)
    ps = init_population_state(spec, init_linear(DIM))
    save_population_state(str(tmp_path), ps)
    other = init_population_state(_pop_spec(200), init_linear(DIM))
    with pytest.raises(ValueError):
        load_population_state(str(tmp_path), other)


# ------------------- fused chunk driver over a population -------------------

def test_run_cohort_rounds_matches_per_round_for_fixed_cohort():
    """One chunk over a fixed cohort == the per-round driver fed the same
    cohort rows (the dense chunk/loop identity transported to cohort
    execution)."""
    m = 120
    spec = _pop_spec(m, participation=0.5)
    pop = synthetic_population(m, dim=DIM, batch_size=B, seed=6)
    cohort = UniformCohort(spec.seed)(0, m, C)

    ps1 = init_population_state(spec, init_linear(DIM))
    ps1, recs = run_cohort_rounds(spec, ps1, pop, np.random.default_rng(0),
                                  n_rounds=3, check_budgets=False)
    assert len(recs) == 3

    from repro.population import cohort_batch
    ps2 = init_population_state(spec, init_linear(DIM))
    rng = np.random.default_rng(0)
    rows = [cohort_batch(spec, pop, cohort, rng) for _ in range(3)]
    from repro.population.runtime import (
        _cohort_round_from_row,
        _gathered_fl,
        _scatter_back,
    )
    del _gathered_fl, _scatter_back
    batches = jax.tree.map(lambda *xs: np.stack(xs), *rows)
    for r in range(3):
        ps2, rec = _cohort_round_from_row(spec, ps2, pop, cohort, batches, r)
    for a, b in zip(jax.tree.leaves(ps1.fl.params),
                    jax.tree.leaves(ps2.fl.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(ps1.store.rho, ps2.store.rho)


# ------------------- CI smoke leg (REPRO_SMOKE_POPULATION) ------------------

@pytest.mark.skipif(not os.environ.get("REPRO_SMOKE_POPULATION"),
                    reason="set REPRO_SMOKE_POPULATION=<M> to smoke cohort "
                           "execution at population scale in this env")
def test_env_population_smoke():
    """CI's population leg: M virtual clients (10_000 by default in CI),
    K = 8 cohort, oracle kernels — per-round and fused drivers both train,
    device block stays K-bound, ledger touches only sampled clients."""
    m = int(os.environ["REPRO_SMOKE_POPULATION"])
    k = 8
    spec = FederationSpec(
        n_clients=k, tau=TAU, loss_fn=logreg_loss, optimizer=OPT,
        clip_norm=1.0, dp=True, sigmas=(0.5,) * k, batch_sizes=(B,) * k,
        population=m, cohort_size=k, compressor="topk",
        compression_ratio=0.25, eps_th=1e9, c_th=1e9)
    pop = synthetic_population(m, dim=DIM, batch_size=B, alpha=0.3, seed=0)
    ps = init_population_state(spec, init_linear(DIM))
    ps, out = train_population(spec, ps, pop, max_rounds=8, chunk_rounds=4)
    assert out["rounds"] == 8
    assert np.isfinite(out["history"][-1]["loss"])
    assert ps.store.rho.shape == (m,)
    assert 0 < (ps.store.rho > 0).sum() <= 8 * k
    for leaf in jax.tree.leaves(ps.fl.params):
        assert leaf.shape[0] == k


# ------------------- resident-cohort driver (PR 8) -------------------------

def _run_cohort_rounds_per_round(spec, pop, rounds):
    st = init_population_state(spec, init_linear(DIM))
    rng = np.random.default_rng(0)
    for _ in range(rounds):
        st, _ = run_cohort_round(spec, st, pop, rng, check_budgets=False)
    return st


def test_resident_identity_gate():
    """Standing fast gate (seed 0, M > K): the resident-cohort driver —
    fresh cohort per round inside the fused scan, sticky state on device —
    is bit-identical to the per-round cohort driver after flush. The
    seed-sweep tier re-runs this at 3 seeds x {q50, topk25} + churn."""
    m, rounds, chunk = 12, 4, 2
    spec = FederationSpec(
        n_clients=C, tau=TAU, loss_fn=logreg_loss, optimizer=OPT,
        clip_norm=1.0, dp=True, sigmas=(0.5,) * C, batch_sizes=(B,) * C,
        population=m, cohort_size=C, compressor="topk",
        compression_ratio=0.25, seed=0)
    pop = synthetic_population(m, DIM, batch_size=B, seed=0)
    a = _run_cohort_rounds_per_round(spec, pop, rounds)

    b = init_population_state(spec, init_linear(DIM))
    rng = np.random.default_rng(0)
    cache = init_resident_cache(spec, b, m, population=pop)
    for _ in range(rounds // chunk):
        b, _ = run_resident_rounds(spec, b, pop, rng, cache,
                                   n_rounds=chunk, check_budgets=False)
    cache.flush(b.store)
    for x, y in zip(jax.tree.leaves(a.fl.params),
                    jax.tree.leaves(b.fl.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(a.store.rho, b.store.rho)
    vids = np.arange(m)
    np.testing.assert_array_equal(a.store.gather_residual(vids),
                                  b.store.gather_residual(vids))


@pytest.mark.skipif(not os.environ.get("REPRO_SMOKE_RESIDENT"),
                    reason="set REPRO_SMOKE_RESIDENT=1 to smoke the "
                           "resident-cohort driver at population scale")
def test_env_resident_smoke():
    """CI's resident leg (oracle kernels): M = 10_000 virtual clients,
    K = 8 cohorts resampled per round inside the fused scan, S = 256 warm
    slots — trains end to end via train_population(resident_cache=S) and
    matches the per-round cohort driver bit for bit on the global model."""
    m, k, s_cap, rounds = 10_000, 8, 256, 8
    spec = FederationSpec(
        n_clients=k, tau=TAU, loss_fn=logreg_loss, optimizer=OPT,
        clip_norm=1.0, dp=True, sigmas=(0.5,) * k, batch_sizes=(B,) * k,
        population=m, cohort_size=k, compressor="topk",
        compression_ratio=0.25, eps_th=1e9, c_th=1e9)
    pop = synthetic_population(m, dim=DIM, batch_size=B, alpha=0.3, seed=0)
    ps = init_population_state(spec, init_linear(DIM))
    ps, out = train_population(spec, ps, pop, max_rounds=rounds,
                               chunk_rounds=4, resident_cache=s_cap,
                               rng=np.random.default_rng(0))
    assert out["rounds"] == rounds
    assert np.isfinite(out["history"][-1]["loss"])
    assert out["resident_cache"]["misses"] > 0
    ref = _run_cohort_rounds_per_round(spec, pop, rounds)
    for x, y in zip(jax.tree.leaves(ref.fl.params),
                    jax.tree.leaves(ps.fl.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(ref.store.rho, ps.store.rho)


# ------------------- launch CLI --------------------------------------------

def test_launch_train_population_cli(tmp_path, capsys):
    """launch/train --population M --cohort-size K end-to-end (tiny smoke
    transformer): trains, reports population stats, saves a resumable
    population checkpoint."""
    from repro.launch.train import main
    save = str(tmp_path / "ckpt")
    rc = main(["--arch", "gemma3-4b", "--smoke", "--rounds", "2",
               "--population", "200", "--cohort-size", "2", "--tau", "2",
               "--batch", "2", "--seq", "16", "--eps", "1e9",
               "--cth", "1e9", "--save", save])
    assert rc == 0
    out = capsys.readouterr().out
    assert '"population": 200' in out
    assert os.path.exists(os.path.join(save, "client_store.npz"))

"""Tests for the repro.api facade: FederationSpec, the round-engine
registry, the pure functional FLState core, and checkpoint/restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    BudgetExceeded,
    FederationSpec,
    FLState,
    Federation,
    available_engines,
    get_engine,
    init_state,
    load_state,
    register_engine,
    round_batch,
    run_round,
    save_state,
    train,
)
from repro.core.fl import Budgets, FLConfig
from repro.data import adult_like, split_iid
from repro.models.linear import init_linear, logreg_loss, make_eval_fn
from repro.optim import sgd

C, TAU, DIM, B = 4, 3, 8, 4


def _spec(**kw):
    base = dict(n_clients=C, tau=TAU, loss_fn=logreg_loss, optimizer=sgd(0.2),
                clip_norm=1.0, dp=True, sigmas=(0.5,) * C,
                batch_sizes=(B,) * C)
    base.update(kw)
    return FederationSpec(**base)


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    return {"x": jnp.asarray(rng.normal(size=(C, TAU, B, DIM)), jnp.float32),
            "y": jnp.asarray(rng.integers(0, 2, size=(C, TAU, B)), jnp.int32)}


def _sampler(m, tau, rng):
    return {"x": rng.normal(size=(tau, B, DIM)).astype(np.float32),
            "y": rng.integers(0, 2, size=(tau, B)).astype(np.int32)}


# ---------------------------- spec ------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError):
        _spec(engine="bogus")
    with pytest.raises(ValueError):
        _spec(topology="bogus")
    with pytest.raises(ValueError):
        _spec(sigmas=(0.5,))            # wrong length
    with pytest.raises(ValueError):
        _spec(tau=0)


def test_spec_budget_edit_keeps_engine_key():
    s = _spec()
    assert s.replace(eps_th=4.0, c_th=100.0).engine_key() == s.engine_key()
    assert s.replace(tau=TAU + 1).engine_key() != s.engine_key()


def test_spec_auto_sigma_design():
    s = _spec(sigmas=None, eps_th=4.0, total_steps=120)
    sig = s.resolved_sigmas()
    assert sig.shape == (C,) and (sig > 0).all()
    from repro.core.privacy import epsilon_after_k
    assert epsilon_after_k(120, s.clip_norm, B, float(sig[0]),
                           s.delta) == pytest.approx(4.0, rel=1e-5)
    with pytest.raises(ValueError):
        _spec(sigmas=None).resolved_sigmas()   # no eps_th/total_steps


def test_engine_registry():
    assert set(available_engines()) >= {"vmap", "map", "shard_map"}
    with pytest.raises(KeyError):
        get_engine("nope")

    @register_engine("_test_engine")
    def _builder(spec):
        return get_engine("vmap")(spec)

    assert get_engine("_test_engine") is _builder


# ---------------------------- engine parity ---------------------------------

@pytest.mark.parametrize("engine", ["map", "shard_map"])
def test_engine_parity_with_vmap(engine):
    """All engines run the same protocol: numerically matching params and
    metrics for a small logreg federation (2 rounds, DP noise on)."""
    params0 = init_linear(DIM)
    batch = _batch()

    def run(engine):
        spec = _spec(engine=engine)
        state = init_state(spec, params0)
        recs = []
        for _ in range(2):
            state, rec = run_round(spec, state, batch, check_budgets=False)
            recs.append(rec)
        return state, recs

    ref_state, ref_recs = run("vmap")
    got_state, got_recs = run(engine)
    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(got_state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    for ra, rb in zip(ref_recs, got_recs):
        assert rb["loss"] == pytest.approx(ra["loss"], rel=1e-5)
        assert rb["max_epsilon"] == pytest.approx(ra["max_epsilon"])


def test_topology_local_only_skips_averaging():
    """local_only = the old make_local_steps_only ablation: client models
    diverge, and grad_accumulate is respected (scan == stack)."""
    params0 = init_linear(DIM)
    batch = _batch()
    for accum in ("stack", "scan"):
        spec = _spec(topology="local_only", engine="vmap",
                     num_microbatches=2, vmap_microbatches=False,
                     grad_accumulate=accum)
        state, _ = run_round(spec, init_state(spec, params0), batch,
                             check_budgets=False)
        w = np.asarray(state.params["w"])
        assert not np.allclose(w[0], w[1])
        if accum == "stack":
            ref = w
        else:
            np.testing.assert_allclose(ref, w, rtol=1e-5, atol=1e-6)


def test_topology_full_average_syncs_clients():
    spec = _spec()
    state, _ = run_round(spec, init_state(spec, init_linear(DIM)), _batch(),
                         check_budgets=False)
    w = np.asarray(state.params["w"])
    for c in range(1, C):
        np.testing.assert_allclose(w[0], w[c], rtol=1e-6)


# ---------------------------- budgets ---------------------------------------

def test_run_round_enforces_budgets():
    spec = _spec(c_th=2 * (100.0 + TAU), eps_th=1e9)
    state = init_state(spec, init_linear(DIM))
    state, _ = run_round(spec, state, _batch())
    state, _ = run_round(spec, state, _batch())
    with pytest.raises(BudgetExceeded) as ei:
        run_round(spec, state, _batch())
    assert ei.value.which == "resource"

    tight = _spec(eps_th=0.5, sigmas=(0.05,) * C)
    with pytest.raises(BudgetExceeded) as ei:
        run_round(tight, init_state(tight, init_linear(DIM)), _batch())
    assert ei.value.which == "privacy"


def test_functional_train_learns():
    ds = adult_like(n=1200, dim=DIM, seed=0)
    fed = split_iid(ds, C, seed=0)
    spec = _spec(sigmas=(0.05,) * C, batch_sizes=tuple(fed.batch_sizes(16)),
                 c_th=2000.0, eps_th=1e9, optimizer=sgd(0.5))
    state = init_state(spec, init_linear(DIM))
    xt, yt = fed.eval_arrays("test")
    state, out = train(spec, state, fed.make_sampler(16), max_rounds=12,
                       eval_fn=make_eval_fn(logreg_loss, xt, yt))
    assert out["rounds"] == 12
    assert out["best"]["eval_loss"] < out["history"][0]["loss"]


# ---------------------------- checkpoint / resume ---------------------------

def test_flstate_checkpoint_roundtrip(tmp_path):
    spec = _spec()
    params0 = init_linear(DIM)
    state = init_state(spec, params0)
    for s in range(2):
        state, _ = run_round(spec, state, _batch(s), check_budgets=False)
    save_state(str(tmp_path), state, extra={"note": "hi"})

    restored, extra = load_state(str(tmp_path), init_state(spec, params0))
    assert extra["note"] == "hi"
    assert restored.rounds_done == state.rounds_done == 2
    assert restored.steps == state.steps
    assert restored.resource_spent == pytest.approx(state.resource_spent)
    np.testing.assert_allclose(restored.rho, state.rho)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # identical continuation: same key, same batch -> same params
    nxt_a, _ = run_round(spec, state, _batch(9), check_budgets=False)
    nxt_b, _ = run_round(spec, restored, _batch(9), check_budgets=False)
    for a, b in zip(jax.tree.leaves(nxt_a.params),
                    jax.tree.leaves(nxt_b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_flstate_checkpoint_roundtrip_with_pipeline(tmp_path):
    """Compressed, partially-participating federation: the error-feedback
    residual round-trips through save_state/load_state and the restored
    state resumes to IDENTICAL next-round params."""
    spec = _spec(participation=0.5, compressor="topk", compression_ratio=0.25)
    params0 = init_linear(DIM)
    state = init_state(spec, params0)
    assert state.residual is not None
    for s in range(3):
        state, _ = run_round(spec, state, _batch(s), check_budgets=False)
    assert np.abs(np.asarray(state.residual)).max() > 0
    save_state(str(tmp_path), state, extra={"note": "pipeline"})

    restored, extra = load_state(str(tmp_path), init_state(spec, params0))
    assert extra["note"] == "pipeline"
    np.testing.assert_array_equal(np.asarray(restored.residual),
                                  np.asarray(state.residual))
    np.testing.assert_allclose(restored.rho, state.rho)
    assert restored.resource_spent == pytest.approx(state.resource_spent)
    # identical continuation: same key stream -> same participant set, same
    # compressor randomness, same params
    nxt_a, rec_a = run_round(spec, state, _batch(9), check_budgets=False)
    nxt_b, rec_b = run_round(spec, restored, _batch(9), check_budgets=False)
    assert rec_a["participants"] == rec_b["participants"]
    for a, b in zip(jax.tree.leaves(nxt_a.params),
                    jax.tree.leaves(nxt_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(nxt_a.residual),
                                  np.asarray(nxt_b.residual))


def test_dense_checkpoint_resumes_under_compression(tmp_path):
    """A checkpoint trained WITHOUT a compressor restores into a spec WITH
    one: the missing residual falls back to like's fresh zeros and the
    compressed federation trains on."""
    params0 = init_linear(DIM)
    dense = _spec()
    state = init_state(dense, params0)
    state, _ = run_round(dense, state, _batch(), check_budgets=False)
    save_state(str(tmp_path), state)

    comp = _spec(compressor="topk", compression_ratio=0.25)
    restored, _ = load_state(str(tmp_path), init_state(comp, params0))
    assert restored.rounds_done == 1
    np.testing.assert_array_equal(np.asarray(restored.residual), 0.0)
    nxt, rec = run_round(comp, restored, _batch(1), check_budgets=False)
    assert np.isfinite(rec["loss"])
    assert np.abs(np.asarray(nxt.residual)).max() > 0


def test_params_only_load_serves_any_optimizer(tmp_path):
    """The serving path (launch/serve.load_federated_params) loads ONLY the
    params leaves, so checkpoints from structurally different optimizer
    states (momentum: velocity) restore without the full FLState. The
    single-replica init works as the path donor (leaves match by path, not
    shape), so serving never allocates C replicas or a residual."""
    from repro.checkpoint import load_checkpoint
    from repro.optim import momentum
    spec = _spec(optimizer=momentum(0.2, 0.9), compressor="topk",
                 compression_ratio=0.5)
    state = init_state(spec, init_linear(DIM))
    state, _ = run_round(spec, state, _batch(), check_budgets=False)
    save_state(str(tmp_path), state)

    tree, _, _ = load_checkpoint(str(tmp_path),
                                 like={"params": init_linear(DIM)})
    for a, b in zip(jax.tree.leaves(tree["params"]),
                    jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------- back-compat wrapper ---------------------------

def test_federation_wrapper_is_thin_over_functional_core():
    """Old-style Federation == spec + init_state + run_round, same numbers."""
    params0 = init_linear(DIM)
    cfg = FLConfig(n_clients=C, tau=TAU, clip_norm=1.0, dp=True)
    fed = Federation(cfg=cfg, loss_fn=logreg_loss, optimizer=sgd(0.2),
                     params0=params0, sampler=_sampler,
                     sigmas=np.full((C,), 0.5, np.float32),
                     batch_sizes=[B] * C, seed=0)
    rec = fed.round()
    assert fed.rounds_done == 1 and fed.history == [rec]

    spec = _spec(seed=0)
    state = init_state(spec, params0)
    rng = np.random.default_rng(0)
    state, rec_f = run_round(spec, state, round_batch(spec, _sampler, rng),
                             check_budgets=False)
    assert rec_f["loss"] == pytest.approx(rec["loss"])
    for a, b in zip(jax.tree.leaves(fed.params),
                    jax.tree.leaves(state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    assert fed.accountant.max_epsilon() == pytest.approx(rec_f["max_epsilon"])

    # historic semantics: .round() charges no resources (train prices them)
    assert fed.resource_spent == 0.0
    out = fed.train(Budgets(c_th=420.0, eps_th=1e9, c1=100.0, c2=1.0),
                    max_rounds=100)
    # 4 more rounds at c1 + c2*tau = 103 fit in 420
    assert out["rounds"] == fed.rounds_done == 5
    assert out["resource_spent"] == pytest.approx(412.0)

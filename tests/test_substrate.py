"""Tests for data pipeline, optimizers, and checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import (
    load_checkpoint,
    load_federation_state,
    save_checkpoint,
    save_federation_state,
)
from repro.core.fl import Budgets, Federation, FLConfig
from repro.data import adult_like, split_by_group, split_dirichlet, split_iid, vehicle_like
from repro.data.tokens import FederatedTokenStream, TokenTaskConfig
from repro.models.linear import init_linear, logreg_loss
from repro.optim import adamw, momentum, sgd, cosine_decay, linear_warmup


# ---------------------------- data ----------------------------------------

def test_adult_like_matches_paper_setting():
    ds = adult_like()
    assert ds.n == 32_561
    fed = split_by_group(ds)
    assert fed.n_clients == 16          # 16 education levels -> 16 devices
    sizes = [c.n_train + c.x_val.shape[0] + c.x_test.shape[0]
             for c in fed.clients]
    assert sum(sizes) == ds.n
    # non-iid: client sizes vary a lot (paper: mean 2035, std 4367)
    assert np.std(sizes) > 0.5 * np.mean(sizes)
    # rows in unit ball
    assert np.linalg.norm(ds.x, axis=1).max() <= 1.0 + 1e-5


def test_vehicle_like_matches_paper_setting():
    ds = vehicle_like(per_sensor=200)   # reduced for test speed
    fed = split_by_group(ds)
    assert fed.n_clients == 23
    assert ds.dim == 100


def test_iid_split_even():
    ds = adult_like(n=3200, dim=16)
    fed = split_iid(ds, 16)
    sizes = [c.n_train for c in fed.clients]
    assert max(sizes) - min(sizes) <= 2


def test_dirichlet_skew():
    ds = adult_like(n=4000, dim=16)
    skew = split_dirichlet(ds, 8, alpha=0.1, seed=0)
    even = split_dirichlet(ds, 8, alpha=100.0, seed=0)
    def label_var(fed):
        rates = [c.y_train.mean() for c in fed.clients]
        return np.var(rates)
    assert label_var(skew) > label_var(even)


def test_token_stream_noniid_and_shapes():
    cfg = TokenTaskConfig(vocab=1024, seq_len=32, n_clients=4, seed=0)
    stream = FederatedTokenStream(cfg, batch_size=8)
    rng = np.random.default_rng(0)
    b = stream.sampler(0, 3, rng)
    assert b["tokens"].shape == (3, 8, 32)
    assert b["labels"].shape == (3, 8, 32)
    assert (b["tokens"] >= 0).all() and (b["tokens"] < 1024).all()
    # next-token alignment
    full0 = np.concatenate([b["tokens"][0, 0, :1],
                            b["labels"][0, 0]])
    np.testing.assert_array_equal(full0[1:], b["labels"][0, 0])
    # non-iid: token histograms differ across clients
    h = []
    for c in range(4):
        toks = stream.sampler(c, 4, rng)["tokens"].ravel()
        h.append(np.bincount(toks, minlength=1024) / toks.size)
    assert np.abs(h[0] - h[1]).sum() > 0.3


# ---------------------------- optimizers ----------------------------------

def _quad_loss(p, _):
    return jnp.sum((p["w"] - 3.0) ** 2)


@pytest.mark.parametrize("opt", [sgd(0.1), momentum(0.05), adamw(0.3)])
def test_optimizers_minimize_quadratic(opt):
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(_quad_loss)(params, None)
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(jnp.add, params, upd)
    np.testing.assert_allclose(params["w"], 3.0, atol=1e-2)


def test_schedules():
    s = cosine_decay(1.0, 100)
    assert float(s(0)) == pytest.approx(1.0)
    assert float(s(100)) == pytest.approx(0.0, abs=1e-6)
    w = linear_warmup(cosine_decay(1.0, 100), 10)
    assert float(w(0)) == pytest.approx(0.1)
    assert float(w(9)) == pytest.approx(1.0)


@settings(max_examples=20, deadline=None)
@given(lr=st.floats(1e-3, 0.5), steps=st.integers(1, 50))
def test_sgd_is_paper_eq7a(lr, steps):
    """SGD update is exactly theta - eta*g."""
    opt = sgd(lr)
    params = {"w": jnp.ones((3,))}
    state = opt.init(params)
    g = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    for _ in range(steps):
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(jnp.add, params, upd)
    np.testing.assert_allclose(
        params["w"], 1.0 - lr * steps * np.asarray([1.0, -2.0, 0.5]),
        rtol=2e-5, atol=1e-5)


# ---------------------------- checkpoint ----------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(10, dtype=np.float32).reshape(2, 5),
            "b": {"c": np.ones((3,), np.int32)},
            "d": [np.zeros((2, 2)), np.full((1,), 7.0)]}
    save_checkpoint(str(tmp_path), tree, step=42, extra={"note": "hi"})
    loaded, step, extra = load_checkpoint(str(tmp_path), like=tree)
    assert step == 42 and extra["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_federation_checkpoint_resume(tmp_path):
    ds = adult_like(n=800, dim=12)
    fed_data = split_iid(ds, 4)
    cfg = FLConfig(n_clients=4, tau=3, clip_norm=1.0, dp=True)
    mk = lambda: Federation(
        cfg=cfg, loss_fn=logreg_loss, optimizer=sgd(0.2),
        params0=init_linear(12), sampler=fed_data.make_sampler(16),
        sigmas=np.full((4,), 0.5, np.float32),
        batch_sizes=fed_data.batch_sizes(16))
    f1 = mk()
    f1.train(Budgets(c_th=400.0, eps_th=1e9), max_rounds=3)
    save_federation_state(str(tmp_path), f1)

    f2 = mk()
    load_federation_state(str(tmp_path), f2)
    assert f2.rounds_done == f1.rounds_done
    assert f2.accountant.max_epsilon() == pytest.approx(
        f1.accountant.max_epsilon())
    for a, b in zip(jax.tree.leaves(f1.params), jax.tree.leaves(f2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # resumed federation keeps training
    f2.train(Budgets(c_th=800.0, eps_th=1e9), max_rounds=6)
    assert f2.rounds_done > f1.rounds_done

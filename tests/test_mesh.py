"""repro.mesh — the pod-scale 2D client x model sharding plane.

Three layers of coverage:

* placement (pure python): the engine="auto" decision table and default
  mesh-shape arithmetic of :mod:`repro.mesh.placement`, pinned value by
  value, plus the ``REPRO_DEVICE_MEM_BYTES`` override.
* spec plumbing: FederationSpec validation of ``mesh_shape`` /
  ``sharding_rules`` / ``replica_bytes``, engine_key cache inclusion, and
  logical-axis rule resolution (mesh2d_rules dedupe).
* parity gates (need ``--xla_force_host_platform_device_count=8``):
  the degenerate mesh (dm=1, clients divide) is BITWISE identical to the
  1D shard_map engine, and padded client counts (C not divisible by dc)
  match the vmap oracle to fp32 tolerance — dense, participation and
  top-k compression pipelines.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FederationSpec, init_state, resolve_engine, run_round
from repro.mesh.placement import (
    DEFAULT_DEVICE_MEM_BYTES,
    ENV_DEVICE_MEM,
    choose_engine,
    default_mesh_shape,
    device_memory_budget,
    model_shards_for,
    n_client_shards,
    replica_fits,
)
from repro.models.linear import init_linear, logreg_loss
from repro.models.sharding import axis_rules, mesh2d_rules, resolve_spec
from repro.optim import sgd

TAU, DIM, B = 3, 8, 4

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _spec(n_clients=4, **kw):
    base = dict(n_clients=n_clients, tau=TAU, loss_fn=logreg_loss,
                optimizer=sgd(0.2), clip_norm=1.0, dp=True,
                sigmas=(0.5,) * n_clients, batch_sizes=(B,) * n_clients)
    base.update(kw)
    return FederationSpec(**base)


def _batch(n_clients=4, seed=0):
    rng = np.random.default_rng(seed)
    return {"x": jnp.asarray(rng.normal(size=(n_clients, TAU, B, DIM)),
                             jnp.float32),
            "y": jnp.asarray(rng.integers(0, 2, size=(n_clients, TAU, B)),
                             jnp.int32)}


def _run(spec, batch, dim=DIM, rounds=2):
    state = init_state(spec, init_linear(dim))
    recs = []
    for _ in range(rounds):
        state, rec = run_round(spec, state, batch)
        recs.append(rec)
    return state, recs


# ---------------------------------------------------------------------------
# placement decision table (pure python — no devices needed)
# ---------------------------------------------------------------------------

GIB = 1024 ** 3


def test_device_memory_budget_default_and_env(monkeypatch):
    monkeypatch.delenv(ENV_DEVICE_MEM, raising=False)
    assert device_memory_budget() == DEFAULT_DEVICE_MEM_BYTES == 16 * GIB
    assert device_memory_budget(default=7) == 7
    monkeypatch.setenv(ENV_DEVICE_MEM, str(2 * GIB))
    assert device_memory_budget() == 2 * GIB
    assert device_memory_budget(default=7) == 2 * GIB   # env wins
    monkeypatch.setenv(ENV_DEVICE_MEM, "0")
    with pytest.raises(ValueError):
        device_memory_budget()


def test_replica_fits():
    assert replica_fits(GIB, hbm_bytes=2 * GIB)
    assert not replica_fits(3 * GIB, hbm_bytes=2 * GIB)
    assert replica_fits(DEFAULT_DEVICE_MEM_BYTES)       # default budget


def test_n_client_shards_divisor_table():
    # largest divisor of C that is <= device count
    assert n_client_shards(8, 8) == 8
    assert n_client_shards(6, 8) == 6
    assert n_client_shards(6, 4) == 3
    assert n_client_shards(7, 4) == 1       # prime > devices: no useful split
    assert n_client_shards(4, 1) == 1


def test_model_shards_for_smallest_sufficient_divisor():
    # smallest divisor of n_devices whose shard fits the budget
    assert model_shards_for(GIB, 8, hbm_bytes=2 * GIB) == 1
    assert model_shards_for(3 * GIB, 8, hbm_bytes=2 * GIB) == 2
    assert model_shards_for(7 * GIB, 8, hbm_bytes=2 * GIB) == 4
    assert model_shards_for(15 * GIB, 8, hbm_bytes=2 * GIB) == 8
    # nothing fits: all devices (best effort)
    assert model_shards_for(100 * GIB, 8, hbm_bytes=2 * GIB) == 8


def test_choose_engine_decision_table():
    # single device: always vmap
    assert choose_engine(8, 1) == "vmap"
    # no footprint hint: 1D placement by divisibility
    assert choose_engine(8, 4) == "shard_map"
    assert choose_engine(7, 4) == "vmap"
    # replica exceeds per-device memory -> the 2D plane
    assert choose_engine(8, 8, replica_bytes=3 * GIB,
                         hbm_bytes=2 * GIB) == "mesh_2d"
    # fits -> fall through to the 1D table
    assert choose_engine(8, 8, replica_bytes=GIB,
                         hbm_bytes=2 * GIB) == "shard_map"
    # adversarial pipelines need the full client view: never mesh_2d
    assert choose_engine(8, 8, replica_bytes=3 * GIB, hbm_bytes=2 * GIB,
                         adversarial=True) == "shard_map"


def test_default_mesh_shape():
    assert default_mesh_shape(8, 8) == (8, 1)
    assert default_mesh_shape(8, 8, replica_bytes=3 * GIB,
                              hbm_bytes=2 * GIB) == (4, 2)
    assert default_mesh_shape(8, 8, replica_bytes=7 * GIB,
                              hbm_bytes=2 * GIB) == (2, 4)
    # dc never exceeds the client count
    assert default_mesh_shape(2, 8, replica_bytes=3 * GIB,
                              hbm_bytes=2 * GIB) == (2, 2)


def test_env_override_steers_choose_engine(monkeypatch):
    monkeypatch.setenv(ENV_DEVICE_MEM, str(2 * GIB))
    assert choose_engine(8, 8, replica_bytes=3 * GIB) == "mesh_2d"
    monkeypatch.setenv(ENV_DEVICE_MEM, str(64 * GIB))
    assert choose_engine(8, 8, replica_bytes=3 * GIB) == "shard_map"


# ---------------------------------------------------------------------------
# spec plumbing
# ---------------------------------------------------------------------------

def test_spec_mesh_fields_validation():
    s = _spec(engine="mesh_2d", mesh_shape=(2, 2))
    assert s.mesh_shape == (2, 2)
    with pytest.raises(ValueError):
        _spec(engine="vmap", mesh_shape=(2, 2))
    with pytest.raises(ValueError):
        _spec(engine="mesh_2d", mesh_shape=(0, 2))
    with pytest.raises(ValueError):
        _spec(engine="mesh_2d", mesh_shape=(2,))
    with pytest.raises(ValueError):
        _spec(engine="mesh_2d", replica_bytes=-1)
    # adversarial pipelines are refused at spec construction
    with pytest.raises(ValueError):
        _spec(engine="mesh_2d", attack="sign_flip", byzantine_fraction=0.25,
              aggregator="median")


def test_spec_mesh_fields_key_the_engine_cache():
    a = _spec(engine="mesh_2d", mesh_shape=(2, 2))
    b = _spec(engine="mesh_2d", mesh_shape=(4, 1))
    c = _spec(engine="auto", replica_bytes=GIB)
    d = _spec(engine="auto")
    keys = {a.engine_key(), b.engine_key(), c.engine_key(), d.engine_key()}
    assert len(keys) == 4


def test_sharding_rules_normalized():
    opt = sgd(0.2)
    a = _spec(engine="mesh_2d", optimizer=opt,
              sharding_rules={"fsdp": "model", "tp": None})
    b = _spec(engine="mesh_2d", optimizer=opt,
              sharding_rules=[("tp", None), ("fsdp", "model")])
    assert a.sharding_rules == b.sharding_rules
    assert a.engine_key() == b.engine_key()


def test_mesh2d_rules_resolve_dedupes_repeated_axis():
    # fsdp and tp both map to "model": a leaf annotated with both must not
    # emit PartitionSpec("model", "model") (invalid) — first dim wins
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("client", "model"))
    with axis_rules(mesh, mesh2d_rules()):
        assert resolve_spec(("fsdp", "tp")) == jax.sharding.PartitionSpec(
            "model")
        assert resolve_spec(("wg", "tp", None)) == jax.sharding.PartitionSpec(
            None, "model")
    # outside any rules context resolution is the identity placement
    assert resolve_spec(("fsdp", "tp")) == jax.sharding.PartitionSpec()


def test_resolve_engine_single_device_never_mesh(monkeypatch):
    monkeypatch.setattr(jax, "devices", lambda: [object()])
    assert resolve_engine(_spec(engine="auto",
                                replica_bytes=100 * GIB)) == "vmap"


# ---------------------------------------------------------------------------
# parity gates (8 host devices)
# ---------------------------------------------------------------------------

PIPELINES = {
    "dense": {},
    "participation": dict(participation=0.5, seed=7),
    "topk": dict(compressor="topk", compression_ratio=0.25),
}


def _assert_states_equal(sa, sb, *, exact: bool):
    for la, lb in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
        if exact:
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        else:
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(sa.rho, sb.rho)
    assert sa.resource_spent == sb.resource_spent


@needs_8_devices
@pytest.mark.parametrize("pipeline", sorted(PIPELINES))
@pytest.mark.parametrize("n_clients", [6, 8])
def test_degenerate_mesh_bitwise_vs_shard_map(pipeline, n_clients):
    """(C, 1) mesh with clients divisible: bit-identical to 1D shard_map."""
    kw = PIPELINES[pipeline]
    batch = _batch(n_clients)
    ref, ref_recs = _run(_spec(n_clients, engine="shard_map", **kw), batch)
    got, got_recs = _run(_spec(n_clients, engine="mesh_2d",
                               mesh_shape=(n_clients, 1), **kw), batch)
    _assert_states_equal(ref, got, exact=True)
    for ra, rb in zip(ref_recs, got_recs):
        np.testing.assert_array_equal(np.asarray(ra["loss"]),
                                      np.asarray(rb["loss"]))


@needs_8_devices
@pytest.mark.parametrize("pipeline", sorted(PIPELINES))
def test_true_2d_mesh_matches_shard_map(pipeline):
    kw = PIPELINES[pipeline]
    batch = _batch(8)
    ref, _ = _run(_spec(8, engine="shard_map", **kw), batch)
    got, _ = _run(_spec(8, engine="mesh_2d", mesh_shape=(4, 2), **kw), batch)
    _assert_states_equal(ref, got, exact=False)


@needs_8_devices
@pytest.mark.parametrize("pipeline", sorted(PIPELINES))
@pytest.mark.parametrize("n_clients", [3, 5, 7, 9])
def test_padded_client_axis_matches_vmap(pipeline, n_clients):
    """C not divisible by dc: pad rows must not perturb the valid clients."""
    kw = PIPELINES[pipeline]
    batch = _batch(n_clients)
    ref, _ = _run(_spec(n_clients, engine="vmap", **kw), batch)
    got, _ = _run(_spec(n_clients, engine="mesh_2d", mesh_shape=(4, 2), **kw),
                  batch)
    _assert_states_equal(ref, got, exact=False)


@needs_8_devices
def test_auto_resolves_mesh_2d_and_completes(monkeypatch):
    """Oversized replica hint routes auto -> mesh_2d and the round runs."""
    monkeypatch.setenv(ENV_DEVICE_MEM, str(256))     # tiny per-device budget
    spec = _spec(8, engine="auto", replica_bytes=100 * DIM)
    assert resolve_engine(spec) == "mesh_2d"
    state, recs = _run(spec, _batch(8), rounds=1)
    assert np.isfinite(float(recs[0]["loss"]))
    assert state.rounds_done == 1

"""Tests for the shard_map round variant and the adaptive designer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.adaptive import AdaptiveDesigner
from repro.core.convergence import ProblemConstants
from repro.core.design import DesignProblem, ResourceModel
from repro.core.fl import FLConfig, make_round_step
from repro.core.fl_shard_map import make_shard_map_round
from repro.core.privacy import PrivacyAccountant, epsilon_after_k
from repro.data import adult_like, split_iid
from repro.models.linear import init_linear, logreg_loss
from repro.optim import sgd
from repro.utils.tree import tree_broadcast_axis0


def test_shard_map_round_matches_gspmd_round():
    """Explicit-collective round == the GSPMD engine (same math, Eq. 7a-7b)."""
    C, tau, dim, B = 1, 3, 8, 4          # 1-device mesh: client axis size 1
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("client",))
    cfg = FLConfig(n_clients=C, tau=tau, clip_norm=1.0, dp=True)
    params0 = init_linear(dim)
    opt = sgd(0.2)
    rs_gspmd = make_round_step(logreg_loss, opt, cfg)
    rs_smap = make_shard_map_round(logreg_loss, opt, cfg, mesh)

    rng = np.random.default_rng(0)
    batch = {"x": jnp.asarray(rng.normal(size=(C, tau, B, dim)), jnp.float32),
             "y": jnp.asarray(rng.integers(0, 2, size=(C, tau, B)), jnp.int32)}
    params = tree_broadcast_axis0(params0, C)
    opt_state = tree_broadcast_axis0(opt.init(params0), C)
    key = jax.random.PRNGKey(0)
    sig = jnp.full((C,), 0.5, jnp.float32)

    p1, _, m1 = rs_gspmd(params, opt_state, batch, key, sig)
    p2, _, m2 = rs_smap(params, opt_state, batch, key, sig)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)


def _problem(eps_th=4.0, c_th=1000.0):
    consts = ProblemConstants(eta=0.05, lam=0.3, lip=1.5, alpha=2.0, xi2=0.4,
                              dim=50, n_clients=4)
    return DesignProblem(consts=consts, resource=ResourceModel(100.0, 1.0),
                         clip_norm=1.0, batch_sizes=[32] * 4, delta=1e-4,
                         eps_th=eps_th, c_th=c_th)


def test_adaptive_designer_never_exceeds_eps():
    """PROPERTY: after any interleaving of phases, total eps <= eps_th."""
    prob = _problem()
    designer = AdaptiveDesigner(prob)
    acc = PrivacyAccountant(clip_norm=1.0, delta=1e-4)
    for m in range(4):
        acc.register_client(m, 32, 1.0)   # sigma updated per phase below
    spent_c = 0.0
    for _ in range(4):
        plan = designer.replan(acc, spent_c)
        sol = plan.solution
        if plan.remaining_c < 101 or plan.remaining_eps_equiv < 1e-3:
            break
        # run ~a quarter of the phase plan, then re-plan
        steps = max(sol.tau, (sol.k // 4) // sol.tau * sol.tau)
        for m in range(4):
            acc.sigmas[m] = float(sol.sigmas[m])
        acc.step(steps)
        spent_c += steps / sol.tau * 100.0 + steps * 1.0
    assert acc.max_epsilon() <= prob.eps_th * (1 + 1e-6)


def test_adaptive_designer_uses_observed_constants():
    prob = _problem()
    designer = AdaptiveDesigner(prob)
    acc = PrivacyAccountant(clip_norm=1.0, delta=1e-4)
    for m in range(4):
        acc.register_client(m, 32, 1.0)
    p1 = designer.replan(acc, 0.0)
    # a much smaller remaining gap favors fewer iterations
    p2 = designer.replan(acc, 0.0, observed={"alpha": 0.01})
    assert p2.solution.k <= p1.solution.k


def test_personalized_privacy_budgets():
    """Beyond-paper: per-client eps budgets via per-client sigma (the paper
    names personalized DP as future work; the engine supports it natively)."""
    from repro.core.privacy import sigma_star
    k, g, x, delta = 400, 1.0, 32, 1e-4
    eps_budgets = [1.0, 4.0, 10.0]
    sigmas = [sigma_star(k, g, x, e, delta) for e in eps_budgets]
    assert sigmas[0] > sigmas[1] > sigmas[2]     # tighter budget, more noise
    for e, s in zip(eps_budgets, sigmas):
        assert epsilon_after_k(k, g, x, s, delta) == pytest.approx(e, rel=1e-6)

"""Integration + property tests for the DP-PASGD round engine (Eq. 7a-7b)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clipping import clip_tree, make_dp_grad_fn
from repro.core.fl import Budgets, Federation, FLConfig, make_round_step
from repro.data import adult_like, split_by_group, split_iid
from repro.models.linear import init_linear, logreg_loss, make_eval_fn
from repro.optim import sgd
from repro.utils.tree import tree_broadcast_axis0, tree_sq_norm


def _tiny_fed(n=600, dim=12, n_clients=4, seed=0):
    ds = adult_like(n=n, dim=dim, seed=seed)
    return split_iid(ds, n_clients, seed=seed)


def test_clip_tree_property():
    tree = {"a": jnp.ones((5, 3)) * 10.0, "b": jnp.ones((7,)) * -3.0}
    clipped, norm = clip_tree(tree, 1.0)
    assert float(jnp.sqrt(tree_sq_norm(clipped))) <= 1.0 + 1e-5
    assert float(norm) > 1.0
    # below the clip: untouched
    small = {"a": jnp.full((2,), 1e-3)}
    c2, _ = clip_tree(small, 1.0)
    np.testing.assert_allclose(c2["a"], small["a"], rtol=1e-6)


def test_per_example_equals_microbatch_of_one():
    """per-example clipping == microbatching with size-1 microbatches."""
    fed = _tiny_fed()
    params = init_linear(12)
    batch = {"x": jnp.asarray(fed.clients[0].x_train[:8]),
             "y": jnp.asarray(fed.clients[0].y_train[:8])}
    key = jax.random.PRNGKey(0)
    g8, _ = make_dp_grad_fn(logreg_loss, 0.5, num_microbatches=8)(
        params, batch, key, 0.0)
    g8b, _ = make_dp_grad_fn(logreg_loss, 0.5, num_microbatches=8,
                             vmap_microbatches=False)(params, batch, key, 0.0)
    for a, b in zip(jax.tree.leaves(g8), jax.tree.leaves(g8b)):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-6)


def test_round_step_tau1_sigma0_is_distributed_sgd():
    """tau=1, sigma=0, no clipping -> classic distributed SGD (Eq. 5)."""
    dim, C = 12, 4
    fed = _tiny_fed(n_clients=C)
    params0 = init_linear(dim)
    cfg = FLConfig(n_clients=C, tau=1, dp=False)
    rs = make_round_step(logreg_loss, sgd(0.5), cfg)

    sampler = fed.make_sampler(16)
    rng = np.random.default_rng(0)
    per_client = [sampler(m, 1, rng) for m in range(C)]
    batch = jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                         *per_client)

    params = tree_broadcast_axis0(params0, C)
    opt = sgd(0.5)
    opt_state = tree_broadcast_axis0(opt.init(params0), C)
    new_p, _, ms = rs(params, opt_state, batch,
                      jax.random.PRNGKey(0), jnp.zeros((C,)))

    # manual Eq. (5): average of per-client single-step updates
    grads = [jax.grad(logreg_loss)(params0,
                                   jax.tree.map(lambda x: x[c, 0], batch))
             for c in range(C)]
    mean_g = jax.tree.map(lambda *g: sum(g) / C, *grads)
    expect = jax.tree.map(lambda p, g: p - 0.5 * g, params0, mean_g)
    for a, b in zip(jax.tree.leaves(expect),
                    jax.tree.leaves(jax.tree.map(lambda x: x[0], new_p))):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_round_step_averages_clients():
    """After a round, every client holds the same (averaged) model."""
    C = 4
    fed = _tiny_fed(n_clients=C)
    params0 = init_linear(12)
    cfg = FLConfig(n_clients=C, tau=3, clip_norm=1.0, dp=True)
    rs = jax.jit(make_round_step(logreg_loss, sgd(0.1), cfg))
    sampler = fed.make_sampler(8)
    rng = np.random.default_rng(0)
    per_client = [sampler(m, 3, rng) for m in range(C)]
    batch = jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                         *per_client)
    params = tree_broadcast_axis0(params0, C)
    opt_state = tree_broadcast_axis0(sgd(0.1).init(params0), C)
    new_p, _, ms = rs(params, opt_state, batch, jax.random.PRNGKey(1),
                      0.1 * jnp.ones((C,)))
    w = np.asarray(new_p["w"])
    for c in range(1, C):
        np.testing.assert_allclose(w[0], w[c], rtol=1e-6)
    assert np.isfinite(float(ms["loss"]))


def test_noise_changes_update_but_average_concentrates():
    """DP noise perturbs each client; averaging shrinks its variance ~1/M."""
    C = 8
    params0 = init_linear(6)
    cfg_dp = FLConfig(n_clients=C, tau=1, clip_norm=1.0, dp=True)
    rs = jax.jit(make_round_step(logreg_loss, sgd(0.1), cfg_dp))
    x = np.zeros((C, 1, 4, 6), np.float32)
    y = np.zeros((C, 1, 4), np.int32)
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    params = tree_broadcast_axis0(params0, C)
    opt_state = tree_broadcast_axis0(sgd(0.1).init(params0), C)

    sig = 1.0
    outs = []
    for s in range(20):
        p, _, _ = rs(params, opt_state, batch, jax.random.PRNGKey(s),
                     sig * jnp.ones((C,)))
        outs.append(np.asarray(p["w"][0]))
    std_avg = np.std(np.stack(outs), axis=0).mean()
    # per-coordinate update noise is eta*sigma/sqrt(M); allow wide tolerance
    expect = 0.1 * sig / np.sqrt(C)
    assert 0.3 * expect < std_avg < 3.0 * expect


def test_federation_budget_stops():
    fed = _tiny_fed()
    params0 = init_linear(12)
    cfg = FLConfig(n_clients=fed.n_clients, tau=5, clip_norm=1.0, dp=True)
    sig = np.full((fed.n_clients,), 1.0, np.float32)
    f = Federation(cfg=cfg, loss_fn=logreg_loss, optimizer=sgd(0.2),
                   params0=params0, sampler=fed.make_sampler(16),
                   sigmas=sig, batch_sizes=fed.batch_sizes(16))
    budgets = Budgets(c_th=420.0, eps_th=1e9, c1=100.0, c2=1.0)
    out = f.train(budgets, max_rounds=100)
    # each round costs c1 + c2*tau = 105 -> exactly 4 rounds fit in 420
    assert out["rounds"] == 4
    assert out["resource_spent"] == pytest.approx(420.0)

    # privacy-limited stop
    f2 = Federation(cfg=cfg, loss_fn=logreg_loss, optimizer=sgd(0.2),
                    params0=params0, sampler=fed.make_sampler(16),
                    sigmas=np.full((fed.n_clients,), 0.05, np.float32),
                    batch_sizes=[4] * fed.n_clients)
    out2 = f2.train(Budgets(c_th=1e9, eps_th=0.5), max_rounds=100)
    assert out2["max_epsilon"] <= 0.5
    assert out2["rounds"] < 100


def test_federation_learns_noniid():
    """End-to-end: DP-PASGD on the non-iid adult surrogate reaches > 70% acc
    with a loose privacy budget."""
    ds = adult_like(n=4000, dim=24, seed=3)
    fed = split_by_group(ds)
    C = fed.n_clients
    params0 = init_linear(24)
    cfg = FLConfig(n_clients=C, tau=10, clip_norm=1.0, dp=True)
    sig = np.full((C,), 0.02, np.float32)
    f = Federation(cfg=cfg, loss_fn=logreg_loss, optimizer=sgd(0.5),
                   params0=params0, sampler=fed.make_sampler(32),
                   sigmas=sig, batch_sizes=fed.batch_sizes(32))
    xt, yt = fed.eval_arrays("test")
    eval_fn = make_eval_fn(logreg_loss, xt, yt)
    out = f.train(Budgets(c_th=3000.0, eps_th=1e9), max_rounds=40,
                  eval_fn=eval_fn, eval_every=5)
    assert out["best"]["eval_acc"] > 0.70

"""Per-architecture smoke tests: a REDUCED variant of each assigned family
runs one forward + one DP-PASGD train step + one prefill/decode step on CPU,
asserting output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_arch, smoke_variant
from repro.core.fl import FLConfig, make_round_step
from repro.models.transformer import Transformer
from repro.optim import sgd
from repro.utils.tree import tree_broadcast_axis0

B, S = 2, 16


def _batch(cfg, key):
    kt, kp = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(kp, (B, S), 0, cfg.vocab),
    }
    if cfg.prefix_len:
        batch["prefix"] = jax.random.normal(
            kp, (B, cfg.prefix_len, cfg.d_model), jnp.float32) * 0.02
    return batch


@pytest.fixture(scope="module", params=ASSIGNED_ARCHS)
def arch(request):
    return request.param


def test_smoke_forward_and_train_step(arch):
    cfg = smoke_variant(get_arch(arch))
    model = Transformer(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    batch = _batch(cfg, key)
    logits, aux = jax.jit(model.forward)(params, batch["tokens"],
                                         batch.get("prefix"))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss = model.loss_fn(params, batch)
    assert np.isfinite(float(loss))

    # one DP-PASGD round (C=2 clients, tau=2) on the reduced model
    C, tau = 2, 2
    flcfg = FLConfig(n_clients=C, tau=tau, clip_norm=1.0, dp=True)
    rs = jax.jit(make_round_step(model.loss_fn, sgd(1e-2), flcfg))
    params_c = tree_broadcast_axis0(params, C)
    opt_c = tree_broadcast_axis0(sgd(1e-2).init(params), C)
    rbatch = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None, None], (C, tau) + x.shape), batch)
    new_p, _, ms = rs(params_c, opt_c, rbatch, jax.random.PRNGKey(1),
                      0.01 * jnp.ones((C,)))
    assert np.isfinite(float(ms["loss"]))
    for leaf in jax.tree.leaves(new_p):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_smoke_prefill_decode(arch):
    cfg = smoke_variant(get_arch(arch))
    model = Transformer(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)
    prefix = batch.get("prefix")

    logits_pf, caches, pos = jax.jit(
        lambda p, t, pre: model.prefill(p, t, pre, max_len=S + 4)
    )(params, batch["tokens"], prefix)
    assert logits_pf.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits_pf, np.float32)).all()

    next_tok = jnp.argmax(logits_pf, axis=-1).astype(jnp.int32)
    logits_dec, caches = jax.jit(model.decode_step)(params, caches, next_tok,
                                                    pos)
    assert logits_dec.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits_dec, np.float32)).all()


def test_smoke_decode_matches_forward(arch):
    """Teacher-forced decode token-by-token == full forward (same params)."""
    cfg = smoke_variant(get_arch(arch))
    if cfg.prefix_len:
        pytest.skip("prefix archs covered by prefill/decode smoke")
    model = Transformer(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)

    full_logits, _ = jax.jit(model.forward)(params, toks)

    caches = model.init_cache(B, S)
    dec = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, caches = dec(params, caches, toks[:, t],
                         jnp.asarray(t, jnp.int32))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-2, atol=2e-2)

"""Property + unit tests for the zCDP accountant (paper §3, §5.2, Eq. 9/23)."""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import privacy


def test_gaussian_zcdp_lemma2():
    # Lemma 2: rho = Delta^2 / (2 sigma^2)
    assert privacy.gaussian_zcdp(2.0, 1.0) == pytest.approx(2.0)
    assert privacy.gaussian_zcdp(1.0, 2.0) == pytest.approx(0.125)
    assert privacy.gaussian_zcdp(1.0, 0.0) == math.inf


def test_composition_lemma1():
    assert privacy.compose_zcdp(0.1, 0.2, 0.3) == pytest.approx(0.6)


def test_zcdp_to_dp_lemma3():
    rho, delta = 0.5, 1e-4
    eps = privacy.zcdp_to_dp(rho, delta)
    assert eps == pytest.approx(rho + 2 * math.sqrt(rho * math.log(1 / delta)))


def test_eq9_matches_accountant():
    k, g, x, sigma, delta = 200, 1.0, 64, 1.5, 1e-4
    acc = privacy.PrivacyAccountant(clip_norm=g, delta=delta)
    acc.register_client(0, x, sigma)
    acc.step(k)
    assert acc.epsilon(0) == pytest.approx(
        privacy.epsilon_after_k(k, g, x, sigma, delta))


@settings(max_examples=200, deadline=None)
@given(
    k=st.integers(1, 100_000),
    g=st.floats(0.01, 100.0),
    x=st.integers(1, 10_000),
    eps_th=st.floats(0.01, 100.0),
    delta=st.floats(1e-8, 1e-2),
)
def test_sigma_star_inverts_eq9(k, g, x, eps_th, delta):
    """PROPERTY: the (corrected) Eq.-23 noise exactly spends the eps budget."""
    sigma = privacy.sigma_star(k, g, x, eps_th, delta)
    eps = privacy.epsilon_after_k(k, g, x, sigma, delta)
    assert eps == pytest.approx(eps_th, rel=1e-6)


@settings(max_examples=100, deadline=None)
@given(
    k=st.integers(1, 10_000),
    sigma=st.floats(0.1, 50.0),
    x=st.integers(1, 1_000),
)
def test_epsilon_monotone_in_k_and_sigma(k, sigma, x):
    """PROPERTY: eps grows with K, shrinks with sigma (paper §5.2 discussion)."""
    e1 = privacy.epsilon_after_k(k, 1.0, x, sigma, 1e-4)
    e2 = privacy.epsilon_after_k(k + 1, 1.0, x, sigma, 1e-4)
    e3 = privacy.epsilon_after_k(k, 1.0, x, sigma * 2, 1e-4)
    assert e2 > e1 > e3


def test_paper_eq23_as_printed_is_inconsistent():
    """Documents the erratum: the printed Eq. (23) under-spends noise."""
    k, g, x, eps_th, delta = 100, 1.0, 32, 1.39, 1e-4
    z = privacy.privacy_z(eps_th, delta)
    sigma_printed = math.sqrt(2 * k * g * g / (x * x * z))
    eps_printed = privacy.epsilon_after_k(k, g, x, sigma_printed, delta)
    assert eps_printed > 10 * eps_th  # badly violates the budget
    sigma_fixed = privacy.sigma_star(k, g, x, eps_th, delta)
    assert privacy.epsilon_after_k(k, g, x, sigma_fixed, delta) == pytest.approx(
        eps_th, rel=1e-6)


def test_rho_budget_identity_with_z():
    # rho* = eps^2 / Z identity used in design.py
    eps_th, delta = 4.0, 1e-4
    assert privacy.rho_budget(eps_th, delta) == pytest.approx(
        eps_th ** 2 / privacy.privacy_z(eps_th, delta))


def test_remaining_steps():
    acc = privacy.PrivacyAccountant(clip_norm=1.0, delta=1e-4)
    acc.register_client(0, 100, 2.0)
    n = acc.remaining_steps(0, eps_th=1.0)
    assert n > 0
    acc.step(n)
    assert acc.epsilon(0) <= 1.0 + 1e-9
    acc.step(1)
    assert acc.epsilon(0) > 1.0


def test_accountant_validates_inputs():
    acc = privacy.PrivacyAccountant(clip_norm=1.0, delta=1e-4)
    with pytest.raises(ValueError):
        acc.register_client(0, 0, 1.0)
    with pytest.raises(ValueError):
        acc.register_client(0, 10, -1.0)


# -------------------- subsampled participation (amplification) --------------

def test_subsampled_rho_pins_amplification_math():
    """Realized-step accounting: each participating step costs q * rho_step
    (q^2 per-round expectation amortized over the ~q participating rounds);
    q = 1 is exact Lemma 2."""
    rho_step = privacy.gaussian_zcdp(privacy.grad_sensitivity(1.0, 32), 2.0)
    assert privacy.subsampled_rho(rho_step, 1.0) == rho_step
    assert privacy.subsampled_rho(rho_step, 0.25) == pytest.approx(
        0.25 * rho_step)
    with pytest.raises(ValueError):
        privacy.subsampled_rho(rho_step, 0.0)
    with pytest.raises(ValueError):
        privacy.subsampled_rho(rho_step, 1.5)


def test_accountant_subsampled_steps_strictly_below_full():
    """Same round count: q < 1 participation yields strictly lower
    max_epsilon than q = 1 — even for a client sampled EVERY round, whose
    per-step rho still carries the amplification factor q."""
    def run(q, rounds=10, tau=5):
        acc = privacy.PrivacyAccountant(clip_norm=1.0, delta=1e-4)
        for m in range(4):
            acc.register_client(m, 32, 1.5)
        for _ in range(rounds):
            acc.step(tau, clients=[0, 1], q=q)   # worst clients always in
        return acc
    full = run(1.0)
    half = run(0.5)
    assert half.max_epsilon() < full.max_epsilon()
    # exact ledger: rho scales linearly with q for a fixed participant set
    assert half.rho(0) == pytest.approx(0.5 * full.rho(0), rel=1e-12)
    # non-participants spent nothing
    assert half.rho(2) == 0.0 and half.epsilon(2) == 0.0
    # the pre-round probe carries the same amplification
    assert half.peek_epsilon(5, q=0.5) < full.peek_epsilon(5, q=1.0)


# -------------------- vectorized chunk replay (step_many) -------------------

def _fresh_acc(sigmas=(1.5, 2.0, 0.8, 1.2), batches=(32, 16, 8, 64)):
    acc = privacy.PrivacyAccountant(clip_norm=1.0, delta=1e-4)
    for m, (x, s) in enumerate(zip(batches, sigmas)):
        acc.register_client(m, x, s)
    return acc


def test_step_many_bit_identical_to_sequential_steps():
    """step_many replays a chunk of rounds bit-for-bit: same dict ledger,
    same step count, and the returned trajectory is the per-round worst
    rho, for masked (partial participation) and unmasked chunks."""
    import numpy as np
    rng = np.random.default_rng(0)
    masks = (rng.random((6, 4)) < 0.5).astype(np.float32)
    for use_masks, q in [(False, 1.0), (True, 1.0), (True, 0.5)]:
        seq, vec = _fresh_acc(), _fresh_acc()
        worst_seq = []
        for r in range(6):
            clients = (np.flatnonzero(masks[r]) if use_masks else None)
            seq.step(3, clients=clients, q=q)
            worst_seq.append(max(seq._rho.values()))
        worst = vec.step_many([3] * 6, masks=masks if use_masks else None,
                              q=q)
        assert vec._rho == seq._rho            # bit-identical dict ledger
        assert vec.steps == seq.steps == 18
        assert list(worst) == worst_seq


def test_step_many_validates_inputs():
    import numpy as np
    acc = _fresh_acc()
    with pytest.raises(ValueError):
        acc.step_many([3, -1])
    with pytest.raises(ValueError):
        acc.step_many([3, 3], masks=np.ones((3, 4)))   # R mismatch
    with pytest.raises(ValueError):
        privacy.PrivacyAccountant(clip_norm=1.0, delta=1e-4).step_many([1])


def test_step_many_handles_infinite_charges():
    """sigma = 0 clients (dp off / undesigned noise) carry rho = inf; the
    masked replay must not turn non-participating inf charges into NaN."""
    import numpy as np
    acc = _fresh_acc(sigmas=(0.0, 2.0, 2.0, 2.0))
    masks = np.asarray([[0, 1, 1, 0], [1, 0, 1, 0]], np.float32)
    worst = acc.step_many([2, 2], masks=masks)
    assert acc.rho(0) == math.inf              # participated in round 2
    assert acc.rho(3) == 0.0                   # never participated
    assert worst[0] < math.inf and worst[1] == math.inf

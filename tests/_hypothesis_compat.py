"""Fallback property-testing shim used when `hypothesis` is not installed.

The test suite uses a small slice of the hypothesis API:

    @settings(max_examples=N, deadline=None)
    @given(k=st.integers(lo, hi), x=st.floats(lo, hi))
    def test_...(k, x): ...

When the real package is available, ``install()`` is a no-op and the tests
run under genuine hypothesis shrinking. When it is missing, ``install()``
registers stand-in ``hypothesis`` / ``hypothesis.strategies`` modules that
replay a deterministic sample of the strategy space (bounds first, then
seeded uniform draws), so the suite still collects and exercises every
property — just without adaptive search.
"""
from __future__ import annotations

import sys
import types

import numpy as np

# Cap replay count: the shim has no shrinking, so hundreds of uniform draws
# add runtime without adding much coverage beyond the bounds + interior mix.
_MAX_REPLAY = 25
_DEFAULT_EXAMPLES = 20


class _Strategy:
    """A bounded scalar strategy: deterministic boundary + seeded draws."""

    def __init__(self, draw, bounds=()):
        self._draw = draw
        self._bounds = tuple(bounds)

    def examples(self, n: int, rng: np.random.Generator):
        out = list(self._bounds[:n])
        while len(out) < n:
            out.append(self._draw(rng))
        return out


def integers(min_value: int, max_value: int) -> _Strategy:
    lo, hi = int(min_value), int(max_value)
    return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)),
                     bounds=(lo, hi) if lo != hi else (lo,))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    lo, hi = float(min_value), float(max_value)
    return _Strategy(lambda rng: float(rng.uniform(lo, hi)),
                     bounds=(lo, hi) if lo != hi else (lo,))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)), bounds=(False, True))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))],
                     bounds=elements)


def given(*args, **strategies):
    if args:
        raise NotImplementedError(
            "_hypothesis_compat only supports keyword strategies")

    def deco(fn):
        def wrapper():
            n = min(getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES),
                    _MAX_REPLAY)
            rng = np.random.default_rng(0)
            cols = {k: s.examples(n, rng) for k, s in strategies.items()}
            for i in range(n):
                fn(**{k: v[i] for k, v in cols.items()})
        # NOT functools.wraps: __wrapped__ would expose fn's signature and
        # make pytest resolve the strategy kwargs as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def settings(max_examples: int | None = None, deadline=None, **_kw):
    def deco(fn):
        if max_examples is not None:
            fn._max_examples = max_examples
        return fn
    return deco


def install() -> bool:
    """Register the shim under ``hypothesis`` if the real package is absent.

    Returns True when the shim was installed, False when real hypothesis is
    already importable.
    """
    try:
        import hypothesis  # noqa: F401
        return False
    except ModuleNotFoundError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from"):
        setattr(st_mod, name, globals()[name])
    mod.strategies = st_mod
    mod.__is_compat_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
    return True

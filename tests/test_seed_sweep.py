"""Seed-sweep flakiness guard (REPRO_SLOW=1): the asyncfl and population
identity gates re-run at 3 extra seeds.

The standing gates in tests/test_asyncfl.py / tests/test_population.py pin
bit-identity at one seed; a gate that holds only at seed 0 is a coincidence
(e.g. a participant draw that happens to be all-clients). This sweep varies
the spec seed, the data stream, and the model init together, and is gated
behind ``REPRO_SLOW=1`` so the default tier-1 run stays fast:

    REPRO_SLOW=1 PYTHONPATH=src python -m pytest tests/test_seed_sweep.py
"""
import os

import jax
import numpy as np
import pytest

from repro.api import FederationSpec, init_state, round_batch, run_round
from repro.asyncfl import UniformLatency, init_async_state, run_async_cycle
from repro.data import adult_like, split_iid
from repro.models.linear import init_linear, logreg_loss
from repro.optim import sgd
from repro.population import (
    UniformCohort,
    chunk_cohorts,
    init_population_state,
    init_resident_cache,
    population_from_federated,
    run_cohort_round,
    run_resident_rounds,
    synthetic_population,
)

pytestmark = pytest.mark.skipif(
    not os.environ.get("REPRO_SLOW"),
    reason="seed sweep is the slow tier: set REPRO_SLOW=1 to run")

C, TAU, DIM, B = 4, 3, 8, 4
SEEDS = (1, 2, 3)               # extra seeds beyond the standing gates' 0
OPT = sgd(0.2)

# the degenerate clock of the async identity gate: every dispatch takes
# exactly 1.1 simulated seconds, so all C uploads arrive together
FLAT_CLOCK = UniformLatency(0, compute=(1.0, 1.0), upload=(0.1, 0.1))


def _spec(engine="vmap", seed=0, **kw):
    base = dict(n_clients=C, tau=TAU, loss_fn=logreg_loss, optimizer=OPT,
                clip_norm=1.0, dp=True, sigmas=(0.5,) * C,
                batch_sizes=(B,) * C, engine=engine, seed=seed)
    base.update(kw)
    return FederationSpec(**base)


def _sampler(m, tau, rng):
    return {"x": rng.normal(size=(tau, B, DIM)).astype(np.float32),
            "y": rng.integers(0, 2, size=(tau, B)).astype(np.int32)}


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("name,kw", [("q50", dict(participation=0.5)),
                                     ("topk25", dict(compressor="topk",
                                                     compression_ratio=0.25))],
                         ids=["q50", "topk25"])
def test_async_sync_identity_gate_seed_sweep(seed, name, kw):
    """Degenerate buffered-async == sync vmap, bit for bit, at every swept
    seed (spec key, model init, and data stream all vary with it)."""
    ss = _spec("vmap", seed=seed, **kw)
    sa = _spec("async_buffered", seed=seed, **kw)
    rng_s, rng_a = np.random.default_rng(seed), np.random.default_rng(seed)
    st_s = init_state(ss, init_linear(DIM, seed=seed))
    st_a = init_async_state(sa, init_linear(DIM, seed=seed), _sampler,
                            rng=rng_a, latency_model=FLAT_CLOCK)
    for _ in range(3):
        st_s, _ = run_round(ss, st_s, round_batch(ss, _sampler, rng_s),
                            check_budgets=False)
        st_a, _ = run_async_cycle(sa, st_a, _sampler, rng_a,
                                  latency_model=FLAT_CLOCK,
                                  check_budgets=False)
        _leaves_equal(jax.tree.map(lambda x: x[0], st_s.params),
                      st_a.global_params)
        np.testing.assert_array_equal(st_s.rho, st_a.fl.rho)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("name,kw", [("q50", dict(participation=0.5)),
                                     ("topk25", dict(compressor="topk",
                                                     compression_ratio=0.25))],
                         ids=["q50", "topk25"])
def test_population_identity_gate_seed_sweep(seed, name, kw):
    """Cohort == population (M == C) == dense participation path, bit for
    bit, at every swept seed."""
    fed = split_iid(adult_like(n=400, dim=DIM, seed=seed), C)
    dense = _spec(seed=seed, **kw)
    pspec = _spec(seed=seed, population=C, cohort_size=C, **kw)
    pop = population_from_federated(fed, B)
    s_d = init_state(dense, init_linear(DIM, seed=seed))
    s_p = init_population_state(pspec, init_linear(DIM, seed=seed))
    rng_d, rng_p = np.random.default_rng(seed), np.random.default_rng(seed)
    sampler = fed.make_sampler(B)
    for _ in range(3):
        s_d, rec_d = run_round(dense, s_d, round_batch(dense, sampler, rng_d),
                               check_budgets=False)
        s_p, rec_p = run_cohort_round(pspec, s_p, pop, rng_p,
                                      check_budgets=False)
        assert float(rec_p["loss"]) == float(rec_d["loss"])
    for a, b in zip(jax.tree.leaves(s_d.params),
                    jax.tree.leaves(s_p.fl.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(s_d.rho, s_p.store.rho)


M_POP = 12                      # M > K: real cohort subsampling


def _run_per_round(pspec, pop, seed, n_rounds):
    """The per-round cohort driver reference: (state, per-round losses)."""
    st = init_population_state(pspec, init_linear(DIM, seed=seed))
    rng = np.random.default_rng(seed)
    losses = []
    for _ in range(n_rounds):
        st, rec = run_cohort_round(pspec, st, pop, rng, check_budgets=False)
        losses.append(float(rec["loss"]))
    return st, losses


def _assert_resident_matches(s_a, s_b, losses_a, losses_b):
    """Full bit-identity: params, opt_state, ledger, participation counts,
    resource meter, residual store rows, and the per-round loss stream."""
    assert losses_a == losses_b
    _leaves_equal(s_a.fl.params, s_b.fl.params)
    _leaves_equal(s_a.fl.opt_state, s_b.fl.opt_state)
    np.testing.assert_array_equal(s_a.store.rho, s_b.store.rho)
    np.testing.assert_array_equal(s_a.store.rounds_participated,
                                  s_b.store.rounds_participated)
    assert float(s_a.fl.resource_spent) == float(s_b.fl.resource_spent)
    if s_a.store.needs_residual():
        vids = np.arange(M_POP)
        np.testing.assert_array_equal(s_a.store.gather_residual(vids),
                                      s_b.store.gather_residual(vids))


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("name,kw", [("q50", dict(participation=0.5)),
                                     ("topk25", dict(compressor="topk",
                                                     compression_ratio=0.25))],
                         ids=["q50", "topk25"])
def test_resident_identity_gate_seed_sweep(seed, name, kw):
    """Resident-cohort driver (fresh cohort per round inside the fused
    scan, S warm clients on device) == per-round cohort driver, bit for
    bit, at every swept seed — the PR-5 identity gate extended to the
    resident path, with M > K so cohorts genuinely subsample."""
    n_rounds, chunk = 6, 3
    pspec = _spec(seed=seed, population=M_POP, cohort_size=C, **kw)
    pop = synthetic_population(M_POP, DIM, batch_size=B, seed=seed)
    s_a, losses_a = _run_per_round(pspec, pop, seed, n_rounds)

    s_b = init_population_state(pspec, init_linear(DIM, seed=seed))
    rng = np.random.default_rng(seed)
    cache = init_resident_cache(pspec, s_b, M_POP, population=pop)
    losses_b = []
    for _ in range(n_rounds // chunk):
        s_b, recs = run_resident_rounds(pspec, s_b, pop, rng, cache,
                                        n_rounds=chunk, check_budgets=False)
        losses_b.extend(float(r["loss"]) for r in recs)
    cache.flush(s_b.store)
    _assert_resident_matches(s_a, s_b, losses_a, losses_b)


@pytest.mark.parametrize("seed", SEEDS)
def test_resident_eviction_churn_identity(seed):
    """Arbitrary warm-set churn: a cache of S == K + 1 slots forces LRU
    evictions (lazy write-back) and re-promotions every chunk, and the
    store still lands bit-identical to the no-cache path after flush —
    on a *stationary* population, so the data-resident shard block and
    its eviction bookkeeping are exercised too."""
    n_rounds = 6
    kw = dict(compressor="topk", compression_ratio=0.25)
    pspec = _spec(seed=seed, population=M_POP, cohort_size=C, **kw)
    pop = synthetic_population(M_POP, DIM, batch_size=B, seed=seed,
                               stationary=True)
    s_a, losses_a = _run_per_round(pspec, pop, seed, n_rounds)

    s_b = init_population_state(pspec, init_linear(DIM, seed=seed))
    rng = np.random.default_rng(seed)
    cache = init_resident_cache(pspec, s_b, C + 1, population=pop)
    losses_b = []
    for _ in range(n_rounds):   # one-round chunks: union K <= S, max churn
        s_b, recs = run_resident_rounds(pspec, s_b, pop, rng, cache,
                                        n_rounds=1, check_budgets=False)
        losses_b.extend(float(r["loss"]) for r in recs)
    cache.flush(s_b.store)
    # the property is vacuous unless eviction actually happened
    assert cache.stats["evictions"] > 0
    _assert_resident_matches(s_a, s_b, losses_a, losses_b)


@pytest.mark.parametrize("seed", SEEDS)
def test_chunked_schedule_matches_per_round(seed):
    """chunk_cohorts realizes EXACTLY the per-round sampler draws, and is
    invariant to how rounds are split into chunks — the schedule-identity
    the resident driver's fused scan relies on."""
    m, k, rounds = 100, 8, 10
    sampler = UniformCohort(seed)
    per_round = np.stack([sampler(r, m, k) for r in range(rounds)])
    np.testing.assert_array_equal(
        chunk_cohorts(sampler, 0, rounds, m, k), per_round)
    np.testing.assert_array_equal(
        np.vstack([chunk_cohorts(sampler, 0, 4, m, k),
                   chunk_cohorts(sampler, 4, rounds - 4, m, k)]), per_round)

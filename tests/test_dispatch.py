"""Tests for the kernel backend dispatch layer and the auto-selection
logic: registry behavior, ``resolve_backend`` (env override + monkeypatched
capability probes), ``resolve_engine`` (monkeypatched device counts), and
the acceptance gate — vmap/map/shard_map rounds numerically identical
whether ``kernel_backend`` is "ref" or "interpret"."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.dispatch as dispatch
from repro.api import FederationSpec, init_state, resolve_engine, run_round
from repro.kernels.dispatch import (
    KERNEL_BACKENDS,
    available_backends,
    backend_works,
    get_kernel,
    kernel_names,
    register_kernel,
    resolve_backend,
)
from repro.models.linear import init_linear, logreg_loss
from repro.optim import sgd

C, TAU, DIM, B = 4, 3, 8, 4


def _spec(**kw):
    base = dict(n_clients=C, tau=TAU, loss_fn=logreg_loss, optimizer=sgd(0.2),
                clip_norm=1.0, dp=True, sigmas=(0.5,) * C,
                batch_sizes=(B,) * C)
    base.update(kw)
    return FederationSpec(**base)


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    return {"x": jnp.asarray(rng.normal(size=(C, TAU, B, DIM)), jnp.float32),
            "y": jnp.asarray(rng.integers(0, 2, size=(C, TAU, B)), jnp.int32)}


# ---------------------------- registry --------------------------------------

def test_registry_contents():
    assert set(kernel_names()) == {"dp_clip_noise", "flash_attention",
                                   "rwkv6_scan", "mamba2_ssd",
                                   "quantize_decompress",
                                   "cohort_gather_scatter"}
    with pytest.raises(KeyError):
        get_kernel("nope")
    for name in kernel_names():
        # ref is the guaranteed floor; listing is ordered best-first
        avail = available_backends(name)
        assert avail[-1] == "ref"
        assert set(avail) <= {"pallas", "interpret", "ref"}


def test_register_kernel_roundtrip():
    calls = []
    register_kernel("_test_kernel", ref=lambda x, **_: calls.append(x) or x)
    try:
        assert "_test_kernel" in kernel_names()
        assert available_backends("_test_kernel") == ("ref",)
        assert resolve_backend("_test_kernel", "auto") == "ref"
        assert get_kernel("_test_kernel")(5) == 5 and calls == [5]
        with pytest.raises(ValueError):   # no pallas impl registered
            get_kernel("_test_kernel", "interpret")
    finally:
        dispatch._REGISTRY.pop("_test_kernel")
        dispatch.backend_works.cache_clear()


# ---------------------------- resolve_backend -------------------------------

def test_resolve_backend_explicit_wins(monkeypatch):
    monkeypatch.setenv(dispatch.KERNEL_BACKEND_ENV, "ref")
    # explicit non-auto ignores both the env var and the probes
    assert resolve_backend("dp_clip_noise", "interpret") == "interpret"
    assert resolve_backend("dp_clip_noise", "ref") == "ref"
    with pytest.raises(ValueError):
        resolve_backend("dp_clip_noise", "bogus")


def test_resolve_backend_env_override(monkeypatch):
    monkeypatch.setenv(dispatch.KERNEL_BACKEND_ENV, "ref")
    assert resolve_backend("dp_clip_noise", "auto") == "ref"
    monkeypatch.setenv(dispatch.KERNEL_BACKEND_ENV, "bogus")
    with pytest.raises(ValueError):
        resolve_backend("dp_clip_noise", "auto")
    monkeypatch.delenv(dispatch.KERNEL_BACKEND_ENV)
    assert resolve_backend("dp_clip_noise", "auto") in ("pallas", "interpret",
                                                        "ref")


def test_resolve_backend_probe_fallback(monkeypatch):
    """On TPU, auto walks pallas > interpret > ref by (monkeypatched)
    capability — interpret is a sensible fallback there (same Mosaic
    lowering semantics, and the oracle may not be tuned for the platform)."""
    monkeypatch.delenv(dispatch.KERNEL_BACKEND_ENV, raising=False)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")

    def works(table):
        return lambda name, backend: table.get(backend, backend == "ref")

    monkeypatch.setattr(dispatch, "backend_works",
                        works({"pallas": True, "interpret": True}))
    assert resolve_backend("dp_clip_noise", "auto") == "pallas"
    monkeypatch.setattr(dispatch, "backend_works",
                        works({"pallas": False, "interpret": True}))
    assert resolve_backend("dp_clip_noise", "auto") == "interpret"
    monkeypatch.setattr(dispatch, "backend_works",
                        works({"pallas": False, "interpret": False}))
    assert resolve_backend("dp_clip_noise", "auto") == "ref"


def test_resolve_backend_ref_outranks_interpret_off_tpu(monkeypatch):
    """ROADMAP open item (closed): on non-TPU backends the auto probe ranks
    the jnp oracle ABOVE pallas interpret mode (~100x slower on CPU) — a
    working interpret backend no longer captures the engine hot path."""
    monkeypatch.delenv(dispatch.KERNEL_BACKEND_ENV, raising=False)

    def works(table):
        return lambda name, backend: table.get(backend, backend == "ref")

    for platform in ("cpu", "gpu"):
        monkeypatch.setattr(jax, "default_backend", lambda p=platform: p)
        monkeypatch.setattr(dispatch, "backend_works",
                            works({"pallas": False, "interpret": True}))
        assert resolve_backend("dp_clip_noise", "auto") == "ref"
    # explicit interpret (arg or env) still reachable for the parity suites
    assert resolve_backend("dp_clip_noise", "interpret") == "interpret"
    monkeypatch.setenv(dispatch.KERNEL_BACKEND_ENV, "interpret")
    assert resolve_backend("dp_clip_noise", "auto") == "interpret"


def test_backend_works_probe_failure_reads_as_unavailable(monkeypatch):
    """A drifted-API exception inside the probe means False, not a raise."""
    entry = dispatch._entry("dp_clip_noise")

    def boom(_impl):
        raise AttributeError("simulated pallas API drift")

    monkeypatch.setitem(dispatch._REGISTRY, "dp_clip_noise",
                        dispatch.KernelEntry(name=entry.name,
                                             pallas_fn=entry.pallas_fn,
                                             ref_fn=entry.ref_fn,
                                             probe=boom))
    dispatch.backend_works.cache_clear()
    try:
        assert backend_works("dp_clip_noise", "interpret") is False
        assert backend_works("dp_clip_noise", "ref") is True
        assert resolve_backend("dp_clip_noise", "auto") == "ref"
    finally:
        dispatch.backend_works.cache_clear()


def test_pallas_backend_gated_on_tpu(monkeypatch):
    dispatch.backend_works.cache_clear()
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert backend_works("dp_clip_noise", "pallas") is False
    dispatch.backend_works.cache_clear()


def test_disable_env_simulates_oracle_only(monkeypatch):
    """KERNEL_DISPATCH_DISABLE makes probes report the named backends
    unavailable — the knob CI's ref leg uses to rehearse a broken pallas."""
    monkeypatch.setenv(dispatch.KERNEL_DISABLE_ENV, "pallas,interpret")
    monkeypatch.delenv(dispatch.KERNEL_BACKEND_ENV, raising=False)
    dispatch.backend_works.cache_clear()
    try:
        assert available_backends("dp_clip_noise") == ("ref",)
        assert resolve_backend("dp_clip_noise", "auto") == "ref"
        assert backend_works("dp_clip_noise", "ref") is True  # not disableable
    finally:
        dispatch.backend_works.cache_clear()


# ---------------------------- spec plumbing ---------------------------------

def test_spec_kernel_backend_validation_and_engine_key():
    with pytest.raises(ValueError):
        _spec(kernel_backend="bogus")
    s = _spec(kernel_backend="ref")
    assert s.fl_config().kernel_backend == "ref"
    assert s.engine_key() != _spec(kernel_backend="interpret").engine_key()
    assert s.replace(eps_th=4.0).engine_key() == s.engine_key()


def test_flconfig_default_keeps_legacy_path():
    from repro.core.fl import FLConfig
    assert FLConfig(n_clients=2, tau=1).kernel_backend is None


# ---------------------------- engine auto selection -------------------------

def test_engine_auto_selection_by_device_count(monkeypatch):
    fake_dev = [object()] * 4
    monkeypatch.setattr(jax, "devices", lambda *a, **k: fake_dev)
    assert resolve_engine(_spec(engine="auto")) == "shard_map"
    monkeypatch.setattr(jax, "devices", lambda *a, **k: fake_dev[:1])
    assert resolve_engine(_spec(engine="auto")) == "vmap"
    # explicit engine is never overridden
    monkeypatch.setattr(jax, "devices", lambda *a, **k: fake_dev)
    assert resolve_engine(_spec(engine="map")) == "map"


def test_engine_auto_with_kernel_backend_auto(monkeypatch):
    """The two auto knobs compose: resolved engine + resolved backend both
    concrete, and the spec-built round runs."""
    monkeypatch.delenv(dispatch.KERNEL_BACKEND_ENV, raising=False)
    spec = _spec(engine="auto", kernel_backend="auto")
    assert resolve_engine(spec) in ("vmap", "map", "shard_map")
    assert resolve_backend("dp_clip_noise", spec.kernel_backend) in (
        "pallas", "interpret", "ref")
    state = init_state(spec, init_linear(DIM))
    state, rec = run_round(spec, state, _batch(), check_budgets=False)
    assert np.isfinite(rec["loss"])


# ---------------------------- acceptance: engine × backend parity -----------

@pytest.mark.parametrize("engine", ["vmap", "map", "shard_map"])
def test_engine_round_parity_ref_vs_interpret(engine):
    """vmap/map/shard_map rounds are numerically identical (atol 1e-5)
    whether the clip+noise hot path runs on "ref" or "interpret"."""
    if "interpret" not in available_backends("dp_clip_noise"):
        pytest.skip("pallas interpret unavailable on this jax")
    params0 = init_linear(DIM)
    batch = _batch()

    def run(backend):
        spec = _spec(engine=engine, kernel_backend=backend)
        state = init_state(spec, params0)
        recs = []
        for _ in range(2):
            state, rec = run_round(spec, state, batch, check_budgets=False)
            recs.append(rec)
        return state, recs

    ref_state, ref_recs = run("ref")
    got_state, got_recs = run("interpret")
    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(got_state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    for ra, rb in zip(ref_recs, got_recs):
        assert rb["loss"] == pytest.approx(ra["loss"], rel=1e-5)

"""Tests for the fused multi-round driver: run_rounds chunk/loop identity,
the vectorized ledger replay, lazy metric records, cached ledger constants,
the incremental budget probe, and the chunked train driver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    BudgetExceeded,
    FederationSpec,
    PrefetchFailed,
    accountant_view,
    exceeds_budgets,
    init_state,
    load_state,
    materialize_record,
    peek_epsilon_fast,
    round_batches,
    rounds_within_budgets,
    run_round,
    run_rounds,
    save_state,
    sigmas_for,
    train,
)
from repro.models.linear import init_linear, logreg_loss
from repro.optim import momentum, sgd

C, TAU, DIM, B = 4, 3, 8, 4


def _spec(**kw):
    base = dict(n_clients=C, tau=TAU, loss_fn=logreg_loss, optimizer=sgd(0.2),
                clip_norm=1.0, dp=True, sigmas=(0.5,) * C,
                batch_sizes=(B,) * C)
    base.update(kw)
    return FederationSpec(**base)


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    return {"x": np.asarray(rng.normal(size=(C, TAU, B, DIM)), np.float32),
            "y": np.asarray(rng.integers(0, 2, size=(C, TAU, B)), np.int32)}


def _stacked(n, seed0=0):
    return jax.tree.map(lambda *xs: np.stack(xs),
                        *[_batch(seed0 + i) for i in range(n)])


def _sampler(m, tau, rng):
    return {"x": rng.normal(size=(tau, B, DIM)).astype(np.float32),
            "y": rng.integers(0, 2, size=(tau, B)).astype(np.int32)}


def _assert_states_identical(a, b):
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(a.opt_state),
                    jax.tree.leaves(b.opt_state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(a.key), np.asarray(b.key))
    np.testing.assert_array_equal(a.rho, b.rho)
    assert (a.residual is None) == (b.residual is None)
    if a.residual is not None:
        np.testing.assert_array_equal(np.asarray(a.residual),
                                      np.asarray(b.residual))
    assert a.steps == b.steps
    assert a.resource_spent == b.resource_spent
    assert a.rounds_done == b.rounds_done


# ---------------------- chunked-vs-loop identity gate ------------------------

IDENTITY_SETTINGS = [
    ("dense", {}),
    ("participation", dict(participation=0.5)),
    ("topk", dict(compressor="topk", compression_ratio=0.25,
                  participation=0.5)),
    ("randk", dict(compressor="randk", compression_ratio=0.25)),
    ("qsgd", dict(compressor="qsgd", compression_bits=6)),
    ("amplified", dict(participation=0.5, amplify_participation=True)),
]


@pytest.mark.parametrize("engine", ["vmap", "map", "shard_map"])
@pytest.mark.parametrize("name,kw", IDENTITY_SETTINGS,
                         ids=[n for n, _ in IDENTITY_SETTINGS])
def test_run_rounds_bit_identical_to_loop(engine, name, kw):
    """run_rounds(n=4) == four run_round calls, bit for bit: params,
    opt_state, rho ledger, error-feedback residual, RNG key, resource
    accounting — and matching per-round metrics records."""
    spec = _spec(engine=engine, **kw)
    params0 = init_linear(DIM)
    n = 4

    seq = init_state(spec, params0)
    seq_recs = []
    for i in range(n):
        seq, rec = run_round(spec, seq, _batch(i), check_budgets=False)
        seq_recs.append(materialize_record(rec))

    fused = init_state(spec, params0)
    fused, recs = run_rounds(spec, fused, _stacked(n), n, check_budgets=False)

    _assert_states_identical(seq, fused)
    assert len(recs) == n
    for ra, rb in zip(seq_recs, (materialize_record(r) for r in recs)):
        assert set(ra) == set(rb)
        assert rb["loss"] == pytest.approx(ra["loss"], rel=1e-6)
        assert rb["round"] == ra["round"]
        assert rb["iterations"] == ra["iterations"]
        assert rb["max_epsilon"] == ra["max_epsilon"]          # exact replay
        assert rb["resource_spent"] == ra["resource_spent"]    # exact replay
        assert rb["participants"] == ra["participants"]


def test_run_rounds_infers_length_and_momentum_carry():
    """n_rounds defaults to the stacked leading axis, and stateful
    optimizers (momentum velocity + int step counter) carry through the
    scan bit-identically."""
    spec = _spec(optimizer=momentum(0.2, 0.9))
    params0 = init_linear(DIM)
    n = 3
    seq = init_state(spec, params0)
    for i in range(n):
        seq, _ = run_round(spec, seq, _batch(i), check_budgets=False)
    fused, recs = run_rounds(spec, init_state(spec, params0), _stacked(n),
                             check_budgets=False)
    assert len(recs) == n
    _assert_states_identical(seq, fused)


def test_checkpoint_resume_mid_chunk(tmp_path):
    """A checkpoint written between chunks resumes onto the same trajectory
    as one uninterrupted chunk: rounds [0,2) + save/load + rounds [2,4) ==
    rounds [0,4)."""
    spec = _spec(engine="vmap", participation=0.5, compressor="topk",
                 compression_ratio=0.25)
    params0 = init_linear(DIM)

    full, _ = run_rounds(spec, init_state(spec, params0), _stacked(4), 4,
                         check_budgets=False)

    half, _ = run_rounds(spec, init_state(spec, params0), _stacked(2), 2,
                         check_budgets=False)
    save_state(str(tmp_path), half)
    restored, _ = load_state(str(tmp_path), init_state(spec, params0))
    resumed, _ = run_rounds(spec, restored, _stacked(2, seed0=2), 2,
                            check_budgets=False)
    _assert_states_identical(full, resumed)


def test_participation_sweep_does_not_alias_cached_chunks():
    """The participant count is baked into the compiled scan (masks are
    sampled inside it), so specs differing only in participation must not
    share a cached chunk fn."""
    params0 = init_linear(DIM)
    half = _spec(participation=0.5)
    quarter = half.replace(participation=0.25)
    assert half.engine_key() == quarter.engine_key()   # mask is runtime for
    #   the single-round path; the chunk cache must still split them
    _, recs_half = run_rounds(half, init_state(half, params0), _stacked(2),
                              check_budgets=False)
    _, recs_quarter = run_rounds(quarter, init_state(quarter, params0),
                                 _stacked(2), check_budgets=False)
    assert all(r["participants"] == 2.0 for r in recs_half)
    assert all(r["participants"] == 1.0 for r in recs_quarter)


def test_run_rounds_rejects_mismatched_length():
    """An explicit n_rounds must match the stacked leading axis — the scan
    length comes from the batches, so a mismatch would train more rounds
    than the ledger charges."""
    spec = _spec()
    state = init_state(spec, init_linear(DIM))
    with pytest.raises(ValueError, match="leading axis"):
        run_rounds(spec, state, _stacked(4), 2, check_budgets=False)


def test_best_tracks_eval_loss_not_train_loss():
    """theta* with an eval_fn compares eval losses: a later round with the
    better eval loss wins even when its train loss is worse."""
    evals = iter([0.5, 0.3])

    def eval_fn(params):
        return {"eval_loss": next(evals)}

    spec = _spec(c_th=1e9, eps_th=1e9)
    state = init_state(spec, init_linear(DIM))
    _, out = train(spec, state, _sampler, max_rounds=2, eval_fn=eval_fn)
    assert out["best"]["round"] == 2
    assert out["best"]["loss"] == pytest.approx(0.3)
    assert out["best"]["eval_loss"] == pytest.approx(0.3)


# ---------------------- budgets ----------------------------------------------

def test_run_rounds_enforces_budgets_chunkwise():
    """A chunk that cannot fully fit raises (state untouched), and the kind
    matches the binding budget."""
    spec = _spec(c_th=3 * (100.0 + TAU), eps_th=1e9)    # room for 3 rounds
    state = init_state(spec, init_linear(DIM))
    with pytest.raises(BudgetExceeded) as ei:
        run_rounds(spec, state, _stacked(4), 4)
    assert ei.value.which == "resource"
    assert state.rounds_done == 0
    state, recs = run_rounds(spec, state, _stacked(3), 3)
    assert len(recs) == 3

    tight = _spec(eps_th=0.5, sigmas=(0.05,) * C)
    with pytest.raises(BudgetExceeded) as ei:
        run_rounds(tight, init_state(tight, init_linear(DIM)), _stacked(2), 2)
    assert ei.value.which == "privacy"


def test_rounds_within_budgets_matches_per_round_probe():
    """The chunk-sizing projection replays exceeds_budgets exactly under
    full participation: it admits precisely the rounds the per-round driver
    runs, and the (n+1)-th probe fails with the same budget kind."""
    spec = _spec(c_th=2 * (100.0 + TAU) + 1.0, eps_th=1e9)
    state = init_state(spec, init_linear(DIM))
    n, which = rounds_within_budgets(spec, state, 10)
    assert (n, which) == (2, "resource")
    ran = 0
    while not exceeds_budgets(spec, state) and ran < 10:
        state, _ = run_round(spec, state, _batch(ran), check_budgets=False)
        ran += 1
    assert ran == n
    assert rounds_within_budgets(spec, state, 10) == (0, "resource")


def test_incremental_probe_matches_accountant_view():
    """peek_epsilon_fast == the O(C) accountant rebuild it replaced, on a
    state with an uneven realized ledger."""
    spec = _spec(participation=1, sigmas=(0.3, 0.5, 0.7, 0.9),
                 batch_sizes=(2, 4, 8, 16))
    state = init_state(spec, init_linear(DIM))
    for i in range(3):
        state, _ = run_round(spec, state, _batch(i), check_budgets=False)
    assert (state.rho > 0).any() and (state.rho == 0).any()
    want = accountant_view(spec, state).peek_epsilon(
        spec.tau, q=spec.accounting_q())
    assert peek_epsilon_fast(spec, state, spec.tau) == want


# ---------------------- laziness / caches ------------------------------------

def test_records_are_lazy_device_scalars():
    """run_round/run_rounds return metric values as 0-d device arrays (no
    forced sync); materialize_record converts them to plain floats."""
    spec = _spec()
    state, rec = run_round(spec, init_state(spec, init_linear(DIM)),
                           _batch(), check_budgets=False)
    assert isinstance(rec["loss"], jax.Array)
    assert isinstance(rec["max_epsilon"], float)     # host-side ledger field
    mat = materialize_record(rec)
    assert isinstance(mat["loss"], float)
    assert mat["round"] == 1

    _, recs = run_rounds(spec, init_state(spec, init_linear(DIM)),
                         _stacked(2), 2, check_budgets=False)
    assert all(isinstance(r["loss"], jax.Array) for r in recs)


def test_sigma_and_ledger_constants_cached_per_spec():
    """The device sigma vector is transferred once per ledger key: budget
    edits reuse it, mechanism edits repopulate it. ledger_key itself is
    memoized on the instance, so per-round probes of an auto-designed-sigma
    spec don't re-run the Eq.-23 design."""
    spec = _spec()
    assert sigmas_for(spec) is sigmas_for(spec)
    assert sigmas_for(spec) is sigmas_for(spec.replace(eps_th=3.0, c_th=9.0))
    assert sigmas_for(spec) is not sigmas_for(spec.replace(sigmas=(0.7,) * C))
    assert spec.ledger_key() is spec.ledger_key()
    designed = _spec(sigmas=None, eps_th=4.0, total_steps=60)
    assert designed.ledger_key() is designed.ledger_key()


def test_prefetch_failure_keeps_completed_chunk():
    """A sampler that dies while prefetching the NEXT chunk must not lose
    the chunk that already executed: run_rounds raises PrefetchFailed with
    the successor state attached, and train records the chunk's history
    before re-raising the original error."""
    spec = _spec(c_th=1e9, eps_th=1e9)

    with pytest.raises(PrefetchFailed) as ei:
        run_rounds(spec, init_state(spec, init_linear(DIM)), _stacked(2), 2,
                   check_budgets=False,
                   prefetch=lambda: (_ for _ in ()).throw(OSError("dead")))
    assert isinstance(ei.value.__cause__, OSError)
    assert ei.value.state.rounds_done == 2
    assert len(ei.value.records) == 2

    calls = {"n": 0}

    def dying_sampler(m, tau, rng):
        calls["n"] += 1
        if calls["n"] > 3 * C:                 # survives the first chunk
            raise OSError("stream closed")
        return _sampler(m, tau, rng)

    state = init_state(spec, init_linear(DIM))
    history = []
    with pytest.raises(OSError):
        train(spec, state, dying_sampler, max_rounds=9, chunk_rounds=3,
              history=history)
    assert len(history) == 3                   # the executed chunk survived
    assert all(isinstance(r["loss"], float) for r in history)


# ---------------------- chunked train driver ---------------------------------

def test_train_chunked_matches_per_round_driver():
    """train(chunk_rounds=4) == train(chunk_rounds=1) under full
    participation: same rounds, same per-round history, identical budget
    stopping point (resource budget binds mid-run)."""
    def run(chunk):
        spec = _spec(c_th=6 * (100.0 + TAU) + 1.0, eps_th=1e9)
        state = init_state(spec, init_linear(DIM))
        return train(spec, state, _sampler, max_rounds=100,
                     chunk_rounds=chunk)

    state_a, out_a = run(1)
    state_b, out_b = run(4)
    assert out_a["rounds"] == out_b["rounds"] == 6
    _assert_states_identical(state_a, state_b)
    assert len(out_a["history"]) == len(out_b["history"])
    for ra, rb in zip(out_a["history"], out_b["history"]):
        assert rb["loss"] == pytest.approx(ra["loss"], rel=1e-6)
        assert rb["max_epsilon"] == ra["max_epsilon"]
    assert out_b["best"]["loss"] == pytest.approx(out_a["best"]["loss"],
                                                  rel=1e-6)


def test_train_chunked_with_eval_at_boundaries():
    """eval_fn runs once per chunk boundary (mid-chunk models never exist);
    theta* tracking uses those boundary evals."""
    calls = []

    def eval_fn(params):
        calls.append(1)
        return {"eval_loss": float(np.asarray(params["w"]).sum() ** 2)}

    spec = _spec(c_th=1e9, eps_th=1e9)
    state = init_state(spec, init_linear(DIM))
    state, out = train(spec, state, _sampler, max_rounds=8, eval_fn=eval_fn,
                       eval_every=1, chunk_rounds=4)
    assert out["rounds"] == 8
    assert len(calls) == 2                     # one eval per chunk
    assert "eval_loss" in out["history"][3]
    assert "eval_loss" in out["history"][7]
    assert "eval_loss" not in out["history"][0]
    assert "eval_loss" in out["best"]


def test_train_chunked_partial_participation_stays_within_budget():
    """Under partial participation the chunk sizing is conservative: the
    chunked driver never exceeds the privacy budget and stops at a state
    the per-round probe also rejects (or max_rounds)."""
    kw = dict(participation=0.5, eps_th=6.0, sigmas=(2.0,) * C, c_th=1e9)
    spec = _spec(**kw)
    state = init_state(spec, init_linear(DIM))
    state, out = train(spec, state, _sampler, max_rounds=50, chunk_rounds=4)
    assert 0 < out["rounds"] < 50              # privacy budget bound the run
    assert out["max_epsilon"] <= spec.eps_th
    assert exceeds_budgets(spec, state) == "privacy"


def test_donated_state_buffers_are_consumed():
    """The donation contract: after run_round the INPUT state's device
    buffers are gone — reusing them raises instead of silently computing
    on freed memory. (XLA may decline to alias a donated buffer — e.g. the
    forced multi-device host platform of the oracle-only CI leg — in which
    case the input legally survives and there is nothing to assert.)"""
    spec = _spec()
    state = init_state(spec, init_linear(DIM))
    nxt, _ = run_round(spec, state, _batch(), check_budgets=False)
    jax.block_until_ready(nxt.params)          # successor fully usable
    leaf = jax.tree.leaves(state.params)[0]
    if not leaf.is_deleted():
        pytest.skip("platform declined buffer donation")
    with pytest.raises(RuntimeError):
        np.asarray(leaf) + 1

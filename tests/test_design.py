"""Tests for the optimal-design solver (paper §5, §7)."""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.convergence import ProblemConstants, theorem1_bound
from repro.core.design import DesignProblem, ResourceModel, grid_search_reference


def make_problem(c_th=1000.0, eps_th=10.0, eta=0.05, lam=0.5, lip=2.0,
                 alpha=1.0, xi2=0.5, dim=100, m=16, x=1628) -> DesignProblem:
    consts = ProblemConstants(eta=eta, lam=lam, lip=lip, alpha=alpha, xi2=xi2,
                              dim=dim, n_clients=m)
    return DesignProblem(
        consts=consts,
        resource=ResourceModel(c1=100.0, c2=1.0),   # paper §8.1 defaults
        clip_norm=1.0, batch_sizes=[x] * m, delta=1e-4,
        eps_th=eps_th, c_th=c_th)


def test_resource_model_eq8():
    r = ResourceModel(c1=100.0, c2=1.0)
    assert r.cost(100, 10) == pytest.approx(100 * 100 / 10 + 100)
    # Eq. 22: binding tau
    tau = r.tau_binding(100, 1000.0)
    assert r.cost(100, tau) == pytest.approx(1000.0)


def test_resource_model_comm_scale_codesigns_tau():
    """Aggregation-pipeline knobs (comm_scale = wire_ratio * q) cheapen the
    c1 term: same budget affords more iterations, and the Eq.-22 binding
    tau* drops (aggregate more often when aggregation is cheap)."""
    dense = ResourceModel(c1=100.0, c2=1.0)
    comp = ResourceModel(c1=100.0, c2=1.0, comm_scale=0.125)
    assert comp.cost(100, 10) == pytest.approx(0.125 * 100 * 100 / 10 + 100)
    assert comp.k_max(1000.0, 10) > dense.k_max(1000.0, 10)
    assert comp.tau_binding(100, 1000.0) < dense.tau_binding(100, 1000.0)
    # the solver inherits the model: compressed problem picks smaller tau*
    p_dense = make_problem()
    p_comp = DesignProblem(
        consts=p_dense.consts, resource=comp, clip_norm=1.0,
        batch_sizes=p_dense.batch_sizes, delta=1e-4,
        eps_th=p_dense.eps_th, c_th=p_dense.c_th)
    sd, sc = p_dense.solve(), p_comp.solve()
    assert sc.cost <= p_comp.c_th * (1 + 1e-9)
    assert sc.tau_relaxed < sd.tau_relaxed
    # strictly larger feasible set + pointwise-smaller objective at every K
    # (smaller tau* shrinks the Theorem-1 divergence term) -> no worse bound
    assert sc.predicted_bound <= sd.predicted_bound * (1 + 1e-6)


def test_solution_respects_budgets():
    p = make_problem()
    sol = p.solve()
    assert sol.cost <= p.c_th * (1 + 1e-9)
    assert sol.tau >= 1 and sol.k >= sol.tau
    assert sol.k % sol.tau == 0              # Theorem-1 divisibility
    assert p.consts.lr_constraint_ok(sol.tau)
    # privacy: Eq. 9 at the chosen sigma must be within budget
    from repro.core.privacy import epsilon_after_k
    for sig, x in zip(sol.sigmas, p.batch_sizes):
        assert epsilon_after_k(sol.k, p.clip_norm, x, sig, p.delta) \
            <= p.eps_th * (1 + 1e-6)


def test_solver_close_to_grid_search():
    """Paper §8.3: solver's tau close to brute-force optimum (on surrogate)."""
    p = make_problem()
    sol = p.solve()
    tau_g, k_g, f_g = grid_search_reference(p, taus=range(1, 21))
    f_sol = theorem1_bound(p.consts, sol.k, sol.tau,
                           [s * s for s in sol.sigmas])
    # solver surrogate value within 10% of grid-search optimum
    assert f_sol <= f_g * 1.10


@settings(max_examples=30, deadline=None)
@given(c_th=st.floats(300, 3000), eps_th=st.floats(0.5, 20))
def test_solver_feasible_across_budgets(c_th, eps_th):
    p = make_problem(c_th=c_th, eps_th=eps_th)
    sol = p.solve()
    assert sol.cost <= c_th * (1 + 1e-9)
    assert math.isfinite(sol.predicted_bound)


def test_tau_star_shifts_with_budgets():
    """Paper §8.5: tau* decreases with resource budget, increases with eps."""
    p_small_c = make_problem(c_th=500.0, eps_th=4.0)
    p_large_c = make_problem(c_th=2000.0, eps_th=4.0)
    assert p_small_c.solve().tau >= p_large_c.solve().tau

    p_small_e = make_problem(c_th=1000.0, eps_th=1.0)
    p_large_e = make_problem(c_th=1000.0, eps_th=10.0)
    assert p_small_e.solve().tau <= p_large_e.solve().tau


def test_objective_monotone_in_tau_at_fixed_k():
    """dF/dtau > 0 (paper §7): larger tau at same K, sigma is never better."""
    p = make_problem()
    consts = p.consts
    sig2 = [1.0] * consts.n_clients
    vals = [theorem1_bound(consts, 500, t, sig2) for t in (1, 2, 5, 10)]
    assert vals == sorted(vals)

"""Opt-in launch environment profiles (allocator + XLA host flags).

The JAX launchers run with whatever environment they inherit; this module
packages the handful of host-level knobs that repeatedly matter for
CPU-hosted federation sims and multi-client mesh testing, applied ONLY
when a launcher is invoked with ``--env-profile`` (never implicitly —
an env profile re-execs the process, see below). Two profiles:

``host``
    Allocator + log hygiene for any launch:

    * ``LD_PRELOAD=<tcmalloc>`` — glibc malloc serializes the large
      short-lived host allocations of batch building / checkpoint IO;
      tcmalloc's thread caches remove that contention. Detected from the
      usual distro paths (:func:`find_tcmalloc`); silently skipped when
      the library isn't installed.
    * ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000`` — quiets
      tcmalloc's large-alloc warnings for multi-GB numpy batches.
    * ``TF_CPP_MIN_LOG_LEVEL=4`` — silences the TF/XLA C++ banner noise.

``cpu-mesh``
    Everything in ``host`` plus the XLA host-platform flags:

    * ``--xla_force_host_platform_device_count=N`` — splits the host CPU
      into N XLA devices so shard_map engines and >1-device code paths
      (``repro.api.engines.resolve_engine``) are testable without
      accelerators; N comes from ``--host-devices``.
    * ``--xla_step_marker_location=1`` — puts step markers at the outer
      while loop, so profiles attribute time to whole training steps
      rather than the program entry.

    Flags are APPENDED to any existing ``XLA_FLAGS`` (existing settings
    win: a flag already present is not duplicated or overridden).

Because ``LD_PRELOAD`` and ``XLA_FLAGS`` must be set before the process
(and XLA) initialize, :func:`apply_env_profile` re-execs the current
interpreter with the profile environment; the re-exec is guarded by
``REPRO_ENV_PROFILE_APPLIED=1`` so it happens exactly once.
:func:`profile_env` is the pure (testable) computation of the env delta.
"""
from __future__ import annotations

import os
import sys
from typing import Mapping

ENV_PROFILES = ("none", "host", "cpu-mesh")

_APPLIED_VAR = "REPRO_ENV_PROFILE_APPLIED"

# distro locations of tcmalloc, preferred first (full > minimal)
TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib64/libtcmalloc.so.4",
)


def find_tcmalloc(paths: tuple[str, ...] = TCMALLOC_PATHS) -> str | None:
    """First installed tcmalloc shared object, or None."""
    for p in paths:
        if os.path.exists(p):
            return p
    return None


def _merge_xla_flags(existing: str, new_flags: list[str]) -> str:
    """Append ``new_flags`` to an ``XLA_FLAGS`` string, skipping any flag
    whose name (the ``--xla_...`` part before ``=``) is already set —
    user-provided flags win over profile defaults."""
    present = {f.split("=", 1)[0] for f in existing.split() if f}
    add = [f for f in new_flags if f.split("=", 1)[0] not in present]
    return " ".join([x for x in [existing.strip()] if x] + add)


def profile_env(profile: str, *, host_devices: int = 1,
                base: Mapping[str, str] | None = None) -> dict[str, str]:
    """The env-var delta ``profile`` applies on top of ``base`` (defaults
    to the current process env). Pure: nothing is mutated or exec'd."""
    if profile not in ENV_PROFILES:
        raise ValueError(f"env profile must be one of {ENV_PROFILES}, "
                         f"got {profile!r}")
    if host_devices < 1:
        raise ValueError(f"host_devices must be >= 1, got {host_devices}")
    base = dict(os.environ if base is None else base)
    if profile == "none":
        return {}
    env: dict[str, str] = {"TF_CPP_MIN_LOG_LEVEL": "4"}
    lib = find_tcmalloc()
    if lib is not None:
        preload = base.get("LD_PRELOAD", "")
        if lib not in preload.split(":"):
            env["LD_PRELOAD"] = ":".join(x for x in (preload, lib) if x)
        env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = "60000000000"
    if profile == "cpu-mesh":
        env["XLA_FLAGS"] = _merge_xla_flags(base.get("XLA_FLAGS", ""), [
            f"--xla_force_host_platform_device_count={host_devices}",
            "--xla_step_marker_location=1",
        ])
    return env


def add_env_profile_args(ap) -> None:
    """Attach the shared ``--env-profile`` / ``--host-devices`` flags to an
    argparse parser. Every launcher (train, serve, dryrun) exposes the same
    pair so a cpu-mesh invocation looks identical across entry points:

        python -m repro.launch.<any> --env-profile cpu-mesh --host-devices 8
    """
    ap.add_argument("--env-profile", default="none", choices=ENV_PROFILES,
                    help="re-exec under a tuned launch environment "
                         "(allocator + XLA host flags); 'cpu-mesh' splits "
                         "the host CPU into --host-devices XLA devices")
    ap.add_argument("--host-devices", type=int, default=1,
                    help="XLA host device count for the 'cpu-mesh' env "
                         "profile")


def apply_env_profile(profile: str | None, *,
                      host_devices: int = 1) -> bool:
    """Re-exec the current process under ``profile``'s environment.

    No-op (returns False) when the profile is ``None``/"none" or the
    process was already re-exec'd (``REPRO_ENV_PROFILE_APPLIED=1``). On
    the first call it does NOT return: the interpreter is replaced via
    ``os.execvpe`` with the same argv and the augmented env. Call this at
    the very top of a launcher ``main``, before any JAX work.
    """
    if profile is None or profile == "none":
        return False
    if os.environ.get(_APPLIED_VAR) == "1":
        return False
    env = dict(os.environ)
    env.update(profile_env(profile, host_devices=host_devices))
    env[_APPLIED_VAR] = "1"
    os.execvpe(sys.executable, [sys.executable] + sys.argv, env)
    raise AssertionError("unreachable: execvpe does not return")

"""End-to-end DP-PASGD training launcher, driven by ``repro.api``.

Runs real training (allocates params) — use reduced/smoke configs or the
~100M example config on CPU; on a TPU pod the same driver runs the full
configs. The optimal-design solver (paper §7) can pick (K, tau, sigma) from
resource/privacy budgets before launch. The engine (vmap / map / shard_map)
is selected declaratively via ``FederationSpec.engine``.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --smoke \
        --rounds 5 --clients 4 --tau 5 --eps 10 --cth 2000

``--chunk-rounds R`` fuses R rounds per XLA dispatch (the run_rounds scan
driver — same math, bit-identical ledger, a fraction of the host overhead).

``--population M --cohort-size K`` switches to cohort execution over M
virtual clients (repro.population): each round trains a sampled cohort of
K devices, device memory is bounded by K independent of M, and the
per-virtual-client privacy ledger / error-feedback residuals live in the
host-side ClientStore. M = 10^5..10^6 runs on a laptop:

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --smoke \
        --rounds 10 --population 100000 --cohort-size 8 --tau 5 --eps 10

``--async-buffer B`` switches to buffered-async federation
(repro.asyncfl, engine ``async_buffered``): the server aggregates the
first B arrivals per flush on a simulated device clock
(``--latency-profile {uniform,lognormal,hetero}``) with staleness-damped
updates (``--staleness-alpha``) and dispatch-time privacy charging:

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --smoke \
        --rounds 10 --clients 8 --tau 5 --async-buffer 4 \
        --latency-profile hetero

``--env-profile {host,cpu-mesh}`` re-execs the launcher under the tuned
host environment (tcmalloc, XLA host-platform flags — see
``repro.launch.env``).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.api import FederationSpec, init_state, save_state, train
from repro.asyncfl import (
    LATENCY_PROFILES,
    init_async_state,
    latency_profile,
    save_async_state,
    train_async,
)
from repro.configs import get_arch, smoke_variant
from repro.launch.env import add_env_profile_args, apply_env_profile
from repro.population import (
    HeterogeneousCohort,
    init_population_state,
    population_from_sampler,
    save_population_state,
    train_population,
)
from repro.core.convergence import ProblemConstants
from repro.core.design import DesignProblem, ResourceModel
from repro.core.fl import design_sigmas
from repro.data.tokens import FederatedTokenStream, TokenTaskConfig
from repro.models.transformer import Transformer
from repro.optim import sgd


def build_federation(cfg, n_clients: int, tau: int, batch_size: int,
                     seq_len: int, sigmas, lr: float = 0.1,
                     clip_norm: float = 1.0, delta: float = 1e-4,
                     engine: str = "auto", seed: int = 0,
                     participation: float = 1.0, compressor: str = "none",
                     compression_ratio: float = 0.1,
                     compression_bits: int = 8, population: int = 0,
                     buffer_size: int | None = None,
                     staleness_alpha: float = 0.0, latency_model=None,
                     aggregator: str = "mean", trim_fraction: float = 0.1,
                     norm_bound_factor: float = 3.0,
                     secure_agg: bool = False, secure_frac_bits: int = 16,
                     dp_accounting: str = "local", attack: str = "none",
                     byzantine_fraction: float = 0.0,
                     attack_scale: float = 10.0,
                     mesh_shape: tuple[int, int] | None = None,
                     replica_bytes: int | None = None,
                     rng=None):
    """Assemble the repro.api handles for a transformer federation.

    Returns ``(model, spec, state, sampler)`` — drive them with
    ``repro.api.train(spec, state, sampler, ...)``. The aggregation-pipeline
    knobs (participation / compressor) pass through to the spec.

    ``population=M > 0`` switches to cohort execution
    (:mod:`repro.population`): ``n_clients`` becomes the per-round cohort
    size K, the token stream spans all M virtual clients (lazy — only the
    sampled cohort's batches are ever synthesized), and the returned
    ``state`` is a :class:`repro.population.PopulationState` to drive with
    ``train_population`` (wrap the sampler via ``population_from_sampler``).

    ``engine="async_buffered"`` returns an
    :class:`repro.asyncfl.AsyncState` (generation 0 already dispatched —
    it consumes the first round batches from ``rng``, so pass the SAME
    ``rng`` to ``train_async``) to drive with ``train_async``;
    ``buffer_size``/``staleness_alpha``/``latency_model`` configure the
    flush and the simulated clocks.
    """
    model = Transformer(cfg)
    task = TokenTaskConfig(vocab=cfg.vocab, seq_len=seq_len,
                           n_clients=population or n_clients, seed=seed)
    stream = FederatedTokenStream(task, batch_size,
                                  prefix_len=cfg.prefix_len,
                                  d_model=cfg.d_model)
    params0 = model.init(jax.random.PRNGKey(seed))
    spec = FederationSpec(
        n_clients=n_clients, tau=tau, loss_fn=model.loss_fn,
        optimizer=sgd(lr), engine=engine, dp=True, clip_norm=clip_norm,
        num_microbatches=1,
        participation=participation, compressor=compressor,
        compression_ratio=compression_ratio,
        compression_bits=compression_bits,
        aggregator=aggregator, trim_fraction=trim_fraction,
        norm_bound_factor=norm_bound_factor, secure_agg=secure_agg,
        secure_frac_bits=secure_frac_bits, dp_accounting=dp_accounting,
        attack=attack, byzantine_fraction=byzantine_fraction,
        attack_scale=attack_scale,
        population=population or None,
        cohort_size=n_clients if population else None,
        buffer_size=buffer_size if engine == "async_buffered" else None,
        staleness_alpha=(staleness_alpha if engine == "async_buffered"
                         else 0.0),
        mesh_shape=mesh_shape, replica_bytes=replica_bytes,
        sigmas=tuple(float(s) for s in np.asarray(sigmas)),
        batch_sizes=(batch_size,) * n_clients, delta=delta, seed=seed)
    if population:
        state = init_population_state(spec, params0)
    elif spec.is_async():
        state = init_async_state(spec, params0, stream.sampler, rng=rng,
                                 latency_model=latency_model)
    else:
        state = init_state(spec, params0)
    return model, spec, state, stream.sampler


def federation_meta(spec) -> dict:
    """The spec scalars a serving driver needs to rebuild a ``like`` FLState
    for ``load_state`` (see ``repro.launch.serve.load_federated_params``)."""
    return {"n_clients": spec.n_clients, "tau": spec.tau,
            "compressor": spec.compressor,
            "compression_ratio": spec.compression_ratio,
            "compression_bits": spec.compression_bits,
            "participation": spec.participants_per_round(),
            "population": spec.population,
            "aggregator": spec.aggregator,
            "secure_agg": spec.secure_agg,
            "dp_accounting": spec.dp_accounting,
            "attack": spec.attack,
            "byzantine_fraction": spec.byzantine_fraction,
            "topology": spec.topology}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke variant of the arch")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--tau", type=int, default=0,
                    help="0 = let the optimal-design solver choose")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--eps", type=float, default=10.0)
    ap.add_argument("--delta", type=float, default=1e-4)
    ap.add_argument("--cth", type=float, default=2000.0)
    ap.add_argument("--c1", type=float, default=100.0)
    ap.add_argument("--c2", type=float, default=1.0)
    ap.add_argument("--engine", default="auto",
                    choices=("vmap", "map", "shard_map", "mesh_2d",
                             "async_buffered", "auto"))
    ap.add_argument("--mesh-shape", default=None,
                    help="dc,dm devices for the mesh_2d engine (client x "
                         "model axes), e.g. 4,2; default: "
                         "repro.mesh.placement.default_mesh_shape")
    ap.add_argument("--replica-hint", action="store_true",
                    help="pass the arch's abstract param+opt-state bytes "
                         "(configs.shapes.replica_footprint_bytes) to the "
                         "spec so engine='auto' can pick mesh_2d when one "
                         "replica exceeds per-device memory")
    ap.add_argument("--async-buffer", type=int, default=0,
                    help="B > 0 switches to buffered-async federation "
                         "(repro.asyncfl): aggregate the first B arrivals "
                         "per flush on simulated device clocks, redispatch "
                         "immediately, pre-charge privacy at dispatch")
    ap.add_argument("--latency-profile", default="uniform",
                    choices=LATENCY_PROFILES,
                    help="simulated per-device latency distribution (async "
                         "mode); 'hetero' couples slowness to the "
                         "Beta-availability cohort model")
    ap.add_argument("--latency-scale", type=float, default=1.0,
                    help="nominal simulated seconds per dispatch")
    ap.add_argument("--staleness-alpha", type=float, default=0.0,
                    help="staleness damping w(s) = 1/(1+s)^alpha applied "
                         "to late arrivals at the flush")
    add_env_profile_args(ap)
    ap.add_argument("--chunk-rounds", type=int, default=1,
                    help="fuse this many rounds into one jitted lax.scan "
                         "dispatch (repro.api.run_rounds): >1 makes the hot "
                         "loop device-resident with <=1 host sync and a "
                         "prefetched batch pipeline per chunk; eval then "
                         "happens at chunk boundaries only")
    ap.add_argument("--population", type=int, default=0,
                    help="train over M virtual clients with cohort "
                         "execution (repro.population): only --cohort-size "
                         "devices are resident per round, device memory is "
                         "independent of M; 0 = dense resident clients")
    ap.add_argument("--cohort-size", type=int, default=0,
                    help="per-round cohort size K (population mode; "
                         "default: --clients)")
    ap.add_argument("--resident-cache", type=int, default=0,
                    help="S > 0 keeps a device-resident shard cache of S "
                         "warm virtual clients (repro.population.resident): "
                         "a fresh cohort is drawn every round inside the "
                         "fused scan (the per-round schedule, unlike plain "
                         "--chunk-rounds population runs which fix one "
                         "cohort per chunk) and steady-state chunks make "
                         "zero blocking host syncs; needs --population and "
                         "--chunk-rounds > 1, and S >= chunk_rounds * K")
    ap.add_argument("--cohort-hetero", action="store_true",
                    help="sample cohorts under the Beta-availability + "
                         "dropout heterogeneity model instead of uniform "
                         "K-of-M")
    ap.add_argument("--cohort-dropout", type=float, default=0.05,
                    help="mid-round dropout rate of the heterogeneity model")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients sampled per round")
    ap.add_argument("--compressor", default="none",
                    choices=("none", "topk", "randk", "qsgd"))
    ap.add_argument("--compress-ratio", type=float, default=0.1)
    ap.add_argument("--compress-bits", type=int, default=8)
    ap.add_argument("--aggregator", default="mean",
                    choices=("mean", "median", "trimmed_mean", "norm_bound"),
                    help="Eq.-7b reduction over participant updates; the "
                         "robust choices bound a byzantine minority's pull "
                         "(repro.core.robust)")
    ap.add_argument("--trim-fraction", type=float, default=0.1,
                    help="per-end trim of --aggregator trimmed_mean")
    ap.add_argument("--norm-bound-factor", type=float, default=3.0,
                    help="--aggregator norm_bound rejects updates whose L2 "
                         "norm exceeds factor x median participant norm")
    ap.add_argument("--secure-agg", action="store_true",
                    help="pairwise-mask secure-aggregation simulation "
                         "(repro.core.secureagg): the server only ever "
                         "materializes the masked fixed-point SUM")
    ap.add_argument("--secure-frac-bits", type=int, default=16,
                    help="fixed-point fractional bits of --secure-agg")
    ap.add_argument("--dp-accounting", default="local",
                    choices=("local", "central"),
                    help="'central' (needs --secure-agg) accounts the "
                         "aggregate-only observer: per-step rho scales by "
                         "1/P for the P pooled participant noises")
    ap.add_argument("--attack", default="none",
                    choices=("none", "sign_flip", "scale"),
                    help="simulate byzantine upload corruption by a static "
                         "--byzantine-fraction subset of resident clients")
    ap.add_argument("--byzantine-fraction", type=float, default=0.0)
    ap.add_argument("--attack-scale", type=float, default=10.0,
                    help="multiplier of --attack scale")
    ap.add_argument("--save", default=None)
    args = ap.parse_args(argv)
    apply_env_profile(args.env_profile, host_devices=args.host_devices)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)

    engine = args.engine
    if args.async_buffer > 0 and engine != "async_buffered":
        engine = "async_buffered"
    is_async = engine == "async_buffered"
    if is_async and args.population:
        raise SystemExit("--async-buffer and --population are mutually "
                         "exclusive (async fleets model heterogeneity via "
                         "--latency-profile hetero)")

    # in population mode the resident block is the cohort, not --clients
    n_resident = (args.cohort_size or args.clients if args.population
                  else args.clients)
    if args.population and not 0 < n_resident <= args.population:
        raise SystemExit(f"--cohort-size must be in [1, {args.population}]")

    if args.tau:
        tau, k = args.tau, args.rounds * args.tau
        sigmas = design_sigmas(k, args.clip, [args.batch] * n_resident,
                               args.eps, args.delta)
    else:
        # paper §7: solve for (K, tau, sigma) under the budgets
        consts = ProblemConstants(eta=args.lr, lam=0.5, lip=2.0, alpha=5.0,
                                  xi2=1.0, dim=1000, n_clients=n_resident)
        prob = DesignProblem(
            consts=consts, resource=ResourceModel(args.c1, args.c2),
            clip_norm=args.clip, batch_sizes=[args.batch] * n_resident,
            delta=args.delta, eps_th=args.eps, c_th=args.cth)
        sol = prob.solve()
        tau = sol.tau
        sigmas = np.asarray(sol.sigmas, np.float32)
        print(f"[design] K*={sol.k} tau*={tau} sigma*={sigmas[0]:.4f} "
              f"bound={sol.predicted_bound:.4f} cost={sol.cost:.0f}")

    latency_model = (latency_profile(args.latency_profile, seed=0,
                                     fleet=n_resident,
                                     scale=args.latency_scale)
                     if is_async else None)
    mesh_shape = None
    if args.mesh_shape:
        dc, dm = (int(x) for x in args.mesh_shape.split(","))
        mesh_shape = (dc, dm)
    if engine == "mesh_2d" and (mesh_shape is None or mesh_shape[1] > 1):
        # model-sharded region: XLA's partial-auto partitioner can't handle
        # while loops, so the layer scans must lower as straight-line HLO
        from dataclasses import replace as _replace
        cfg = _replace(cfg, scan_unroll=True)
    replica_bytes = None
    if args.replica_hint:
        from repro.configs.shapes import replica_footprint_bytes
        replica_bytes = replica_footprint_bytes(cfg, optimizer=sgd(args.lr))
        print(f"[mesh] replica footprint "
              f"{replica_bytes / 1024 ** 3:.2f} GiB (params + opt state)")

    rng = np.random.default_rng(0)
    model, spec, state, sampler = build_federation(
        cfg, n_resident, tau, args.batch, args.seq, sigmas, lr=args.lr,
        clip_norm=args.clip, delta=args.delta, engine=engine,
        mesh_shape=mesh_shape, replica_bytes=replica_bytes,
        participation=args.participation, compressor=args.compressor,
        compression_ratio=args.compress_ratio,
        compression_bits=args.compress_bits, population=args.population,
        buffer_size=args.async_buffer or None,
        staleness_alpha=args.staleness_alpha,
        latency_model=latency_model,
        aggregator=args.aggregator, trim_fraction=args.trim_fraction,
        norm_bound_factor=args.norm_bound_factor,
        secure_agg=args.secure_agg, secure_frac_bits=args.secure_frac_bits,
        dp_accounting=args.dp_accounting, attack=args.attack,
        byzantine_fraction=args.byzantine_fraction,
        attack_scale=args.attack_scale, rng=rng)
    spec = spec.replace(eps_th=args.eps, c_th=args.cth,
                        c1=args.c1, c2=args.c2)
    t0 = time.time()
    if is_async:
        state, out = train_async(spec, state, sampler, max_rounds=args.rounds,
                                 rng=rng, chunk_rounds=args.chunk_rounds,
                                 latency_model=latency_model)
    elif args.population:
        pop = population_from_sampler(args.population, sampler,
                                      name="federated-tokens")
        cohort_sampler = (HeterogeneousCohort(seed=spec.seed,
                                              dropout=args.cohort_dropout)
                          if args.cohort_hetero else None)
        state, out = train_population(spec, state, pop,
                                      cohort_sampler=cohort_sampler,
                                      max_rounds=args.rounds,
                                      chunk_rounds=args.chunk_rounds,
                                      resident_cache=args.resident_cache)
    else:
        state, out = train(spec, state, sampler, max_rounds=args.rounds,
                           chunk_rounds=args.chunk_rounds)
    dt = time.time() - t0
    summary = {
        "arch": cfg.name, "rounds": out["rounds"],
        "chunk_rounds": args.chunk_rounds,
        "final_loss": out["history"][-1]["loss"] if out["history"] else None,
        "max_epsilon": out["max_epsilon"],
        "resource_spent": out["resource_spent"],
        "wall_s": round(dt, 1),
    }
    if is_async:
        summary.update({
            "buffer_size": spec.resolved_buffer_size(),
            "latency_profile": args.latency_profile,
            "staleness_alpha": args.staleness_alpha,
            "sim_seconds": out["sim_seconds"],
        })
    if args.population:
        summary.update({
            "population": args.population, "cohort_size": n_resident,
            # sampled != realized under --participation < 1: the cohort
            # counter ticks for every sampled client, the rho ledger only
            # for clients that actually ran (and spent privacy)
            "distinct_sampled":
                int((state.store.rounds_participated > 0).sum()),
            "distinct_participants": int((state.store.rho > 0).sum()),
        })
        if "resident_cache" in out:
            summary["resident_cache"] = out["resident_cache"]
    print(json.dumps(summary, indent=2))
    if args.save:
        extra = {"history": out["history"], **federation_meta(spec)}
        if is_async:
            save_async_state(args.save, state, extra=extra)
        elif args.population:
            save_population_state(args.save, state, extra=extra)
        else:
            save_state(args.save, state, extra=extra)
        print(f"saved federation state to {args.save}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

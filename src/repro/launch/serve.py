"""Batched serving driver: prefill a batch of prompts, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.models.transformer import Transformer


def generate(model: Transformer, params, prompts, gen_tokens: int,
             prefix=None, temperature: float = 0.0, seed: int = 0):
    """prompts (B, S) int32 -> generated (B, gen_tokens) int32."""
    b, s = prompts.shape
    max_len = s + gen_tokens + (model.cfg.prefix_len or 0)
    prefill = jax.jit(lambda p, t, pre: model.prefill(p, t, pre,
                                                      max_len=max_len))
    decode = jax.jit(model.decode_step)

    logits, caches, pos = prefill(params, prompts, prefix)
    key = jax.random.PRNGKey(seed)
    outs = []
    tok = None
    for i in range(gen_tokens):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)
        outs.append(tok)
        logits, caches = decode(params, caches, tok, pos + i)
    return jnp.stack(outs, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)),
        jnp.int32)
    prefix = None
    if cfg.prefix_len:
        prefix = jnp.asarray(
            rng.standard_normal((args.batch, cfg.prefix_len, cfg.d_model)),
            jnp.float32) * 0.02

    t0 = time.time()
    out = generate(model, params, prompts, args.gen, prefix,
                   args.temperature)
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch,
        "generated_shape": list(out.shape),
        "tokens_per_s": round(args.batch * args.gen / dt, 1),
        "sample": np.asarray(out[0, :8]).tolist(),
    }, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

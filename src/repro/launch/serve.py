"""Serving driver: continuous-batching engine by default, the static
prefill+decode batch kept as ``--static`` baseline.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
        --requests 8 --prompt-len 32 --gen 16

Serving a federated model: pass ``--fl-checkpoint DIR`` pointing at a
``repro.api.save_state`` checkpoint (e.g. from ``repro.launch.train
--save DIR``) and the driver loads it through ``FederationSpec`` /
``FLState`` / ``load_state`` and serves the aggregated model
(``repro.api.eval_params``) instead of random init.

Both paths warm up (compile) before the timed run, so ``tokens_per_s``
is steady-state; compile time is reported separately as ``compile_s``.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.models.transformer import Transformer


def load_federated_params(model: Transformer, directory: str):
    """The single serving model out of a DP-PASGD checkpoint directory.

    Reads the spec scalars the training launcher stored next to the arrays
    (``federation_meta``) and loads ONLY the params leaves — no optimizer
    state, no error-feedback residual, no C-way replica allocation — so
    checkpoints from any optimizer and any compressor serve alike at
    params-sized memory. The client axis collapses exactly as
    ``repro.api.eval_params``: any replica under ``full_average``, the
    cross-client mean under ``local_only``. Buffered-async checkpoints
    (``repro.asyncfl.save_async_state``) store the already-collapsed
    server model under ``global_params`` — serve that, never the K
    in-flight slot storages their ``params`` leaves hold.
    """
    from repro.api import collapse_clients
    from repro.checkpoint import checkpoint_leaf_paths, load_checkpoint

    with open(os.path.join(directory, "meta.json")) as f:
        meta = json.load(f)["extra"]
    # path donor only: load_checkpoint matches leaves by path, so the
    # single-replica init supplies the params/<leaf> paths and the stored
    # (C, ...) arrays come back untouched
    donor = model.init(jax.random.PRNGKey(0))
    if any(p.split("/", 1)[0] == "global_params"
           for p in checkpoint_leaf_paths(directory)):
        tree, _, _ = load_checkpoint(directory, like={"global_params": donor})
        return tree["global_params"]
    tree, _, _ = load_checkpoint(directory, like={"params": donor})
    return collapse_clients(tree["params"],
                            meta.get("topology", "full_average"))


@functools.lru_cache(maxsize=64)
def _decode_fns(model: Transformer, temperature: float, max_len: int):
    """The static path's two jitted programs: batch prefill, and ONE
    fused sample+decode step — greedy and sampled decode both dispatch
    once per token (the PRNG split happens inside the program, in the
    same order the old host loop used, so sampled outputs are
    unchanged). Cached per (model, temperature, max_len) so repeated
    generate/serve_static calls reuse the compiled programs instead of
    paying a fresh trace+compile each call."""
    prefill = jax.jit(lambda p, t, pre: model.prefill(p, t, pre,
                                                      max_len=max_len))

    def step(params, caches, logits, pos, key):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)
        logits, caches = model.decode_step(params, caches, tok, pos)
        return logits, caches, tok, key

    return prefill, jax.jit(step, donate_argnums=(1,))


def generate(model: Transformer, params, prompts, gen_tokens: int,
             prefix=None, temperature: float = 0.0, seed: int = 0):
    """prompts (B, S) int32 -> generated (B, gen_tokens) int32."""
    b, s = prompts.shape
    max_len = s + gen_tokens + (model.cfg.prefix_len or 0)
    prefill, step = _decode_fns(model, temperature, max_len)

    logits, caches, pos = prefill(params, prompts, prefix)
    key = jax.random.PRNGKey(seed)
    outs = []
    for i in range(gen_tokens):
        logits, caches, tok, key = step(params, caches, logits, pos + i,
                                        key)
        outs.append(tok)
    return jnp.stack(outs, axis=1)


def _run_static(model, params, args, cfg):
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)),
        jnp.int32)
    prefix = None
    if cfg.prefix_len:
        prefix = jnp.asarray(
            rng.standard_normal((args.batch, cfg.prefix_len, cfg.d_model)),
            jnp.float32) * 0.02

    t0 = time.perf_counter()
    out = generate(model, params, prompts, args.gen, prefix,
                   args.temperature)
    jax.block_until_ready(out)
    t1 = time.perf_counter()
    out = generate(model, params, prompts, args.gen, prefix,
                   args.temperature)
    jax.block_until_ready(out)
    t2 = time.perf_counter()
    steady = t2 - t1
    return {
        "mode": "static",
        "generated_shape": list(out.shape),
        "tokens_per_s": round(args.batch * args.gen / steady, 1),
        "compile_s": round((t1 - t0) - steady, 3),
        "sample": np.asarray(out[0, :8]).tolist(),
    }


def _run_engine(model, params, args, cfg):
    from repro.serve import (SlotEngine, poisson_workload, serve_continuous)

    max_len = args.prompt_len + args.gen
    engine = SlotEngine(model, params, n_slots=args.batch, max_len=max_len,
                        block_size=args.block_size,
                        temperature=args.temperature)
    workload = poisson_workload(args.requests, args.rate, cfg.vocab,
                                prompt_lens=(args.prompt_len,),
                                gen_lens=(args.gen,))
    engine.warmup(buckets=[r.prompt_len for r in workload])
    report = serve_continuous(engine, workload)
    first = report.requests[0]
    return {
        "mode": "continuous",
        **report.summary(),
        "sample": first.out[:8],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--static", action="store_true",
                    help="pre-engine baseline: one static prefill+decode "
                         "batch (forced for prefix-conditioned archs)")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (engine) / batch rows (static)")
    ap.add_argument("--requests", type=int, default=8,
                    help="workload size of the engine mode")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson arrival rate (requests/sim-second)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--block-size", type=int, default=0,
                    help="paged KV block length (0: one block per slot)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--fl-checkpoint", default=None,
                    help="serve the aggregated model of a repro.api "
                         "save_state checkpoint instead of random init")
    from repro.launch.env import add_env_profile_args, apply_env_profile
    add_env_profile_args(ap)
    args = ap.parse_args(argv)
    apply_env_profile(args.env_profile, host_devices=args.host_devices)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    model = Transformer(cfg)
    if args.fl_checkpoint:
        params = load_federated_params(model, args.fl_checkpoint)
    else:
        params = model.init(jax.random.PRNGKey(0))

    if args.static or cfg.prefix_len:
        result = _run_static(model, params, args, cfg)
    else:
        result = _run_engine(model, params, args, cfg)
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch,
        "params": "federated" if args.fl_checkpoint else "random-init",
        **result,
    }, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

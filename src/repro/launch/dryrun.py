import os
import sys as _sys
# only effective before jax initializes (the intended `python -m` entry);
# when imported into a live process (tests), mutating XLA_FLAGS would do
# nothing for jax and only pollute the env for later readers. Skipped when
# the user steers the device count themselves via --env-profile/--host-devices
# (repro.launch.env re-exec) or an explicit XLA_FLAGS — never clobber those.
if ("jax" not in _sys.modules
        and os.environ.get("REPRO_ENV_PROFILE_APPLIED") != "1"
        and "--env-profile" not in _sys.argv
        and "--xla_force_host_platform_device_count"
            not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512"
                               ).strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh, and extract the roofline terms
from the compiled artifact. No tensor is ever allocated (ShapeDtypeStruct
stand-ins only); the 512 host devices above exist only for this module.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out-dir experiments/dryrun
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import (
    ASSIGNED_ARCHS,
    get_arch,
    get_shape,
    input_specs,
    supports_shape,
)
from repro.api import FederationSpec, get_engine
from repro.launch.mesh import (
    default_n_clients,
    make_federated_mesh,
    make_production_mesh,
    make_serving_mesh,
)
from repro.models.sharding import (
    axis_rules,
    named_sharding_tree,
    serve_rules,
    train_rules,
)
from repro.models.transformer import Transformer
from repro.optim import sgd
from repro.utils.hlo import analyze_hlo, cost_analysis_dict
from repro.utils.roofline import (
    RooflineTerms,
    active_params,
    model_flops_estimate,
)

HBM_PER_CHIP = 16 * 1024 ** 3   # v5e: 16 GiB


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _stack_clients(sds_tree, c):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((c,) + x.shape, x.dtype), sds_tree)


def _prepend_client_axes(axes_tree):
    return jax.tree.map(lambda t: ("client",) + t, axes_tree,
                        is_leaf=lambda t: isinstance(t, tuple))


def _spec_sharding(mesh, tree, spec_fn):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree.map(lambda x: NamedSharding(mesh, spec_fn(x)), tree)


def _replicated(mesh, tree):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree.map(lambda x: NamedSharding(mesh, P()), tree)


def param_count(params_sds) -> int:
    import math
    return sum(math.prod(x.shape) if x.shape else 1
               for x in jax.tree.leaves(params_sds))


# ---------------------------------------------------------------------------
# lowering builders
# ---------------------------------------------------------------------------

ACT_BUDGET_BYTES = 5e9   # per-device activation-carry budget for training


def _auto_microbatches(cfg, shape, n_clients: int, replica: int) -> int:
    """Split each client's local batch into sequential microbatches so the
    per-device remat carry (L x B_micro x S x d x 2B) stays under budget."""
    per_client_b = shape.global_batch // n_clients
    n_layers = sum(s.n_steps * len(s.pattern) for s in cfg.segments)
    d_act = cfg.d_model * (2 if cfg.ssm_state else 1)
    seq = shape.seq_len + cfg.prefix_len
    bytes_per_seq = seq * d_act * 2 * n_layers
    b_micro_dev = max(1, int(ACT_BUDGET_BYTES // bytes_per_seq))
    need = max(1, -(-per_client_b // (replica * b_micro_dev)))  # ceil
    # round up to a divisor of the per-client batch
    n_mb = need
    while per_client_b % n_mb:
        n_mb += 1
    return min(n_mb, per_client_b)


def lower_train(cfg, shape, mesh, n_clients: int, tau: int, lr: float = 0.1,
                microbatches: int | None = None,
                grad_accumulate: str = "stack",
                gather_weights: bool = False, ddp: bool = False):
    """Lower one DP-PASGD round (tau local steps + 1 averaging) — Eq. 7a-7b."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    fed_mesh = make_federated_mesh(mesh, n_clients)
    model = Transformer(cfg)
    key = jax.random.PRNGKey(0)
    params1 = jax.eval_shape(model.init, key)
    axes = model.param_axes()
    opt = sgd(lr)
    opt1 = jax.eval_shape(opt.init, params1)

    params_c = _stack_clients(params1, n_clients)
    opt_c = _stack_clients(opt1, n_clients)
    batch = input_specs(cfg, shape, n_clients=n_clients, tau=tau)

    replica = fed_mesh.shape["replica"]
    n_mb = microbatches or _auto_microbatches(cfg, shape, n_clients, replica)
    spec = FederationSpec(n_clients=n_clients, tau=tau, loss_fn=model.loss_fn,
                          optimizer=opt, engine="vmap", clip_norm=1.0,
                          dp=True, num_microbatches=n_mb,
                          vmap_microbatches=False,
                          grad_accumulate=grad_accumulate)
    round_step = get_engine("vmap")(spec)

    rules = train_rules()
    if gather_weights:
        rules["wg"] = None
    if ddp:
        # replicate params within the client group (no FSDP): removes the
        # contracting-dim sharding and its per-token activation all-reduces;
        # only valid when params fit replicated (<= ~6 GiB/device)
        rules["fsdp"] = None
        rules["wg"] = None
    with axis_rules(fed_mesh, rules):
        p_sh = named_sharding_tree(fed_mesh, _prepend_client_axes(axes),
                                   params_c)
        o_sh = jax.tree.map(
            lambda x: NamedSharding(fed_mesh, P("client")), opt_c)
        b_sh = jax.tree.map(
            lambda x: NamedSharding(
                fed_mesh,
                P("client", None, "replica")), batch)
        key_c = jax.random.PRNGKey(0)
        sig = jax.ShapeDtypeStruct((n_clients,), jnp.float32)
        k_sh = NamedSharding(fed_mesh, P())
        s_sh = NamedSharding(fed_mesh, P("client"))

        jitted = jax.jit(round_step,
                         in_shardings=(p_sh, o_sh, b_sh, k_sh, s_sh),
                         out_shardings=(p_sh, o_sh, None))
        lowered = jitted.lower(params_c, opt_c, batch, key_c, sig)
    n_params = param_count(params1)
    tokens = shape.global_batch * shape.seq_len * tau
    return lowered, n_params, tokens, n_mb


def lower_prefill(cfg, shape, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    serve_mesh = make_serving_mesh(mesh)
    model = Transformer(cfg)
    params1 = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    axes = model.param_axes()
    batch = input_specs(cfg, shape)
    fsdp = _needs_param_sharding(params1, serve_mesh)

    with axis_rules(serve_mesh, serve_rules(fsdp_over_data=fsdp)):
        p_sh = named_sharding_tree(serve_mesh, axes, params1)
        b_sh = {k: NamedSharding(serve_mesh, P("data"))
                for k in batch}

        def prefill_step(params, batch):
            return model.prefill(params, batch["tokens"],
                                 batch.get("prefix"),
                                 max_len=shape.seq_len)

        jitted = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(params1, batch)
    n_params = param_count(params1)
    tokens = shape.global_batch * shape.seq_len
    return lowered, n_params, tokens


def lower_decode(cfg, shape, mesh, donate_cache: bool = True):
    from jax.sharding import NamedSharding, PartitionSpec as P
    serve_mesh = make_serving_mesh(mesh)
    model = Transformer(cfg)
    params1 = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    axes = model.param_axes()
    b = shape.global_batch
    caches = jax.eval_shape(lambda: model.init_cache(b, shape.seq_len))
    cache_axes = model.cache_axes()
    shard_seq = shape.name == "long_500k"
    fsdp = _needs_param_sharding(params1, serve_mesh)
    rules = serve_rules(fsdp_over_data=fsdp, shard_seq=shard_seq)
    # KV-cache placement: shard kv heads over the model axis when they
    # divide it; otherwise shard the cache sequence dim over "model"
    # (decode attention reduces over seq -> all-reduce, still cheap).
    kv_divides = cfg.n_kv_heads % serve_mesh.shape["model"] == 0
    if shard_seq:
        rules["batch"] = None     # batch=1: the data axis shards the cache seq
        rules["cache_seq"] = ("data", "model") if not kv_divides else "data"
        rules["kv_tp"] = "model" if kv_divides else None
    elif not kv_divides:
        rules["kv_tp"] = None
        rules["cache_seq"] = "model"

    with axis_rules(serve_mesh, rules):
        p_sh = named_sharding_tree(serve_mesh, axes, params1)
        c_sh = named_sharding_tree(serve_mesh, cache_axes, caches)
        tok = jax.ShapeDtypeStruct((b,), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        t_sh = NamedSharding(serve_mesh,
                             P("data") if (not shard_seq and b % serve_mesh.shape["data"] == 0)
                             else P())

        def serve_step(params, caches, tokens, pos):
            return model.decode_step(params, caches, tokens, pos)

        jitted = jax.jit(serve_step,
                         in_shardings=(p_sh, c_sh, t_sh,
                                       NamedSharding(serve_mesh, P())),
                         out_shardings=(None, c_sh),
                         donate_argnums=(1,) if donate_cache else ())
        lowered = jitted.lower(params1, caches, tok, pos)
    n_params = param_count(params1)
    tokens = b   # one new token per sequence
    return lowered, n_params, tokens


def _needs_param_sharding(params_sds, serve_mesh) -> bool:
    """Shard params over the data axis too (serving FSDP) when a pure-TP
    placement would exceed ~60% of one chip's HBM."""
    n = param_count(params_sds)
    bytes_per_chip_tp = n * 2 / serve_mesh.shape["model"]
    return bytes_per_chip_tp > 0.6 * HBM_PER_CHIP


# ---------------------------------------------------------------------------
# mesh placement report (repro.mesh plane)
# ---------------------------------------------------------------------------


def mesh_report(archs, n_clients: int, n_devices: int,
                device_mem_bytes: int | None = None) -> list[dict]:
    """Per-arch 2D-mesh placement audit: one client replica's param +
    opt-state bytes (abstract, from ``configs.shapes.replica_footprint_bytes``)
    against the per-device budget under the mesh ``repro.mesh.placement``
    would choose — the static answer to "does engine='auto' pick mesh_2d
    here, and does each model shard actually fit?".
    """
    from repro.configs.shapes import replica_footprint_bytes
    from repro.mesh.placement import (
        choose_engine,
        default_mesh_shape,
        device_memory_budget,
    )
    from repro.optim import sgd

    budget = device_memory_budget(default=device_mem_bytes)
    opt = sgd(0.1)
    rows = []
    for arch in archs:
        cfg = get_arch(arch)
        replica = replica_footprint_bytes(cfg, optimizer=opt)
        engine = choose_engine(n_clients, n_devices, replica_bytes=replica,
                               hbm_bytes=budget)
        dc, dm = default_mesh_shape(n_clients, n_devices,
                                    replica_bytes=replica, hbm_bytes=budget)
        per_device = -(-replica // dm)    # ceil: largest model shard
        rows.append({
            "arch": arch,
            "replica_bytes": int(replica),
            "engine": engine,
            "mesh_shape": [dc, dm],
            "per_device_bytes": int(per_device),
            "budget_bytes": int(budget),
            "fits": bool(per_device <= budget),
            "n_clients": n_clients,
            "n_devices": n_devices,
        })
    return rows


def print_mesh_report(rows) -> None:
    hdr = (f"{'arch':<22} {'replica':>10} {'engine':>10} {'mesh':>7} "
           f"{'per-dev':>10} {'budget':>10} fits")
    print(hdr)
    print("-" * len(hdr))
    gib = 1024 ** 3
    for r in rows:
        dc, dm = r["mesh_shape"]
        print(f"{r['arch']:<22} {r['replica_bytes'] / gib:>9.2f}G "
              f"{r['engine']:>10} {dc:>3}x{dm:<3} "
              f"{r['per_device_bytes'] / gib:>9.2f}G "
              f"{r['budget_bytes'] / gib:>9.2f}G "
              f"{'yes' if r['fits'] else 'NO'}")


# ---------------------------------------------------------------------------
# run + report
# ---------------------------------------------------------------------------

OPTS = ("scan_accum", "onehot_embed", "causal_buckets", "rwkv_chunk",
        "moe_dense", "donate_cache", "gather_weights", "ddp")


def apply_opts(cfg, opts: tuple[str, ...]):
    """Beyond-paper §Perf optimizations, applied on top of the baseline."""
    from dataclasses import replace
    kw = {}
    if "onehot_embed" in opts:
        kw["embed_impl"] = "one_hot"
    if "causal_buckets" in opts:
        kw["causal_buckets"] = True
    if "rwkv_chunk" in opts:
        kw["rwkv_chunk"] = 64
    if "moe_dense" in opts:
        kw["moe_impl"] = "dense"
    return replace(cfg, **kw) if kw else cfg


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            n_clients: int | None = None, tau: int = 4,
            compile_it: bool = True, microbatches: int | None = None,
            opts: tuple[str, ...] = ()) -> dict:
    cfg = apply_opts(get_arch(arch), opts)
    shape = get_shape(shape_name)
    ok, why = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if shape.kind == "train":
        c = default_n_clients(mesh, n_clients)
        lowered, n_params, tokens, n_mb = lower_train(
            cfg, shape, mesh, c, tau, microbatches=microbatches,
            grad_accumulate="scan" if "scan_accum" in opts else "stack",
            gather_weights="gather_weights" in opts, ddp="ddp" in opts)
        extra = {"n_clients": c, "tau": tau, "microbatches": n_mb,
                 "opts": list(opts)}
    elif shape.kind == "prefill":
        lowered, n_params, tokens = lower_prefill(cfg, shape, mesh)
        extra = {}
    else:
        lowered, n_params, tokens = lower_decode(
            cfg, shape, mesh, donate_cache="no_donate" not in opts)
        extra = {"opts": list(opts)} if opts else {}
    t_lower = time.time() - t0

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "status": "lowered",
        "n_params": n_params, "tokens_per_step": tokens,
        "lower_s": round(t_lower, 1), **extra,
    }
    if not compile_it:
        return rec

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    rec["status"] = "compiled"

    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    # loop-aware accounting (cost_analysis counts scan bodies once; our
    # models scan over layers and tau, so we parse the HLO instead)
    model_cost = analyze_hlo(hlo)
    flops = float(model_cost.flops)
    hbm = float(model_cost.hbm_bytes)
    coll = {k: int(v) for k, v in model_cost.coll_breakdown.items()}
    coll["total"] = int(model_cost.coll_bytes)
    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if mem is not None and hasattr(mem, attr):
            mem_rec[attr] = int(getattr(mem, attr))
    arg = mem_rec.get("argument_size_in_bytes", 0)
    tmp = mem_rec.get("temp_size_in_bytes", 0)
    out_b = mem_rec.get("output_size_in_bytes", 0)
    alias = mem_rec.get("alias_size_in_bytes", 0)
    live = arg + tmp + out_b - alias
    chips = mesh.size

    n_active = active_params(cfg, float(n_params))
    mf = model_flops_estimate(n_active, tokens, shape.kind)
    terms = RooflineTerms(flops=flops, hbm_bytes=hbm,
                          coll_bytes=float(coll.get("total", 0)),
                          model_flops=mf, chips=chips, coll_breakdown=coll)
    rec.update({
        "cost_analysis_raw": {k: float(v) for k, v in cost.items()
                              if k in ("flops", "bytes accessed")},
        "memory_analysis": mem_rec,
        "live_bytes_per_device": int(live),
        "fits_hbm": bool(live <= HBM_PER_CHIP),
        "roofline": terms.as_dict(),
        "active_params": n_active,
    })
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--opt", default="",
                    help="comma list of §Perf optimizations: "
                         "scan_accum,onehot_embed,causal_buckets")
    ap.add_argument("--tag", default="",
                    help="suffix for output json (e.g. _opt)")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--mesh-report", action="store_true",
                    help="report per-device param+opt-state bytes for each "
                         "arch under the 2D mesh engine='auto' would pick "
                         "(repro.mesh.placement), instead of lowering")
    ap.add_argument("--device-mem-gb", type=float, default=None,
                    help="per-device HBM budget in GiB for --mesh-report "
                         "(default: REPRO_DEVICE_MEM_BYTES env or 16 GiB)")
    from repro.launch.env import add_env_profile_args, apply_env_profile
    add_env_profile_args(ap)
    args = ap.parse_args(argv)
    apply_env_profile(args.env_profile, host_devices=args.host_devices)

    os.makedirs(args.out_dir, exist_ok=True)

    if args.mesh_report:
        archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
        mem = (int(args.device_mem_gb * 1024 ** 3)
               if args.device_mem_gb else None)
        rows = mesh_report(archs, n_clients=args.clients or 8,
                           n_devices=len(jax.devices()),
                           device_mem_bytes=mem)
        print_mesh_report(rows)
        out = os.path.join(args.out_dir, "mesh_report.json")
        with open(out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {out}")
        return 0 if all(r["fits"] for r in rows) else 1
    combos = ([(a, s) for a in ASSIGNED_ARCHS
               for s in ("train_4k", "prefill_32k", "decode_32k",
                         "long_500k")]
              if args.all else [(args.arch, args.shape)])

    opts = tuple(o for o in args.opt.split(",") if o)
    results = []
    for arch, shape in combos:
        tag = (f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
               + args.tag)
        print(f"=== dryrun {tag} ===", flush=True)
        try:
            rec = run_one(arch, shape, multi_pod=args.multi_pod,
                          n_clients=args.clients, tau=args.tau,
                          compile_it=not args.lower_only,
                          microbatches=args.microbatches, opts=opts)
        except Exception as e:  # noqa: BLE001 - record failures, keep going
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "error": f"{type(e).__name__}: {e}"}
        results.append(rec)
        with open(os.path.join(args.out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=2)
        if rec.get("roofline"):
            r = rec["roofline"]
            print(f"  params={rec['n_params']/1e9:.2f}B "
                  f"flops/dev={r['flops_per_device']/1e12:.2f}T "
                  f"coll/dev={r['coll_bytes_per_device']/1e9:.3f}GB "
                  f"bottleneck={r['bottleneck']} "
                  f"useful={r['useful_flops_fraction']:.2%} "
                  f"fits_hbm={rec['fits_hbm']}", flush=True)
        else:
            print(f"  {rec['status']}: {rec.get('reason', rec.get('error', ''))}",
                  flush=True)
    bad = [r for r in results if r["status"] == "error"]
    print(f"done: {len(results)} combos, {len(bad)} errors")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())

"""Production meshes + the derived federated / serving views.

``make_production_mesh`` is the prescribed entry point (single-pod 16x16
"data" x "model"; multi-pod 2x16x16 with a leading "pod" axis). DP-PASGD
derives a ("client", "replica", "model") view of the SAME devices: the
client axis groups contiguous slabs (one divergent model replica each —
the federated clients), "replica" is within-client data parallel (also the
FSDP shard axis), "model" is tensor parallel. Serving derives a flat
("data", "model") view.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_federated_mesh(mesh: Mesh, n_clients: int) -> Mesh:
    """("client", "replica", "model") view over the production mesh devices.

    The model axis is preserved (last mesh dim); the pod x data axes are
    re-grouped into client x replica. Clients are contiguous slabs, so in the
    multi-pod mesh client boundaries align with pod boundaries whenever
    n_clients >= n_pods — the round-boundary all-reduce is then the only
    cross-pod collective, which is the paper's communication pattern.
    """
    devices = mesh.devices
    model = devices.shape[-1]
    total = devices.size // model
    if total % n_clients:
        raise ValueError(f"{n_clients} clients do not divide {total} "
                         "data-parallel slots")
    replica = total // n_clients
    return Mesh(devices.reshape(n_clients, replica, model),
                ("client", "replica", "model"))


def make_serving_mesh(mesh: Mesh) -> Mesh:
    """("data", "model") view (pod axis folded into data)."""
    devices = mesh.devices
    model = devices.shape[-1]
    return Mesh(devices.reshape(-1, model), ("data", "model"))


def default_n_clients(mesh: Mesh, requested: int | None = None) -> int:
    """Default federation size: 4 clients per pod (=> 4-way FSDP within each
    client on a 16x16 pod), doubling with the pod count."""
    if requested:
        return requested
    n_pods = mesh.devices.shape[0] if mesh.devices.ndim == 3 else 1
    return 4 * n_pods

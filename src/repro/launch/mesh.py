"""Production meshes + the derived federated / serving views.

``make_production_mesh`` is the prescribed entry point (single-pod 16x16
"data" x "model"; multi-pod 2x16x16 with a leading "pod" axis). DP-PASGD
derives a ("client", "replica", "model") view of the SAME devices: the
client axis groups contiguous slabs (one divergent model replica each —
the federated clients), "replica" is within-client data parallel (also the
FSDP shard axis), "model" is tensor parallel. Serving derives a flat
("data", "model") view.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_mesh_2d(mesh_shape: tuple[int, int], devices=None) -> Mesh:
    """The 2D federation mesh of :mod:`repro.mesh`: ``mesh_shape = (dc, dm)``
    client blocks x model shards over the local devices.

    Each of the ``dc`` client blocks is a CONTIGUOUS slab of ``dm`` devices
    (row-major reshape), so on a pod whose device order walks pods first,
    client blocks align with pod boundaries whenever ``dm`` divides the pod
    size — tau local steps then touch only intra-slab (tensor-parallel)
    links and the round-boundary client reduction is the sole cross-slab
    collective, the paper's communication pattern at pod scale. ``dm = 1``
    is the degenerate mesh: bit-identical to the 1D ``shard_map`` engine.
    """
    dc, dm = int(mesh_shape[0]), int(mesh_shape[1])
    if dc < 1 or dm < 1:
        raise ValueError(f"mesh_shape must be two positive ints, "
                         f"got {mesh_shape!r}")
    devices = list(jax.devices()) if devices is None else list(devices)
    if dc * dm > len(devices):
        raise ValueError(f"mesh_shape {(dc, dm)} needs {dc * dm} devices, "
                         f"only {len(devices)} available")
    grid = np.asarray(devices[:dc * dm]).reshape(dc, dm)
    return Mesh(grid, ("client", "model"))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_federated_mesh(mesh: Mesh, n_clients: int) -> Mesh:
    """("client", "replica", "model") view over the production mesh devices.

    The model axis is preserved (last mesh dim); the pod x data axes are
    re-grouped into client x replica. Clients are contiguous slabs, so in the
    multi-pod mesh client boundaries align with pod boundaries whenever
    n_clients >= n_pods — the round-boundary all-reduce is then the only
    cross-pod collective, which is the paper's communication pattern.
    """
    devices = mesh.devices
    model = devices.shape[-1]
    total = devices.size // model
    if total % n_clients:
        raise ValueError(f"{n_clients} clients do not divide {total} "
                         "data-parallel slots")
    replica = total // n_clients
    return Mesh(devices.reshape(n_clients, replica, model),
                ("client", "replica", "model"))


def make_serving_mesh(mesh: Mesh) -> Mesh:
    """("data", "model") view (pod axis folded into data)."""
    devices = mesh.devices
    model = devices.shape[-1]
    return Mesh(devices.reshape(-1, model), ("data", "model"))


def default_n_clients(mesh: Mesh, requested: int | None = None) -> int:
    """Default federation size: 4 clients per pod (=> 4-way FSDP within each
    client on a 16x16 pod), doubling with the pod count."""
    if requested:
        return requested
    n_pods = mesh.devices.shape[0] if mesh.devices.ndim == 3 else 1
    return 4 * n_pods

"""Device-resident cohort execution: a warm-client shard cache + per-round
cohorts inside the fused scan (§Perf opt — ISSUE 8 tentpole).

The chunk-boundary driver of :mod:`repro.population.runtime` fixes ONE
cohort per fused chunk because the cohort's sticky state (error-feedback
residual rows, per-vid rho) lives in the host :class:`ClientStore` and a
per-round cohort change would force a host gather/scatter round trip every
round. This module removes that constraint by keeping a **resident cache**
of S >> K warm virtual clients on device:

* **residual** — an (S, D) f32 device block, the warm clients'
  error-feedback rows. Write-back: rows move host-ward only on eviction or
  :meth:`ResidentCache.flush` (both as *lazy* device slices — nothing
  blocks until the flush actually materializes them).
* **rho** — an (S,) f64 host vector. The zCDP ledger is exact host math by
  repo convention, so "resident" here means *write-through*: charged
  during the chunk replay, flushed to the store at every chunk boundary
  (free of device syncs — it never lived on device).
* **data** — optionally, the warm clients' (S, tau, B, ...) shard block on
  device. Only exact when the population declares itself ``stationary``
  (the sampler ignores its rng — each client re-reads a fixed local
  shard, the typical IoT regime); fresh-per-round sampling populations
  keep streaming host-built batches, which draw from the shared rng in
  per-round order and therefore cannot be cached across rounds without
  changing the realized data stream.

With the warm set resident, :func:`run_resident_rounds` draws a **fresh
cohort every round inside the fused ``lax.scan``**: the per-round cohorts
come from the same stateless ``(seed, round_idx)`` draw the per-round
driver makes (:func:`repro.population.samplers.chunk_cohorts`), their vids
are mapped to cache slots on the host, and the (R, K) slot plan rides into
the scan where the ``cohort_gather_scatter`` kernel moves rows between the
cache and the round's K-block as pure device ops. Chunked and per-round
drivers therefore realize the SAME cohort schedule — the gap the
chunk-boundary driver documented — and the steady-state chunk makes **zero
blocking host syncs** under full within-cohort participation (partial
participation keeps run_rounds' one stacked-mask fetch per chunk: the
conditional ledger needs the realized sets).

Exactness contract (the PR-5 identity gate, extended): the store round-trip
preserves f32 bits, the kernel is a pure row copy on every backend, and the
host ledger replay mirrors the per-round driver's float operations
expression by expression (same repeated adds, same np.max / zcdp_to_dp /
``_population_epsilon_fix`` order, a running mirror of the store's monotone
``_max_rho``). So the resident path is bit-identical to the per-round
cohort driver on the same schedule — params, opt_state, rho, residual,
resource_spent — for any S, any eviction churn; and with M == C, cohort ==
population, S == M it is bit-identical to the dense engines (the degenerate
slot map is the identity). tests/test_population.py and
tests/test_seed_sweep.py pin both.

The store stays authoritative between chunks for everything except the
warm residual rows; :meth:`ResidentCache.flush` (called by
``train_population`` before returning, and by anything that wants to
checkpoint) restores full authority.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.engines import chunked_round_fn_for, resident_chunked_round_fn_for
from repro.api.spec import FederationSpec
from repro.api.state import (
    PrefetchFailed,
    _raise_budget,
    round_rho_charges,
    sigmas_for,
)
from repro.core.privacy import zcdp_to_dp
from repro.kernels.ops import cohort_gather
from repro.population.population import ClientPopulation
from repro.population.samplers import CohortSampler, chunk_cohorts
from repro.population.store import ClientStore


# fused promotion updates (one dispatch per chunk instead of one per device
# block), module-level so every cache instance shares the jit compile cache.
# The residual and data slot sets differ — evicted-then-repromoted vids keep
# a pending residual row but always re-land their (stationary, reproducible)
# data row. Donating the old blocks keeps promotion allocation-neutral.
@functools.partial(jax.jit, donate_argnums=(0, 1))
def _promote_with_data(residual, data, slr, rrows, sld, drows):
    return (residual.at[slr].set(rrows),
            jax.tree.map(lambda c, r: c.at[sld].set(r), data, drows))


@functools.partial(jax.jit, donate_argnums=(0,))
def _promote_residual(residual, slr, rrows):
    return residual.at[slr].set(rrows)


@jax.jit
def _unstack_metrics(ms):
    """Split the scan's stacked (R, ...) metric leaves into R per-round
    views in ONE dispatch (the eager per-round ``v[r]`` slicing was a
    dispatch per key per round — measurable at chunk granularity). The
    outputs stay lazy device scalars; nothing blocks."""
    return jax.tree.map(lambda v: tuple(v), ms)


class ResidentCache:
    """The warm-client shard cache (see module docstring).

    Host half: ``vids`` (S,) int64 slot->vid map (-1 empty), ``slot_of``
    its inverse, ``rho`` (S,) f64 write-through ledger rows, ``last_used``
    LRU stamps. Device half: ``residual`` (S, D) f32 write-back rows (None
    for non-pipeline specs — no sticky device state) and optionally
    ``data``, the warm shards' (S, tau, B, ...) pytree (stationary
    populations only). ``pending`` holds evicted residual rows as lazy
    references ``vid -> (batch, row)`` into per-eviction (n, D) device
    gathers until :meth:`flush` materializes them — eviction itself is one
    device gather per batch of victims and never blocks the host.
    """

    def __init__(self, capacity: int, residual_dim: int | None = None,
                 data_template: Any = None):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self.residual_dim = residual_dim
        self.vids = np.full((self.capacity,), -1, np.int64)
        self.slot_of: dict[int, int] = {}
        self.last_used = np.zeros((self.capacity,), np.int64)
        self.rho = np.zeros((self.capacity,), np.float64)
        self.residual = (jnp.zeros((self.capacity, residual_dim), jnp.float32)
                         if residual_dim is not None else None)
        self.data = (jax.tree.map(
            lambda x: jnp.zeros((self.capacity,) + x.shape, x.dtype),
            data_template) if data_template is not None else None)
        self.pending: dict[int, tuple[jax.Array, int]] = {}
        self.clock = 0
        self.stats = {"hits": 0, "misses": 0, "evictions": 0, "flushes": 0}

    def warm_count(self) -> int:
        return len(self.slot_of)

    def _stamp(self, vids: np.ndarray) -> None:
        self.clock += 1
        idx = np.asarray([self.slot_of[int(v)] for v in vids], np.int64)
        self.last_used[idx] = self.clock

    def ensure_resident(self, store: ClientStore, vids: np.ndarray, *,
                        population: ClientPopulation | None = None,
                        tau: int | None = None,
                        data_rows: dict[int, Any] | None = None) -> None:
        """Promote ``vids`` into the cache, evicting LRU slots not in the
        incoming set. All device movement is lazy: evicted residual rows
        become pending device slices, promoted rows land with one batched
        ``.at[slots].set`` — no host sync. ``data_rows`` optionally
        supplies pre-materialized shards for cold vids (the prefetch path
        of ``train_population``); missing ones fall back to the sampler.
        """
        vids = np.unique(np.asarray(vids, np.int64))
        if vids.size > self.capacity:
            raise ValueError(
                f"chunk needs {vids.size} distinct warm clients but the "
                f"resident cache holds {self.capacity}; raise "
                f"--resident-cache or lower chunk_rounds (a chunk can touch "
                f"up to chunk_rounds * K distinct vids)")
        need = [int(v) for v in vids if int(v) not in self.slot_of]
        self.stats["hits"] += int(vids.size) - len(need)
        self.stats["misses"] += len(need)
        if need:
            needed = {int(v) for v in vids}
            free = [int(s) for s in np.flatnonzero(self.vids < 0)]
            if len(free) < len(need):
                lru = sorted(
                    (int(self.last_used[s]), int(s))
                    for s in np.flatnonzero(self.vids >= 0)
                    if int(self.vids[s]) not in needed)
                free += [s for _, s in lru[:len(need) - len(free)]]
            victims = [s for s in free if self.vids[s] >= 0]
            if self.residual is not None and victims:
                # write-back without a sync: ONE lazy device gather of the
                # victims' rows, referenced per-vid as (batch, row) until
                # flush() materializes the batch — no per-victim dispatch
                vrows = self.residual[np.asarray(victims, np.int32)]
                for j, s in enumerate(victims):
                    self.pending[int(self.vids[s])] = (vrows, j)
            for s in victims:
                del self.slot_of[int(self.vids[s])]
                self.vids[s] = -1
            self.stats["evictions"] += len(victims)
            slots_new = free[:len(need)]
            need_arr = np.asarray(need, np.int64)
            slots_arr = np.asarray(slots_new, np.int64)
            self.vids[slots_arr] = need_arr
            for v, s in zip(need, slots_new):
                self.slot_of[v] = s
            # rho is write-through: between chunks the store is
            # authoritative, so promotion is a plain host read
            self.rho[slots_arr] = store.rho[need_arr]
            # cold residual rows come out of the store in ONE batched
            # gather; re-promoted pending rows (evicted earlier, sampled
            # again before a flush) are sliced out of their lazy eviction
            # batches — the rare path, kept out of the fused update
            cold = [v for v in need if v not in self.pending]
            warm = [v for v in need if v in self.pending]
            if self.residual is not None:
                sl_cold = np.asarray([self.slot_of[v] for v in cold],
                                     np.int32)
                rrows = store.gather_residual(np.asarray(cold, np.int64))
            if self.data is not None:
                if population is None or tau is None:
                    raise ValueError("data-resident promotion needs the "
                                     "population and tau")
                throwaway = np.random.default_rng(0)
                shards = []
                for v in need:
                    got = None if data_rows is None else data_rows.get(v)
                    if got is None:
                        # stationary contract: the sampler ignores its rng,
                        # so a throwaway generator re-derives the client's
                        # fixed shard without touching the shared stream
                        got = population.sampler(int(v), tau, throwaway)
                    shards.append(got)
                drows = jax.tree.map(lambda *xs: np.stack(xs), *shards)
                self.residual, self.data = _promote_with_data(
                    self.residual, self.data, sl_cold, rrows,
                    np.asarray(slots_new, np.int32), drows)
            elif self.residual is not None:
                self.residual = _promote_residual(self.residual, sl_cold,
                                                  rrows)
            if self.residual is not None and warm:
                sl = np.asarray([self.slot_of[v] for v in warm], np.int32)
                rows = [self.pending.pop(v) for v in warm]
                self.residual = self.residual.at[sl].set(
                    jnp.stack([batch[j] for batch, j in rows]))
        self._stamp(vids)

    def slots_for(self, cohorts: np.ndarray) -> np.ndarray:
        """Map an (R, K) vid plan to its (R, K) int32 cache-slot plan."""
        flat = np.asarray([self.slot_of[int(v)]
                           for v in np.asarray(cohorts).ravel()], np.int32)
        return flat.reshape(np.asarray(cohorts).shape)

    def flush(self, store: ClientStore) -> None:
        """Materialize every warm + pending residual row into the store and
        write the warm rho rows through — after this the store is fully
        authoritative again (checkpoint-safe). The one deliberate blocking
        sync of the resident path; rho and the slot map are host-only."""
        self.stats["flushes"] += 1
        occ = np.flatnonzero(self.vids >= 0)
        if self.residual is not None:
            if occ.size:
                rows = np.asarray(
                    self.residual[np.asarray(occ, np.int32)])
                store.scatter_residual(self.vids[occ], rows)
            if self.pending:
                vids = np.asarray(sorted(self.pending), np.int64)
                # materialize each eviction batch once, then pick rows
                mat: dict[int, np.ndarray] = {}

                def _row(batch, j):
                    if id(batch) not in mat:
                        mat[id(batch)] = np.asarray(batch)
                    return mat[id(batch)][j]

                rows = np.stack([_row(*self.pending[int(v)]) for v in vids])
                store.scatter_residual(vids, rows)
                self.pending.clear()
        if occ.size:
            store.scatter_rho(self.vids[occ], self.rho[occ])

    def reset(self) -> None:
        """Drop all residency (after a flush): slots empty, device arrays
        kept allocated. Stale rows are never read — gathers only touch
        slots in ``slot_of`` and promotion overwrites before use."""
        self.vids.fill(-1)
        self.slot_of.clear()
        self.last_used.fill(0)
        self.clock = 0


def init_resident_cache(spec: FederationSpec, pstate,
                        capacity: int,
                        population: ClientPopulation | None = None,
                        ) -> ResidentCache:
    """Build the resident cache for ``spec``: capacity clamped to
    min(capacity, M), residual block sized from the store, and the data
    block allocated iff the population declares ``stationary`` (and the
    spec has a pipeline — the data-resident scan variant is the pipeline
    form; streaming batches otherwise)."""
    if not spec.is_population():
        raise ValueError("resident caches need a population spec "
                         "(FederationSpec(population=M, cohort_size=K))")
    capacity = min(int(capacity), spec.population)
    if capacity < spec.n_clients:
        raise ValueError(f"resident cache capacity {capacity} < cohort "
                         f"size {spec.n_clients}")
    data_template = None
    if (population is not None and population.stationary
            and spec.has_pipeline()):
        shard = population.sampler(0, spec.tau, np.random.default_rng(0))
        data_template = jax.tree.map(np.asarray, shard)
    return ResidentCache(capacity,
                         residual_dim=pstate.store.residual_dim,
                         data_template=data_template)


def run_resident_rounds(spec: FederationSpec, pstate,
                        population: ClientPopulation, rng,
                        cache: ResidentCache,
                        n_rounds: int | None = None,
                        cohort_sampler: CohortSampler | None = None,
                        check_budgets: bool = True,
                        cohorts: np.ndarray | None = None,
                        batches: Any = None,
                        data_rows: dict[int, Any] | None = None,
                        prefetch: Callable[[], None] | None = None,
                        ) -> tuple[Any, list[dict]]:
    """A fused chunk of R rounds with a FRESH COHORT PER ROUND (§Perf opt).

    The per-round cohorts are ``chunk_cohorts(sampler, rounds_done, R)`` —
    the identical stateless schedule the per-round driver realizes — their
    union is promoted into ``cache``, and the (R, K) slot plan rides into
    the fused scan where the ``cohort_gather_scatter`` kernel moves
    residual rows cache<->round-block as pure device ops. Steady-state host
    syncs per chunk: ZERO under full within-cohort participation (the
    all-slots participation mask is deterministic, so the ledger replays
    without fetching it), ONE stacked-mask fetch otherwise.

    ``batches`` may be passed pre-built with leaves (R, K, tau, B, ...) in
    per-round cohort order (the prefetch path); for data-resident caches
    (stationary populations) batches must be None — the scan gathers each
    round's shards from the cache instead. Bit-identical to R sequential
    ``run_cohort_round`` calls; raises/returns like ``run_cohort_rounds``
    (donation consumes the input state's device buffers; ``PrefetchFailed``
    carries the completed PopulationState)."""
    from repro.population import runtime as rt

    sampler = rt._resolve_cohort_sampler(spec, cohort_sampler)
    if spec.is_async():
        raise ValueError("resident execution is a synchronous-cohort "
                         "driver; async specs use repro.asyncfl")
    if cohorts is None:
        if n_rounds is None or n_rounds <= 0:
            raise ValueError(f"n_rounds must be positive, got {n_rounds}")
        cohorts = chunk_cohorts(sampler, pstate.fl.rounds_done, n_rounds,
                                spec.population, spec.n_clients)
    cohorts = np.asarray(cohorts)
    if n_rounds is None:
        n_rounds = int(cohorts.shape[0])
    if cohorts.shape[0] != n_rounds:
        raise ValueError(f"n_rounds={n_rounds} != cohort plan leading axis "
                         f"{cohorts.shape[0]}")
    # row 0 through the standard single-cohort check (spec/population/shape
    # errors), the remaining rows vectorized — per-row python checks were
    # measurable at chunk granularity
    rt._check_cohort(spec, population, cohorts[0])
    if cohorts.ndim != 2 or cohorts.shape[1] != spec.n_clients:
        raise ValueError(f"cohort plan has shape {cohorts.shape}, expected "
                         f"({n_rounds}, {spec.n_clients})")
    srt = np.sort(cohorts, axis=1)
    if np.any(srt[:, 1:] == srt[:, :-1]):
        raise ValueError("cohort vids must be unique within each round")
    if cohorts.min() < 0 or cohorts.max() >= spec.population:
        raise ValueError(f"cohort vids out of range [0, {spec.population})")
    data_resident = cache.data is not None
    if data_resident and batches is not None:
        raise ValueError("data-resident chunks gather shards from the "
                         "cache; don't pass batches")
    if batches is None and not data_resident:
        per = [rt.cohort_batch(spec, population, cohorts[r], rng)
               for r in range(n_rounds)]
        batches = jax.device_put(
            jax.tree.map(lambda *xs: np.stack(xs), *per))
    if check_budgets:
        ok, which = rt.rounds_within_population_budgets(spec, pstate,
                                                        n_rounds)
        if ok < n_rounds:
            _raise_budget(which, spec)

    cache.ensure_resident(pstate.store, np.unique(cohorts),
                          population=population, tau=spec.tau,
                          data_rows=data_rows)
    slots = cache.slots_for(cohorts)

    fl = pstate.fl
    sig = sigmas_for(spec)
    pipeline = spec.has_pipeline()
    full_part = spec.participants_per_round() >= spec.n_clients
    prefetch_exc = None

    def _prefetch():
        nonlocal prefetch_exc
        if prefetch is not None:
            try:
                prefetch()
            except Exception as e:    # noqa: BLE001 — re-raised below
                prefetch_exc = e

    if pipeline:
        fn = resident_chunked_round_fn_for(spec, data_resident=data_resident)
        operand = cache.data if data_resident else batches
        new_p, new_s, key, new_cache, ms, masks = fn(
            fl.params, fl.opt_state, operand, jnp.asarray(slots), fl.key,
            sig, cache.residual)
        cache.residual = new_cache
        _prefetch()
        if full_part:
            # P == C makes participation_mask deterministically all-ones
            # (a permutation prefix of length C covers every slot): the
            # ledger replays without fetching the stacked masks — the
            # steady-state chunk's last blocking sync, now gone
            masks_np = None
        else:
            masks_np = np.asarray(masks)   # the one blocking sync per chunk
    else:
        fn = chunked_round_fn_for(spec)
        new_p, new_s, key, ms = fn(fl.params, fl.opt_state, batches,
                                   fl.key, sig)
        _prefetch()
        masks_np = None

    # exact host ledger replay, mirroring the per-round driver expression by
    # expression: per-cohort repeated adds, the pre-round global-max mirror
    # of the store's monotone _max_rho, and the same lift order
    charges = round_rho_charges(spec)
    running = pstate.store.max_rho()
    M = spec.population
    ms_rows = _unstack_metrics(ms)     # one dispatch, R lazy views per key
    recs: list[dict] = []
    spent = fl.resource_spent
    touched: set[int] = set()
    for r in range(n_rounds):
        vids_r = cohorts[r]
        slots_r = slots[r]
        outside = -math.inf if vids_r.size == M else running
        if masks_np is None:
            add = charges
            participants = float(spec.n_clients)
        else:
            m = masks_np[r]
            add = np.where(m > 0, charges, 0.0)
            participants = float(int(m.sum()))
        block = cache.rho[slots_r] + add
        cache.rho[slots_r] = block
        pstate.store.note_participation(vids_r, 1)
        touched.update(int(v) for v in vids_r)
        spent = spent + spec.round_cost()
        rec = {k: v[r] for k, v in ms_rows.items()}  # lazy 0-d device views
        rec["round"] = fl.rounds_done + r + 1
        rec["iterations"] = (fl.rounds_done + r + 1) * spec.tau
        rec["max_epsilon"] = zcdp_to_dp(float(np.max(block)), spec.delta)
        rec["resource_spent"] = spent
        rec["participants"] = participants
        rt._population_epsilon_fix(rec, outside, spec.delta)
        running = max(running, float(np.max(block)))
        recs.append(rec)

    # rho write-through: the store is authoritative again at the boundary
    # (host-only — budget probes between chunks stay exact and sync-free)
    tv = np.asarray(sorted(touched), np.int64)
    tslots = np.asarray([cache.slot_of[int(v)] for v in tv], np.int64)
    pstate.store.scatter_rho(tv, cache.rho[tslots])

    changes: dict = dict(
        params=new_p, opt_state=new_s, key=key,
        rho=cache.rho[slots[-1]].copy(),
        steps=fl.steps + n_rounds * spec.tau,
        resource_spent=spent,
        rounds_done=fl.rounds_done + n_rounds)
    if pipeline and cache.residual is not None:
        # the FLState keeps its "current cohort view" contract: a lazy
        # device gather of the last round's rows out of the cache
        changes["residual"] = cohort_gather(
            cache.residual, jnp.asarray(slots[-1], jnp.int32),
            backend=spec.kernel_backend)
    new_state = pstate.replace(fl=fl.replace(**changes))
    if prefetch_exc is not None:
        raise PrefetchFailed(prefetch_exc, new_state, recs) from prefetch_exc
    return new_state, recs

"""Pluggable cohort samplers: which K of the M virtual clients run a round.

A cohort sampler is a callable

    sampler(round_idx, population_size, cohort_size) -> sorted unique vids (K,)

Sampling is *stateless per round*: the draw is derived deterministically
from ``(seed, round_idx)``, so checkpoint/resume needs no sampler state
(the round counter on the FLState suffices), the fused multi-round driver
can pre-compute chunk cohorts, and two drivers replay the identical cohort
schedule. Returned vids are SORTED — a cohort is a set, and the canonical
order makes ``cohort == population`` literally ``arange(M)``, which is what
pins the bit-identity of the M == C gate against the dense engines.

Two samplers ship:

* :class:`UniformCohort` — uniform K-of-M without replacement, the
  cross-device FL baseline (and the model under which the K/M subsampling
  amplification of ``repro.core.privacy`` is stated).
* :class:`HeterogeneousCohort` — a per-client availability / dropout model
  for scenario diversity: client m is reachable in a round with probability
  ``rate_m ~ Beta(a, b)`` (charging state, duty cycling), and a selected
  client drops out mid-round with probability ``dropout`` (lost uplink);
  dropped slots are backfilled so the realized cohort keeps its fixed size
  K (static jit shapes). The availability rates are the only O(M) state —
  one float32 vector, materialized lazily on first use.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol

import numpy as np


# integer stream tags (numpy SeedSequence entropy must be ints on older
# numpys): disjoint sub-streams of one sampler seed
_COHORT_TAG = 0xC0407
_RATES_TAG = 0x7A7E5


class CohortSampler(Protocol):
    def __call__(self, round_idx: int, population_size: int,
                 cohort_size: int) -> np.ndarray: ...


def _check_cohort_args(population_size: int, cohort_size: int) -> None:
    if not 1 <= cohort_size <= population_size:
        raise ValueError(f"cohort_size must be in [1, {population_size}], "
                         f"got {cohort_size}")


def _uniform_without_replacement(rng: np.random.Generator, m: int,
                                 k: int) -> np.ndarray:
    """K of M without replacement. For small cohorts of huge populations
    (the IoT regime) rejection sampling is O(K) instead of the O(M)
    permutation ``Generator.choice`` pays."""
    if k * 16 >= m:
        return rng.choice(m, size=k, replace=False)
    picked = np.unique(rng.integers(0, m, size=2 * k))
    while picked.size < k:
        picked = np.unique(np.concatenate(
            [picked, rng.integers(0, m, size=2 * k)]))
    return rng.permutation(picked)[:k]


def chunk_cohorts(sampler: "CohortSampler", start: int, n_rounds: int,
                  population_size: int, cohort_size: int) -> np.ndarray:
    """The stacked (R, K) per-round cohorts of rounds [start, start + R).

    Row r is ``sampler(start + r, ...)`` — the SAME stateless per-round
    draw the per-round driver makes, which is what pins chunked ==
    per-round cohort schedules for the resident-cohort path: both drivers
    call through here (directly or one round at a time), so fusing R
    rounds into one scan never changes which clients train when."""
    if n_rounds <= 0:
        raise ValueError(f"n_rounds must be positive, got {n_rounds}")
    return np.stack([sampler(start + r, population_size, cohort_size)
                     for r in range(n_rounds)])


@dataclass(frozen=True)
class UniformCohort:
    """Uniform K-of-M cohorts, the cross-device FL default."""
    seed: int = 0
    # the subsampling-amplification accounting of
    # FederationSpec(amplify_participation=True) is stated for uniform
    # K-of-M draws; samplers that can honestly make this claim set it
    # (the population drivers refuse amplified accounting otherwise)
    uniform_over_population = True

    def __call__(self, round_idx: int, population_size: int,
                 cohort_size: int) -> np.ndarray:
        _check_cohort_args(population_size, cohort_size)
        rng = np.random.default_rng((self.seed, _COHORT_TAG, int(round_idx)))
        vids = _uniform_without_replacement(rng, population_size, cohort_size)
        return np.sort(vids.astype(np.int64))


@dataclass(frozen=True)
class HeterogeneousCohort:
    """Availability/dropout cohorts: a heterogeneity model over the fleet.

    ``availability=(a, b)`` draws one Beta(a, b) reachability rate per
    virtual client (default mean 0.8 — most devices usually reachable, a
    long tail rarely so); per round, each client is available i.i.d. at its
    rate and the cohort is drawn uniformly from the available set. A drawn
    client then *drops out* mid-round with probability proportional to its
    unreliability — ``dropout * (1 - rate_m) / mean(1 - rate)`` over the
    round's available set, so the fleet-average drop rate is ~``dropout``
    but flaky devices bear it — and its slot is backfilled from the
    remaining available clients. Identity-dependent dropout is the point:
    an identity-blind coin flip over a uniformly drawn set would be a
    distributional no-op (the backfill restores uniformity), whereas this
    model skews realized cohorts toward reliable devices beyond what
    availability alone does — stragglers cost selection bias (what the
    privacy caveat below is about), never a jagged block shape. If fewer
    than K clients are available at all, the server is modeled as
    re-polling: the shortfall is filled from the unavailable set (rare
    under the defaults; deliberate at extreme rates).

    Privacy caveat: the amplification accounting of
    ``FederationSpec(amplify_participation=True)`` assumes *uniform* K-of-M
    sampling. Under availability skew a high-rate client realizes more than
    K/M of the rounds and the expectation-level bound does not transport;
    the sound default (conditional per-realized-client ledger, q = 1) stays
    exact because it charges realized participation only. The ClientStore's
    per-vid ledger is what surfaces that skew.
    """
    seed: int = 0
    availability: tuple[float, float] = (8.0, 2.0)   # Beta(a, b); mean 0.8
    dropout: float = 0.05
    _rates: dict = field(default_factory=dict, compare=False, repr=False)

    def __post_init__(self):
        a, b = self.availability
        if a <= 0 or b <= 0:
            raise ValueError(f"availability Beta params must be positive, "
                             f"got {self.availability}")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {self.dropout}")

    def rates(self, population_size: int) -> np.ndarray:
        """The per-client availability rates (M,) — lazily materialized and
        cached per population size (one f32 vector is the model's only O(M)
        state)."""
        got = self._rates.get(population_size)
        if got is None:
            rng = np.random.default_rng((self.seed, _RATES_TAG))
            a, b = self.availability
            got = rng.beta(a, b, size=population_size).astype(np.float32)
            self._rates[population_size] = got
        return got

    def __call__(self, round_idx: int, population_size: int,
                 cohort_size: int) -> np.ndarray:
        _check_cohort_args(population_size, cohort_size)
        rng = np.random.default_rng((self.seed, _COHORT_TAG, int(round_idx)))
        avail = np.flatnonzero(rng.random(population_size)
                               < self.rates(population_size))
        if avail.size < cohort_size:
            rest = np.setdiff1d(np.arange(population_size), avail,
                                assume_unique=True)
            top_up = rng.permutation(rest)[:cohort_size - avail.size]
            return np.sort(np.concatenate([avail, top_up]).astype(np.int64))
        order = rng.permutation(avail)
        unrel = 1.0 - self.rates(population_size)[order]
        p_drop = np.minimum(
            1.0, self.dropout * unrel / max(float(unrel.mean()), 1e-9))
        survives = rng.random(order.size) >= p_drop
        # first-K survivors; dropped / late candidates backfill in draw order
        ranked = np.concatenate([order[survives], order[~survives]])
        return np.sort(ranked[:cohort_size].astype(np.int64))

"""ClientStore: sticky per-virtual-client state, host-resident, sparse.

Cohort execution keeps only K client replicas on device, but two pieces of
DP-PASGD state are *per client*, not per slot, and must survive between a
client's cohort appearances:

* the **error-feedback residual** of the compressed aggregation pipeline
  (``repro.core.aggregation``) — what the codec dropped from the client's
  last update, re-sent on its next participation;
* the **privacy ledger** — spent zCDP rho per virtual client (the
  conditional per-realized-client ledger: a client pays only for rounds it
  actually ran).

The store keeps a dense (M,) float64 rho vector and (M,) participation
counter (8 + 8 bytes per virtual client — 16 MB at M = 10^6), and a
*sparse* residual table: a (D,) float32 row exists only for clients that
have ever carried nonzero error-feedback state, so host memory scales with
cohort coverage, not with M x D. Rows that return to exactly zero are
pruned. Per round the cohort's rows are gathered into the (K, D) device
block and scattered back — device memory stays bounded by K regardless
of M.

The store checkpoints alongside the FLState
(:func:`repro.population.runtime.save_population_state`) as one ``.npz``
(dense ledgers + the sparse rows with their vid index), so
checkpoint/resume round-trips the ledger and residuals bit-for-bit.
"""
from __future__ import annotations

import numpy as np

STORE_FILENAME = "client_store.npz"


class ClientStore:
    """Sticky per-virtual-client federation state (see module docstring)."""

    def __init__(self, population: int, residual_dim: int | None = None):
        if population <= 0:
            raise ValueError(f"population must be positive, got {population}")
        self.population = population
        self.residual_dim = residual_dim
        self.rho = np.zeros((population,), np.float64)
        self.rounds_participated = np.zeros((population,), np.int64)
        self._residual: dict[int, np.ndarray] = {}
        # running worst-rho cache: zCDP composition only ever adds, so the
        # max is monotone and scatter_rho can maintain it in O(K) — the
        # budget probes then never pay an O(M) reduce per round/chunk.
        # Writes that bypass scatter_rho (direct ``store.rho[...] = ``
        # surgery) must call refresh_max_rho() after.
        self._max_rho = 0.0

    # -- residual (sparse) ---------------------------------------------------

    def needs_residual(self) -> bool:
        return self.residual_dim is not None

    def residual_rows(self) -> int:
        """How many clients currently hold a (nonzero) residual row."""
        return len(self._residual)

    def gather_residual(self, cohort: np.ndarray) -> np.ndarray:
        """The cohort's (K, D) f32 residual block (zeros for clients that
        have never participated / whose residual was pruned)."""
        if self.residual_dim is None:
            raise ValueError("store was built without a residual_dim")
        out = np.zeros((len(cohort), self.residual_dim), np.float32)
        for i, vid in enumerate(cohort):
            row = self._residual.get(int(vid))
            if row is not None:
                out[i] = row
        return out

    def scatter_residual(self, cohort: np.ndarray, block) -> None:
        """Write the round's updated (K, D) residual block back to the
        cohort's rows. All-zero rows are pruned (a client whose codec
        dropped nothing — or that never participated under a partial
        within-cohort mask and had no prior row — costs no host memory)."""
        if self.residual_dim is None:
            raise ValueError("store was built without a residual_dim")
        block = np.asarray(block, np.float32)
        if block.shape != (len(cohort), self.residual_dim):
            raise ValueError(f"residual block shape {block.shape} != "
                             f"({len(cohort)}, {self.residual_dim})")
        for i, vid in enumerate(cohort):
            vid = int(vid)
            if np.any(block[i]):
                self._residual[vid] = block[i].copy()
            else:
                self._residual.pop(vid, None)

    # -- privacy ledger ------------------------------------------------------

    def gather_rho(self, cohort: np.ndarray) -> np.ndarray:
        return self.rho[np.asarray(cohort)].copy()

    def scatter_rho(self, cohort: np.ndarray, rho_block) -> None:
        block = np.asarray(rho_block, np.float64)
        self.rho[np.asarray(cohort)] = block
        self._max_rho = max(self._max_rho, float(np.max(block)))

    def note_participation(self, cohort: np.ndarray, rounds: int = 1) -> None:
        """Count cohort membership (rounds the client was *sampled* for —
        under a within-cohort participation mask some may have idled)."""
        self.rounds_participated[np.asarray(cohort)] += int(rounds)

    def max_rho(self) -> float:
        """Worst spent rho over the population — O(1) from the running
        cache (see __init__; exact for ledgers written via scatter_rho)."""
        return self._max_rho

    def refresh_max_rho(self) -> float:
        """Recompute the worst-rho cache with one O(M) pass — required
        after mutating ``rho`` without going through scatter_rho."""
        self._max_rho = float(np.max(self.rho))
        return self._max_rho

    # -- checkpointing -------------------------------------------------------

    def save(self, path: str) -> None:
        vids = np.asarray(sorted(self._residual), np.int64)
        rows = (np.stack([self._residual[int(v)] for v in vids])
                if vids.size else
                np.zeros((0, self.residual_dim or 0), np.float32))
        np.savez(path, population=np.int64(self.population),
                 residual_dim=np.int64(-1 if self.residual_dim is None
                                       else self.residual_dim),
                 rho=self.rho, rounds_participated=self.rounds_participated,
                 residual_vids=vids, residual_rows=rows)

    @classmethod
    def load(cls, path: str) -> "ClientStore":
        with np.load(path) as z:
            dim = int(z["residual_dim"])
            store = cls(int(z["population"]),
                        residual_dim=None if dim < 0 else dim)
            store.rho = z["rho"].astype(np.float64)
            store.refresh_max_rho()
            store.rounds_participated = (
                z["rounds_participated"].astype(np.int64))
            for vid, row in zip(z["residual_vids"], z["residual_rows"]):
                store._residual[int(vid)] = row.astype(np.float32)
        return store

"""``repro.population`` — virtual client populations with cohort execution.

Scale DP-PASGD from tens of resident clients to millions of virtual IoT
devices: a :class:`ClientPopulation` names M clients behind a lazy
per-client sampler, a cohort sampler draws K << M of them per round, and
the drivers here gather ONLY the sampled cohort onto the device — device
memory is bounded by K, independent of M. Sticky per-client state
(error-feedback residuals, the per-client privacy ledger) lives in the
host-side :class:`ClientStore`, sparse-updated by cohort and checkpointed
with the model.

    from repro.population import (
        init_population_state, synthetic_population, train_population)

    spec = FederationSpec(n_clients=K, tau=8, loss_fn=loss,
                          optimizer=sgd(0.3), population=M, cohort_size=K,
                          sigmas=(sigma,) * K, batch_sizes=(B,) * K)
    pop = synthetic_population(M, dim=20, batch_size=B, alpha=0.3)
    pstate = init_population_state(spec, params0)
    pstate, out = train_population(spec, pstate, pop, chunk_rounds=8)

With M == C and cohort == population this path is bit-for-bit the dense
``repro.api`` participation path (the identity gate of
tests/test_population.py).

``train_population(..., resident_cache=S)`` upgrades the fused chunks to
*device-resident* cohort execution (:mod:`repro.population.resident`): S
warm clients' sticky state — and, for stationary populations, their data
shards — stay on device, a fresh cohort is drawn every round INSIDE the
fused scan (the per-round driver's exact schedule), and the steady-state
chunk makes zero blocking host syncs under full within-cohort
participation.
"""
from repro.population.attacks import (
    POPULATION_ATTACKS,
    is_byzantine_vid,
    malicious_population,
)
from repro.population.population import (
    ClientPopulation,
    population_from_federated,
    population_from_sampler,
    synthetic_population,
)
from repro.population.resident import (
    ResidentCache,
    init_resident_cache,
    run_resident_rounds,
)
from repro.population.runtime import (
    PopulationState,
    cohort_batch,
    cohort_batches,
    device_block_bytes,
    exceeds_population_budgets,
    init_population_state,
    load_population_state,
    peek_population_epsilon,
    rounds_within_population_budgets,
    run_cohort_round,
    run_cohort_rounds,
    save_population_state,
    train_population,
)
from repro.population.samplers import (
    CohortSampler,
    HeterogeneousCohort,
    UniformCohort,
    chunk_cohorts,
)
from repro.population.store import ClientStore

__all__ = [
    "POPULATION_ATTACKS", "is_byzantine_vid", "malicious_population",
    "ClientPopulation", "population_from_federated", "population_from_sampler",
    "synthetic_population",
    "PopulationState", "cohort_batch", "cohort_batches", "device_block_bytes",
    "exceeds_population_budgets", "init_population_state",
    "load_population_state", "peek_population_epsilon",
    "rounds_within_population_budgets", "run_cohort_round",
    "run_cohort_rounds", "save_population_state", "train_population",
    "ResidentCache", "init_resident_cache", "run_resident_rounds",
    "CohortSampler", "HeterogeneousCohort", "UniformCohort", "chunk_cohorts",
    "ClientStore",
]

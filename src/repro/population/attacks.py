"""Malicious virtual clients: data-level poisoning bound to vids.

The update attacks of :mod:`repro.core.robust` corrupt a STATIC byzantine
set of resident clients at the server boundary — the right model when C
device slots are stable identities. A :class:`ClientPopulation` has no
stable slots: cohort slot k hosts a different virtual client every round,
so "client 3 is compromised" must bind to the *virtual id*, and the
corruption must ride the data path the vid owns. This module wraps a
population so that a deterministic ``byzantine_fraction`` subset of its M
virtual ids serves poisoned shards:

* ``label_flip`` — the classic data poison: every label the byzantine vid
  serves is flipped ``c -> n_classes - 1 - c``
  (:func:`repro.core.robust.flip_labels`). Feature tensors pass through
  bit-unchanged, so an honest-vid cohort round is bit-for-bit the base
  population's.

Byzantine membership is per-vid deterministic (hash-style draw from
``(seed, TAG, vid)``), so it is stable across rounds, cohort draws, and
process restarts without materializing an M-length table — the same
laziness contract as the population samplers themselves.
"""
from __future__ import annotations

import numpy as np

from repro.core.robust import _BYZ_TAG, flip_labels, validate_attack
from repro.population.population import ClientPopulation

POPULATION_ATTACKS = ("label_flip",)


def is_byzantine_vid(vid: int, byzantine_fraction: float,
                     seed: int = 0) -> bool:
    """Deterministic per-vid byzantine membership: an independent
    Bernoulli(byzantine_fraction) coin from ``default_rng((seed, TAG,
    vid))`` — O(1) per query, no M-length state, stable for the
    population's lifetime. (The resident-mode analogue,
    :func:`repro.core.robust.byzantine_flags`, draws an EXACT count
    without replacement — affordable at C resident clients, not at
    M = 10^6 virtual ones.)"""
    validate_attack("none", byzantine_fraction)
    rng = np.random.default_rng((seed, _BYZ_TAG, int(vid)))
    return bool(rng.random() < byzantine_fraction)


def malicious_population(base: ClientPopulation, attack: str = "label_flip",
                         byzantine_fraction: float = 0.25,
                         n_classes: int = 2,
                         seed: int = 0) -> ClientPopulation:
    """Wrap ``base`` so its byzantine vids serve poisoned shards.

    The wrapper is itself a lazy :class:`ClientPopulation` (same M, same
    sampler contract), so it drops into ``train_population`` /
    ``run_cohort_round`` unchanged and composes with
    :class:`repro.population.samplers.HeterogeneousCohort` — an unreliable
    AND partly-malicious fleet is
    ``malicious_population(synthetic_population(M))`` driven by a
    heterogeneous cohort sampler. With ``byzantine_fraction=0`` the
    wrapper is the identity: every shard passes through bit-unchanged.
    """
    if attack not in POPULATION_ATTACKS:
        raise ValueError(f"population attack must be one of "
                         f"{POPULATION_ATTACKS} (update-level attacks are "
                         f"resident-mode features — see "
                         f"FederationSpec.attack), got {attack!r}")
    validate_attack("none", byzantine_fraction)
    if n_classes < 2:
        raise ValueError(f"n_classes must be >= 2, got {n_classes}")

    def sampler(vid: int, tau: int, rng: np.random.Generator):
        shard = base.sampler(vid, tau, rng)
        if not is_byzantine_vid(vid, byzantine_fraction, seed):
            return shard
        poisoned = dict(shard)
        poisoned["y"] = flip_labels(shard["y"], n_classes)
        return poisoned

    return ClientPopulation(
        n_clients=base.n_clients, sampler=sampler,
        name=f"{base.name or 'population'}+{attack}{byzantine_fraction}")

"""Cohort execution: drive the DP-PASGD engines over a virtual population.

The device never sees the population. Each round the driver

1. draws a **cohort** of K = ``spec.n_clients`` virtual ids from the M =
   ``spec.population`` clients (:mod:`repro.population.samplers`,
   deterministic per round index),
2. **gathers** the cohort onto the device block: the K per-client data
   shards are materialized lazily from the :class:`ClientPopulation`, and
   the cohort's sticky state (error-feedback residual rows, per-vid rho)
   comes out of the :class:`ClientStore`,
3. runs the *existing* compiled round — ``repro.api.run_round`` /
   ``run_rounds`` over the K-block, unchanged; ``spec.population`` is not
   part of ``engine_key()``, so sweeping M reuses one XLA program and
   device memory is bounded by K, independent of M —
4. **scatters** the cohort's updated residual rows and rho charges back
   into the store.

Identity gate: with M == C and cohort == population the gather/scatter are
the identity (the uniform sampler returns sorted vids, so the full cohort
is ``arange(M)``), the data RNG stream is consumed in the same order, and
the very same cached round function runs — the cohort path is bit-for-bit
the dense ``participation`` path (pinned in tests/test_population.py).

Fused drivers: :func:`run_cohort_rounds` chunks R rounds through
``repro.api.run_rounds`` with ONE cohort per chunk — cohorts resample at
chunk boundaries, so for chunk_rounds > 1 the per-round and chunk-boundary
drivers realize *different cohort schedules* (both deterministic; they
coincide when cohort == population). Passing ``resident=`` a
:class:`repro.population.resident.ResidentCache` removes that gap: the
warm-client shard cache stays on device and a FRESH cohort is drawn every
round inside the fused scan from the same stateless per-round draw, so the
resident chunked driver realizes the per-round schedule exactly — and the
steady-state chunk makes zero blocking host syncs under full within-cohort
participation (see :mod:`repro.population.resident`).

All value semantics are linear, as in ``repro.api.state``: a successful
round CONSUMES the input state's device buffers (donation) — continue from
the returned :class:`PopulationState`.
"""
from __future__ import annotations

import dataclasses
import math
import os
from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np

from repro.api.spec import FederationSpec
from repro.api.state import (
    FLState,
    PrefetchFailed,
    _raise_budget,
    budget_train_loop,
    eval_params,
    init_state,
    load_state,
    round_rho_charges,
    run_round,
    run_rounds,
    save_state,
)
from repro.core.aggregation import tree_dim
from repro.core.privacy import rho_budget, zcdp_to_dp
from repro.population.population import ClientPopulation
from repro.population.samplers import CohortSampler, UniformCohort
from repro.population.store import STORE_FILENAME, ClientStore


@dataclass(frozen=True)
class PopulationState:
    """Training state of a cohort-executed federation: the device-resident
    K-block :class:`FLState` plus the host-resident per-virtual-client
    :class:`ClientStore`. ``fl.rho`` holds the *current cohort's* ledger
    view (gathered/scattered each round); the store is authoritative."""
    fl: FLState
    store: ClientStore

    def replace(self, **changes) -> "PopulationState":
        return dataclasses.replace(self, **changes)


def init_population_state(spec: FederationSpec, params0: Any,
                          key: jax.Array | None = None) -> PopulationState:
    """Fresh population state: a K-block FLState + an empty ClientStore."""
    if not spec.is_population():
        raise ValueError("init_population_state needs a population spec "
                         "(FederationSpec(population=M, cohort_size=K))")
    fl = init_state(spec, params0, key)
    pipe = spec.aggregation_pipeline()
    dim = (tree_dim(params0)
           if pipe is not None and pipe.needs_residual() else None)
    return PopulationState(fl=fl,
                           store=ClientStore(spec.population,
                                             residual_dim=dim))


# ---------------------------------------------------------------------------
# cohort data plumbing
# ---------------------------------------------------------------------------

def cohort_batch(spec: FederationSpec, population: ClientPopulation,
                 cohort: np.ndarray, rng) -> Any:
    """Stack the cohort's lazily-materialized shards into the (K, tau, B,
    ...) round batch — ``repro.api.round_batch`` with vids instead of a
    dense client range (identical stream order when cohort == arange)."""
    per_client = [population.sampler(int(v), spec.tau, rng) for v in cohort]
    return jax.tree.map(lambda *xs: np.stack(xs), *per_client)


def cohort_batches(spec: FederationSpec, population: ClientPopulation,
                   cohort: np.ndarray, rng, n_rounds: int) -> Any:
    """``n_rounds`` stacked cohort batches, leaves (R, K, tau, B, ...) —
    the chunk operand of :func:`run_cohort_rounds` (one fixed cohort per
    chunk), drawn from ``rng`` in per-round order like
    ``repro.api.round_batches``."""
    rounds = [cohort_batch(spec, population, cohort, rng)
              for _ in range(n_rounds)]
    return jax.tree.map(lambda *xs: np.stack(xs), *rounds)


def _resolve_cohort_sampler(spec: FederationSpec,
                            cohort_sampler: CohortSampler | None,
                            ) -> CohortSampler:
    """Default the sampler, and refuse the one silently-unsound knob
    combination: ``amplify_participation=True`` charges q_eff = (K/M) *
    participation per realized step, a bound stated for UNIFORM cohorts —
    under an availability-skewed sampler a high-rate device realizes far
    more than K/M of the rounds and the reported epsilon would understate
    its true loss. Samplers that honestly draw uniform K-of-M declare
    ``uniform_over_population = True`` (as :class:`UniformCohort` does);
    everything else must use the sound conditional default ledger."""
    sampler = cohort_sampler or UniformCohort(spec.seed)
    if spec.amplify_participation and not getattr(
            sampler, "uniform_over_population", False):
        raise ValueError(
            "amplify_participation=True needs a uniform K-of-M cohort "
            f"sampler; {type(sampler).__name__} does not declare "
            "uniform_over_population, so the K/M amplification bound does "
            "not hold for its skewed cohorts — drop "
            "amplify_participation (the conditional per-realized-client "
            "ledger stays exact) or use UniformCohort")
    return sampler


def _check_cohort(spec: FederationSpec, population: ClientPopulation,
                  cohort: np.ndarray) -> np.ndarray:
    if not spec.is_population():
        raise ValueError("cohort drivers need a population spec "
                         "(FederationSpec(population=M, cohort_size=K)); "
                         "use repro.api.run_round for dense federations")
    cohort = np.asarray(cohort)
    if cohort.shape != (spec.n_clients,):
        raise ValueError(f"cohort has shape {cohort.shape}, expected "
                         f"({spec.n_clients},) (= spec cohort_size)")
    if population.n_clients != spec.population:
        raise ValueError(f"population object has {population.n_clients} "
                         f"clients, spec.population={spec.population}")
    if np.unique(cohort).size != cohort.size:
        raise ValueError("cohort vids must be unique")
    if cohort.min() < 0 or cohort.max() >= spec.population:
        raise ValueError(f"cohort vids out of range [0, {spec.population})")
    return cohort


def _gathered_fl(spec: FederationSpec, pstate: PopulationState,
                 cohort: np.ndarray) -> FLState:
    """The K-block FLState with the cohort's sticky state gathered in."""
    fl = pstate.fl
    changes: dict = {"rho": pstate.store.gather_rho(cohort)}
    if pstate.store.needs_residual():
        changes["residual"] = jax.numpy.asarray(
            pstate.store.gather_residual(cohort))
    return fl.replace(**changes)


def device_block_bytes(pstate: PopulationState, batch: Any = None) -> int:
    """Bytes of the device-resident cohort block (params, opt_state,
    residual, plus an optional batch operand) — the quantity the
    cohort-scaling benchmark pins as independent of M."""
    trees = [pstate.fl.params, pstate.fl.opt_state]
    if pstate.fl.residual is not None:
        trees.append(pstate.fl.residual)
    if batch is not None:
        trees.append(batch)
    return int(sum(np.dtype(x.dtype).itemsize * int(np.prod(x.shape))
                   for t in trees for x in jax.tree.leaves(t)))


# ---------------------------------------------------------------------------
# budget probes (population-wide: worst rho over the store, not the cohort)
# ---------------------------------------------------------------------------

def _max_round_charge(spec: FederationSpec) -> float:
    """Worst-case per-round rho increment of any virtual client (population
    slots are homogeneous by spec validation, but take the max anyway)."""
    return float(np.max(round_rho_charges(spec)))


def peek_population_epsilon(spec: FederationSpec, pstate: PopulationState,
                            extra_rounds: int = 0) -> float:
    """Worst-client eps over the POPULATION if the worst client were
    sampled into the next ``extra_rounds`` cohorts — the population analog
    of ``repro.api.peek_epsilon_fast`` (same conservative stance: the probe
    assumes the worst client participates)."""
    worst = pstate.store.max_rho() + extra_rounds * _max_round_charge(spec)
    return zcdp_to_dp(worst, spec.delta)


def exceeds_population_budgets(spec: FederationSpec,
                               pstate: PopulationState) -> str | None:
    """Would one more cohort round break a budget? "resource" / "privacy"
    / None, mirroring ``repro.api.exceeds_budgets``."""
    if pstate.fl.resource_spent + spec.round_cost() > spec.c_th:
        return "resource"
    if peek_population_epsilon(spec, pstate, 1) > spec.eps_th:
        return "privacy"
    return None


def rounds_within_population_budgets(spec: FederationSpec,
                                     pstate: PopulationState,
                                     limit: int) -> tuple[int, str | None]:
    """How many future cohort rounds CERTAINLY fit the budgets (capped at
    ``limit``), plus the next-binding budget. Worst-case projection: the
    same (worst) client is assumed sampled and charged every round, so a
    chunk sized by this bound never contains a round the per-round driver
    would have refused — exact when cohort == population with full
    participation, conservative otherwise (the caller re-probes on the
    realized ledger, as the dense ``rounds_within_budgets`` contract)."""
    charge = _max_round_charge(spec)
    cost = spec.round_cost()
    worst = pstate.store.max_rho()
    spent = pstate.fl.resource_spent
    n = 0
    while n < limit:
        if spent + cost > spec.c_th:
            return n, "resource"
        if zcdp_to_dp(worst + charge, spec.delta) > spec.eps_th:
            return n, "privacy"
        worst += charge
        spent += cost
        n += 1
    return n, None


# ---------------------------------------------------------------------------
# round drivers
# ---------------------------------------------------------------------------

def _population_epsilon_fix(rec: dict, outside_max: float,
                            delta: float) -> None:
    """Lift a cohort-local ``max_epsilon`` record to the population max.

    The inner driver computed eps over the cohort's rho only; clients
    outside the cohort are static during the round(s), so the population
    worst is max(outside_max, cohort_worst). ``rho_budget`` is the exact
    inverse of ``zcdp_to_dp`` (rho = (sqrt(ln(1/delta) + eps) -
    sqrt(ln(1/delta)))^2), recovering the cohort-worst rho from the
    record. With cohort == population (outside_max == -inf) the record is
    already the population worst: leave it untouched — the inversion
    roundtrip costs a ULP, and the identity gate demands bit equality."""
    if math.isinf(outside_max) and outside_max < 0:
        return
    eps = rec["max_epsilon"]
    cohort_rho = math.inf if math.isinf(eps) else rho_budget(eps, delta)
    rec["max_epsilon"] = zcdp_to_dp(max(cohort_rho, outside_max), delta)


def _outside_max_rho(store: ClientStore, cohort: np.ndarray) -> float:
    """An exact stand-in for the worst rho among clients NOT in the cohort
    (-inf when cohort == population), read BEFORE the round charges land.

    Returns the pre-round GLOBAL max instead of masking out the cohort
    (that mask is an O(M) copy per chunk — the one M-scaling cost the
    cohort-scaling benchmark flagged). The substitution is exact where the
    value is used: ``_population_epsilon_fix`` takes
    max(cohort_worst_after_round, outside_max), rho is non-decreasing, and
    pre_global_max = max(outside_max, pre_cohort_max) with pre_cohort_max
    <= cohort_worst_after_round — so the max is unchanged."""
    if len(cohort) == store.population:
        return -math.inf
    return store.max_rho()


def _scatter_back(pstate: PopulationState, cohort: np.ndarray,
                  fl: FLState, n_rounds: int) -> PopulationState:
    """Write the round's cohort state back into the store. The residual
    fetch is the cohort path's one forced device sync (per round for the
    per-round driver, per chunk for the fused one)."""
    pstate.store.scatter_rho(cohort, fl.rho)
    if pstate.store.needs_residual():
        pstate.store.scatter_residual(cohort, np.asarray(fl.residual))
    pstate.store.note_participation(cohort, n_rounds)
    return pstate.replace(fl=fl)


def run_cohort_round(spec: FederationSpec, pstate: PopulationState,
                     population: ClientPopulation, rng,
                     cohort_sampler: CohortSampler | None = None,
                     check_budgets: bool = True,
                     ) -> tuple[PopulationState, dict]:
    """One cohort round: sample K of M, gather, run the compiled K-block
    round (``repro.api.run_round``, same engine cache), scatter back.

    Returns (successor state, record); the record is the dense round record
    with ``max_epsilon`` lifted to the population worst. Raises
    ``BudgetExceeded`` (state untouched) like the dense driver. Input
    device buffers are donated — continue from the returned state."""
    if check_budgets:
        which = exceeds_population_budgets(spec, pstate)
        if which is not None:
            _raise_budget(which, spec)
    sampler = _resolve_cohort_sampler(spec, cohort_sampler)
    cohort = _check_cohort(spec, population, sampler(
        pstate.fl.rounds_done, spec.population, spec.n_clients))
    batch = cohort_batch(spec, population, cohort, rng)
    outside_max = _outside_max_rho(pstate.store, cohort)
    fl, rec = run_round(spec, _gathered_fl(spec, pstate, cohort), batch,
                        check_budgets=False)
    new = _scatter_back(pstate, cohort, fl, 1)
    _population_epsilon_fix(rec, outside_max, spec.delta)
    return new, rec


def run_cohort_rounds(spec: FederationSpec, pstate: PopulationState,
                      population: ClientPopulation, rng,
                      n_rounds: int | None = None,
                      cohort_sampler: CohortSampler | None = None,
                      check_budgets: bool = True,
                      cohort: np.ndarray | None = None,
                      batches: Any = None,
                      prefetch: Callable[[], None] | None = None,
                      resident: Any = None,
                      cohorts: np.ndarray | None = None,
                      ) -> tuple[PopulationState, list[dict]]:
    """A fused chunk of R rounds over ONE cohort (resampled per chunk).

    The chunk runs through ``repro.api.run_rounds`` — one jitted
    ``lax.scan`` dispatch over the K-block, one host sync — with the
    cohort's sticky state gathered before and scattered after. ``cohort``
    and ``batches`` may be passed pre-built (the double-buffered prefetch
    of :func:`train_population`); otherwise the cohort is drawn for round
    index ``fl.rounds_done`` and the batches built from ``rng``. A raising
    ``prefetch`` propagates as ``PrefetchFailed`` carrying the completed
    *PopulationState* (store already updated), mirroring the dense
    contract.

    ``resident=`` a :class:`repro.population.resident.ResidentCache`
    switches to resident-cohort execution: a fresh cohort PER ROUND inside
    the scan (the per-round driver's exact schedule), sticky state moving
    through the device-resident cache instead of per-chunk store
    round-trips. ``cohorts`` may pass the pre-drawn (R, K) per-round plan
    (with ``batches`` leaves then (R, K, tau, B, ...) in per-round cohort
    order); ``cohort`` must be None."""
    if resident is not None:
        from repro.population.resident import run_resident_rounds
        if cohort is not None:
            raise ValueError("resident execution draws a fresh cohort per "
                             "round; pass the (R, K) plan via cohorts=, "
                             "not a single cohort")
        return run_resident_rounds(spec, pstate, population, rng, resident,
                                   n_rounds, cohort_sampler=cohort_sampler,
                                   check_budgets=check_budgets,
                                   cohorts=cohorts, batches=batches,
                                   prefetch=prefetch)
    if cohorts is not None:
        raise ValueError("a per-round cohort plan needs resident= (the "
                         "chunk-boundary path runs one cohort per chunk)")
    sampler = _resolve_cohort_sampler(spec, cohort_sampler)
    if cohort is None:
        if batches is not None:
            raise ValueError("pre-built batches need their cohort")
        cohort = sampler(pstate.fl.rounds_done, spec.population,
                         spec.n_clients)
    cohort = _check_cohort(spec, population, cohort)
    if batches is None:
        if n_rounds is None or n_rounds <= 0:
            raise ValueError(f"n_rounds must be positive, got {n_rounds}")
        batches = cohort_batches(spec, population, cohort, rng, n_rounds)
    if check_budgets:
        lead = int(jax.tree.leaves(batches)[0].shape[0])
        ok, which = rounds_within_population_budgets(
            spec, pstate, n_rounds if n_rounds is not None else lead)
        if ok < (n_rounds if n_rounds is not None else lead):
            _raise_budget(which, spec)
    outside_max = _outside_max_rho(pstate.store, cohort)
    try:
        fl, recs = run_rounds(spec, _gathered_fl(spec, pstate, cohort),
                              batches, n_rounds, check_budgets=False,
                              prefetch=prefetch)
    except PrefetchFailed as pf:
        new = _scatter_back(pstate, cohort, pf.state, len(pf.records))
        for rec in pf.records:
            _population_epsilon_fix(rec, outside_max, spec.delta)
        raise PrefetchFailed(pf.__cause__, new, pf.records) from pf.__cause__
    new = _scatter_back(pstate, cohort, fl, len(recs))
    for rec in recs:
        _population_epsilon_fix(rec, outside_max, spec.delta)
    return new, recs


# ---------------------------------------------------------------------------
# budget-aware training driver
# ---------------------------------------------------------------------------

def train_population(spec: FederationSpec, pstate: PopulationState,
                     population: ClientPopulation,
                     cohort_sampler: CohortSampler | None = None,
                     max_rounds: int = 10_000,
                     eval_fn: Callable | None = None, eval_every: int = 1,
                     rng=None, history: list[dict] | None = None,
                     chunk_rounds: int = 1,
                     resident_cache: int = 0,
                     ) -> tuple[PopulationState, dict]:
    """Cohort-executed ``repro.api.train``: rounds until a budget binds.

    ``chunk_rounds=R > 1`` fuses R rounds per XLA dispatch over one cohort
    (cohorts resample at chunk boundaries), with the next chunk's cohort
    drawn and its batches built + ``device_put`` while the current chunk
    computes. The whole budget/prefetch/tail/eval structure IS the dense
    driver's — one shared :func:`repro.api.state.budget_train_loop` —
    parameterized here with cohort probes
    (:func:`rounds_within_population_budgets`) and cohort chunks
    ``(cohort, device batches)``. Returns (state, summary) shaped like
    ``repro.api.train``'s.

    ``resident_cache=S > 0`` switches the chunks to resident-cohort
    execution (:mod:`repro.population.resident`): S warm clients' sticky
    state stays on device, every round draws a fresh cohort inside the
    fused scan (the per-round driver's exact schedule), and the store is
    touched only at chunk boundaries (rho write-through; residual rows on
    eviction/flush). For stationary populations the warm shards' data rows
    are cached on device too — steady-state chunks then build no per-round
    host batches at all. Needs chunk_rounds > 1 (the per-round driver
    already realizes the per-round schedule) and S >= min(chunk_rounds * K,
    M). The summary gains a ``resident_cache`` entry with hit/miss/eviction
    counts, and the cache is flushed before returning (the store is
    checkpoint-authoritative again)."""
    if rng is None:
        rng = np.random.default_rng(spec.seed)
    sampler = _resolve_cohort_sampler(spec, cohort_sampler)
    history = [] if history is None else history
    cache = None
    if resident_cache:
        from repro.population.resident import init_resident_cache
        from repro.population.samplers import chunk_cohorts
        if chunk_rounds <= 1:
            raise ValueError(
                "resident_cache needs chunk_rounds > 1: per-round cohorts "
                "inside the fused scan are what the cache buys; the "
                "per-round driver already realizes that schedule")
        cache = init_resident_cache(spec, pstate, resident_cache,
                                    population=population)
        need = min(chunk_rounds * spec.n_clients, spec.population)
        if cache.capacity < need:
            raise ValueError(
                f"resident_cache={cache.capacity} can underflow: a chunk "
                f"may touch up to {need} distinct vids (chunk_rounds * K); "
                f"raise it or lower chunk_rounds")

    if cache is None:
        def build_chunk(start: int, n: int):
            cohort = sampler(start, spec.population, spec.n_clients)
            return (cohort, jax.device_put(
                cohort_batches(spec, population, cohort, rng, n)))

        def run_chunk(ps, chunk, n, prefetch):
            cohort, batches = chunk
            return run_cohort_rounds(spec, ps, population, rng, n,
                                     cohort_sampler=sampler,
                                     check_budgets=False,
                                     cohort=cohort, batches=batches,
                                     prefetch=prefetch)

        def run_tail(ps, chunk, r):
            # tail rows were built for this chunk's (single) cohort, so it
            # stays fixed across them (per-round path, compiled round
            # reused)
            cohort, batches = chunk
            return _cohort_round_with_batch(
                spec, ps, population, cohort,
                jax.tree.map(lambda x, r=r: x[r], batches))
    else:
        def build_chunk(start: int, n: int):
            cohorts = chunk_cohorts(sampler, start, n, spec.population,
                                    spec.n_clients)
            if cache.data is not None:
                # stationary shards: pre-materialize only the COLD vids'
                # rows (warm ones are already on device — the cache's whole
                # point); residency won't change before run_chunk promotes
                # exactly this plan's union. One throwaway generator serves
                # every call — the stationary sampler ignores it
                throwaway = np.random.default_rng(0)
                rows = {int(v): population.sampler(int(v), spec.tau,
                                                   throwaway)
                        for v in np.unique(cohorts)
                        if int(v) not in cache.slot_of}
                return (cohorts, None, rows)
            per = [cohort_batch(spec, population, cohorts[r], rng)
                   for r in range(n)]
            return (cohorts, jax.device_put(
                jax.tree.map(lambda *xs: np.stack(xs), *per)), None)

        def run_chunk(ps, chunk, n, prefetch):
            from repro.population.resident import run_resident_rounds
            cohorts, batches, rows = chunk
            return run_resident_rounds(spec, ps, population, rng, cache, n,
                                       cohort_sampler=sampler,
                                       check_budgets=False,
                                       cohorts=cohorts, batches=batches,
                                       data_rows=rows, prefetch=prefetch)

        def run_tail(ps, chunk, r):
            # budget/max_rounds edge: hand the rows to the per-round store
            # path. The cache flushes first (store regains authority) and
            # resets — its rows would go stale as the store-side rounds
            # land. Happens at most once per training run.
            cohorts, batches, rows = chunk
            if cache.warm_count() or cache.pending:
                cache.flush(ps.store)
                cache.reset()
            if batches is None:
                # stationary sampler ignores its rng: rebuild is exact and
                # consumes no shared stream
                batch = cohort_batch(spec, population, cohorts[r],
                                     np.random.default_rng(0))
            else:
                batch = jax.tree.map(lambda x, r=r: x[r], batches)
            return _cohort_round_with_batch(spec, ps, population,
                                            cohorts[r], batch)

    pstate, best = budget_train_loop(
        state=pstate, max_rounds=max_rounds, eval_fn=eval_fn,
        eval_every=eval_every, history=history, chunk_rounds=chunk_rounds,
        rounds_done=lambda ps: ps.fl.rounds_done,
        exceeds=lambda ps: exceeds_population_budgets(spec, ps) is not None,
        safe_rounds=lambda ps, cap: rounds_within_population_budgets(
            spec, ps, cap)[0],
        run_single=lambda ps: run_cohort_round(
            spec, ps, population, rng, cohort_sampler=sampler,
            check_budgets=False),
        build_chunk=build_chunk, run_chunk=run_chunk, run_tail=run_tail,
        eval_model=lambda ps: eval_params(spec, ps.fl))
    summary = {
        "best": best, "rounds": pstate.fl.rounds_done,
        "resource_spent": pstate.fl.resource_spent,
        "max_epsilon": zcdp_to_dp(pstate.store.max_rho(), spec.delta),
        "history": history,
    }
    if cache is not None:
        cache.flush(pstate.store)
        summary["resident_cache"] = dict(cache.stats)
    return pstate, summary


def _cohort_round_with_batch(spec, pstate, population, cohort, batch):
    """Tail-chunk helper: one per-round-path round over an explicit cohort
    and its pre-built (K, tau, B, ...) batch."""
    cohort = _check_cohort(spec, population, cohort)
    outside_max = _outside_max_rho(pstate.store, cohort)
    fl, rec = run_round(spec, _gathered_fl(spec, pstate, cohort), batch,
                        check_budgets=False)
    new = _scatter_back(pstate, cohort, fl, 1)
    _population_epsilon_fix(rec, outside_max, spec.delta)
    return new, rec


def _cohort_round_from_row(spec, pstate, population, cohort, batches, r):
    """Back-compat shim: round ``r`` of a stacked pre-built chunk."""
    return _cohort_round_with_batch(
        spec, pstate, population, cohort,
        jax.tree.map(lambda x, r=r: x[r], batches))


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------

def save_population_state(directory: str, pstate: PopulationState,
                          extra: dict | None = None) -> None:
    """Persist a PopulationState: the FLState checkpoint plus the
    ClientStore (sparse residual rows + per-vid ledger) alongside it."""
    save_state(directory, pstate.fl,
               extra={"population": int(pstate.store.population),
                      **(extra or {})})
    pstate.store.save(os.path.join(directory, STORE_FILENAME))


def load_population_state(directory: str, like: PopulationState,
                          ) -> tuple[PopulationState, dict]:
    """Restore a PopulationState saved by :func:`save_population_state`.

    ``like`` supplies the pytree structure (a fresh
    ``init_population_state``); the store is restored wholesale and
    validated against ``like``'s population geometry."""
    fl, extra = load_state(directory, like.fl)
    store = ClientStore.load(os.path.join(directory, STORE_FILENAME))
    if store.population != like.store.population:
        raise ValueError(f"checkpoint population {store.population} != "
                         f"spec population {like.store.population}")
    if store.residual_dim != like.store.residual_dim:
        raise ValueError(f"checkpoint residual_dim {store.residual_dim} != "
                         f"{like.store.residual_dim} (compressor mismatch?)")
    return PopulationState(fl=fl, store=store), extra

"""Virtual client populations: M clients behind a lazy per-client sampler.

Cross-device FL at IoT scale (Khan et al. 2021; Imteaj et al. 2020) runs a
small per-round *cohort* K drawn from a huge *population* M >> K. The dense
``FederatedData.clients`` list — every client's shard resident in host
memory, every client's replica resident on device — is the wrong shape for
that regime. A :class:`ClientPopulation` instead names M *virtual* clients
and materializes a client's data only when that client is sampled into a
cohort: the backing ``sampler(vid, tau, rng)`` synthesizes (or loads) the
shard on demand, so host memory holds O(#distinct-client-parameters) and
device memory holds the K-block only.

Three constructors ship:

* :func:`population_from_federated` — wrap a resident
  :class:`repro.data.FederatedData` (M == its client count). This is the
  identity bridge: with cohort == population the cohort execution path is
  bit-for-bit the dense engines.
* :func:`synthetic_population` — M virtual clients with Dirichlet
  label-skew (per-client class distribution ~ Dirichlet(alpha)) and a
  per-client feature shift, synthesized in the style of
  :mod:`repro.data.synthetic` (unit-ball features). Each client's
  distribution parameters are re-derived from ``(seed, vid)`` at sample
  time — nothing per-client is ever held resident, so M = 10^6 costs the
  same host memory as M = 10.
* :func:`population_from_sampler` — adapt any existing
  ``sampler(client, tau, rng)`` (e.g. a ``FederatedTokenStream``) whose
  client axis is already lazy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

Sampler = Callable[[int, int, np.random.Generator], Any]


@dataclass(frozen=True)
class ClientPopulation:
    """M virtual clients behind a lazy per-client batch sampler.

    ``sampler(vid, tau, rng)`` returns one client's round shard with leading
    axes (tau, B, ...) — the same contract as the resident samplers of
    ``repro.api.round_batch``, with ``vid`` ranging over the whole
    population [0, n_clients). It must be cheap to call for any vid without
    touching the other M-1 clients.

    ``stationary=True`` declares the sampler IGNORES its ``rng`` — every
    call for a vid returns the same fixed shard (the on-device-dataset IoT
    regime). That is the contract that lets the resident-cohort driver
    (:mod:`repro.population.resident`) keep warm clients' data rows on
    device across rounds exactly: a cached shard equals what streaming
    would have rebuilt, bit for bit, and no shared-rng stream is consumed.
    Fresh-per-round sampling populations must leave it False — their data
    stream depends on call order and cannot be cached without changing it.
    """
    n_clients: int                  # M (population size)
    sampler: Sampler
    name: str = ""
    stationary: bool = False

    def __post_init__(self):
        if self.n_clients <= 0:
            raise ValueError(f"population must be positive, "
                             f"got {self.n_clients}")


def population_from_federated(fed, batch_size: int) -> ClientPopulation:
    """A :class:`repro.data.FederatedData` viewed as a (resident) population.

    M equals the federation's client count; the sampler is
    ``fed.make_sampler(batch_size)`` verbatim, so a cohort == population
    configuration consumes the data RNG stream identically to the dense
    drivers (the bit-identity gate of tests/test_population.py).
    """
    return ClientPopulation(n_clients=fed.n_clients,
                            sampler=fed.make_sampler(batch_size),
                            name=fed.name or "federated")


def population_from_sampler(n_clients: int, sampler: Sampler,
                            name: str = "",
                            stationary: bool = False) -> ClientPopulation:
    """Adapt an existing lazy ``sampler(client, tau, rng)`` (token streams,
    custom loaders) whose client axis already scales to ``n_clients``.
    Pass ``stationary=True`` only if the sampler ignores ``rng`` (see
    :class:`ClientPopulation`)."""
    return ClientPopulation(n_clients=n_clients, sampler=sampler, name=name,
                            stationary=stationary)


def synthetic_population(n_clients: int, dim: int = 20, batch_size: int = 8,
                         n_classes: int = 2, alpha: float = 0.5,
                         client_shift: float = 1.0, noise: float = 0.8,
                         label_strength: float = 0.9,
                         seed: int = 0,
                         stationary: bool = False) -> ClientPopulation:
    """M virtual clients with Dirichlet(alpha) label skew, fully lazy.

    Population-level structure (class directions, the label signal) is drawn
    once from ``seed``; everything client-specific — the class mixture
    ``p_vid ~ Dirichlet(alpha)`` and a feature-space shift (the client's
    "sensor placement", as in ``vehicle_like``) — is re-derived from
    ``(seed, vid)`` inside the sampler, so per-client state is materialized
    on demand and discarded. Small ``alpha`` -> strongly non-iid clients
    (most clients see a single dominant class), large ``alpha`` -> iid.

    Labels are ints in [0, n_classes); with the default ``n_classes=2`` the
    batches plug straight into ``repro.models.linear.logreg_loss``. Features
    are normalized to the unit ball (paper §4 assumption), matching
    :mod:`repro.data.synthetic`.

    ``stationary=True`` draws each client's shard from its own ``(seed,
    vid)`` generator instead of the shared round rng — the client re-reads
    one fixed local dataset every round (and the shared stream is never
    consumed), which is the contract the resident-cohort driver needs to
    cache warm data rows on device (see :class:`ClientPopulation`).
    """
    if n_classes < 2:
        raise ValueError(f"n_classes must be >= 2, got {n_classes}")
    if alpha <= 0:
        raise ValueError(f"Dirichlet alpha must be positive, got {alpha}")
    root = np.random.default_rng(seed)
    class_dirs = root.normal(size=(n_classes, dim)) / np.sqrt(dim)

    def sampler(vid: int, tau: int, rng: np.random.Generator):
        # lazy shard: the client's distribution parameters exist only for
        # the duration of this call
        vrng = np.random.default_rng((seed, int(vid)))
        p = vrng.dirichlet([alpha] * n_classes)
        shift = vrng.normal(size=dim) / np.sqrt(dim) * client_shift
        draw = vrng if stationary else rng
        y = draw.choice(n_classes, size=(tau, batch_size), p=p)
        x = draw.normal(scale=noise, size=(tau, batch_size, dim))
        x += shift
        x += class_dirs[y] * label_strength
        norms = np.linalg.norm(x, axis=-1, keepdims=True)
        x = (x / np.maximum(norms, 1.0)).astype(np.float32)
        return {"x": x, "y": y.astype(np.int32)}

    tag = "-fixed" if stationary else ""
    return ClientPopulation(n_clients=n_clients, sampler=sampler,
                            name=f"dirichlet{alpha}-M{n_clients}{tag}",
                            stationary=stationary)

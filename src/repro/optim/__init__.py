from repro.optim.optimizers import Optimizer, sgd, momentum, adamw
from repro.optim.schedules import constant, cosine_decay, linear_warmup

__all__ = [
    "Optimizer", "sgd", "momentum", "adamw",
    "constant", "cosine_decay", "linear_warmup",
]

"""Optimizers built from scratch (no optax available offline).

The paper's DP-PASGD update (Eq. 7a) is plain SGD — that is the faithful
default. Momentum and AdamW are provided for the beyond-paper experiments.
API mirrors the (init, update) gradient-transformation convention:
``update`` returns a *delta* to be added to the params.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def _resolve_lr(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


class SgdState(NamedTuple):
    step: jnp.ndarray


def sgd(lr) -> Optimizer:
    """theta <- theta - eta * g   (paper Eq. 7a)."""
    def init(params):
        return SgdState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        eta = _resolve_lr(lr, state.step)
        upd = jax.tree.map(lambda g, p: (-eta * g).astype(p.dtype), grads,
                           params)
        return upd, SgdState(step=state.step + 1)

    return Optimizer(init, update)


class MomentumState(NamedTuple):
    step: jnp.ndarray
    velocity: Any


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return MomentumState(
            step=jnp.zeros((), jnp.int32),
            velocity=jax.tree.map(jnp.zeros_like, params),
        )

    def update(grads, state, params):
        eta = _resolve_lr(lr, state.step)
        vel = jax.tree.map(lambda v, g: beta * v + g, state.velocity, grads)
        if nesterov:
            upd = jax.tree.map(
                lambda v, g, p: (-eta * (beta * v + g)).astype(p.dtype),
                vel, grads, params)
        else:
            upd = jax.tree.map(lambda v, p: (-eta * v).astype(p.dtype), vel,
                               params)
        return upd, MomentumState(step=state.step + 1, velocity=vel)

    return Optimizer(init, update)


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        f32zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(f32zeros, params),
            nu=jax.tree.map(f32zeros, params),
        )

    def update(grads, state, params):
        step = state.step + 1
        eta = _resolve_lr(lr, state.step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def _upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            delta = -eta * (mhat / (jnp.sqrt(vhat) + eps)
                            + weight_decay * p.astype(jnp.float32))
            return delta.astype(p.dtype)

        upd = jax.tree.map(_upd, mu, nu, params)
        return upd, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)

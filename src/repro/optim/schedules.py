"""Learning-rate schedules (callables step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def sched(step):
        return jnp.asarray(lr, dtype=jnp.float32)
    return sched


def cosine_decay(lr: float, decay_steps: int, final_frac: float = 0.0):
    def sched(step):
        t = jnp.clip(step / decay_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.asarray(lr * (final_frac + (1.0 - final_frac) * cos), jnp.float32)
    return sched


def linear_warmup(base, warmup_steps: int):
    """Wrap another schedule (or float) with linear warmup."""
    inner = base if callable(base) else constant(base)
    def sched(step):
        warm = jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
        return warm * inner(jnp.maximum(step - warmup_steps, 0))
    return sched

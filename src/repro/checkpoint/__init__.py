from repro.checkpoint.checkpoint import (
    checkpoint_leaf_paths,
    load_checkpoint,
    load_federation_state,
    save_checkpoint,
    save_federation_state,
)

__all__ = ["checkpoint_leaf_paths", "load_checkpoint",
           "load_federation_state", "save_checkpoint",
           "save_federation_state"]

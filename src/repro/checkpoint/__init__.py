from repro.checkpoint.checkpoint import (
    load_checkpoint,
    load_federation_state,
    save_checkpoint,
    save_federation_state,
)

__all__ = ["load_checkpoint", "load_federation_state", "save_checkpoint",
           "save_federation_state"]

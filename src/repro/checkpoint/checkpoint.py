"""Checkpointing: pytree <-> on-disk, with per-leaf chunking and metadata.

Layout of a checkpoint directory:
    meta.json              treedef paths, shapes, dtypes, step, extra metadata
    arrays/<idx>.npy       one file per leaf (mmap-friendly), possibly
                           split into arrays/<idx>.<part>.npy chunks

Works for single-host; on a real multi-host pod each host saves its
addressable shards under arrays/<idx>.shard<k>.npy (same format), which is
why leaves are stored one-file-per-leaf rather than one big archive.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

_SEP = "/"
_CHUNK_BYTES = 1 << 30   # split leaves bigger than 1 GiB


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = []
    leaves = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        paths.append(_SEP.join(parts))
        leaves.append(leaf)
    return paths, leaves, treedef


def save_checkpoint(directory: str, tree: Any, step: int = 0,
                    extra: dict | None = None) -> None:
    os.makedirs(os.path.join(directory, "arrays"), exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    meta = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        n_parts = max(1, (arr.nbytes + _CHUNK_BYTES - 1) // _CHUNK_BYTES)
        meta["leaves"].append({
            "path": path, "index": i, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "parts": int(n_parts),
        })
        if n_parts == 1:
            np.save(os.path.join(directory, "arrays", f"{i}.npy"), arr)
        else:
            flat = arr.reshape(-1)
            for p, part in enumerate(np.array_split(flat, n_parts)):
                np.save(os.path.join(directory, "arrays", f"{i}.{p}.npy"),
                        part)
    tmp = os.path.join(directory, "meta.json.tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f)
    os.replace(tmp, os.path.join(directory, "meta.json"))


def checkpoint_leaf_paths(directory: str) -> list[str]:
    """The leaf paths stored in a checkpoint (cheap: reads meta.json only).
    Lets callers decide which optional subtrees (e.g. FLState.residual)
    a checkpoint actually carries before asking for them via ``like``."""
    with open(os.path.join(directory, "meta.json")) as f:
        meta = json.load(f)
    return [rec["path"] for rec in meta["leaves"]]


def load_checkpoint(directory: str, like: Any | None = None):
    """Returns (tree, step, extra). If ``like`` is given, the result uses its
    treedef (and validates paths); otherwise a nested dict is rebuilt."""
    with open(os.path.join(directory, "meta.json")) as f:
        meta = json.load(f)
    arrays = {}
    for rec in meta["leaves"]:
        i = rec["index"]
        if rec["parts"] == 1:
            arr = np.load(os.path.join(directory, "arrays", f"{i}.npy"))
        else:
            parts = [np.load(os.path.join(directory, "arrays",
                                          f"{i}.{p}.npy"))
                     for p in range(rec["parts"])]
            arr = np.concatenate(parts).reshape(rec["shape"])
        arrays[rec["path"]] = arr.astype(rec["dtype"])

    if like is not None:
        paths, leaves, treedef = _flatten_with_paths(like)
        missing = [p for p in paths if p not in arrays]
        if missing:
            raise ValueError(f"checkpoint missing leaves: {missing[:5]}...")
        new_leaves = [arrays[p] for p in paths]
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return tree, meta["step"], meta["extra"]

    # rebuild a nested dict from paths
    root: dict = {}
    for path, arr in arrays.items():
        parts = path.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return root, meta["step"], meta["extra"]


def save_federation_state(directory: str, fed) -> None:
    """Persist a repro.api.Federation: its FLState + sigmas and history.

    Thin sugar over ``repro.api.save_state`` (which handles the arrays and
    the accountant snapshot); use that directly for functional drivers.
    """
    from repro.api.state import save_state
    save_state(directory, fed.state,
               extra={"sigmas": np.asarray(fed.sigmas).tolist(),
                      "history": fed.history})


def load_federation_state(directory: str, fed) -> None:
    """Restore a Federation saved by :func:`save_federation_state`."""
    from repro.api.state import load_state
    state, extra = load_state(directory, fed.state)
    fed.restore(state, history=extra.get("history"))

"""Mesh-aware engine placement: the ``engine="auto"`` decision table.

Pure arithmetic over (client count, device count, per-replica footprint,
per-device memory budget) — no jax import, so the table is unit-pinnable
without devices. ``repro.api.engines.resolve_engine`` consults
:func:`choose_engine` with the process device count; launchers and dryrun
feed the footprint from :func:`repro.configs.shapes.replica_footprint_bytes`
(the spec carries it as the ``replica_bytes`` hint).

The rule, in order:

1. one device -> ``vmap`` (nothing to shard);
2. replica footprint known and over budget -> ``mesh_2d`` (the only engine
   that can split a replica), UNLESS the spec is adversarial — the robust /
   secure reductions are full-view and stay on the 1D engines;
3. multiple devices and a client axis worth sharding -> ``shard_map``;
4. otherwise ``vmap``.

The per-device budget defaults to a v5e chip (16 GiB, matching
``repro.launch.dryrun.HBM_PER_CHIP``) and is overridable via the
``REPRO_DEVICE_MEM_BYTES`` env var so CPU-simulated meshes can rehearse
"does not fit" placements with byte-for-byte the production logic.
"""
from __future__ import annotations

import math
import os

DEFAULT_DEVICE_MEM_BYTES = 16 * 1024 ** 3   # v5e HBM, as launch.dryrun
ENV_DEVICE_MEM = "REPRO_DEVICE_MEM_BYTES"


def device_memory_budget(default: int | None = None) -> int:
    """Per-device memory budget in bytes (env override > default > v5e)."""
    env = os.environ.get(ENV_DEVICE_MEM)
    if env:
        budget = int(env)
        if budget <= 0:
            raise ValueError(f"{ENV_DEVICE_MEM} must be positive, "
                             f"got {budget}")
        return budget
    return DEFAULT_DEVICE_MEM_BYTES if default is None else int(default)


def replica_fits(replica_bytes: int, hbm_bytes: int | None = None) -> bool:
    """Does one whole model replica (+ optimizer state) fit one device?"""
    return int(replica_bytes) <= device_memory_budget(hbm_bytes)


def n_client_shards(n_clients: int, n_devices: int) -> int:
    """Largest divisor of n_clients that fits in the device count — the 1D
    engine's client-axis size (it requires clients to divide exactly)."""
    return max(d for d in range(1, min(n_clients, n_devices) + 1)
               if n_clients % d == 0)


def model_shards_for(replica_bytes: int, n_devices: int,
                     hbm_bytes: int | None = None) -> int:
    """Smallest divisor ``dm`` of ``n_devices`` with ``replica_bytes / dm``
    under the per-device budget (``n_devices`` if even full sharding cannot
    cover it — best effort, the dryrun report flags the overflow)."""
    budget = device_memory_budget(hbm_bytes)
    for dm in range(1, n_devices + 1):
        if n_devices % dm == 0 and math.ceil(replica_bytes / dm) <= budget:
            return dm
    return n_devices


def choose_engine(n_clients: int, n_devices: int,
                  replica_bytes: int | None = None,
                  hbm_bytes: int | None = None,
                  adversarial: bool = False) -> str:
    """The ``engine="auto"`` decision (see module docstring for the table)."""
    if n_devices <= 1:
        return "vmap"
    if (replica_bytes is not None and not adversarial
            and not replica_fits(replica_bytes, hbm_bytes)):
        return "mesh_2d"
    if n_client_shards(n_clients, n_devices) > 1:
        return "shard_map"
    return "vmap"


def default_mesh_shape(n_clients: int, n_devices: int,
                       replica_bytes: int | None = None,
                       hbm_bytes: int | None = None) -> tuple[int, int]:
    """Default ``(dc, dm)`` split of the local devices.

    ``dm`` is the smallest model-axis size that brings a replica under the
    per-device budget (1 when no footprint is known — all devices go to
    client blocks); the remaining factor becomes client blocks, clamped to
    the client count (padding handles non-dividing clients, but blocks
    beyond ``n_clients`` would sit empty)."""
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    dm = (1 if replica_bytes is None
          else model_shards_for(replica_bytes, n_devices, hbm_bytes))
    dc = max(1, min(n_devices // dm, n_clients))
    return dc, dm

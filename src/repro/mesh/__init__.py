"""repro.mesh — the pod-scale 2D client x model execution plane.

The 1D ``shard_map`` engine shards only the *client* axis: every device must
hold whole model replicas, which caps the model size at one device's memory.
This subsystem generalizes the round to a 2D mesh ``(dc, dm)`` built by
:func:`repro.launch.mesh.make_mesh_2d`:

* the **client axis** (size ``dc``) stays MANUAL — each mesh slab owns a
  contiguous block of client replicas and the Eq.-7b aggregation is the one
  explicit collective over it, exactly as in the 1D engine;
* the **model axis** (size ``dm``) is left to GSPMD (shard_map partial-auto
  mode): weights and activations shard 1/dm per the logical-axis rules of
  :func:`repro.models.sharding.mesh2d_rules`, so a replica that does not fit
  one device trains across its slab with zero changes to the round math.

Clients that do not divide ``dc`` are padded with inert rows (``valid = 0``
weights drop them from every mean exactly); the degenerate mesh
``(dc, 1)`` delegates to the 1D builder and is bit-identical to
``engine="shard_map"``. :mod:`repro.mesh.placement` holds the
``engine="auto"`` decision table: configs whose per-replica footprint
(:func:`repro.configs.shapes.replica_footprint_bytes`) exceeds the
per-device budget place onto ``mesh_2d``, everything else keeps the local
1D logic. Select via ``FederationSpec(engine="mesh_2d", mesh_shape=...,
sharding_rules=...)``.
"""
from repro.mesh.engine import default_param_specs, make_mesh_2d_round
from repro.mesh.placement import (
    DEFAULT_DEVICE_MEM_BYTES,
    choose_engine,
    default_mesh_shape,
    device_memory_budget,
    model_shards_for,
    n_client_shards,
    replica_fits,
)

__all__ = [
    "DEFAULT_DEVICE_MEM_BYTES",
    "choose_engine",
    "default_mesh_shape",
    "default_param_specs",
    "device_memory_budget",
    "make_mesh_2d_round",
    "model_shards_for",
    "n_client_shards",
    "replica_fits",
]

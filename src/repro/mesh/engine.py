"""The mesh_2d round: DP-PASGD on a ("client", "model") 2D mesh.

Structure of one round (Eq. 7a-7b on the 2D mesh of
:func:`repro.launch.mesh.make_mesh_2d`):

* the **client axis** is MANUAL, exactly as in the 1D
  :mod:`repro.core.fl_shard_map` engine — each of the ``dc`` slabs owns a
  contiguous block of client replicas and the only cross-slab collective is
  the Eq.-7b reduction (a psum of block partial sums);
* the **model axis** is AUTO (shard_map partial-manual mode): inside the
  per-slab body every model tensor keeps whatever GSPMD sharding it carries
  from outside, so the tau-step local scan runs 1/dm-sharded over the slab's
  ``dm`` devices. The logical-axis rules
  (:func:`repro.models.sharding.mesh2d_rules` by default) and the
  :func:`default_param_specs` input constraints pin that layout.

Clients that do not divide ``dc`` are padded to ``Cp = ceil(C/dc) * dc``
rows. Pad rows are *copies of client 0's operands* — their local rounds
compute real (finite, same-dtype) values so nothing poisons a mean via
NaN * 0 — and a ``valid`` 0/1 vector drops them from every aggregate
exactly (:func:`repro.core.fl.tree_valid_mean_axis0`; the pipeline path
zero-pads the participation mask instead, which its masked sums already
handle). The degenerate mesh ``(dc, 1)`` with dividing clients delegates to
:func:`repro.core.fl_shard_map.make_shard_map_round` verbatim, making
bitwise identity with ``engine="shard_map"`` structural rather than
numerical luck.

The adversarial extensions (robust aggregators, secure sum, update attacks)
are full-view reductions over exactly ``n_clients`` gathered rows and do not
compose with the padded client axis — ``FederationSpec`` validation refuses
them on this engine (use ``engine="shard_map"``).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fl import (
    FLConfig,
    TOPOLOGIES,
    make_grad_fn,
    make_local_round,
    pipeline_round_keys,
    tree_valid_mean_axis0,
)
from repro.core.fl_shard_map import _shard_map, make_shard_map_round
from repro.models.sharding import axis_rules, mesh2d_rules
from repro.optim.optimizers import Optimizer
from repro.utils.tree import tree_broadcast_axis0

CLIENT_AXIS = "client"
MODEL_AXIS = "model"


def default_param_specs(tree, dm: int, *, client_axis: str = CLIENT_AXIS,
                        model_axis: str = MODEL_AXIS):
    """Per-leaf PartitionSpecs for client-stacked state on the 2D mesh.

    Every leaf carries the leading client axis; with ``dm > 1`` the model
    axis lands on the LARGEST remaining dim divisible by ``dm`` (the dim
    whose sharding saves the most memory — for a (C, d_in, d_out) weight
    that is the bigger of the two matmul dims, matching what
    ``mesh2d_rules`` picks for annotated layers). Leaves with no shardable
    dim (per-client scalars like optimizer step counters) replicate over
    the model axis. Used to constrain params/opt_state at the shard_map
    boundary so GSPMD starts from the intended layout instead of
    discovering one per jit cache entry.
    """
    def one(x):
        spec: list = [client_axis] + [None] * (x.ndim - 1)
        if dm > 1:
            sizes = [(x.shape[i], i) for i in range(1, x.ndim)
                     if x.shape[i] % dm == 0 and x.shape[i] >= dm]
            if sizes:
                spec[max(sizes)[1]] = model_axis
        while len(spec) > 1 and spec[-1] is None:
            spec.pop()
        return P(*spec)

    return jax.tree.map(one, tree)


def _constrain(tree, mesh: Mesh, dm: int):
    specs = default_param_specs(tree, dm)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)), tree, specs)


def _replicate(tree, mesh: Mesh):
    """Pin every leaf fully replicated (wsc P()) before it enters the
    partial-auto shard_map region.

    Load-bearing, not an optimization: on current XLA, an operand whose
    producer op carries an inferred (non-fully-specified) sharding gets
    corrupted data movement at the partial-manual boundary — e.g. raw
    ``jax.random.split`` keys or concatenated masks arrive as garbage
    inside the body. An explicit replicated constraint is the one
    annotation that reliably survives the boundary for every dtype/rank
    tested; see the padding-parity pins in tests/test_mesh.py."""
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P())), tree)


def _pad_one(x, pad: int, row0: bool):
    """Pad ``x`` to ``pad`` extra rows via dynamic_update_slice into a zero
    buffer (optionally re-writing row 0 into each pad row).

    DELIBERATELY not ``jnp.concatenate``/``broadcast_to``/``jnp.pad``/
    gather: on current XLA, operands built by those ops and fed into a
    partial-manual shard_map region come out with corrupted data movement
    (sharding propagation across the manual-subgroup boundary mishandles
    their producer shardings; the same family of bug as the
    IsManualSubgroup abort). DUS-built buffers round-trip exactly — pinned
    by the padding-parity tests in tests/test_mesh.py."""
    n = x.shape[0]
    buf = jax.lax.dynamic_update_slice(
        jnp.zeros((n + pad,) + x.shape[1:], x.dtype), x, (0,) * x.ndim)
    if row0:
        first = jax.lax.dynamic_slice(x, (0,) * x.ndim,
                                      (1,) + x.shape[1:])
        for i in range(pad):
            buf = jax.lax.dynamic_update_slice(
                buf, first, (n + i,) + (0,) * (x.ndim - 1))
    return buf


def _pad_rows(tree, pad: int):
    """Append ``pad`` copies of row 0 along axis 0 of every leaf (inert but
    numerically well-behaved pad clients)."""
    if pad == 0:
        return tree
    return jax.tree.map(lambda x: _pad_one(x, pad, row0=True), tree)


def _pad_zero_rows(tree, pad: int):
    if pad == 0 or tree is None:
        return tree
    return jax.tree.map(lambda x: _pad_one(x, pad, row0=False), tree)


def _unpad_rows(tree, n: int):
    return jax.tree.map(lambda x: x[:n], tree)


def make_mesh_2d_round(loss_fn: Callable, optimizer: Optimizer,
                       cfg: FLConfig, mesh: Mesh, *, rules=None,
                       topology: str = "full_average", pipeline=None,
                       constrain_params: bool = True):
    """Build ``round_step`` on a 2D ("client", "model") mesh.

    Signature and key/compressor streams are identical to the other engines:
    ``(params, opt_state, batch, key, sigmas) -> (new_p, new_s, metrics)``,
    or with ``pipeline`` the 7-operand masked/residual form. ``rules`` is a
    logical->mesh dict for the model annotations (default
    :func:`repro.models.sharding.mesh2d_rules`); ``constrain_params=False``
    skips the boundary layout constraints and lets GSPMD choose freely.
    """
    if topology not in TOPOLOGIES:
        raise ValueError(f"topology must be one of {TOPOLOGIES}, "
                         f"got {topology!r}")
    if pipeline is not None and topology != "full_average":
        raise ValueError("the aggregation pipeline requires "
                         "topology='full_average'")
    if pipeline is not None and (pipeline.aggregator is not None
                                 or pipeline.secure is not None
                                 or pipeline.attack is not None):
        raise ValueError(
            "mesh_2d does not support the adversarial extensions (robust "
            "aggregator / secure sum / update attack): their full-view "
            "reductions do not compose with the padded client axis. Use "
            "engine='shard_map'.")
    dc = mesh.shape[CLIENT_AXIS]
    dm = mesh.shape[MODEL_AXIS]
    C = cfg.n_clients
    Cp = -(-C // dc) * dc
    pad = Cp - C
    if dm == 1 and pad == 0:
        # Degenerate mesh: the 1D engine body on the same devices. The
        # "model" axis (size 1) is manual-but-unused, which is bitwise
        # identical to the 1D ("client",) mesh — pinned by tests/test_mesh.
        return make_shard_map_round(loss_fn, optimizer, cfg, mesh,
                                    client_axis=CLIENT_AXIS,
                                    topology=topology, pipeline=pipeline)
    block = Cp // dc
    rules = mesh2d_rules() if rules is None else dict(rules)
    # unroll=True: RNG inside a while loop inside a partial-manual shard_map
    # region aborts XLA's sharding propagation (IsManualSubgroup check);
    # fully unrolling the tau scan removes the loop, values unchanged
    local_round = make_local_round(make_grad_fn(loss_fn, cfg), optimizer,
                                   cfg.tau, unroll=True)
    psum = lambda x: jax.lax.psum(x, axis_name=CLIENT_AXIS)

    def per_shard(params, opt_state, batches, keys, sigmas, valid):
        """Local view: leading axis = block; model tensors stay GSPMD-
        sharded over the (auto) model axis throughout."""
        new_p, new_s, ms = jax.vmap(local_round)(params, opt_state, batches,
                                                 keys, sigmas)
        denom = psum(jnp.sum(valid))
        if topology == "full_average":
            # ---- Eq. (7b) with pad rows weighted out: valid-weighted block
            # sums, one psum over the client axis, broadcast back.
            avg = tree_valid_mean_axis0(new_p, valid, denom, all_sum=psum)
            new_p = tree_broadcast_axis0(avg, block)
            if cfg.average_opt_state:
                avg_s = tree_valid_mean_axis0(new_s, valid, denom,
                                              all_sum=psum)
                new_s = tree_broadcast_axis0(avg_s, block)
        ms = tree_valid_mean_axis0(ms, valid, denom, all_sum=psum)
        return new_p, new_s, ms

    def local_rounds(params, opt_state, batches, keys, sigmas):
        """Stage 1 (partial-auto): the tau local steps of this slab's block,
        model tensors GSPMD-sharded, ZERO collectives."""
        return jax.vmap(local_round)(params, opt_state, batches, keys,
                                     sigmas)

    def aggregate_pipeline(params, new_p, new_s, opt_state, residual, mask,
                           agg_keys, ms):
        """Stage 2 (FULL-manual over both axes): the pipeline Eq.-7b seam.
        The compressor's flatten / top_k / scatter ops do not lower under
        the partial-auto partitioner, so this stage runs with the model
        axis manual-but-unused — every model device computes the reduction
        redundantly on gathered whole updates, exactly the 1D engine's
        semantics. Pad rows enter with mask = 0, so the masked sums /
        denominators of ``pipeline.aggregate`` drop them exactly."""
        new_p, new_s, residual = pipeline.aggregate(
            params, new_p, new_s, opt_state, residual, mask, agg_keys,
            all_sum=psum)
        ms = pipeline.masked_metrics(ms, mask, all_sum=psum)
        return new_p, new_s, residual, ms

    cspec = P(CLIENT_AXIS)
    auto = frozenset({MODEL_AXIS})
    if pipeline is None:
        smapped = _shard_map(per_shard, mesh,
                             in_specs=(cspec,) * 6,
                             out_specs=(cspec, cspec, P()),
                             auto=auto)

        def round_step(params, opt_state, batch, key, sigmas):
            keys = jax.random.split(key, C)
            with axis_rules(mesh, rules):
                params = _pad_rows(params, pad)
                opt_state = _pad_rows(opt_state, pad)
                if constrain_params:
                    params = _constrain(params, mesh, dm)
                    opt_state = _constrain(opt_state, mesh, dm)
                valid = _replicate(
                    _pad_one(jnp.ones((C,), jnp.float32), pad, row0=False),
                    mesh)
                new_p, new_s, ms = smapped(
                    params, opt_state,
                    _replicate(_pad_rows(batch, pad), mesh),
                    _replicate(_pad_rows(keys, pad), mesh),
                    _replicate(_pad_rows(sigmas, pad), mesh), valid)
                if constrain_params:
                    new_p = _constrain(new_p, mesh, dm)
                    new_s = _constrain(new_s, mesh, dm)
            return _unpad_rows(new_p, C), _unpad_rows(new_s, C), ms

        return round_step

    smapped_local = _shard_map(local_rounds, mesh,
                               in_specs=(cspec,) * 5,
                               out_specs=(cspec, cspec, cspec),
                               auto=auto)
    smapped_agg = _shard_map(aggregate_pipeline, mesh,
                             in_specs=(cspec,) * 8,
                             out_specs=(cspec, cspec, cspec, P()))

    def round_step_pipeline(params, opt_state, batch, key, sigmas, mask,
                            residual):
        keys, agg_keys = pipeline_round_keys(key, C)
        with axis_rules(mesh, rules):
            params = _pad_rows(params, pad)
            opt_state = _pad_rows(opt_state, pad)
            if constrain_params:
                params = _constrain(params, mesh, dm)
                opt_state = _constrain(opt_state, mesh, dm)
            new_p, new_s, ms = smapped_local(
                params, opt_state,
                _replicate(_pad_rows(batch, pad), mesh),
                _replicate(_pad_rows(keys, pad), mesh),
                _replicate(_pad_rows(sigmas, pad), mesh))
            new_p, new_s, residual, ms = smapped_agg(
                params, new_p, new_s, opt_state,
                _pad_zero_rows(residual, pad),
                _replicate(_pad_zero_rows(mask, pad), mesh),
                _replicate(_pad_rows(agg_keys, pad), mesh), ms)
            if constrain_params:
                new_p = _constrain(new_p, mesh, dm)
                new_s = _constrain(new_s, mesh, dm)
        return (_unpad_rows(new_p, C), _unpad_rows(new_s, C),
                _unpad_rows(residual, C), ms)

    return round_step_pipeline

"""Three-term roofline model for TPU v5e (target hardware).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

The post-SPMD HLO module IS the per-device program, so cost_analysis()
numbers are already per-device; no extra division by chip count.
"""
from __future__ import annotations

from dataclasses import dataclass, field

# -- TPU v5e hardware constants (from the brief) ---------------------------
PEAK_FLOPS_BF16 = 197e12     # FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (~1 link per sharded axis hop)


@dataclass
class RooflineTerms:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective bytes
    model_flops: float = 0.0     # 6*N*D (train) / 2*N*D (inference), global
    chips: int = 1
    coll_breakdown: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO flops summed over chips)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "chips": self.chips,
            "coll_breakdown": self.coll_breakdown,
        }


def model_flops_estimate(n_params_active: float, tokens: float,
                         kind: str) -> float:
    """6*N*D for training, 2*N*D for inference forward passes."""
    if kind == "train":
        return 6.0 * n_params_active * tokens
    return 2.0 * n_params_active * tokens


def active_params(cfg, n_params_total: float) -> float:
    """MoE: scale expert params down to the activated top-k fraction."""
    if not cfg.n_experts:
        return n_params_total
    # expert FFN params per layer: 3 * d_model * moe_d_ff * n_experts
    moe_layers = sum(1 for ls in cfg.layer_specs() if ls.ffn == "moe")
    expert_total = 3.0 * cfg.d_model * (cfg.moe_d_ff or cfg.d_ff) \
        * cfg.n_experts * moe_layers
    expert_active = expert_total * cfg.top_k / cfg.n_experts
    return n_params_total - expert_total + expert_active

"""HLO-text analysis: loop-aware flops / bytes / collective accounting.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified in
this repo's tests), but our models are built from lax.scan over layers and
the DP-PASGD round scans over tau — so raw cost_analysis undercounts by the
trip counts. This module parses the post-optimization (post-SPMD, i.e.
per-device) HLO text, builds the computation call graph of while loops,
extracts trip counts from loop conditions, and aggregates:

  - flops:       2 * out_elements * contracted_size per ``dot``
  - hbm bytes:   operand + result bytes of top-level (fused) instructions
  - collectives: result bytes per all-gather/all-reduce/reduce-scatter/
                 all-to-all/collective-permute

each multiplied by the product of enclosing trip counts. Fusion-internal
computations are excluded (their traffic is the fusion instruction's
operands/results at the call site).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s*\(.*->")
_WHILE_RE = re.compile(
    r"\bwhile\("
    r".*?(?:condition=%?([\w.\-_]+).*?body=%?([\w.\-_]+)"
    r"|body=%?([\w.\-_]+).*?condition=%?([\w.\-_]+))")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_DOT_RE = re.compile(r"=\s*([\w\[\],{}\d]+)\s+dot\(")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}\d]+)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"[\s(<]")


def _shapes_in(s: str):
    for m in _SHAPE_RE.finditer(s):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        yield dtype, n


def _bytes_in(s: str) -> int:
    return sum(n * _DTYPE_BYTES[d] for d, n in _shapes_in(s))


def _elements_of_first_shape(s: str) -> int:
    for _, n in _shapes_in(s):
        return n
    return 0


@dataclass
class HloCostModel:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: dict = field(default_factory=dict)
    n_whiles: int = 0
    raw_per_comp: dict = field(default_factory=dict)


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    current = None
    for line in text.splitlines():
        stripped = line.strip()
        m = _COMP_HDR.match(line) if not line.startswith(" ") else None
        if m and "{" in line:
            current = m.group(1)
            comps[current] = []
            if line.startswith("ENTRY"):
                comps["__entry__"] = comps[current]
        elif current is not None:
            if stripped == "}":
                current = None
            else:
                comps[current].append(stripped)
    return comps


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-_]+)\s*=\s*(\S+)")


def _def_shapes(lines: list[str]) -> dict[str, str]:
    """instruction name -> result shape string, within one computation."""
    out = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            out[m.group(1)] = m.group(2)
    return out


def _dims_of(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def _split_top_level(s: str) -> list[str]:
    """Split on commas outside [] / {} (shape dims and layouts keep commas)."""
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "[{":
            depth += 1
        elif ch in "]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return parts


def _dot_flops(line: str, defs: dict[str, str]) -> float:
    """2 * out_elems * contracted_size from a dot instruction line."""
    m = _DOT_RE.search(line)
    if not m:
        return 0.0
    out_elems = _elements_of_first_shape(m.group(1))
    paren = line[line.index("dot(") + 4:]
    paren = paren.split(")")[0]
    # operands are either bare names ("%p0") or typed ("f32[64,128]{1,0} %p0"
    # in newer XLA dumps) — split at top level so shape commas don't cut
    lhs_tok = _split_top_level(paren)[0].strip()
    if "[" in lhs_tok and "]" in lhs_tok:    # shape printed inline
        dims = _dims_of(lhs_tok)
    else:                                    # look up the defining instr
        dims = _dims_of(defs.get(lhs_tok.lstrip("%"), ""))
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if cm and cm.group(1) and dims:
        k = 1
        for i in (int(i) for i in cm.group(1).split(",")):
            if i < len(dims):
                k *= dims[i]
    else:
        k = dims[-1] if dims else 1
    return 2.0 * out_elems * k


def analyze_hlo(text: str) -> HloCostModel:
    comps = _split_computations(text)

    # --- while loops: body/cond -> trip count --------------------------
    trip_of_comp: dict[str, int] = {}
    called_from: dict[str, list[str]] = defaultdict(list)
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond = wm.group(1) or wm.group(4)
                body = wm.group(2) or wm.group(3)
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                else:
                    trip = 1
                    for cl in comps.get(cond, []):
                        for c in _CONST_RE.finditer(cl):
                            trip = max(trip, int(c.group(1)))
                trip_of_comp[body] = trip
                trip_of_comp[cond] = trip
                called_from[body].append(name)
                called_from[cond].append(name)

    entry_name = None
    for name, lines in comps.items():
        if name != "__entry__" and comps.get("__entry__") is lines:
            entry_name = name

    # multiplier = product of trip counts up the while-nesting chain
    def multiplier(name: str, seen=()) -> float:
        if name == entry_name or name in seen:
            return 1.0
        t = trip_of_comp.get(name)
        if t is None:
            return 0.0          # fusion body / reducer: counted at call site
        parents = called_from.get(name, [])
        pm = max((multiplier(p, seen + (name,)) for p in parents),
                 default=1.0)
        return t * max(pm, 1.0)

    out = HloCostModel()
    walk = {entry_name: 1.0} if entry_name else {}
    for b, t in trip_of_comp.items():
        walk[b] = multiplier(b)

    for name, mult in walk.items():
        if not mult or name not in comps:
            continue
        flops = hbm = 0.0
        coll: dict[str, float] = defaultdict(float)
        defs = _def_shapes(comps[name])
        for line in comps[name]:
            if " dot(" in line:
                flops += _dot_flops(line, defs)
            cmm = _COLL_RE.search(line)
            if cmm:
                op = cmm.group(2).replace("-start", "")
                coll[op] += _bytes_in(cmm.group(1))
            # hbm traffic: operand + result bytes of top-level instructions;
            # skip zero-traffic bookkeeping ops. Slicing ops only touch the
            # slice, not the full operand — count result bytes only (else a
            # loop that dynamic-slices a big stacked tensor gets charged the
            # whole tensor every iteration).
            if "=" not in line:
                continue
            if any(f" {op}(" in line for op in
                   ("get-tuple-element", "tuple", "parameter", "bitcast",
                    "constant", "after-all", "iota")):
                continue
            if any(f" {op}(" in line for op in
                   ("dynamic-slice", "dynamic-update-slice", "gather",
                    "scatter", "slice", "broadcast")):
                rhs = line.split(" = ", 1)[1]
                hbm += 2 * _bytes_in(rhs.split("(")[0])   # read + write slice
            else:
                hbm += _bytes_in(line)
        out.flops += mult * flops
        out.hbm_bytes += mult * hbm
        for k, v in coll.items():
            out.coll_breakdown[k] = out.coll_breakdown.get(k, 0.0) + mult * v
        out.raw_per_comp[name] = {"mult": mult, "flops": flops,
                                  "hbm": hbm, "coll": dict(coll)}
    out.coll_bytes = sum(out.coll_breakdown.values())
    out.n_whiles = len(trip_of_comp) // 2
    return out


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions.

    Older jax returns one properties dict; newer versions return a
    one-element list of dicts (one per partition). Returns {} when XLA
    provides no analysis.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


# ---------------------------------------------------------------------------
# legacy single-pass collective accounting (kept for tests / comparison)
# ---------------------------------------------------------------------------

def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Loop-aware collective bytes per kind."""
    model = analyze_hlo(hlo_text)
    out = {k: int(v) for k, v in model.coll_breakdown.items()}
    out["total"] = int(model.coll_bytes)
    return out


def count_ops(hlo_text: str, opcode: str) -> int:
    return len(re.findall(rf"\b{re.escape(opcode)}\(", hlo_text))

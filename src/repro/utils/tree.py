"""Pytree math utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)

def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a, b):
    leaves = jax.tree.map(lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return jax.tree.reduce(jnp.add, leaves)


def tree_sq_norm(a):
    leaves = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), a)
    return jax.tree.reduce(jnp.add, leaves)


def tree_norm(a):
    return jnp.sqrt(tree_sq_norm(a))


def tree_size(a) -> int:
    """Total number of scalar parameters in the tree (static)."""
    return sum(x.size for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_mean_over_axis0(a, keep_dtype: bool = False):
    """Mean over a leading (client) axis of every leaf.

    ``keep_dtype=True`` guarantees each mean comes back in its leaf's dtype.
    Without it ``jnp.mean`` promotes int leaves to f32, which makes the
    output pytree carry-unstable under ``lax.scan`` chunking, defeats
    buffer donation (in/out dtype mismatch), and silently retraces the
    jitted round on its second call. f32-and-wider float leaves take the
    plain mean (full native precision); sub-f32 floats (bf16/f16) detour
    through an f32 accumulation; integer leaves — whose replicas must agree
    (optimizer step counters: every client steps in lockstep) — take the
    first replica, exact at any magnitude where an f32 round-trip would
    corrupt counters above 2^24."""
    if keep_dtype:
        def _mean_keep(x):
            if jnp.issubdtype(x.dtype, jnp.integer):
                return x[0]
            if (jnp.issubdtype(x.dtype, jnp.floating)
                    and jnp.finfo(x.dtype).bits >= 32):
                return jnp.mean(x, axis=0)
            return jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype)

        return jax.tree.map(_mean_keep, a)
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), a)


def tree_broadcast_axis0(a, n: int):
    """Tile every leaf along a new leading axis of size n."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_split_keys(key, tree):
    """One PRNG key per leaf, returned as a matching pytree."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))


def tree_add_noise(key, tree, sigma):
    """Add isotropic N(0, sigma^2) noise to every leaf, preserving dtypes
    (sigma may be a traced f32 scalar)."""
    keytree = tree_split_keys(key, tree)
    def _noise(k, x):
        n = sigma * jax.random.normal(k, x.shape, dtype=jnp.float32)
        return (x.astype(jnp.float32) + n).astype(x.dtype)
    return jax.tree.map(_noise, keytree, tree)

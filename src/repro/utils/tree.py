"""Pytree math utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)

def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a, b):
    leaves = jax.tree.map(lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return jax.tree.reduce(jnp.add, leaves)


def tree_sq_norm(a):
    leaves = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), a)
    return jax.tree.reduce(jnp.add, leaves)


def tree_norm(a):
    return jnp.sqrt(tree_sq_norm(a))


def tree_size(a) -> int:
    """Total number of scalar parameters in the tree (static)."""
    return sum(x.size for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_mean_over_axis0(a):
    """Mean over a leading (client) axis of every leaf."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), a)


def tree_broadcast_axis0(a, n: int):
    """Tile every leaf along a new leading axis of size n."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_split_keys(key, tree):
    """One PRNG key per leaf, returned as a matching pytree."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))


def tree_add_noise(key, tree, sigma):
    """Add isotropic N(0, sigma^2) noise to every leaf, preserving dtypes
    (sigma may be a traced f32 scalar)."""
    keytree = tree_split_keys(key, tree)
    def _noise(k, x):
        n = sigma * jax.random.normal(k, x.shape, dtype=jnp.float32)
        return (x.astype(jnp.float32) + n).astype(x.dtype)
    return jax.tree.map(_noise, keytree, tree)

"""Config registry: importing this package registers all assigned archs."""
from repro.configs.base import (
    ArchConfig,
    LayerSpec,
    Segment,
    get_arch,
    list_archs,
    patterned_segments,
    register,
    smoke_variant,
    uniform_segments,
)

# one module per assigned architecture (registration side effect)
from repro.configs import codeqwen15_7b      # noqa: F401
from repro.configs import gemma3_4b          # noqa: F401
from repro.configs import granite_20b        # noqa: F401
from repro.configs import internvl2_76b     # noqa: F401
from repro.configs import llama4_maverick_400b  # noqa: F401
from repro.configs import mistral_large_123b    # noqa: F401
from repro.configs import musicgen_large     # noqa: F401
from repro.configs import phi35_moe_42b      # noqa: F401
from repro.configs import rwkv6_1_6b         # noqa: F401
from repro.configs import zamba2_7b          # noqa: F401

from repro.configs.shapes import (
    SHAPES,
    InputShape,
    get_shape,
    input_specs,
    supports_shape,
)

ASSIGNED_ARCHS = [
    "internvl2-76b",
    "musicgen-large",
    "mistral-large-123b",
    "codeqwen1.5-7b",
    "rwkv6-1.6b",
    "zamba2-7b",
    "gemma3-4b",
    "phi3.5-moe-42b-a6.6b",
    "granite-20b",
    "llama4-maverick-400b-a17b",
]

__all__ = [
    "ArchConfig", "LayerSpec", "Segment", "get_arch", "list_archs",
    "patterned_segments", "register", "smoke_variant", "uniform_segments",
    "SHAPES", "InputShape", "get_shape", "input_specs", "supports_shape",
    "ASSIGNED_ARCHS",
]

"""codeqwen1.5-7b [dense]: qwen1.5 architecture [hf:Qwen/CodeQwen1.5-7B].

Assigned spec: 32L d_model=4096 32H (GQA kv=32 = MHA) d_ff=13440 vocab=92416.
Qwen1.5 uses QKV biases.
"""
from repro.configs.base import ArchConfig, LayerSpec, register, uniform_segments

CODEQWEN15_7B = register(ArchConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    source="hf:Qwen/CodeQwen1.5-7B",
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    n_layers=32,
    segments=uniform_segments(32, LayerSpec(mixer="attn", ffn="mlp")),
    qkv_bias=True,
    loss_chunk=1024,
    rope_theta=1e6,
    subquadratic=False,
))

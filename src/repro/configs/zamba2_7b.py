"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention [arXiv:2411.15242].

Assigned spec: 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000,
ssm_state=64. We implement 81 Mamba2 layers with ONE shared-weight
attention+MLP block invoked every 6 layers (13 invocations), each with
per-invocation LoRA deltas on the attention projections — the adaptation of
Zamba2's shared blocks recorded in DESIGN.md §5.5. Hybrid -> long_500k runs.
"""
from repro.configs.base import ArchConfig, LayerSpec, Segment, register

_M = LayerSpec(mixer="mamba2", ffn="none")
_SH = LayerSpec(mixer="shared_attn", ffn="shared_mlp")

ZAMBA2_7B = register(ArchConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    source="arXiv:2411.15242",
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    n_layers=81,             # mamba2 layers; + 13 shared-attn invocations
    segments=(
        Segment(n_steps=13, pattern=(_SH, _M, _M, _M, _M, _M, _M)),
        Segment(n_steps=1, pattern=(_M, _M, _M)),
    ),
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    lora_rank=64,
    rope_theta=1e4,
    subquadratic=True,
))

"""The four assigned input shapes + input_specs() stand-ins for dry-runs.

Decode shapes lower ``serve_step`` (one new token, KV cache of seq_len);
train_4k lowers the DP-PASGD ``train_step`` (round of tau local steps);
prefill_32k lowers ``prefill``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


def needs_subquadratic(shape: InputShape) -> bool:
    return shape.name == "long_500k"


def supports_shape(cfg: ArchConfig, shape: InputShape) -> tuple[bool, str]:
    """long_500k only runs on sub-quadratic decode paths (DESIGN.md §4)."""
    if needs_subquadratic(shape) and not cfg.subquadratic:
        return False, ("skip: pure full-attention arch has no sub-quadratic "
                       "path for 500k decode (DESIGN.md §4)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_count_estimate(cfg: ArchConfig) -> int:
    """Parameter count of one model replica, via ``jax.eval_shape`` on the
    arch's init (abstract — no allocation, no devices needed)."""
    from repro.models.transformer import Transformer
    model = Transformer(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return sum(int(x.size) for x in jax.tree.leaves(params_sds))


def replica_footprint_bytes(cfg: ArchConfig, optimizer=None) -> int:
    """Bytes of ONE client replica: params + optimizer state, from abstract
    shapes. This is the ``FederationSpec.replica_bytes`` hint that drives
    the mesh-aware ``engine="auto"`` placement (repro.mesh.placement) and
    the per-device budget report of ``launch/dryrun --mesh-report``.
    Activations / gradients are excluded — the placement compares this
    against the per-device budget with the same margin conventions as
    ``launch.dryrun`` (which reports them separately).
    """
    from repro.models.transformer import Transformer
    model = Transformer(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    leaves = list(jax.tree.leaves(params_sds))
    if optimizer is not None:
        opt_sds = jax.eval_shape(optimizer.init, params_sds)
        leaves += list(jax.tree.leaves(opt_sds))
    return sum(int(x.size) * x.dtype.itemsize for x in leaves)


def input_specs(cfg: ArchConfig, shape: InputShape, n_clients: int = 1,
                tau: int = 1, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train:   {"tokens": (C, tau, B/C, S), "labels": ..., ["prefix": ...]}
    prefill: {"tokens": (B, S), ["prefix": (B, P, d)]}
    decode:  {"tokens": (B,), "pos": ()} (+ caches built separately)
    """
    s, b = shape.seq_len, shape.global_batch
    if shape.kind == "train":
        assert b % n_clients == 0, (b, n_clients)
        bc = b // n_clients
        batch = {
            "tokens": _sds((n_clients, tau, bc, s), jnp.int32),
            "labels": _sds((n_clients, tau, bc, s), jnp.int32),
        }
        if cfg.prefix_len:
            batch["prefix"] = _sds((n_clients, tau, bc, cfg.prefix_len,
                                    cfg.d_model), dtype)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.prefix_len:
            batch["prefix"] = _sds((b, cfg.prefix_len, cfg.d_model), dtype)
        return batch
    if shape.kind == "decode":
        return {"tokens": _sds((b,), jnp.int32),
                "pos": _sds((), jnp.int32)}
    raise ValueError(shape.kind)

"""phi3.5-moe-42b-a6.6b [moe]: 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct].

Assigned spec: 32L d_model=4096 32H (GQA kv=8) d_ff=6400 (per expert)
vocab=32064, MoE 16e top-2.
"""
from repro.configs.base import ArchConfig, LayerSpec, register, uniform_segments

PHI35_MOE_42B = register(ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab=32064,
    n_layers=32,
    segments=uniform_segments(32, LayerSpec(mixer="attn", ffn="moe")),
    n_experts=16,
    top_k=2,
    moe_d_ff=6400,
    rope_theta=1e4,
    subquadratic=False,
))

"""mistral-large-123b [dense] [hf:mistralai/Mistral-Large-Instruct-2407].

Assigned spec: 88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
from repro.configs.base import ArchConfig, LayerSpec, register, uniform_segments

MISTRAL_LARGE_123B = register(ArchConfig(
    name="mistral-large-123b",
    arch_type="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    n_layers=88,
    head_dim=128,
    segments=uniform_segments(88, LayerSpec(mixer="attn", ffn="mlp")),
    rope_theta=1e6,
    subquadratic=False,
))

"""internvl2-76b [vlm]: InternViT + InternLM2 backbone [arXiv:2404.16821].

Assigned spec: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The vision encoder is a STUB: input_specs() provides precomputed patch
embeddings (B, 256, d_model); this config is the language backbone that
consumes them (DESIGN.md §5.4).
"""
from repro.configs.base import ArchConfig, LayerSpec, register, uniform_segments

INTERNVL2_76B = register(ArchConfig(
    name="internvl2-76b",
    arch_type="vlm",
    source="arXiv:2404.16821",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    n_layers=80,
    segments=uniform_segments(80, LayerSpec(mixer="attn", ffn="mlp")),
    rope_theta=1e6,
    prefix_len=256,          # ViT patch embeddings stub
    loss_chunk=1024,
    subquadratic=False,
))

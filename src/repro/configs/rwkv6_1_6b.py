"""rwkv6-1.6b [ssm]: RWKV-6 "Finch" — data-dependent decay [arXiv:2404.05892].

Assigned spec: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536.
Each layer = time-mix (WKV6 recurrence) + channel-mix. Sub-quadratic:
decode state is O(1) in sequence length -> long_500k runs.
"""
from repro.configs.base import ArchConfig, LayerSpec, register, uniform_segments

RWKV6_1_6B = register(ArchConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    source="arXiv:2404.05892",
    d_model=2048,
    n_heads=32,              # = d_model / rwkv_headdim (bookkeeping only)
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    n_layers=24,
    segments=uniform_segments(24, LayerSpec(mixer="rwkv6", ffn="rwkv_cm")),
    rwkv_headdim=64,
    loss_chunk=1024,
    subquadratic=True,
))

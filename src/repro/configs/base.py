"""Architecture config schema + registry.

An architecture is a stack of *segments*; each segment is ``n_steps``
repetitions (lax.scan with stacked params) of a ``pattern`` of layers. This
lets heterogeneous archs (gemma3's 5:1 local:global, llama4's 3:1
chunked:global iRoPE, zamba2's shared-attention-every-6-mamba) compile as a
small number of scans instead of L unrolled layers.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

_REGISTRY: dict[str, "ArchConfig"] = {}


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"        # attn | mamba2 | rwkv6 | shared_attn
    ffn: str = "mlp"           # mlp | moe | rwkv_cm | none | shared_mlp
    attn_kind: str = "full"    # full | swa | chunk
    use_rope: bool = True


@dataclass(frozen=True)
class Segment:
    n_steps: int
    pattern: tuple[LayerSpec, ...]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str             # dense | moe | ssm | hybrid | vlm | audio
    source: str                # paper / model-card citation
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    n_layers: int
    segments: tuple[Segment, ...]
    head_dim: int = 0          # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    window: int = 0            # sliding-window size (swa layers)
    chunk: int = 0             # chunk size (chunked-attention layers)
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scale
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    moe_impl: str = "scatter"
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    ssd_chunk: int = 128
    # --- RWKV ---
    rwkv_headdim: int = 64
    rwkv_chunk: int = 0        # 0 = per-token scan; >0 = chunk-parallel WKV6
    # --- shared attention block (zamba2) ---
    lora_rank: int = 0
    # --- modality frontend stub (vlm / audio) ---
    prefix_len: int = 0        # precomputed patch/frame embeddings length
    # --- misc ---
    tie_head: bool = True
    dtype: str = "bfloat16"
    remat: bool = True
    # fully unroll the layer/loss-chunk scans (straight-line HLO). Required
    # inside the mesh_2d partial-auto shard_map region, where XLA's SPMD
    # partitioner cannot propagate manual-subgroup shardings into while
    # loops (hlo_sharding_util IsManualSubgroup check). Numerics identical;
    # compile time grows with depth, so keep False everywhere else.
    scan_unroll: bool = False
    block_q: int = 512
    loss_chunk: int = 0        # 0 = unchunked cross-entropy (hillclimb knob)
    embed_impl: str = "gather"  # "gather" | "one_hot" (§Perf knob)
    causal_buckets: bool = False  # bucketed causal block-skip (§Perf knob)
    # long-context support (decides long_500k applicability)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_specs(self):
        out = []
        for seg in self.segments:
            for _ in range(seg.n_steps):
                out.extend(seg.pattern)
        return out

    def count_mixers(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for ls in self.layer_specs():
            counts[ls.mixer] = counts.get(ls.mixer, 0) + 1
        return counts


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # import all config modules lazily
        import repro.configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def uniform_segments(n_layers: int, spec: LayerSpec) -> tuple[Segment, ...]:
    return (Segment(n_steps=n_layers, pattern=(spec,)),)


def patterned_segments(n_layers: int, pattern: tuple[LayerSpec, ...]
                       ) -> tuple[Segment, ...]:
    """Repeat ``pattern`` as many full times as fits; remainder becomes a
    second segment with a truncated pattern."""
    p = len(pattern)
    full, rem = divmod(n_layers, p)
    segs = []
    if full:
        segs.append(Segment(n_steps=full, pattern=pattern))
    if rem:
        segs.append(Segment(n_steps=1, pattern=pattern[:rem]))
    return tuple(segs)


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced config of the same family: <=2 segment steps, d_model<=256,
    <=4 experts — runnable on CPU for the per-arch smoke tests."""
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    segs = []
    total = 0
    for seg in cfg.segments:
        if total >= 2:
            break
        segs.append(Segment(n_steps=1, pattern=seg.pattern[:4]))
        total += 1
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=0,
        d_ff=min(cfg.d_ff, 512),
        vocab=min(cfg.vocab, 512),
        n_layers=sum(len(s.pattern) * s.n_steps for s in segs),
        segments=tuple(segs),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        moe_d_ff=min(cfg.moe_d_ff, 256) if cfg.moe_d_ff else 0,
        # drop-free capacity at smoke scale so teacher-forced decode matches
        # the full forward exactly (capacity drops are a train-time effect)
        capacity_factor=4.0 if cfg.n_experts else cfg.capacity_factor,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=32 if cfg.ssm_state else cfg.ssm_headdim,
        rwkv_headdim=32,
        window=min(cfg.window, 16) if cfg.window else 0,
        chunk=min(cfg.chunk, 16) if cfg.chunk else 0,
        lora_rank=min(cfg.lora_rank, 4) if cfg.lora_rank else 0,
        prefix_len=min(cfg.prefix_len, 8) if cfg.prefix_len else 0,
        block_q=8,
        ssd_chunk=8,
        loss_chunk=0,
        dtype="float32",
        remat=False,
    )

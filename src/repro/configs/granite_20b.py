"""granite-20b [dense]: llama-arch code model, MQA [arXiv:2405.04324].

Assigned spec: 52L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ArchConfig, LayerSpec, register, uniform_segments

GRANITE_20B = register(ArchConfig(
    name="granite-20b",
    arch_type="dense",
    source="arXiv:2405.04324",
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    n_layers=52,
    segments=uniform_segments(52, LayerSpec(mixer="attn", ffn="mlp")),
    rope_theta=1e4,
    loss_chunk=1024,
    subquadratic=False,
))

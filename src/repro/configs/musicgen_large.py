"""musicgen-large [audio]: decoder-only over EnCodec tokens [arXiv:2306.05284].

Assigned spec: 48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192 vocab=2048.
EnCodec frontend is a STUB: tokens ARE codec tokens (vocab 2048); the text
conditioning is adapted from cross-attention to prefix embeddings (B, 64, d)
— documented deviation (DESIGN.md §5.4).
"""
from repro.configs.base import ArchConfig, LayerSpec, register, uniform_segments

MUSICGEN_LARGE = register(ArchConfig(
    name="musicgen-large",
    arch_type="audio",
    source="arXiv:2306.05284",
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    n_layers=48,
    segments=uniform_segments(48, LayerSpec(mixer="attn", ffn="mlp")),
    rope_theta=1e4,
    prefix_len=64,           # T5 text-conditioning embeddings stub
    subquadratic=False,
))

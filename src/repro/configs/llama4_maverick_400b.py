"""llama4-maverick-400b-a17b [moe]: 128 experts top-1, early fusion, iRoPE
[hf:meta-llama/Llama-4-Scout-17B-16E family].

Assigned spec: 48L d_model=5120 40H (GQA kv=8) d_ff=8192 (per expert)
vocab=202048, MoE 128e top-1 + shared expert. iRoPE adaptation: 3-in-4 layers
use chunked attention (8192-token chunks, RoPE); 1-in-4 layers are global
with NoRoPE. The chunked layers bound the decode cache -> long_500k runs
(global layers' caches sharded over sequence).
"""
from repro.configs.base import ArchConfig, LayerSpec, patterned_segments, register

# Maverick interleaves MoE and dense FFN layers 1:1 (interleave_moe_step=2);
# attention is iRoPE 3:1 chunked:global. Period-4 pattern: 24 MoE + 24 dense.
_C_MOE = LayerSpec(mixer="attn", ffn="moe", attn_kind="chunk", use_rope=True)
_C_MLP = LayerSpec(mixer="attn", ffn="mlp", attn_kind="chunk", use_rope=True)
_G_MLP = LayerSpec(mixer="attn", ffn="mlp", attn_kind="full", use_rope=False)

LLAMA4_MAVERICK_400B = register(ArchConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_layers=48,
    head_dim=128,
    segments=patterned_segments(48, (_C_MOE, _C_MLP, _C_MOE, _G_MLP)),
    chunk=8192,
    n_experts=128,
    top_k=1,
    moe_d_ff=8192,
    shared_expert=True,
    capacity_factor=1.25,
    loss_chunk=1024,
    rope_theta=5e5,
    subquadratic=True,
))

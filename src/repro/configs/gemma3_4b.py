"""gemma3-4b [dense]: 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family].

Assigned spec: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
Gemma3 uses head_dim=256 (decoupled from d_model/n_heads), sliding window
1024 on local layers, sqrt(d) embedding scaling. The 5:1 SWA pattern gives a
sub-quadratic decode path (global layers' caches are sharded over sequence)
-> long_500k runs.
"""
from repro.configs.base import ArchConfig, LayerSpec, patterned_segments, register

_LOCAL = LayerSpec(mixer="attn", ffn="mlp", attn_kind="swa")
_GLOBAL = LayerSpec(mixer="attn", ffn="mlp", attn_kind="full")

GEMMA3_4B = register(ArchConfig(
    name="gemma3-4b",
    arch_type="dense",
    source="hf:google/gemma-3-1b-pt",
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab=262144,
    n_layers=34,
    head_dim=256,
    segments=patterned_segments(
        34, (_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL)),
    window=1024,
    embed_scale=True,
    loss_chunk=1024,
    rope_theta=1e6,
    subquadratic=True,
))

"""Sensitivity enforcement + Gaussian mechanism for DP-PASGD (paper Eq. 7a).

The paper assumes G-Lipschitz losses so that the stochastic-gradient
sensitivity is 2G/X_m (§5.2). For non-convex models we *enforce* that
assumption by clipping gradients to norm G before averaging, which yields the
identical privacy algebra. Three granularities:

  num_microbatches == batch   -> per-example clipping (faithful DP-SGD style)
  1 < num_microbatches < batch -> per-microbatch clipping
  num_microbatches == 1        -> flat clipping of the mean gradient
                                  (memory-tractable mode for billion-param runs)

After clipping, Gaussian noise b ~ N(0, sigma^2 I_d) is added to the averaged
gradient — exactly Eq. (7a).

The clip+noise arithmetic — the per-step hot-spot on a constrained device —
can be routed through the fused ``dp_clip_noise`` kernel via
``make_dp_grad_fn(..., kernel_backend=...)`` (see
:mod:`repro.kernels.dispatch`); the default ``None`` keeps the legacy
per-leaf jnp path bit-for-bit.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.utils.tree import (
    tree_add_noise,
    tree_scale,
    tree_sq_norm,
)


def clip_tree(grads, clip_norm: float):
    """Scale a gradient pytree so its global L2 norm is <= clip_norm.
    Preserves each leaf's dtype (the scale is an f32 scalar)."""
    norm = jnp.sqrt(tree_sq_norm(grads))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))
    clipped = jax.tree.map(lambda x: (x * scale).astype(x.dtype), grads)
    return clipped, norm


def make_dp_grad_fn(
    loss_fn: Callable,
    clip_norm: float,
    num_microbatches: int = 1,
    vmap_microbatches: bool = True,
    accumulate: str = "stack",
    kernel_backend: str | None = None,
) -> Callable:
    """Build dp_grad(params, batch, key, sigma) -> (noisy_grad, metrics).

    ``loss_fn(params, batch)`` must return the mean loss over the leading batch
    axis of every leaf of ``batch``. ``sigma`` is a traced scalar so a single
    compiled step serves every noise level (the accountant varies sigma).

    ``accumulate`` (sequential path only):
      "stack": lax.map + mean — materializes num_microbatches gradient copies
               (paper-faithful baseline lowering).
      "scan":  running-sum scan carry — one gradient buffer regardless of the
               microbatch count (§Perf optimization).

    ``kernel_backend`` routes the clip(+noise) arithmetic through the fused
    ``dp_clip_noise`` kernel of :mod:`repro.kernels.dispatch` on the named
    backend ("pallas" | "interpret" | "ref" | "auto"); ``None`` keeps the
    legacy per-leaf jnp path. Both draw the noise from the identical
    per-leaf key stream, so the choice only changes arithmetic order.
    """
    vg_fn = jax.value_and_grad(loss_fn)

    if kernel_backend is not None:
        from repro.kernels.dispatch import resolve_backend
        from repro.kernels.ops import dp_clip_noise_tree

        # resolve (and capability-probe) the backend now, at build time:
        # inside the traced round the probes could not run
        kernel_backend = resolve_backend("dp_clip_noise", kernel_backend)

        def _clip(g):
            return dp_clip_noise_tree(g, None, clip_norm, 0.0,
                                      backend=kernel_backend)

        def _clip_noise(g, key, sigma):
            return dp_clip_noise_tree(g, key, clip_norm, sigma,
                                      backend=kernel_backend)
    else:
        def _clip(g):
            return clip_tree(g, clip_norm)

        def _clip_noise(g, key, sigma):
            clipped, norm = clip_tree(g, clip_norm)
            return tree_add_noise(key, clipped, sigma), norm

    def _one_microbatch(params, mb):
        loss, g = vg_fn(params, mb)
        clipped, norm = _clip(g)
        return clipped, loss, norm

    def dp_grad(params, batch, key, sigma):
        if num_microbatches == 1:
            # fused hot path: one kernel does norm + scale + noise (Eq. 7a)
            loss, g = vg_fn(params, batch)
            noisy, pre_norm = _clip_noise(g, key, sigma)
            metrics = {"loss": loss, "grad_norm_preclip": pre_norm}
            return noisy, metrics
        else:
            # reshape leading axis B -> (n_micro, B / n_micro)
            def _split(x):
                b = x.shape[0]
                if b % num_microbatches:
                    raise ValueError(
                        f"batch {b} not divisible by microbatches {num_microbatches}")
                return x.reshape((num_microbatches, b // num_microbatches) + x.shape[1:])
            mbs = jax.tree.map(_split, batch)
            if vmap_microbatches:
                clipped_all, losses, norms = jax.vmap(partial(_one_microbatch, params))(mbs)
                clipped = jax.tree.map(lambda x: jnp.mean(x, axis=0), clipped_all)
                loss, pre_norm = jnp.mean(losses), jnp.mean(norms)
            elif accumulate == "scan":
                def body(carry, mb):
                    acc, loss_acc, norm_acc = carry
                    c, l, n = _one_microbatch(params, mb)
                    acc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), acc, c)
                    return (acc, loss_acc + l, norm_acc + n), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (acc, loss, pre_norm), _ = jax.lax.scan(
                    body, (zeros, jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32)), mbs)
                clipped = jax.tree.map(
                    lambda a, p: (a / num_microbatches).astype(p.dtype),
                    acc, params)
                loss = loss / num_microbatches
                pre_norm = pre_norm / num_microbatches
            else:
                clipped_all, losses, norms = jax.lax.map(
                    partial(_one_microbatch, params), mbs)
                clipped = jax.tree.map(lambda x: jnp.mean(x, axis=0),
                                       clipped_all)
                loss, pre_norm = jnp.mean(losses), jnp.mean(norms)
        # microbatch paths clip per microbatch (kernel when selected) and
        # noise the averaged gradient once, per the Eq. 7a mechanism
        noisy = tree_add_noise(key, clipped, sigma)
        metrics = {"loss": loss, "grad_norm_preclip": pre_norm}
        return noisy, metrics

    return dp_grad


def make_plain_grad_fn(loss_fn: Callable) -> Callable:
    """Non-private gradient with the same signature (sigma ignored)."""
    vg_fn = jax.value_and_grad(loss_fn)

    def plain_grad(params, batch, key, sigma):
        del key, sigma
        loss, g = vg_fn(params, batch)
        return g, {"loss": loss, "grad_norm_preclip": jnp.sqrt(tree_sq_norm(g))}

    return plain_grad

"""Byzantine-robust aggregation + update attacks for the Eq.-7b boundary.

The paper's DP-PASGD trusts every device; the IoT-FL surveys it builds on
(Briggs et al. 2020, arXiv:2004.11794; Khan et al. 2021, arXiv:2009.13012)
list malicious participants as a core open challenge at the edge. This
module supplies the two halves of that threat model as plugins on the
:class:`repro.core.aggregation.AggregationPipeline` seam:

* **robust aggregators** — replace the participant mean of Eq. 7b with a
  reduction whose output a bounded fraction of corrupted updates cannot
  drag arbitrarily far:

  ``median``        coordinate-wise median of the participant updates
                    (Yin et al. 2018 coordinate-median GD).
  ``trimmed_mean``  coordinate-wise mean after dropping the
                    ``trim_fraction`` largest and smallest values per
                    coordinate (Yin et al. 2018).
  ``norm_bound``    reject whole updates whose L2 norm exceeds
                    ``factor x median participant norm``, mean of the
                    survivors (norm-outlier screening; the median norm
                    always survives, so the mean is never empty).

  ``mean`` (the default) keeps the exact existing pipeline expressions —
  a spec with ``aggregator="mean"`` never leaves the PR-3 code path.

* **update attacks** — the byzantine clients' upload corruption, applied
  at the server boundary to whatever the client would honestly have sent
  (after compression: a malicious device corrupts its wire bytes, not its
  own error-feedback bookkeeping):

  ``sign_flip``  send the negated update (gradient-ascent poisoning).
  ``scale``      send the update scaled by ``attack_scale`` (a boosted /
                 model-replacement style attack; a NEGATIVE scale is the
                 boosted sign-flip poison — the strongest of the three,
                 since it both inverts and amplifies the direction).

  The byzantine SET is static over a resident federation's lifetime —
  compromised devices stay compromised — drawn once per
  ``(seed, byzantine_fraction)`` with the repo's deterministic
  ``default_rng((seed, TAG))`` idiom. Label-flip (the data-level attack)
  binds to virtual client ids instead and lives in
  :func:`repro.population.attacks.malicious_population`.

Both plugin families are engine-agnostic: they consume the full (C, D)
participant-update view, which the shard_map engine materializes with one
``all_gather`` over the client mesh axis (only when a robust aggregator /
attack / secure sum is actually configured — the default paths keep their
psum-only collective schedule).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

AGGREGATORS = ("mean", "median", "trimmed_mean", "norm_bound")
ATTACKS = ("none", "sign_flip", "scale")

_BYZ_TAG = 0xB42A17


def validate_aggregator(name: str, trim_fraction: float = 0.1,
                        norm_bound_factor: float = 3.0) -> None:
    """Single source of the robust-aggregator knob invariants."""
    if name not in AGGREGATORS:
        raise ValueError(f"aggregator must be one of {AGGREGATORS}, "
                         f"got {name!r}")
    if not 0.0 <= trim_fraction < 0.5:
        raise ValueError(f"trim_fraction must be in [0, 0.5) (trimming half "
                         f"from each end leaves nothing), "
                         f"got {trim_fraction}")
    if norm_bound_factor <= 0.0:
        raise ValueError(f"norm_bound_factor must be positive, "
                         f"got {norm_bound_factor}")


def validate_attack(name: str, byzantine_fraction: float = 0.0,
                    attack_scale: float = 10.0) -> None:
    """Single source of the update-attack knob invariants."""
    if name not in ATTACKS:
        raise ValueError(f"attack must be one of {ATTACKS}, got {name!r}")
    if not 0.0 <= byzantine_fraction < 1.0:
        raise ValueError(f"byzantine_fraction must be in [0, 1) (a fully "
                         f"byzantine fleet has no signal to aggregate), "
                         f"got {byzantine_fraction}")
    if attack_scale == 0.0:
        raise ValueError(f"attack_scale must be nonzero (zero would silently "
                         f"drop the byzantine uploads instead of corrupting "
                         f"them; negative scales are the boosted sign-flip "
                         f"poison), got {attack_scale}")


# ---------------------------------------------------------------------------
# robust aggregators: (P, D) participant updates -> (D,) aggregate
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CoordinateMedian:
    """Coordinate-wise median of the participant updates."""

    def __call__(self, updates: jnp.ndarray) -> jnp.ndarray:
        return jnp.median(updates, axis=0)


@dataclass(frozen=True)
class TrimmedMean:
    """Coordinate-wise ``trim_fraction``-trimmed mean: per coordinate, sort
    the P participant values, drop ``floor(trim_fraction * P)`` from each
    end, average the rest."""
    trim_fraction: float

    def __call__(self, updates: jnp.ndarray) -> jnp.ndarray:
        p = updates.shape[0]
        k = int(self.trim_fraction * p)
        s = jnp.sort(updates, axis=0)
        return jnp.mean(s[k:p - k], axis=0)


@dataclass(frozen=True)
class NormBound:
    """Mean over the participants whose L2 norm is within ``factor`` times
    the median participant norm; norm outliers are rejected whole. The
    median-norm update always passes its own bound (factor >= 1 keeps at
    least half the cohort), so the denominator is never zero — it is
    additionally floored at one for pathological factors < 1."""
    factor: float

    def __call__(self, updates: jnp.ndarray) -> jnp.ndarray:
        norms = jnp.linalg.norm(updates, axis=1)
        keep = (norms <= self.factor * jnp.median(norms)).astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(keep), 1.0)
        return jnp.sum(keep[:, None] * updates, axis=0) / denom


def make_aggregator(name: str, trim_fraction: float = 0.1,
                    norm_bound_factor: float = 3.0):
    """Instantiate a robust aggregator by spec name; ``"mean"`` -> None
    (the pipeline's existing masked-mean expressions stay untouched)."""
    validate_aggregator(name, trim_fraction, norm_bound_factor)
    if name == "mean":
        return None
    if name == "median":
        return CoordinateMedian()
    if name == "trimmed_mean":
        return TrimmedMean(trim_fraction)
    return NormBound(norm_bound_factor)


def participant_rows(updates: jnp.ndarray, mask: jnp.ndarray,
                     n_participants: int) -> jnp.ndarray:
    """Gather the (P, D) participant block out of the full (C, D) update
    matrix under the 0/1 participation ``mask`` — shape-static (P is the
    spec's fixed per-round count), so robust reductions stay jit-stable
    under participation. The stable argsort keeps participants in client
    order; every shipped aggregator is permutation-invariant anyway (the
    property test of tests/test_robustness.py pins that)."""
    order = jnp.argsort(-mask, stable=True)
    return jnp.take(updates, order[:n_participants], axis=0)


# ---------------------------------------------------------------------------
# update attacks
# ---------------------------------------------------------------------------

def byzantine_flags(n_clients: int, byzantine_fraction: float,
                    seed: int = 0) -> tuple[int, ...]:
    """The static 0/1 byzantine membership of a resident federation:
    ``round(fraction * C)`` clients drawn without replacement from
    ``default_rng((seed, _BYZ_TAG))`` — deterministic per (seed, fraction),
    the repo's stateless-sampler idiom."""
    validate_attack("none", byzantine_fraction)
    n_byz = int(round(byzantine_fraction * n_clients))
    flags = np.zeros((n_clients,), np.int64)
    if n_byz > 0:
        rng = np.random.default_rng((seed, _BYZ_TAG))
        flags[rng.choice(n_clients, size=n_byz, replace=False)] = 1
    return tuple(int(f) for f in flags)


@dataclass(frozen=True)
class UpdateAttack:
    """Corrupt the flagged clients' uploads at the server boundary.

    ``flags`` is the static 0/1 byzantine membership over the C clients
    (see :func:`byzantine_flags`); honest rows pass through bit-unchanged
    (the corruption is a select, not an arithmetic no-op)."""
    attack: str                      # "sign_flip" | "scale"
    flags: tuple[int, ...]
    scale: float = 10.0

    def __call__(self, updates: jnp.ndarray) -> jnp.ndarray:
        sel = jnp.asarray(self.flags, jnp.float32)[:, None] > 0
        if self.attack == "sign_flip":
            return jnp.where(sel, -updates, updates)
        return jnp.where(sel, self.scale * updates, updates)


def make_attack(name: str, flags: tuple[int, ...],
                attack_scale: float = 10.0):
    """Instantiate an update attack by spec name; ``"none"`` (or an
    all-honest flag vector) -> None."""
    validate_attack(name, attack_scale=attack_scale)
    if name == "none" or not any(flags):
        return None
    return UpdateAttack(name, tuple(int(f) for f in flags), attack_scale)


def flip_labels(y: np.ndarray, n_classes: int) -> np.ndarray:
    """The label-flip data poison: class c -> n_classes - 1 - c (the
    standard targeted flip; an involution, so flipping twice restores the
    data). Used by :func:`repro.population.attacks.malicious_population`."""
    return (n_classes - 1 - np.asarray(y)).astype(np.asarray(y).dtype)

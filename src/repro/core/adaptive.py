"""Beyond-paper extension: adaptive re-solving of the DP-PASGD design.

The paper picks (K, tau, sigma) ONCE from constants estimated before
training (§8.1). Those estimates (alpha, xi^2, lambda) are exactly the
quantities a running federation observes — so re-solving the design problem
on the REMAINING budgets mid-run adapts tau as the loss landscape reveals
itself (cf. Wang & Joshi's adaptive communication, paper ref [33], but
driven by the paper's own Theorem-1 surrogate and privacy accounting).

Privacy correctness: the total zCDP of a run with per-phase noise sigma_i
over k_i steps is sum_i k_i * 2G^2/(X^2 sigma_i^2) (Lemma 1) — the
accountant tracks it exactly, and each re-solve budgets only the REMAINING
rho, so eps_th is never exceeded regardless of how often we re-plan.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace

import numpy as np

from repro.core.convergence import ProblemConstants
from repro.core.design import DesignProblem, DesignSolution, ResourceModel
from repro.core.privacy import rho_budget


@dataclass
class AdaptivePlan:
    solution: DesignSolution
    remaining_eps_equiv: float     # eps-budget equivalent of remaining rho
    remaining_c: float
    phase: int


class AdaptiveDesigner:
    """Re-solves the optimal design on remaining (resource, privacy) budget.

    Usage:
        designer = AdaptiveDesigner(problem)
        plan = designer.replan(fed.accountant, resource_spent, observed)
        -> run plan.solution.tau-sized rounds with plan.solution.sigmas
    """

    def __init__(self, problem: DesignProblem):
        self.problem = problem
        self.phase = 0

    def _remaining_eps(self, accountant) -> float:
        """Convert remaining rho budget back to an eps budget (invert
        Lemma 3 on the unspent part)."""
        delta = self.problem.delta
        rho_total = rho_budget(self.problem.eps_th, delta)
        rho_spent = max((accountant.rho(m) for m in accountant.batch_sizes),
                        default=0.0)
        left = max(rho_total - rho_spent, 0.0)
        ld = math.log(1.0 / delta)
        return left + 2.0 * math.sqrt(left * ld)

    def replan(self, accountant, resource_spent: float,
               observed: dict | None = None) -> AdaptivePlan:
        """observed may update {"alpha": current loss gap, "xi2": ..., "lam": ...}."""
        consts = self.problem.consts
        if observed:
            consts = ProblemConstants(
                eta=consts.eta,
                lam=float(observed.get("lam", consts.lam)),
                lip=float(observed.get("lip", consts.lip)),
                alpha=float(observed.get("alpha", consts.alpha)),
                xi2=float(observed.get("xi2", consts.xi2)),
                dim=consts.dim, n_clients=consts.n_clients)
        eps_left = self._remaining_eps(accountant)
        c_left = max(self.problem.c_th - resource_spent, 0.0)
        sub = replace(self.problem, consts=consts, eps_th=max(eps_left, 1e-6),
                      c_th=max(c_left, 1.0))
        sol = sub.solve()
        self.phase += 1
        return AdaptivePlan(solution=sol, remaining_eps_equiv=eps_left,
                            remaining_c=c_left, phase=self.phase)

"""Convergence analysis of DP-PASGD (paper §6, Theorem 1).

Theorem 1: under L-smoothness, lambda-strong convexity, unbiased gradients
with variance bound xi^2, learning rate satisfying
    eta L + eta^2 L^2 tau (tau - 1) <= 1,
after K iterations (K divisible by tau):

    E[ L(theta*) - L* ] <= (1 - eta lam)^K (alpha - B) / K + B

with  B = [eta L + eta^2 L^2 (tau - 1) M] / (2 lam M) * (xi^2 + d/M sum_m sigma_m^2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence


@dataclass(frozen=True)
class ProblemConstants:
    """Estimated problem constants (paper §8.1 estimates these beforehand)."""
    eta: float       # learning rate
    lam: float       # strong-convexity constant lambda
    lip: float       # gradient-Lipschitz constant L
    alpha: float     # initial optimality gap L(theta^0) - L*
    xi2: float       # mini-batch gradient variance bound xi^2
    dim: int         # model dimension d
    n_clients: int   # M

    def lr_constraint_ok(self, tau: float) -> bool:
        """Eq. (21e): eta L + eta^2 L^2 tau(tau-1) <= 1."""
        e, L = self.eta, self.lip
        return e * L + e * e * L * L * tau * (tau - 1.0) <= 1.0 + 1e-12

    def tau_max(self) -> float:
        """Largest tau satisfying Eq. (21e)."""
        e, L = self.eta, self.lip
        a = e * e * L * L
        if a == 0:
            return math.inf
        c = e * L - 1.0
        # a tau^2 - a tau + c <= 0  ->  tau <= (a + sqrt(a^2 - 4 a c)) / (2a)
        disc = a * a - 4.0 * a * c
        if disc < 0:
            return 1.0
        return (a + math.sqrt(disc)) / (2.0 * a)


def noise_term(consts: ProblemConstants, sigmas2: Sequence[float]) -> float:
    """xi^2 + (d / M) * sum_m sigma_m^2   (the variance payload of B)."""
    return consts.xi2 + consts.dim / consts.n_clients * float(sum(sigmas2))


def bound_b(consts: ProblemConstants, tau: float, sigmas2: Sequence[float]) -> float:
    """Eq. (13): the asymptotic error floor B."""
    e, L, lam, M = consts.eta, consts.lip, consts.lam, consts.n_clients
    pref = (e * L + e * e * L * L * (tau - 1.0) * M) / (2.0 * lam * M)
    return pref * noise_term(consts, sigmas2)


def theorem1_bound(consts: ProblemConstants, k: int, tau: float,
                   sigmas2: Sequence[float]) -> float:
    """Eq. (12): expected optimality gap after K iterations."""
    if k < 1:
        raise ValueError("K must be >= 1")
    b = bound_b(consts, tau, sigmas2)
    decay = (1.0 - consts.eta * consts.lam) ** k
    return decay * (consts.alpha - b) / k + b


def reduces_to_distributed_sgd(consts: ProblemConstants, k: int) -> float:
    """Sanity helper: tau=1, sigma=0 recovers the distributed-SGD bound."""
    return theorem1_bound(consts, k, tau=1.0, sigmas2=[0.0] * consts.n_clients)

"""Pairwise-mask secure-aggregation simulation (Bonawitz et al. 2017 style).

DP-PASGD's honest-but-curious server sees every client's individual noisy
update; the IoT-FL reviews (Briggs et al. 2020, arXiv:2004.11794) pair
local DP with *secure aggregation* so the server only ever materializes
the cohort SUM. This module simulates the arithmetic core of the pairwise
masking protocol, in both a host-level (vid-addressed, numpy) form and a
jit-traceable form plugged into the aggregation pipeline:

* updates are encoded to **fixed point** (``round(x * 2^frac_bits)``,
  arithmetic modulo 2^32) — modular integer arithmetic is what makes mask
  cancellation EXACT rather than float-approximate;
* every ordered client pair (i, j) shares a per-round mask
  ``m_ij = -m_ji (mod 2^32)`` derived deterministically from
  ``(seed, vid_i, vid_j, round_idx)`` (the repo's stateless
  ``default_rng((seed, TAG, ...))`` idiom — a stand-in for the
  Diffie-Hellman-agreed PRG seeds of the real protocol);
* client i uploads ``enc(x_i) + sum_j m_ij`` — individually
  uniform-random garbage to the server — and the masks telescope away in
  the cohort sum;
* **dropout recovery**: when clients drop mid-round (the PR-5
  ``HeterogeneousCohort`` unreliability model), the survivors' uploads
  still carry their masks against the dropped; the server reconstructs
  exactly those pair masks (``dropout_correction`` — in the real protocol
  via the survivors' secret shares of the dropped clients' seeds) and
  subtracts them, recovering the exact survivor sum.

Exactness caveat: decoding is exact while the true survivor sum stays in
``[-2^31, 2^31) / 2^frac_bits`` per coordinate — at the default 16
fractional bits that is a per-coordinate sum magnitude of 32768, far
beyond any clipped-update cohort this repo runs. Quantization (the one
lossy step, bounded by ``0.5 / 2^frac_bits`` per client per coordinate)
happens at ENCODE time; masking and dropout recovery add zero error on
top — ``masked == unmasked`` holds bit-for-bit in the integer domain,
which is the identity the tests pin.

Privacy accounting: with secure aggregation the honest-but-curious server
observes only the masked SUM, whose noise is the P participants' pooled
Gaussian noise — see :func:`central_rho_scale` for the central-DP
accounting mode (``FederationSpec(dp_accounting="central")``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

_SECAGG_TAG = 0x5ECA66
MODULUS = 2 ** 32


def validate_secure(frac_bits: int) -> None:
    """Single source of the secure-aggregation knob invariants."""
    if not 1 <= frac_bits <= 24:
        raise ValueError(f"secure_frac_bits must be in [1, 24] (above 24 "
                         f"a single encoded unit-scale update can overflow "
                         f"the 2^32 field), got {frac_bits}")


# ---------------------------------------------------------------------------
# fixed-point codec (numpy, host side)
# ---------------------------------------------------------------------------

def fp_encode(x, frac_bits: int = 16) -> np.ndarray:
    """float -> field element: ``round(x * 2^frac_bits) mod 2^32`` (uint32)."""
    q = np.round(np.asarray(x, np.float64) * (1 << frac_bits)).astype(np.int64)
    return (q % MODULUS).astype(np.uint32)


def fp_decode(u, frac_bits: int = 16) -> np.ndarray:
    """field element -> float, interpreting the upper half as negatives."""
    v = np.asarray(u, np.int64)
    v = np.where(v >= MODULUS // 2, v - MODULUS, v)
    return v / float(1 << frac_bits)


def _mod_sum(terms) -> np.ndarray:
    total = None
    for t in terms:
        t = np.asarray(t, np.int64)
        total = t if total is None else (total + t) % MODULUS
    return total.astype(np.uint32)


# ---------------------------------------------------------------------------
# host-level protocol (vid-addressed; composes with population cohorts)
# ---------------------------------------------------------------------------

def pairwise_mask(seed: int, vid_i: int, vid_j: int, round_idx: int,
                  dim: int) -> np.ndarray:
    """The (dim,) uint32 mask client ``vid_i`` ADDS for its pair with
    ``vid_j`` this round. Derived from the unordered pair
    ``default_rng((seed, TAG, lo, hi, round_idx))`` and signed by the
    ordering, so ``pairwise_mask(i, j) + pairwise_mask(j, i) == 0 (mod
    2^32)`` — the cancellation the whole protocol rests on."""
    if vid_i == vid_j:
        raise ValueError(f"a client ({vid_i}) shares no mask with itself")
    lo, hi = (vid_i, vid_j) if vid_i < vid_j else (vid_j, vid_i)
    rng = np.random.default_rng((seed, _SECAGG_TAG, lo, hi, round_idx))
    m = rng.integers(0, MODULUS, size=dim, dtype=np.uint64).astype(np.uint32)
    if vid_i == lo:
        return m
    return ((MODULUS - m.astype(np.int64)) % MODULUS).astype(np.uint32)


def masked_update(update, vid: int, cohort: Iterable[int], seed: int,
                  round_idx: int, frac_bits: int = 16) -> np.ndarray:
    """What client ``vid`` uploads: its fixed-point update plus its pair
    masks against every OTHER cohort member — marginally uniform on the
    field, so the server learns nothing from it alone."""
    validate_secure(frac_bits)
    dim = np.asarray(update).shape[-1]
    terms = [fp_encode(update, frac_bits)]
    terms += [pairwise_mask(seed, vid, int(j), round_idx, dim)
              for j in cohort if int(j) != vid]
    return _mod_sum(terms)


def dropout_correction(survivors: Iterable[int], dropped: Iterable[int],
                       seed: int, round_idx: int, dim: int) -> np.ndarray:
    """The mask residue the dropped clients leave in the survivor sum:
    ``sum_{i in survivors, j in dropped} m_ij (mod 2^32)`` — exactly what
    the real protocol reconstructs from the survivors' secret shares of
    the dropped clients' mask seeds. Zero when nothing dropped."""
    terms = [np.zeros((dim,), np.uint32)]
    for i in survivors:
        for j in dropped:
            terms.append(pairwise_mask(seed, int(i), int(j), round_idx, dim))
    return _mod_sum(terms)


def secure_aggregate(updates: Mapping[int, np.ndarray],
                     cohort: Iterable[int], seed: int, round_idx: int,
                     dropped: Iterable[int] = (),
                     frac_bits: int = 16) -> np.ndarray:
    """The server's view of one secure-aggregation round: sum the
    survivors' masked uploads, subtract the reconstructed dropped-pair
    masks, decode. Returns the (dim,) float survivor-update sum — equal,
    bit-for-bit in the integer domain, to summing the survivors' plain
    fixed-point encodings (:func:`unmasked_fixed_point_sum`)."""
    cohort = [int(v) for v in cohort]
    dropped = {int(v) for v in dropped}
    if not set(dropped) <= set(cohort):
        raise ValueError(f"dropped clients {sorted(dropped)} must be cohort "
                         f"members {cohort}")
    survivors = [v for v in cohort if v not in dropped]
    if not survivors:
        raise ValueError("every cohort member dropped: nothing to aggregate")
    uploads = [masked_update(updates[v], v, cohort, seed, round_idx,
                             frac_bits) for v in survivors]
    dim = uploads[0].shape[-1]
    total = _mod_sum(uploads)
    corr = dropout_correction(survivors, dropped, seed, round_idx, dim)
    total = ((total.astype(np.int64) - corr.astype(np.int64)) % MODULUS)
    return fp_decode(total.astype(np.uint32), frac_bits)


def unmasked_fixed_point_sum(updates: Mapping[int, np.ndarray],
                             survivors: Iterable[int],
                             frac_bits: int = 16) -> np.ndarray:
    """The reference the masked protocol must reproduce exactly: the plain
    modular sum of the survivors' fixed-point encodings, decoded."""
    total = _mod_sum(fp_encode(updates[int(v)], frac_bits)
                     for v in survivors)
    return fp_decode(total, frac_bits)


# ---------------------------------------------------------------------------
# central-DP accounting of the masked sum
# ---------------------------------------------------------------------------

def central_rho_scale(n_participants: int) -> float:
    """zCDP scale factor of the central (aggregate-observer) accounting
    mode: the masked sum pools P independent per-client Gaussian noises,
    so against an observer who only sees the sum, each client's release
    carries an effective noise multiplier ``sqrt(P) * sigma`` — rho is
    quadratic in 1/sigma (Lemma 2), hence the per-step charge scales by
    ``1/P`` (distributed-DP aggregation amplification, cf. the
    distributed-Gaussian treatments in Kairouz et al. 2021).

    Deliberate modeling caveats (mirror ``subsampled_rho``'s style): the
    bound holds against the AGGREGATE observer only — a client's own
    local view keeps the full Lemma-2 cost; and it credits every
    participant's noise as honest, so it composes with the byzantine
    threat model of :mod:`repro.core.robust` only insofar as byzantine
    clients still add their noise. The sound local default
    (``dp_accounting="local"``) is unaffected by secure aggregation."""
    if n_participants < 1:
        raise ValueError(f"n_participants must be >= 1, "
                         f"got {n_participants}")
    return 1.0 / n_participants


# ---------------------------------------------------------------------------
# jit-traceable masked sum (the AggregationPipeline plugin)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SecureMaskedSum:
    """The in-engine twin of the host protocol: same fixed-point field,
    same antisymmetric pair masks and dropout recovery, with masks drawn
    from the round's PRNG stream (``fold_in`` of the per-round aggregation
    key) instead of vid-addressed host RNG — the engines have no round
    index operand, and the carried key already advances per round. The
    non-participants of the round's mask ARE the dropped set: their pair
    masks are reconstructed and subtracted, exercising the recovery path
    every partial-participation round.

    Static under jit (one instance per FederationSpec); O(C^2 * D) mask
    material per round — the cohort sizes this repo runs (K <= a few
    hundred) keep that far below the batch itself."""
    n_clients: int
    frac_bits: int = 16

    def __post_init__(self):
        validate_secure(self.frac_bits)

    def masked_mean(self, updates: jnp.ndarray, mask: jnp.ndarray,
                    base_key: jax.Array) -> jnp.ndarray:
        """(C, D) updates + 0/1 (C,) participation -> the (D,) participant
        MEAN, computed through the masked modular sum. uint32 end to end:
        jnp reductions keep the input dtype, so every sum wraps mod 2^32
        exactly like the host protocol."""
        c, d = self.n_clients, updates.shape[1]
        scale = float(1 << self.frac_bits)
        enc = jnp.round(updates.astype(jnp.float32) * scale).astype(
            jnp.int32).astype(jnp.uint32)
        key = jax.random.fold_in(base_key, _SECAGG_TAG)
        ii, jj = np.triu_indices(c, k=1)
        if len(ii):
            pair_ids = jnp.asarray(ii * c + jj, jnp.uint32)
            bits = jax.vmap(lambda pid: jax.random.bits(
                jax.random.fold_in(key, pid), (d,), jnp.uint32))(pair_ids)
            masks = jnp.zeros((c, c, d), jnp.uint32)
            masks = masks.at[ii, jj].set(bits)
            masks = masks.at[jj, ii].set(jnp.zeros_like(bits) - bits)
        else:
            masks = jnp.zeros((c, c, d), jnp.uint32)
        uploads = enc + jnp.sum(masks, axis=1)          # each client's view
        part = mask > 0
        server = jnp.sum(jnp.where(part[:, None], uploads, jnp.uint32(0)),
                         axis=0)
        # dropout recovery: reconstruct the (survivor, dropped) pair masks
        left = part[:, None] & ~part[None, :]
        corr = jnp.sum(jnp.where(left[:, :, None], masks, jnp.uint32(0)),
                       axis=(0, 1))
        total = server - corr
        signed = total.astype(jnp.int32).astype(jnp.float32) / scale
        return signed / jnp.sum(mask)

"""Pluggable aggregation pipeline for the Eq.-7b round boundary.

The seed protocol hard-codes "all clients ship a dense fp32 update each
aggregation". This module makes that one point pluggable along the two big
communication levers of the IoT-FL literature (Briggs et al. 2020,
arXiv:2004.11794; Imteaj et al. 2020, arXiv:2002.10610):

* **partial participation** — only a per-round sampled subset of clients
  uploads (and conceptually trains); the server averages over participants
  and re-broadcasts, and the non-participants' local work is discarded so
  they never spend privacy;
* **compressed communication** — each participant's model *update*
  (delta from the round-start global model) is pushed through a lossy
  :class:`Compressor` before averaging. The part the compressor dropped is
  carried in a per-client **error-feedback residual** (Seide et al. 2014 /
  Karimireddy et al. 2019 EF-SGD) that is added back to the next update the
  client sends, so the compression error stays bounded instead of
  accumulating. The residual is federation state: it lives on
  :class:`repro.api.FLState` and round-trips through checkpoints.

Three compressors ship by default (plus ``"none"``):

``topk``   keep the ``ratio * d`` largest-|coordinate| entries of the update.
``randk``  keep ``ratio * d`` uniformly sampled coordinates (unscaled; the
           error-feedback residual corrects the bias).
``qsgd``   QSGD-style stochastic uniform quantization to ``bits`` bits per
           coordinate (Alistarh et al. 2017), routed through the fused
           ``quantize_decompress`` kernel of :mod:`repro.kernels.dispatch`.

Everything here simulates the wire losslessly in dense arrays — compress
and decompress happen back-to-back — so the engines stay pure pytree maps;
the *accounting* of what the wire would have carried is
``FederationSpec.comm_scale()`` (Eq. 8 charges ``c1 * wire_ratio * q`` per
aggregation).

The pipeline is engine-agnostic: :meth:`AggregationPipeline.aggregate`
reduces over whatever client block it is handed plus an ``all_sum``
closure — the identity for the full-view GSPMD engines, ``lax.psum`` over
the ``client`` mesh axis inside the shard_map engine — so vmap / map /
shard_map share one implementation of the boundary.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Protocol

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_broadcast_axis0

COMPRESSORS = ("none", "topk", "randk", "qsgd")


# ---------------------------------------------------------------------------
# flat <-> pytree plumbing (compressors act on one flat update vector)
# ---------------------------------------------------------------------------

def flatten_tree(tree) -> jax.Array:
    """Concatenate every leaf of a (single-client) pytree into one f32 (D,)."""
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])


def unflatten_like(flat: jax.Array, tree):
    """Inverse of :func:`flatten_tree` given the structure donor ``tree``."""
    leaves, treedef = jax.tree.flatten(tree)
    out, off = [], 0
    for x in leaves:
        out.append(flat[off:off + x.size].reshape(x.shape).astype(x.dtype))
        off += x.size
    return jax.tree.unflatten(treedef, out)


def tree_dim(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# compressors
# ---------------------------------------------------------------------------

class Compressor(Protocol):
    """Lossy update codec: flat f32 (D,) -> its dense decompressed image.

    ``__call__(flat, key)`` must be jit/vmap-traceable; ``key`` feeds any
    sampling the codec does (coordinate choice, stochastic rounding).
    ``wire_ratio`` is the fraction of the dense fp32 bytes the compressed
    form would occupy on the wire (index overhead ignored).
    """

    def __call__(self, flat: jax.Array, key: jax.Array) -> jax.Array: ...

    def wire_ratio(self) -> float: ...


def validate_compression(name: str, ratio: float = 0.1,
                         bits: int = 8) -> None:
    """Single source of the compressor-knob invariants (spec + factory)."""
    if name not in COMPRESSORS:
        raise ValueError(f"compressor must be one of {COMPRESSORS}, "
                         f"got {name!r}")
    if not 0.0 < ratio <= 1.0:
        raise ValueError(f"compression_ratio must be in (0, 1], got {ratio}")
    if not 1 <= bits <= 16:
        raise ValueError(f"compression_bits must be in [1, 16], got {bits}")


def compression_wire_ratio(name: str, ratio: float = 0.1,
                           bits: int = 8) -> float:
    """Compressed-update bytes as a fraction of the dense fp32 update
    (topk/randk: kept-coordinate fraction, index overhead ignored;
    qsgd: bits/32). The one place the wire math lives — the Compressor
    classes and FederationSpec.wire_ratio() both delegate here."""
    validate_compression(name, ratio, bits)
    if name in ("topk", "randk"):
        return ratio
    if name == "qsgd":
        return bits / 32.0
    return 1.0


def _keep_k(ratio: float, d: int) -> int:
    return max(1, min(d, int(round(ratio * d))))


@dataclass(frozen=True)
class TopK:
    """Keep the ``ratio * d`` largest-magnitude coordinates."""
    ratio: float

    def __call__(self, flat, key):
        del key
        k = _keep_k(self.ratio, flat.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        return jnp.zeros_like(flat).at[idx].set(flat[idx])

    def wire_ratio(self) -> float:
        return compression_wire_ratio("topk", ratio=self.ratio)


@dataclass(frozen=True)
class RandK:
    """Keep ``ratio * d`` uniformly sampled coordinates (fresh each round).

    Deliberately unscaled: the classic unbiased d/k rescaling explodes the
    variance at small k, while under error feedback the residual re-sends
    whatever mass the sampling dropped, so the biased form converges
    (Karimireddy et al. 2019, Thm. 2 applies to any delta-contraction).
    """
    ratio: float

    def __call__(self, flat, key):
        d = flat.shape[0]
        k = _keep_k(self.ratio, d)
        idx = jax.random.permutation(key, d)[:k]
        return jnp.zeros_like(flat).at[idx].set(flat[idx])

    def wire_ratio(self) -> float:
        return compression_wire_ratio("randk", ratio=self.ratio)


@dataclass(frozen=True)
class QSGD:
    """QSGD-style stochastic uniform quantization to ``bits`` bits/coord.

    The round trip (quantize -> wire -> dequantize) is fused into the
    ``quantize_decompress`` kernel; randomness for the stochastic rounding
    is drawn from ``key`` so the codec stays deterministic per round key
    and oracle-checkable across kernel backends.
    """
    bits: int
    kernel_backend: str = "auto"

    def __call__(self, flat, key):
        from repro.kernels.ops import quantize_decompress_flat
        u = jax.random.uniform(key, flat.shape, jnp.float32)
        y, _ = quantize_decompress_flat(flat, u, self.bits,
                                        backend=self.kernel_backend)
        return y

    def wire_ratio(self) -> float:
        return compression_wire_ratio("qsgd", bits=self.bits)


def make_compressor(name: str, ratio: float = 0.1, bits: int = 8,
                    kernel_backend: str = "auto") -> Compressor | None:
    """Instantiate a compressor by spec name; ``"none"`` -> None."""
    validate_compression(name, ratio, bits)
    if name == "none":
        return None
    if name == "topk":
        return TopK(ratio)
    if name == "randk":
        return RandK(ratio)
    from repro.kernels.dispatch import resolve_backend
    # resolve (and capability-probe) eagerly: pipelines are built outside
    # the traced round, where the probe can actually run
    return QSGD(bits, resolve_backend("quantize_decompress", kernel_backend))


# ---------------------------------------------------------------------------
# participation
# ---------------------------------------------------------------------------

def participation_mask(key: jax.Array, n_clients: int,
                       n_participants: int) -> jax.Array:
    """0/1 f32 (C,) mask with exactly ``n_participants`` ones, uniformly
    sampled without replacement. Fixed-size (not Poisson) sampling keeps the
    aggregation denominator static and the round jit-shape stable."""
    idx = jax.random.permutation(key, n_clients)[:n_participants]
    return jnp.zeros((n_clients,), jnp.float32).at[idx].set(1.0)


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------

def _identity(x):
    return x


@dataclass(frozen=True)
class AggregationPipeline:
    """The Eq.-7b round boundary with participation masking, compression,
    and error feedback. One instance per FederationSpec (static under jit).

    The adversarial extensions (PR 7) plug in as three optional fields,
    every one of which defaults to "off" and leaves the PR-3 expressions
    byte-identical: ``aggregator`` (a :mod:`repro.core.robust` reduction
    replacing the participant mean), ``secure`` (the
    :class:`repro.core.secureagg.SecureMaskedSum` masked modular sum), and
    ``attack`` (the byzantine upload corruption, applied at the server
    boundary to whatever the clients would honestly have sent). They are
    full-view reductions: under shard_map the per-shard blocks are first
    ``all_gather``-ed (only on these paths — the default protocol keeps
    its psum-only schedule).
    """
    n_clients: int
    compressor: Compressor | None       # None -> dense updates
    average_opt_state: bool = True
    aggregator: Any = None              # robust (P, D) -> (D,) reduction
    secure: Any = None                  # SecureMaskedSum | None
    attack: Any = None                  # UpdateAttack | None
    n_participants: int | None = None   # static P (robust row gather)

    def needs_residual(self) -> bool:
        return self.compressor is not None

    def init_residual(self, params0) -> jax.Array | None:
        """(C, D) zero error-feedback residual — new FLState pytree field.
        ``params0`` is the single-replica init (no client axis)."""
        if not self.needs_residual():
            return None
        return jnp.zeros((self.n_clients, tree_dim(params0)), jnp.float32)

    def aggregate(self, prev_params, new_params, new_opt_state, prev_opt_state,
                  residual, mask, agg_keys,
                  all_sum: Callable[[Any], Any] = _identity,
                  all_gather: Callable[[Any], Any] = _identity):
        """Replace the dense mean of Eq. 7b for one client block.

        prev/new params and opt_state: stacked pytrees, leading axis = the
        local block size B (== n_clients on the GSPMD engines, the per-shard
        block under shard_map). ``residual`` is (B, D) or None; ``mask`` is
        the 0/1 (B,) participation slice; ``agg_keys`` are per-client PRNG
        keys (B, ...). ``all_sum`` closes the cross-shard reduction;
        ``all_gather`` (identity on the full-view engines) concatenates the
        per-shard blocks into the global (C, ...) view, consulted ONLY by
        the adversarial extensions — attacks and robust/secure reductions
        need the whole cohort, not block partial sums.

        Returns ``(params, opt_state, residual)``: every participant's
        (compressed, error-fed) update is averaged into the global model
        and the global model re-broadcast over the block. Non-participants'
        residual is left untouched; their optimizer state is kept when
        ``average_opt_state=False`` and — like every client's — overwritten
        with the participants' average when True (the Eq.-7b default,
        which deliberately syncs optimizer history with the model). Robust
        and secure reductions apply to the MODEL update only; optimizer
        state keeps the masked-mean/keep semantics (a caveat the ROADMAP
        table records — pair robust aggregation with stateless SGD or
        ``average_opt_state=False`` against stateful poisoning).
        """
        block = mask.shape[0]
        denom = all_sum(jnp.sum(mask))                      # >= 1 by spec

        def _masked_mean_bcast(new):
            m = mask.reshape((-1,) + (1,) * (new.ndim - 1))
            s = all_sum(jnp.sum(m * new.astype(jnp.float32), axis=0))
            avg = (s / denom).astype(new.dtype)
            return jnp.broadcast_to(avg[None], new.shape)

        adversarial = (self.aggregator is not None or self.secure is not None
                       or self.attack is not None)
        if self.compressor is not None or adversarial:
            flat_prev = jax.vmap(flatten_tree)(prev_params)     # (B, D)
            flat_new = jax.vmap(flatten_tree)(new_params)
            if self.compressor is not None:
                corrected = (flat_new - flat_prev) + residual
                sent = jax.vmap(self.compressor)(corrected, agg_keys)
                sel = mask[:, None]
                residual = sel * (corrected - sent) + (1.0 - sel) * residual
            else:
                sent = flat_new - flat_prev
            if adversarial:
                g_sent = all_gather(sent)                   # (C, D)
                g_mask = all_gather(mask)                   # (C,)
                if self.attack is not None:
                    # byzantine clients corrupt their wire bytes, not their
                    # own error-feedback bookkeeping (residual stays honest)
                    g_sent = self.attack(g_sent)
                if self.secure is not None:
                    avg_delta = self.secure.masked_mean(
                        g_sent, g_mask, all_gather(agg_keys)[0])
                elif self.aggregator is not None:
                    from repro.core.robust import participant_rows
                    rows = participant_rows(g_sent, g_mask,
                                            self.n_participants)
                    avg_delta = self.aggregator(rows)
                else:
                    avg_delta = (jnp.sum(g_mask[:, None] * g_sent, axis=0)
                                 / jnp.sum(g_mask))
            else:
                avg_delta = all_sum(jnp.sum(sel * sent, axis=0)) / denom
            # prev params are globally synchronized (full_average every
            # round), so any replica anchors the new global model
            single_prev = jax.tree.map(lambda x: x[0], prev_params)
            new_global = unflatten_like(flat_prev[0] + avg_delta, single_prev)
            params = tree_broadcast_axis0(new_global, block)
        else:
            # dense updates against a synchronized global model: the masked
            # mean of the participants' new replicas IS the new global —
            # stay in pytree space, no (B, D) flatten copies
            params = jax.tree.map(_masked_mean_bcast, new_params)

        if self.average_opt_state:
            opt_state = jax.tree.map(_masked_mean_bcast, new_opt_state)
        else:
            # non-participants did not really train: keep their old state
            def _mask_leaf(new, old):
                m = mask.reshape((-1,) + (1,) * (new.ndim - 1))
                return (m * new.astype(jnp.float32)
                        + (1.0 - m) * old.astype(jnp.float32)).astype(new.dtype)
            opt_state = jax.tree.map(_mask_leaf, new_opt_state, prev_opt_state)
        return params, opt_state, residual

    def masked_metrics(self, metrics, mask,
                       all_sum: Callable[[Any], Any] = _identity):
        """Mean of per-client metric leaves (B,) over the participants only
        (non-participants' local work is discarded, so is their loss)."""
        denom = all_sum(jnp.sum(mask))
        return jax.tree.map(lambda x: all_sum(jnp.sum(mask * x)) / denom,
                            metrics)

"""DP-PASGD round engine (paper Eq. 7a–7b) as a composable JAX module.

One *round* = tau local noisy-SGD steps on each of C clients (zero
cross-client communication, expressed as a ``lax.scan``) followed by one
global model average over the client axis (Eq. 7b) — the single cross-client
collective per round. Params carry a leading client axis C on every leaf; on
a device mesh that axis is sharded over the derived ``client`` mesh axis, so
GSPMD lowers the round-boundary mean to exactly one all-reduce over the
client (and pod) axis.

The engine is generic over the model: it only needs ``loss_fn(params, batch)``.
The fully-local ablation (no averaging) is the same builder with
``topology="local_only"``; the explicit-collective variant lives in
``core/fl_shard_map.py``.

This module is the low-level building block. **New code should go through
``repro.api``** — a declarative :class:`repro.api.FederationSpec` selects
between this builder (engines ``"vmap"``/``"map"``), the shard_map variant
(engine ``"shard_map"``), and the topology, and the pure-functional
``init_state``/``run_round`` drive training. The mutable
:class:`repro.api.Federation` (re-exported here for back-compat) is a thin
wrapper over that functional core.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clipping import make_dp_grad_fn, make_plain_grad_fn
from repro.core.privacy import sigma_star
from repro.optim.optimizers import Optimizer
from repro.utils.tree import (
    tree_add,
    tree_broadcast_axis0,
    tree_mean_over_axis0,
)

TOPOLOGIES = ("full_average", "local_only")


@dataclass(frozen=True)
class FLConfig:
    """Configuration of one DP-PASGD federation."""
    n_clients: int
    tau: int                      # global aggregation period (local steps/round)
    clip_norm: float = 1.0        # G (sensitivity bound)
    dp: bool = True               # False -> PASGD (no noise, no clipping)
    num_microbatches: int = 1     # see clipping.py; =local batch -> per-example
    vmap_microbatches: bool = True
    grad_accumulate: str = "stack"  # "stack" (baseline) | "scan" (§Perf opt)
    average_opt_state: bool = True  # average optimizer state with the models
    vmap_clients: bool = True     # False -> lax.map (sequential clients; CPU sims)
    kernel_backend: str | None = None  # clip+noise via kernels.dispatch
    #   None -> legacy pure-jnp path; "pallas"/"interpret"/"ref"/"auto" ->
    #   the fused dp_clip_noise kernel on that backend (repro.api default)


def make_grad_fn(loss_fn: Callable, cfg: FLConfig) -> Callable:
    """The per-step gradient: DP (clip + noise, Eq. 7a) or plain."""
    if cfg.dp:
        return make_dp_grad_fn(loss_fn, cfg.clip_norm, cfg.num_microbatches,
                               cfg.vmap_microbatches, cfg.grad_accumulate,
                               kernel_backend=cfg.kernel_backend)
    return make_plain_grad_fn(loss_fn)


def make_local_round(grad_fn: Callable, optimizer: Optimizer, tau: int,
                     unroll: int | bool = 1):
    """tau local DP-SGD steps of ONE client (Eq. 7a). No collectives.

    Returns ``local_round(params, opt_state, batches, key, sigma)`` ->
    ``(params, opt_state, metrics)`` with metrics averaged over the tau steps.
    Shared by the GSPMD/vmap engines here and the shard_map engines.

    ``unroll`` passes through to the tau scan — numerics are identical at
    any value. The mesh_2d engine builds with ``unroll=True`` (fully
    unrolled): on current jax/XLA the threefry custom partitioner aborts
    when RNG sits inside a while loop inside a partial-manual shard_map
    region (``Check failed: sharding.IsManualSubgroup()``), and unrolling
    removes the while loop without touching the values.
    """
    def local_round(params, opt_state, batches, key, sigma):
        keys = jax.random.split(key, tau)

        def step(carry, inp):
            p, s = carry
            mb, k = inp
            g, metrics = grad_fn(p, mb, k, sigma)
            upd, s = optimizer.update(g, s, p)
            return (tree_add(p, upd), s), metrics

        (params, opt_state), ms = jax.lax.scan(step, (params, opt_state),
                                               (batches, keys),
                                               unroll=unroll)
        return params, opt_state, jax.tree.map(jnp.mean, ms)

    return local_round


def tree_valid_mean_axis0(tree, valid, denom, all_sum=lambda x: x):
    """Mean over axis 0 of every leaf, weighted by the 0/1 ``valid`` vector
    and normalized by the (possibly cross-shard) ``denom`` count.

    The padded-client Eq.-7b boundary of the mesh_2d engine (repro.mesh):
    when C clients do not divide the client-block mesh axis, blocks are
    padded to Cp rows and pad rows carry ``valid = 0`` — this weighted form
    with ``denom = all_sum(sum(valid))`` reproduces the exact mean over the
    C real clients. Sums run in f32 and cast back per leaf (int leaves such
    as optimizer step counters round-trip exactly — weighted means of
    identical integers are integral). ``all_sum`` closes the cross-shard
    reduction, ``lax.psum`` over the client axis under shard_map."""
    def one(x):
        v = valid.reshape((-1,) + (1,) * (x.ndim - 1))
        s = all_sum(jnp.sum(v * x.astype(jnp.float32), axis=0))
        return (s / denom).astype(x.dtype)

    return jax.tree.map(one, tree)


def pipeline_round_keys(key, n_clients: int):
    """The per-round PRNG schedule of the pipeline engines: one local-round
    key and one aggregation (compressor) key per client, derived from a
    single round key. Shared by the GSPMD and shard_map builders so their
    key/compressor streams stay bit-identical and parity-testable."""
    key, agg_key = jax.random.split(key)
    keys = jax.random.split(key, n_clients)
    agg_keys = jax.random.split(agg_key, n_clients)
    return keys, agg_keys


def make_round_step(loss_fn: Callable, optimizer: Optimizer, cfg: FLConfig,
                    topology: str = "full_average", pipeline=None):
    """Build ``round_step(params, opt_state, batch, key, sigmas)``.

    params/opt_state : pytrees with leading client axis C on every leaf
    batch            : pytree with leading axes (C, tau, local_batch, ...)
    sigmas           : (C,) per-client per-step noise std (traced; Eq. 23)
    topology         : "full_average" (Eq. 7b averaging each round) or
                       "local_only" (ablation: fully-local training, no
                       cross-client communication ever)
    pipeline         : optional :class:`repro.core.aggregation
                       .AggregationPipeline`. ``None`` (the default) keeps
                       this builder bit-for-bit the seed protocol; with a
                       pipeline the returned function takes two extra
                       operands and threads the error-feedback residual:
                       ``round_step(params, opt_state, batch, key, sigmas,
                       mask, residual) -> (new_p, new_s, new_residual,
                       metrics)`` where ``mask`` is the 0/1 (C,)
                       participation mask sampled by the driver.
    returns          : (new_params, new_opt_state, metrics)
    """
    if topology not in TOPOLOGIES:
        raise ValueError(f"topology must be one of {TOPOLOGIES}, "
                         f"got {topology!r}")
    if pipeline is not None and topology != "full_average":
        raise ValueError("the aggregation pipeline requires "
                         "topology='full_average'")
    local_round = make_local_round(make_grad_fn(loss_fn, cfg), optimizer,
                                   cfg.tau)

    def _local_rounds(params, opt_state, batch, keys, sigmas):
        if cfg.vmap_clients:
            return jax.vmap(local_round)(params, opt_state, batch,
                                         keys, sigmas)
        return jax.lax.map(lambda args: local_round(*args),
                           (params, opt_state, batch, keys, sigmas))

    def round_step(params, opt_state, batch, key, sigmas):
        keys = jax.random.split(key, cfg.n_clients)
        new_p, new_s, ms = _local_rounds(params, opt_state, batch, keys,
                                         sigmas)
        if topology == "full_average":
            # ---- Eq. (7b): periodic global averaging ----------------------
            avg = tree_mean_over_axis0(new_p)
            new_p = tree_broadcast_axis0(avg, cfg.n_clients)
            if cfg.average_opt_state:
                # keep_dtype: int leaves (step counters) must come back as
                # ints or the round is carry-unstable under scan chunking,
                # undonatable, and retraced on its second call
                new_s = tree_broadcast_axis0(
                    tree_mean_over_axis0(new_s, keep_dtype=True),
                    cfg.n_clients)
        ms = jax.tree.map(jnp.mean, ms)
        return new_p, new_s, ms

    def round_step_pipeline(params, opt_state, batch, key, sigmas, mask,
                            residual):
        keys, agg_keys = pipeline_round_keys(key, cfg.n_clients)
        new_p, new_s, ms = _local_rounds(params, opt_state, batch, keys,
                                         sigmas)
        new_p, new_s, residual = pipeline.aggregate(
            params, new_p, new_s, opt_state, residual, mask, agg_keys)
        ms = pipeline.masked_metrics(ms, mask)
        return new_p, new_s, residual, ms

    return round_step if pipeline is None else round_step_pipeline


def make_chunked_round(round_fn: Callable, *, pipeline: bool = False,
                       n_clients: int | None = None,
                       n_participants: int | None = None) -> Callable:
    """Fuse R rounds of ``round_fn`` into one ``lax.scan`` program (§Perf
    opt: the multi-round hot loop becomes device-resident — one XLA dispatch
    and one host sync per chunk instead of per round).

    Without a pipeline the returned function is

        chunk_fn(params, opt_state, batches, key, sigmas)
            -> (params, opt_state, key, metrics)

    with ``batches`` leaves shaped (R, C, tau, B, ...) — the R stacked round
    batches — and metrics leaves stacked (R,). With ``pipeline=True`` it is

        chunk_fn(params, opt_state, batches, key, sigmas, residual)
            -> (params, opt_state, key, residual, metrics, masks)

    where the per-round participation masks (returned stacked (R, C) so the
    host ledger can replay the realized sets) are sampled INSIDE the scan
    from the carried key with exactly ``repro.api.state.run_round``'s split
    schedule — the chunk is bit-identical to R sequential run_round calls.
    The chunk length R is read from ``batches`` at trace time, so one
    returned function serves every chunk size (jit retraces per R)."""
    if pipeline:
        if n_clients is None or n_participants is None:
            raise ValueError("pipeline chunking needs n_clients and "
                             "n_participants to sample masks inside the scan")
        from repro.core.aggregation import participation_mask

        def chunk_fn_pipeline(params, opt_state, batches, key, sigmas,
                              residual):
            def body(carry, batch):
                p, s, k, r = carry
                k, sub = jax.random.split(k)
                sub, mask_key = jax.random.split(sub)
                mask = participation_mask(mask_key, n_clients, n_participants)
                p, s, r, ms = round_fn(p, s, batch, sub, sigmas, mask, r)
                return (p, s, k, r), (ms, mask)

            (params, opt_state, key, residual), (ms, masks) = jax.lax.scan(
                body, (params, opt_state, key, residual), batches)
            return params, opt_state, key, residual, ms, masks

        return chunk_fn_pipeline

    def chunk_fn(params, opt_state, batches, key, sigmas):
        def body(carry, batch):
            p, s, k = carry
            k, sub = jax.random.split(k)
            p, s, ms = round_fn(p, s, batch, sub, sigmas)
            return (p, s, k), ms

        (params, opt_state, key), ms = jax.lax.scan(
            body, (params, opt_state, key), batches)
        return params, opt_state, key, ms

    return chunk_fn


def make_resident_chunked_round(round_fn: Callable, *, n_clients: int,
                                n_participants: int,
                                kernel_backend: str = "auto",
                                data_resident: bool = False) -> Callable:
    """:func:`make_chunked_round`'s pipeline form with a *fresh cohort per
    round*: the per-round cohorts' cache-slot indices are threaded into the
    scan as a stacked operand, and the error-feedback residual lives in the
    device-resident (S, D) cohort cache instead of a (K, D) carry (§Perf
    opt — the resident-population driver of
    :mod:`repro.population.resident`).

        chunk_fn(params, opt_state, batches, slots, key, sigmas, cache)
            -> (params, opt_state, key, cache, metrics, masks)

    ``batches`` leaves are (R, K, tau, B, ...), ``slots`` is the (R, K)
    int32 per-round cohort -> cache-slot map (host-precomputed from the
    same stateless ``(seed, round_idx)`` draw the per-round driver uses, so
    both drivers realize the identical cohort schedule), and ``cache`` is
    the (S, D) resident residual block. Each round gathers its cohort's K
    rows out of the cache, runs the unchanged pipeline round body with
    run_round's exact key-split schedule, and scatters the updated rows
    back — both movements through the fused ``cohort_gather_scatter``
    kernel (:mod:`repro.kernels.dispatch`), pure device ops: the chunk
    never blocks on the host for sticky state.

    ``data_resident=True`` is the stationary-population form: ``batches``
    is then the (S, tau, B, ...) warm-shard cache pytree (a scan constant,
    not a scanned operand) and each round's (K, tau, B, ...) batch is
    gathered from it by slot through the same kernel — the chunk reads NO
    per-round host-built data at all. Only exact when every client's shard
    is fixed (``ClientPopulation.stationary``); fresh-per-round sampling
    populations must stream ``batches`` as the stacked operand."""
    from repro.core.aggregation import participation_mask
    from repro.kernels.dispatch import resolve_backend
    from repro.kernels.ops import cohort_gather, cohort_scatter

    # resolve eagerly, at build time: capability probes cannot run inside
    # the traced scan body (dispatch's trace-state guard would silently
    # demote auto to ref there)
    kernel_backend = resolve_backend("cohort_gather_scatter",
                                     kernel_backend or "auto")

    def chunk_fn(params, opt_state, batches, slots, key, sigmas, cache):
        def gather_shards(slot):
            # rows of every (S, ...) leaf for this round's cohort, moved by
            # the same slot-indexed kernel as the residual (leaves flatten
            # to (S, prod) row blocks; reshape is free)
            def one(x):
                rows = cohort_gather(x.reshape((x.shape[0], -1)), slot,
                                     backend=kernel_backend)
                return rows.reshape((slot.shape[0],) + x.shape[1:])
            return jax.tree.map(one, batches)

        def body(carry, operand):
            if data_resident:
                slot = operand
                batch = gather_shards(slot)
            else:
                batch, slot = operand
            p, s, k, c = carry
            k, sub = jax.random.split(k)
            sub, mask_key = jax.random.split(sub)
            mask = participation_mask(mask_key, n_clients, n_participants)
            # participation-only pipelines have no error-feedback state:
            # the cache carry is None (an empty pytree) and the round body
            # takes/returns residual=None, exactly like the dense form
            r = (cohort_gather(c, slot, backend=kernel_backend)
                 if c is not None else None)
            p, s, r, ms = round_fn(p, s, batch, sub, sigmas, mask, r)
            if c is not None:
                c = cohort_scatter(c, slot, r, backend=kernel_backend)
            return (p, s, k, c), (ms, mask)

        xs = slots if data_resident else (batches, slots)
        (params, opt_state, key, cache), (ms, masks) = jax.lax.scan(
            body, (params, opt_state, key, cache), xs)
        return params, opt_state, key, cache, ms, masks

    return chunk_fn


@dataclass
class Budgets:
    """Per-device budgets of the optimal-design problem (paper §5.3)."""
    c_th: float = float("inf")     # resource budget C_th
    eps_th: float = float("inf")   # privacy budget eps_th
    c1: float = 100.0              # comm cost / aggregation (paper §8.1 default)
    c2: float = 1.0                # compute cost / local step


def design_sigmas(k: int, clip_norm: float, batch_sizes: list[int],
                  eps_th: float, delta: float) -> np.ndarray:
    """Vector of Eq.-(23) optimal noise levels, one per client."""
    return np.asarray([sigma_star(k, clip_norm, x, eps_th, delta)
                       for x in batch_sizes], dtype=np.float32)


def __getattr__(name: str):
    # Back-compat: the stateful driver now lives in repro.api as a thin
    # wrapper over the functional core (imported lazily to avoid a cycle).
    if name == "Federation":
        from repro.api.federation import Federation
        return Federation
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

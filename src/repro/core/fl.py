"""DP-PASGD round engine (paper Eq. 7a–7b) as a composable JAX module.

One *round* = tau local noisy-SGD steps on each of C clients (zero
cross-client communication, expressed as a ``lax.scan``) followed by one
global model average over the client axis (Eq. 7b) — the single cross-client
collective per round. Params carry a leading client axis C on every leaf; on
a device mesh that axis is sharded over the derived ``client`` mesh axis, so
GSPMD lowers the round-boundary mean to exactly one all-reduce over the
client (and pod) axis.

The engine is generic over the model: it only needs ``loss_fn(params, batch)``.
It powers both the paper-scale experiments (logreg / SVM, 16–23 clients on
CPU) and the pod-scale transformer runs (clients = mesh slabs).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clipping import make_dp_grad_fn, make_plain_grad_fn
from repro.core.privacy import PrivacyAccountant, sigma_star
from repro.optim.optimizers import Optimizer
from repro.utils.tree import (
    tree_add,
    tree_broadcast_axis0,
    tree_mean_over_axis0,
)


@dataclass(frozen=True)
class FLConfig:
    """Configuration of one DP-PASGD federation."""
    n_clients: int
    tau: int                      # global aggregation period (local steps/round)
    clip_norm: float = 1.0        # G (sensitivity bound)
    dp: bool = True               # False -> PASGD (no noise, no clipping)
    num_microbatches: int = 1     # see clipping.py; =local batch -> per-example
    vmap_microbatches: bool = True
    grad_accumulate: str = "stack"  # "stack" (baseline) | "scan" (§Perf opt)
    average_opt_state: bool = True  # average optimizer state with the models
    vmap_clients: bool = True     # False -> lax.map (sequential clients; CPU sims)


def make_round_step(loss_fn: Callable, optimizer: Optimizer, cfg: FLConfig):
    """Build ``round_step(params, opt_state, batch, key, sigmas)``.

    params/opt_state : pytrees with leading client axis C on every leaf
    batch            : pytree with leading axes (C, tau, local_batch, ...)
    sigmas           : (C,) per-client per-step noise std (traced; Eq. 23)
    returns          : (new_params, new_opt_state, metrics)
    """
    if cfg.dp:
        grad_fn = make_dp_grad_fn(loss_fn, cfg.clip_norm, cfg.num_microbatches,
                                  cfg.vmap_microbatches, cfg.grad_accumulate)
    else:
        grad_fn = make_plain_grad_fn(loss_fn)

    def local_round(params, opt_state, batches, key, sigma):
        """tau local DP-SGD steps of ONE client (Eq. 7a). No collectives."""
        keys = jax.random.split(key, cfg.tau)

        def step(carry, inp):
            p, s = carry
            mb, k = inp
            g, metrics = grad_fn(p, mb, k, sigma)
            upd, s = optimizer.update(g, s, p)
            p = tree_add(p, upd)
            return (p, s), metrics

        (params, opt_state), ms = jax.lax.scan(step, (params, opt_state),
                                               (batches, keys))
        # mean metrics over the tau local steps
        ms = jax.tree.map(lambda x: jnp.mean(x), ms)
        return params, opt_state, ms

    def round_step(params, opt_state, batch, key, sigmas):
        keys = jax.random.split(key, cfg.n_clients)
        if cfg.vmap_clients:
            new_p, new_s, ms = jax.vmap(local_round)(params, opt_state, batch,
                                                     keys, sigmas)
        else:
            new_p, new_s, ms = jax.lax.map(
                lambda args: local_round(*args),
                (params, opt_state, batch, keys, sigmas))
        # ---- Eq. (7b): periodic global averaging -------------------------
        avg = tree_mean_over_axis0(new_p)
        new_p = tree_broadcast_axis0(avg, cfg.n_clients)
        if cfg.average_opt_state:
            new_s = tree_broadcast_axis0(tree_mean_over_axis0(new_s),
                                         cfg.n_clients)
        ms = jax.tree.map(lambda x: jnp.mean(x), ms)
        return new_p, new_s, ms

    return round_step


def make_local_steps_only(loss_fn: Callable, optimizer: Optimizer, cfg: FLConfig):
    """Round WITHOUT the averaging step (ablation: fully-local training)."""
    if cfg.dp:
        grad_fn = make_dp_grad_fn(loss_fn, cfg.clip_norm, cfg.num_microbatches,
                                  cfg.vmap_microbatches)
    else:
        grad_fn = make_plain_grad_fn(loss_fn)

    def local_round(params, opt_state, batches, key, sigma):
        keys = jax.random.split(key, cfg.tau)

        def step(carry, inp):
            p, s = carry
            mb, k = inp
            g, metrics = grad_fn(p, mb, k, sigma)
            upd, s = optimizer.update(g, s, p)
            return (tree_add(p, upd), s), metrics

        (params, opt_state), ms = jax.lax.scan(step, (params, opt_state),
                                               (batches, keys))
        return params, opt_state, jax.tree.map(jnp.mean, ms)

    def round_step(params, opt_state, batch, key, sigmas):
        keys = jax.random.split(key, cfg.n_clients)
        new_p, new_s, ms = jax.vmap(local_round)(params, opt_state, batch, keys,
                                                 sigmas)
        return new_p, new_s, jax.tree.map(jnp.mean, ms)

    return round_step


# ---------------------------------------------------------------------------
# Federation driver: budget-aware training loop used by the paper experiments.
# ---------------------------------------------------------------------------

@dataclass
class Budgets:
    """Per-device budgets of the optimal-design problem (paper §5.3)."""
    c_th: float = float("inf")     # resource budget C_th
    eps_th: float = float("inf")   # privacy budget eps_th
    c1: float = 100.0              # comm cost / aggregation (paper §8.1 default)
    c2: float = 1.0                # compute cost / local step


@dataclass
class Federation:
    """Coordinates clients, the round step, and the privacy accountant.

    ``sampler(client, tau, rng) -> batch pytree with leading axes (tau, B)``
    """
    cfg: FLConfig
    loss_fn: Callable
    optimizer: Optimizer
    params0: Any                              # single-replica init (no C axis)
    sampler: Callable[[int, int, np.random.Generator], Any]
    sigmas: np.ndarray                        # (C,) per-step noise std
    delta: float = 1e-4
    batch_sizes: list[int] = field(default_factory=list)  # X_m per client
    seed: int = 0

    def __post_init__(self):
        c = self.cfg.n_clients
        self.params = tree_broadcast_axis0(self.params0, c)
        opt0 = self.optimizer.init(self.params0)
        self.opt_state = tree_broadcast_axis0(opt0, c)
        self.accountant = PrivacyAccountant(clip_norm=self.cfg.clip_norm,
                                            delta=self.delta)
        for m in range(c):
            bs = self.batch_sizes[m] if self.batch_sizes else 1
            self.accountant.register_client(m, bs, float(self.sigmas[m]))
        self._round_step = jax.jit(
            make_round_step(self.loss_fn, self.optimizer, self.cfg))
        self._rng = np.random.default_rng(self.seed)
        self._key = jax.random.PRNGKey(self.seed)
        self.resource_spent = 0.0
        self.rounds_done = 0
        self.history: list[dict] = []

    # -- data --------------------------------------------------------------
    def _round_batch(self):
        per_client = [self.sampler(m, self.cfg.tau, self._rng)
                      for m in range(self.cfg.n_clients)]
        return jax.tree.map(lambda *xs: np.stack(xs), *per_client)

    # -- training ----------------------------------------------------------
    def round(self) -> dict:
        batch = self._round_batch()
        self._key, sub = jax.random.split(self._key)
        sig = jnp.asarray(self.sigmas, jnp.float32)
        self.params, self.opt_state, ms = self._round_step(
            self.params, self.opt_state, batch, sub, sig)
        self.accountant.step(self.cfg.tau)
        self.rounds_done += 1
        rec = {k: float(v) for k, v in ms.items()}
        rec["round"] = self.rounds_done
        rec["iterations"] = self.rounds_done * self.cfg.tau
        rec["max_epsilon"] = self.accountant.max_epsilon()
        self.history.append(rec)
        return rec

    def round_cost(self, budgets: Budgets) -> float:
        """Eq. (8) per round: c1 + c2 * tau."""
        return budgets.c1 + budgets.c2 * self.cfg.tau

    def train(self, budgets: Budgets, max_rounds: int = 10_000,
              eval_fn: Callable | None = None, eval_every: int = 1) -> dict:
        """Run rounds until a budget (resource or privacy) would be exceeded.

        Tracks theta* = argmin of the evaluated loss (paper uses the best
        model among K iterations).
        """
        best = {"loss": float("inf"), "round": 0}
        while self.rounds_done < max_rounds:
            nxt_cost = self.resource_spent + self.round_cost(budgets)
            if nxt_cost > budgets.c_th:
                break
            # peek privacy after tau more steps on a copy
            probe = max(
                (self.accountant.rho(m)
                 + self.cfg.tau * 2 * self.cfg.clip_norm ** 2
                 / (self.accountant.batch_sizes[m] ** 2
                    * max(self.accountant.sigmas[m], 1e-30) ** 2))
                for m in self.accountant.batch_sizes)
            from repro.core.privacy import zcdp_to_dp
            if zcdp_to_dp(probe, self.delta) > budgets.eps_th:
                break
            rec = self.round()
            self.resource_spent = nxt_cost
            rec["resource_spent"] = self.resource_spent
            evaluated = False
            if eval_fn is not None and self.rounds_done % eval_every == 0:
                avg_params = jax.tree.map(lambda x: x[0], self.params)
                rec.update(eval_fn(avg_params))
                evaluated = True
            # theta* tracking: compare on eval loss when available, else train
            if eval_fn is None:
                crit = rec["loss"]
            elif evaluated:
                crit = rec["eval_loss"]
            else:
                crit = float("inf")
            if crit < best["loss"]:
                best = {"loss": crit, "round": self.rounds_done, **rec}
        return {"best": best, "rounds": self.rounds_done,
                "resource_spent": self.resource_spent,
                "max_epsilon": self.accountant.max_epsilon(),
                "history": self.history}


def design_sigmas(k: int, clip_norm: float, batch_sizes: list[int],
                  eps_th: float, delta: float) -> np.ndarray:
    """Vector of Eq.-(23) optimal noise levels, one per client."""
    return np.asarray([sigma_star(k, clip_norm, x, eps_th, delta)
                       for x in batch_sizes], dtype=np.float32)

from repro.core.clipping import clip_tree, make_dp_grad_fn, make_plain_grad_fn
from repro.core.convergence import ProblemConstants, bound_b, theorem1_bound
from repro.core.design import (
    DesignProblem,
    DesignSolution,
    ResourceModel,
    grid_search_reference,
)
from repro.core.fl import Budgets, FLConfig, design_sigmas, make_round_step
from repro.core.privacy import (
    PrivacyAccountant,
    compose_zcdp,
    epsilon_after_k,
    gaussian_zcdp,
    grad_sensitivity,
    privacy_z,
    sigma_star,
    zcdp_to_dp,
)

__all__ = [
    "clip_tree", "make_dp_grad_fn", "make_plain_grad_fn",
    "ProblemConstants", "bound_b", "theorem1_bound",
    "DesignProblem", "DesignSolution", "ResourceModel", "grid_search_reference",
    "Budgets", "Federation", "FLConfig", "design_sigmas", "make_round_step",
    "PrivacyAccountant", "compose_zcdp", "epsilon_after_k", "gaussian_zcdp",
    "grad_sensitivity", "privacy_z", "sigma_star", "zcdp_to_dp",
]


def __getattr__(name):
    # Federation now lives in repro.api (thin wrapper over the functional
    # core); re-exported lazily to break the core <-> api import cycle.
    if name == "Federation":
        from repro.api.federation import Federation
        return Federation
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Optimal schematic design of DP-PASGD (paper §5, §7).

Given per-device resource budget C_th and privacy budget eps_th, choose
(tau, K, {sigma_m}) minimizing the Theorem-1 surrogate objective (Eq. 21/24):

  - resource model (Eq. 8):   C = c1 K / tau + c2 K <= C_th
  - dF/dtau > 0  =>  resource constraint binds:  tau* = c1 K / (C_th - c2 K)
  - dF/dsigma^2 > 0  =>  privacy constraint binds:  sigma_m* from Eq. (23)
  - 1-D problem in K (Eq. 24), solved by projected gradient descent (paper's
    method) with a coarse grid warm-start for robustness; integers recovered
    by nearest-integer rounding (paper §7 heuristic).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from repro.core.convergence import ProblemConstants, bound_b, theorem1_bound
from repro.core.privacy import rho_budget, sigma_star


@dataclass(frozen=True)
class ResourceModel:
    """Eq. (8): C = c1 * comm_scale * K / tau + c2 K.

    ``comm_scale`` extends the paper's model with the aggregation-pipeline
    knobs: ``wire_ratio * q`` (compression times participation,
    ``FederationSpec.comm_scale()``). Cheaper aggregations shift the Eq.-22
    binding tau* down — ``solve()`` co-designs tau against compression and
    participation for free. Default 1.0 is the paper's dense protocol.
    """
    c1: float  # communication cost of one dense full-cohort aggregation
    c2: float  # computation cost of one local update
    comm_scale: float = 1.0  # pipeline multiplier on c1 (wire_ratio * q)

    def _c1(self) -> float:
        return self.c1 * self.comm_scale

    def cost(self, k: float, tau: float) -> float:
        return self._c1() * k / tau + self.c2 * k

    def tau_binding(self, k: float, c_th: float) -> float:
        """Eq. (22): tau* that spends exactly the resource budget at K=k."""
        denom = c_th - self.c2 * k
        if denom <= 0:
            return math.inf
        return self._c1() * k / denom

    def k_max(self, c_th: float, tau: float) -> float:
        """Largest K affordable at aggregation period tau."""
        return c_th / (self._c1() / tau + self.c2)


@dataclass(frozen=True)
class DesignSolution:
    k: int
    tau: int
    sigmas: tuple[float, ...]       # per-client sigma_m*
    predicted_bound: float          # Theorem-1 surrogate at the solution
    cost: float                     # resource cost at the solution
    k_relaxed: float                # continuous optimum before rounding
    tau_relaxed: float


@dataclass(frozen=True)
class DesignProblem:
    consts: ProblemConstants
    resource: ResourceModel
    clip_norm: float                 # G
    batch_sizes: Sequence[int]       # X_m per client
    delta: float
    eps_th: float
    c_th: float

    # ---- Eq. (24) pieces -------------------------------------------------
    def _sigma2_sum(self, k: float) -> float:
        """sum_m (sigma_m*)^2 with corrected Eq. (23) substituted:
        2 K G^2 / (X_m^2 rho*), rho* = eps_th^2 / Z (see privacy.sigma_star)."""
        rho = rho_budget(self.eps_th, self.delta)
        g2 = self.clip_norm ** 2
        return sum(2.0 * k * g2 / (x * x * rho) for x in self.batch_sizes)

    def tau_of_k(self, k: float) -> float:
        """tau choice at K=k: binding value clamped to [1, tau_max]."""
        t = self.resource.tau_binding(k, self.c_th)
        return min(max(t, 1.0), self.consts.tau_max())

    def objective(self, k: float) -> float:
        """Relaxed Eq. (24) objective F(K) with tau*, sigma* substituted."""
        if k < 1.0:
            return math.inf
        tau = self.tau_of_k(k)
        if self.resource.cost(k, tau) > self.c_th * (1.0 + 1e-9):
            return math.inf
        c = self.consts
        sig2 = self._sigma2_sum(k)
        payload = c.xi2 + c.dim / c.n_clients * sig2
        pref = (c.eta * c.lip + c.eta ** 2 * c.lip ** 2 * (tau - 1.0) * c.n_clients) \
            / (2.0 * c.lam * c.n_clients)
        b = pref * payload
        decay = (1.0 - c.eta * c.lam) ** k
        return decay / k * (c.alpha - b) + b

    # ---- solver ----------------------------------------------------------
    def k_feasible_range(self) -> tuple[float, float]:
        r, c = self.resource, self.consts
        tau_hi = min(c.tau_max(), 1e6)
        k_hi = r.k_max(self.c_th, tau_hi)
        return 1.0, max(1.0, k_hi)

    def solve_relaxed(self, n_grid: int = 400, gd_iters: int = 200,
                      gd_lr: float | None = None) -> float:
        """Grid warm-start + projected gradient descent on K (paper §7)."""
        k_lo, k_hi = self.k_feasible_range()
        if k_hi <= k_lo:
            return k_lo
        # log-spaced grid warm start
        best_k, best_f = k_lo, self.objective(k_lo)
        for i in range(n_grid + 1):
            k = math.exp(math.log(k_lo) + (math.log(k_hi) - math.log(k_lo)) * i / n_grid)
            f = self.objective(k)
            if f < best_f:
                best_k, best_f = k, f
        # gradient descent refinement (central differences)
        k = best_k
        lr = gd_lr if gd_lr is not None else max(1.0, 0.01 * k)
        for _ in range(gd_iters):
            h = max(1e-3, 1e-4 * k)
            g = (self.objective(k + h) - self.objective(k - h)) / (2.0 * h)
            if not math.isfinite(g):
                break
            k_new = min(max(k - lr * g, k_lo), k_hi)
            if self.objective(k_new) > self.objective(k) - 1e-15:
                lr *= 0.5
                if lr < 1e-6:
                    break
                continue
            k = k_new
        return k if self.objective(k) <= best_f else best_k

    def solve(self) -> DesignSolution:
        k_rel = self.solve_relaxed()
        tau_rel = self.tau_of_k(k_rel)
        # paper §7: round to nearest integers; then repair feasibility.
        k = max(1, round(k_rel))
        tau = max(1, round(tau_rel))
        # keep K an integer multiple of tau (Theorem 1 assumption)
        k = max(tau, (k // tau) * tau)
        # repair: rounding down tau can overshoot the budget -> bump tau up
        guard = 0
        while self.resource.cost(k, tau) > self.c_th and guard < 10_000:
            if tau < self.consts.tau_max():
                tau += 1
            else:
                k = max(tau, k - tau)
            guard += 1
        sigmas = tuple(
            sigma_star(k, self.clip_norm, x, self.eps_th, self.delta)
            for x in self.batch_sizes
        )
        bound = theorem1_bound(self.consts, k, tau, [s * s for s in sigmas])
        return DesignSolution(
            k=k, tau=tau, sigmas=sigmas, predicted_bound=bound,
            cost=self.resource.cost(k, tau), k_relaxed=k_rel, tau_relaxed=tau_rel,
        )


def grid_search_reference(problem: DesignProblem, taus: Sequence[int],
                          ks_per_tau: int = 64) -> tuple[int, int, float]:
    """Brute-force (tau, K) search over the surrogate — the paper's comparison
    baseline (§8.3). Returns (tau, K, bound)."""
    best = (1, 1, math.inf)
    for tau in taus:
        if not problem.consts.lr_constraint_ok(tau):
            continue
        k_hi = problem.resource.k_max(problem.c_th, tau)
        if k_hi < tau:
            continue
        for i in range(1, ks_per_tau + 1):
            k = max(tau, int(k_hi * i / ks_per_tau) // tau * tau)
            sig2 = [
                sigma_star(k, problem.clip_norm, x, problem.eps_th, problem.delta) ** 2
                for x in problem.batch_sizes
            ]
            f = theorem1_bound(problem.consts, k, tau, sig2)
            if f < best[2]:
                best = (tau, k, f)
    return best

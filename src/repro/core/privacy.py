"""zCDP privacy accounting for DP-PASGD (paper §3, §5.2).

Implements:
  - Lemma 1: zCDP composition (rho adds).
  - Lemma 2: Gaussian mechanism satisfies (Delta^2 / 2 sigma^2)-zCDP.
  - Lemma 3: rho-zCDP  =>  (rho + 2 sqrt(rho log(1/delta)), delta)-DP.
  - Eq. (9): closed-form overall privacy loss of device m after K iterations:
        eps_m = 2 K G^2 / (X_m^2 sigma_m^2)
              + (2 G / (X_m sigma_m)) sqrt(2 K log(1/delta)).
  - Eq. (23): closed-form optimal (privacy-budget-binding) noise variance:
        (sigma_m*)^2 = 2 K G^2 / (X_m^2 * Z),
        Z = eps_th + 2 log(1/delta) + 2 sqrt(log(1/delta)^2 + eps_th log(1/delta)).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np


def gaussian_zcdp(sensitivity: float, sigma: float) -> float:
    """Lemma 2: rho of one Gaussian-mechanism release."""
    if sigma <= 0:
        return math.inf
    return sensitivity ** 2 / (2.0 * sigma ** 2)


def compose_zcdp(*rhos: float) -> float:
    """Lemma 1: composition adds rho."""
    return float(sum(rhos))


def zcdp_to_dp(rho: float, delta: float) -> float:
    """Lemma 3: convert rho-zCDP to (eps, delta)-DP."""
    if rho == math.inf:
        return math.inf
    return rho + 2.0 * math.sqrt(rho * math.log(1.0 / delta))


def grad_sensitivity(clip_norm: float, batch_size: int) -> float:
    """Paper §5.2: Delta_2(g) <= 2 G / X_m for a size-X_m mini-batch."""
    return 2.0 * clip_norm / batch_size


def subsampled_rho(rho_step: float, q: float) -> float:
    """Per-step zCDP cost under per-round client subsampling at rate q.

    Beyond the paper: with partial participation, the per-round release of
    client m's update is the *subsampled* Gaussian mechanism — present with
    probability q, absorbed into the aggregate otherwise — whose expected
    per-round cost is ~ q^2 * rho_step in the small-q regime (the RDP
    amplification of Abadi et al. 2016 / Wang et al. 2019, transported to
    zCDP). The accountant charges only *realized* participating rounds
    (a ~q fraction of them), so the per-realized-step amplification factor
    is q^2 / q = q, matching the q^2-per-round expectation while keeping
    the ledger deterministic. q = 1 is exact Lemma 2 (no amplification).

    Caveat (deliberate modeling choice): the q factor bounds the *marginal*
    mechanism, i.e. it holds in expectation over the participation draw. A
    client that happens to be sampled in far more than a q-fraction of a
    short run is undercharged relative to participation-conditioned
    accounting (which would cost the full rho_step per realized step — the
    amplification benefits the subsampling-blind observer, not the
    conditioned one). For a worst-case conditional ledger, account with
    q = 1 and keep the reduced realized step count —
    ``FederationSpec(amplify_participation=False)`` selects exactly that.
    """
    if not 0.0 < q <= 1.0:
        raise ValueError(f"participation rate q must be in (0, 1], got {q}")
    return q * rho_step


def composed_subsampling_q(*qs: float) -> float:
    """Compose independent subsampling stages into one realized-step rate.

    Cohort execution stacks two Bernoulli gates in front of every local
    step: the client is drawn into the round's cohort (rate K/M over the
    population) and then participates within the cohort (the
    ``participation`` rate q of the aggregation pipeline). The stages are
    independent draws, so the probability a given client realizes a given
    round's steps is the product — and that product is the q of
    :func:`subsampled_rho` under the expectation-level amplification
    (``FederationSpec(amplify_participation=True)``). Every caveat of
    ``subsampled_rho`` transports unchanged: the bound is marginal over
    BOTH draws, assumes uniform sampling (availability-skewed cohorts
    break it — see ``repro.population.samplers.HeterogeneousCohort``), and
    the sound conditional default (q = 1, charge realized steps only) is
    unaffected because the per-client ledger already charges each virtual
    client exactly the rounds it ran.
    """
    q = 1.0
    for qi in qs:
        if not 0.0 < qi <= 1.0:
            raise ValueError(f"subsampling rates must be in (0, 1], "
                             f"got {qi}")
        q *= qi
    return q


def per_step_charges(rho_steps, q: float):
    """Vectorized :func:`subsampled_rho` over a (C,) per-step rho vector —
    THE per-realized-local-step charge expression of every ledger surface
    (``PrivacyAccountant.step``/``step_many`` and the incremental probes of
    ``repro.api.state``). Keeping it here means a change to the
    amplification model cannot desynchronize the probe from the ledger."""
    if not 0.0 < q <= 1.0:
        raise ValueError(f"participation rate q must be in (0, 1], got {q}")
    return q * np.asarray(rho_steps, np.float64)


def epsilon_after_k(k: int, clip_norm: float, batch_size: int, sigma: float,
                    delta: float) -> float:
    """Eq. (9): overall (eps, delta)-DP loss of one device after k iterations."""
    if sigma <= 0:
        return math.inf
    g, x = clip_norm, batch_size
    rho = 2.0 * k * g * g / (x * x * sigma * sigma)  # Lemmas 1+2
    return zcdp_to_dp(rho, delta)                    # == Eq. (9) expanded


def privacy_z(eps_th: float, delta: float) -> float:
    """Eq. (25): Z constant of the binding privacy constraint."""
    ld = math.log(1.0 / delta)
    return eps_th + 2.0 * ld + 2.0 * math.sqrt(ld * ld + eps_th * ld)


def rho_budget(eps_th: float, delta: float) -> float:
    """Largest rho whose Lemma-3 conversion stays within (eps_th, delta)-DP.

    Inverting eps = rho + 2 sqrt(rho log(1/delta)) gives
        sqrt(rho*) = sqrt(log(1/delta) + eps) - sqrt(log(1/delta))
    and one can check rho* = eps_th^2 / Z with Z from Eq. (25).
    """
    ld = math.log(1.0 / delta)
    return (math.sqrt(ld + eps_th) - math.sqrt(ld)) ** 2


def sigma_star(k: int, clip_norm: float, batch_size: int, eps_th: float,
               delta: float) -> float:
    """Eq. (23) corrected: smallest per-step noise std meeting eps_th at K=k.

    NOTE (paper erratum): Eq. (23) as printed reads
        (sigma*)^2 = 2 K G^2 / (X^2 Z),
    but substituting it back into Eq. (9) does NOT give eps_th. The correct
    inversion of Eq. (9) is rho* = eps_th^2 / Z, hence
        (sigma*)^2 = 2 K G^2 Z / (X^2 eps_th^2)   ==  2 K G^2 / (X^2 rho*).
    Verified by the property test eps(sigma*(K)) == eps_th (tests/test_privacy).
    """
    rho = rho_budget(eps_th, delta)  # == eps_th^2 / privacy_z(eps_th, delta)
    var = 2.0 * k * clip_norm ** 2 / (batch_size ** 2 * rho)
    return math.sqrt(var)


@dataclass
class PrivacyAccountant:
    """Tracks per-client zCDP over the run; one instance per federation.

    Each DP-PASGD iteration queries every client's dataset once (the gradient),
    so every local step adds gaussian_zcdp(2G/X_m, sigma_m) to client m.
    """
    clip_norm: float
    delta: float
    batch_sizes: dict[int, int] = field(default_factory=dict)   # client -> X_m
    sigmas: dict[int, float] = field(default_factory=dict)      # client -> sigma_m
    _rho: dict[int, float] = field(default_factory=dict)
    # dispatch/arrival split (buffered-async federation): the slice of _rho
    # that was charged at dispatch time for uploads still in flight. _rho
    # ALWAYS includes it — peek_epsilon/max_epsilon therefore probe the
    # dispatched view, so a straggler's pending charge can never outrun the
    # budget check; landed_rho() subtracts it for the arrived-only view.
    _pending: dict[int, float] = field(default_factory=dict)
    steps: int = 0

    def register_client(self, client: int, batch_size: int, sigma: float) -> None:
        if batch_size <= 0:
            raise ValueError(f"batch_size must be positive, got {batch_size}")
        if sigma < 0:
            raise ValueError(f"sigma must be non-negative, got {sigma}")
        self.batch_sizes[client] = batch_size
        self.sigmas[client] = sigma
        self._rho.setdefault(client, 0.0)

    def step(self, n_steps: int = 1, clients=None, q: float = 1.0) -> None:
        """Account for n_steps local iterations.

        ``clients`` restricts the charge to the round's realized participant
        set (everyone when None) — non-participants take no steps, query
        nothing, and spend nothing. ``q`` is the per-round participation
        rate; each charged step costs :func:`subsampled_rho` (amplification
        by client subsampling; identity at q = 1).
        """
        if n_steps < 0:
            raise ValueError("n_steps must be >= 0")
        charged = (self.batch_sizes.keys() if clients is None
                   else [int(m) for m in clients])
        for m in charged:
            sens = grad_sensitivity(self.clip_norm, self.batch_sizes[m])
            self._rho[m] += n_steps * subsampled_rho(
                gaussian_zcdp(sens, self.sigmas[m]), q)
        self.steps += n_steps

    def step_many(self, taus, masks=None, q: float = 1.0) -> np.ndarray:
        """Vectorized ledger replay of a chunk of rounds.

        ``taus`` are the per-round local-step counts (R,); ``masks`` the
        stacked realized 0/1 participation masks (R, C), columns aligned to
        the sorted registered client ids (``None`` -> every client
        participates every round). Per client, the per-round increments are
        applied in round order with the same floating-point expression as
        :meth:`step`, so the resulting ledger is bit-for-bit identical to R
        sequential ``step(tau_r, clients=participants_r, q=q)`` calls — the
        conditional per-round ledger stays the source of truth; the fused
        multi-round driver merely replays it in O(R) numpy row operations
        instead of O(R*C) Python dict updates.

        Returns the (R,) worst-client rho trajectory (after each round), so
        chunked drivers can materialize per-round epsilon records without a
        second replay.
        """
        clients = sorted(self.batch_sizes)
        if not clients:
            raise ValueError("no clients registered")
        taus = [int(t) for t in taus]
        if any(t < 0 for t in taus):
            raise ValueError("n_steps must be >= 0")
        if masks is not None:
            masks = np.asarray(masks)
            if masks.shape != (len(taus), len(clients)):
                raise ValueError(f"masks shape {masks.shape} != "
                                 f"({len(taus)}, {len(clients)})")
        # identical per-step charge expression as step():
        #   n_steps * subsampled_rho(gaussian_zcdp(sens_m, sigma_m), q)
        charge = per_step_charges(
            [gaussian_zcdp(grad_sensitivity(self.clip_norm,
                                            self.batch_sizes[m]),
                           self.sigmas[m]) for m in clients], q)
        rho = np.asarray([self._rho[m] for m in clients], np.float64)
        worst = np.empty((len(taus),), np.float64)
        for r, tau in enumerate(taus):
            inc = tau * charge
            if masks is not None:
                # where (not *): 0 * inf charges (sigma=0 clients) are NaN,
                # and step() never touches non-participants at all
                inc = np.where(masks[r] > 0, inc, 0.0)
            rho = rho + inc
            worst[r] = np.max(rho)
        for i, m in enumerate(clients):
            self._rho[m] = float(rho[i])
        self.steps += sum(taus)
        return worst

    def charge_at_dispatch(self, n_steps: int, clients, q: float = 1.0,
                           ) -> None:
        """Pre-charge ``clients`` the full Lemma-2 cost of ``n_steps`` local
        iterations at DISPATCH time (buffered-async federation).

        Async semantics: a client's DP releases are determined the moment
        it is handed a model version and starts its tau noisy steps — the
        noise it will add is already fixed, regardless of when (or whether)
        its upload lands in a buffer. Charging at dispatch keeps the ledger
        sound against stragglers: ``_rho`` (hence ``peek_epsilon`` /
        ``max_epsilon``) includes the in-flight charge immediately, so the
        budget probe can never be outrun by an upload that is still in the
        air. The per-step expression is identical to :meth:`step`'s
        (``n_steps * subsampled_rho(rho_step, q)``). :meth:`note_arrival`
        moves the charge from pending to landed when the upload arrives —
        total rho is unchanged by arrival."""
        if n_steps < 0:
            raise ValueError("n_steps must be >= 0")
        for m in clients:
            m = int(m)
            sens = grad_sensitivity(self.clip_norm, self.batch_sizes[m])
            inc = n_steps * subsampled_rho(
                gaussian_zcdp(sens, self.sigmas[m]), q)
            self._rho[m] += inc
            self._pending[m] = self._pending.get(m, 0.0) + inc
        self.steps += n_steps

    def note_arrival(self, clients) -> None:
        """Mark ``clients``' in-flight uploads as landed: their pending
        charge (already in ``_rho`` since dispatch) becomes landed rho.
        Total rho is unchanged — arrival is bookkeeping, not a release."""
        for m in clients:
            self._pending.pop(int(m), None)

    def pending_rho(self, client: int) -> float:
        """The dispatch-time pre-charge of ``client``'s in-flight upload
        (0.0 when nothing is in flight)."""
        return self._pending.get(client, 0.0)

    def landed_rho(self, client: int) -> float:
        """rho from arrived uploads only (total minus in-flight)."""
        return self._rho.get(client, 0.0) - self.pending_rho(client)

    def rho(self, client: int) -> float:
        return self._rho.get(client, 0.0)

    def epsilon(self, client: int) -> float:
        return zcdp_to_dp(self.rho(client), self.delta)

    def max_epsilon(self) -> float:
        if not self._rho:
            return 0.0
        return max(self.epsilon(m) for m in self._rho)

    def peek_epsilon(self, extra_steps: int = 0, q: float = 1.0) -> float:
        """Worst-client eps if every client took ``extra_steps`` more local
        iterations — WITHOUT mutating the accountant.

        This is the pre-round probe of the budget-aware training loop: run
        the next round only if ``peek_epsilon(tau) <= eps_th``. rho composes
        additively (Lemma 1) and Lemma 3 is monotone in rho, so the max can
        be taken in rho-space before the single conversion. Under partial
        participation pass the round's rate ``q``: the probe stays
        conservative (it assumes the worst client IS sampled) while its
        per-step cost carries the subsampling amplification.
        """
        if extra_steps < 0:
            raise ValueError("extra_steps must be >= 0")
        if not self.batch_sizes:
            return 0.0
        worst_rho = max(
            self._rho.get(m, 0.0)
            + extra_steps * subsampled_rho(
                gaussian_zcdp(grad_sensitivity(self.clip_norm, x),
                              self.sigmas[m]), q)
            for m, x in self.batch_sizes.items())
        return zcdp_to_dp(worst_rho, self.delta)

    def remaining_steps(self, client: int, eps_th: float) -> int:
        """How many more local steps client m can take before exceeding eps_th."""
        x, s = self.batch_sizes[client], self.sigmas[client]
        if s == 0:
            return 0
        rho_step = gaussian_zcdp(grad_sensitivity(self.clip_norm, x), s)
        left = rho_budget(eps_th, self.delta) - self._rho[client]
        return max(0, int(left / rho_step))

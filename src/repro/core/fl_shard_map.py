"""shard_map DP-PASGD round: explicit collective schedule (Eq. 7a-7b).

The GSPMD engine in core/fl.py lets the partitioner place the round-boundary
all-reduce. This variant instead expresses the schedule explicitly with
``jax.shard_map``: each mesh slot along the ``client`` axis owns its replica,
runs tau local noisy-SGD steps with ZERO collectives, then one
``jax.lax.pmean`` over the client axis is the aggregation — byte-for-byte
the paper's protocol, and the single point where cross-client traffic can
exist. Used for the paper-scale (replicated-model) experiments and as the
reference collective schedule for the GSPMD lowering.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.clipping import make_dp_grad_fn, make_plain_grad_fn
from repro.core.fl import FLConfig
from repro.optim.optimizers import Optimizer
from repro.utils.tree import tree_add


def make_shard_map_round(loss_fn: Callable, optimizer: Optimizer,
                         cfg: FLConfig, mesh: Mesh,
                         client_axis: str = "client"):
    """Build round_step(params, opt_state, batch, key, sigmas) on ``mesh``.

    params/opt_state carry a leading client axis sharded over ``client_axis``
    (local view inside the shard_map has leading dim 1). batch leaves are
    (C, tau, B, ...); sigmas is (C,).
    """
    if cfg.dp:
        grad_fn = make_dp_grad_fn(loss_fn, cfg.clip_norm,
                                  cfg.num_microbatches,
                                  cfg.vmap_microbatches, cfg.grad_accumulate)
    else:
        grad_fn = make_plain_grad_fn(loss_fn)

    def per_client(params, opt_state, batches, keys, sigma):
        """Local view: leading axis 1 (this client's shard)."""
        squeeze = lambda t: jax.tree.map(lambda x: x[0], t)
        params, opt_state = squeeze(params), squeeze(opt_state)
        batches, sigma = squeeze(batches), sigma[0]
        step_keys = jax.random.split(keys[0], cfg.tau)

        def step(carry, inp):
            p, s = carry
            mb, k = inp
            g, metrics = grad_fn(p, mb, k, sigma)
            upd, s = optimizer.update(g, s, p)
            return (tree_add(p, upd), s), metrics

        (params, opt_state), ms = jax.lax.scan(step, (params, opt_state),
                                               (batches, step_keys))
        # ---- Eq. (7b): THE collective — one pmean over the client axis ----
        params = jax.tree.map(
            lambda x: jax.lax.pmean(x, axis_name=client_axis), params)
        if cfg.average_opt_state:
            opt_state = jax.tree.map(
                lambda x: jax.lax.pmean(x.astype(jnp.float32),
                                        axis_name=client_axis
                                        ).astype(x.dtype), opt_state)
        ms = jax.tree.map(lambda x: jax.lax.pmean(jnp.mean(x), client_axis),
                          ms)
        unsq = lambda t: jax.tree.map(lambda x: x[None], t)
        return unsq(params), unsq(opt_state), ms

    cspec = P(client_axis)
    smapped = jax.shard_map(
        per_client, mesh=mesh,
        in_specs=(cspec, cspec, cspec, cspec, cspec),
        out_specs=(cspec, cspec, P()),
        check_vma=False)

    def round_step(params, opt_state, batch, key, sigmas):
        keys = jax.random.split(key, cfg.n_clients)
        return smapped(params, opt_state, batch, keys, sigmas)

    return round_step

"""shard_map DP-PASGD round: explicit collective schedule (Eq. 7a-7b).

The GSPMD engine in core/fl.py lets the partitioner place the round-boundary
all-reduce. This variant instead expresses the schedule explicitly with
``jax.shard_map``: each mesh slot along the ``client`` axis owns a contiguous
*block* of ``n_clients / mesh.shape[client]`` model replicas, runs tau local
noisy-SGD steps per replica with ZERO collectives, then one
``jax.lax.pmean`` over the client axis is the aggregation — byte-for-byte
the paper's protocol, and the single point where cross-client traffic can
exist. With fewer devices than clients the block is vmapped locally, so the
same engine runs 23-client CPU simulations and pod-scale slab-per-client
runs unchanged.

**New code should select this engine via ``repro.api``**
(``FederationSpec(engine="shard_map")``) rather than calling
:func:`make_shard_map_round` directly; the facade builds the client mesh and
unifies the call signature with the GSPMD engines. The per-step clip+noise
inside each shard follows ``FLConfig.kernel_backend`` (see
:mod:`repro.kernels.dispatch`), identically to the GSPMD engines — the
Pallas kernel composes under ``shard_map`` + ``vmap`` + ``scan``.

The built round also composes under the fused multi-round chunking of
:func:`repro.core.fl.make_chunked_round` (an outer ``lax.scan`` carrying
params/opt_state/key/residual with the per-round collective inside) and
under ``jax.jit`` buffer donation — every carried operand keeps its dtype
across the round, so donated client replicas are reused in place.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.fl import (
    FLConfig,
    TOPOLOGIES,
    make_grad_fn,
    make_local_round,
    pipeline_round_keys,
)
from repro.optim.optimizers import Optimizer
from repro.utils.tree import tree_broadcast_axis0


def _shard_map(f, mesh, in_specs, out_specs, auto=frozenset()):
    """shard_map across jax versions: jax.shard_map (>=0.6, check_vma) or
    jax.experimental.shard_map (0.4.x, check_rep). Replication checking is
    disabled either way — the out_specs deliberately mix P(client) and P().

    ``auto`` names mesh axes left under GSPMD control (partial-manual mode):
    the body is manual over the remaining axes only, and operands keep
    whatever sharding the partitioner gave them along the auto axes. The
    mesh_2d engine (repro.mesh) runs with ``auto={"model"}`` so model
    tensors stay sharded straight through the per-client round body."""
    kw = {"auto": frozenset(auto)} if auto else {}
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, **kw)


def make_shard_map_round(loss_fn: Callable, optimizer: Optimizer,
                         cfg: FLConfig, mesh: Mesh,
                         client_axis: str = "client",
                         topology: str = "full_average", pipeline=None):
    """Build round_step(params, opt_state, batch, key, sigmas) on ``mesh``.

    params/opt_state carry a leading client axis sharded over ``client_axis``
    (local view inside the shard_map has leading dim n_clients / n_shards).
    batch leaves are (C, tau, B, ...); sigmas is (C,).

    With an :class:`repro.core.aggregation.AggregationPipeline` the built
    function takes ``(params, opt_state, batch, key, sigmas, mask, residual)``
    and returns ``(new_p, new_s, new_residual, metrics)`` — identical
    signature and per-client key/compressor streams as the GSPMD engines, so
    the three engines stay parity-testable under every pipeline setting. The
    mask, residual, and per-client compressor keys are sharded over
    ``client_axis`` like everything else; the cross-shard reduction is the
    same single ``lax.pmean``-class collective (a psum of masked block sums).
    """
    if topology not in TOPOLOGIES:
        raise ValueError(f"topology must be one of {TOPOLOGIES}, "
                         f"got {topology!r}")
    if pipeline is not None and topology != "full_average":
        raise ValueError("the aggregation pipeline requires "
                         "topology='full_average'")
    n_shards = mesh.shape[client_axis]
    if cfg.n_clients % n_shards:
        raise ValueError(f"{cfg.n_clients} clients do not divide over "
                         f"{n_shards} '{client_axis}' mesh slots")
    block = cfg.n_clients // n_shards
    local_round = make_local_round(make_grad_fn(loss_fn, cfg), optimizer,
                                   cfg.tau)

    def per_shard(params, opt_state, batches, keys, sigmas):
        """Local view: leading axis = block (this slot's client replicas)."""
        new_p, new_s, ms = jax.vmap(local_round)(params, opt_state, batches,
                                                 keys, sigmas)
        ms = jax.tree.map(jnp.mean, ms)         # mean over the local block
        if topology == "full_average":
            # ---- Eq. (7b): THE collective — one pmean over the client axis
            # (local block mean first, so the pmean moves C/n_shards fewer
            # bytes than an all-gather would).
            pmean = lambda x: jax.lax.pmean(x, axis_name=client_axis)
            avg = jax.tree.map(lambda x: pmean(jnp.mean(x, axis=0)), new_p)
            new_p = tree_broadcast_axis0(avg, block)
            if cfg.average_opt_state:
                avg_s = jax.tree.map(
                    lambda x: pmean(jnp.mean(x.astype(jnp.float32), axis=0)
                                    ).astype(x.dtype), new_s)
                new_s = tree_broadcast_axis0(avg_s, block)
        ms = jax.tree.map(lambda x: jax.lax.pmean(x, client_axis), ms)
        return new_p, new_s, ms

    def per_shard_pipeline(params, opt_state, batches, keys, agg_keys,
                           sigmas, mask, residual):
        """Pipeline variant: masked/compressed Eq.-7b with error feedback.
        The collective is one psum of the block's masked update sums —
        except under the adversarial extensions (robust aggregator /
        secure sum / update attack), whose reductions do not decompose
        into block partial sums: there the pipeline gathers the blocks
        into the full (C, ...) view via ``all_gather`` (tiled along the
        client axis, so row order matches the GSPMD engines) and every
        shard computes the identical global result."""
        new_p, new_s, ms = jax.vmap(local_round)(params, opt_state, batches,
                                                 keys, sigmas)
        psum = lambda x: jax.lax.psum(x, axis_name=client_axis)
        gather = lambda x: jax.lax.all_gather(x, client_axis, axis=0,
                                              tiled=True)
        new_p, new_s, residual = pipeline.aggregate(
            params, new_p, new_s, opt_state, residual, mask, agg_keys,
            all_sum=psum, all_gather=gather)
        ms = pipeline.masked_metrics(ms, mask, all_sum=psum)
        return new_p, new_s, residual, ms

    cspec = P(client_axis)
    if pipeline is None:
        smapped = _shard_map(
            per_shard, mesh,
            in_specs=(cspec, cspec, cspec, cspec, cspec),
            out_specs=(cspec, cspec, P()))

        def round_step(params, opt_state, batch, key, sigmas):
            keys = jax.random.split(key, cfg.n_clients)
            return smapped(params, opt_state, batch, keys, sigmas)

        return round_step

    smapped = _shard_map(
        per_shard_pipeline, mesh,
        in_specs=(cspec,) * 8,
        out_specs=(cspec, cspec, cspec, P()))

    def round_step_pipeline(params, opt_state, batch, key, sigmas, mask,
                            residual):
        keys, agg_keys = pipeline_round_keys(key, cfg.n_clients)
        return smapped(params, opt_state, batch, keys, agg_keys, sigmas,
                       mask, residual)

    return round_step_pipeline

"""Mamba2 SSD chunk-scan Pallas kernel (TPU target; validated interpret=True).

Grid: (B*H, n_chunks) with the chunk axis sequential ("arbitrary" dimension
semantics on TPU) so the (P, N) SSM state lives in VMEM scratch and is
carried across chunk iterations — the inter-chunk recurrence never touches
HBM. Per chunk the kernel computes the intra-chunk quadratic form and the
state contribution:

    L      = cumsum(dt * a)                         (Q,)
    M[t,s] = (c_t . b_s) * exp(L_t - L_s) * dt_s * [s <= t]
    y      = M @ x  +  exp(L_t) * (c_t . state)
    state <- exp(L_Q) * state + sum_s exp(L_Q - L_s) dt_s x_s b_s^T

Tiles: x (Q, P)=(128, 64), b/c (Q, N)=(128, 64), state (P, N)=(64, 64) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sfin_ref, s_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, 0].astype(jnp.float32)                    # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)                  # (Q,)
    a = a_ref[0].astype(jnp.float32)                       # ()
    b = b_ref[0, 0].astype(jnp.float32)                    # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)                    # (Q, N)
    state = s_ref[...]                                     # (P, N)

    l = jnp.cumsum(dt * a)                                 # (Q,)
    l_last = l[-1]

    # intra-chunk
    scores = c @ b.T                                       # (Q, Q)
    decay = jnp.exp(l[:, None] - l[None, :])
    q = x.shape[0]
    tri = (jax.lax.iota(jnp.int32, q)[:, None]
           >= jax.lax.iota(jnp.int32, q)[None, :])
    m = jnp.where(tri, scores * decay, 0.0) * dt[None, :]
    y = m @ x                                              # (Q, P)

    # inter-chunk: contribution of the carried state
    y += jnp.exp(l)[:, None] * (c @ state.T)               # (Q, P)

    # state update
    w = jnp.exp(l_last - l) * dt                           # (Q,)
    new_state = jnp.exp(l_last) * state + (w[:, None] * x).T @ b
    s_ref[...] = new_state
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit():
        sfin_ref[0] = new_state.astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd(x, dt, a, b_in, c_in, *, chunk: int = 128,
               interpret: bool = True):
    """x (B,S,H,P); dt (B,S,H) post-softplus; a (H,); b/c (B,S,N).
    Returns (y (B,S,H,P), final state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # layout: (B*H, nc, chunk, ...)
    xb = jnp.moveaxis(x, 2, 1).reshape(bsz * h, nc, chunk, p)
    dtb = jnp.moveaxis(dt, 2, 1).reshape(bsz * h, nc, chunk)
    ab = jnp.tile(a[None, :], (bsz, 1)).reshape(bsz * h)
    bb = jnp.broadcast_to(b_in[:, None], (bsz, h, s, n)
                          ).reshape(bsz * h, nc, chunk, n)
    cb = jnp.broadcast_to(c_in[:, None], (bsz, h, s, n)
                          ).reshape(bsz * h, nc, chunk, n)

    y, s_fin = pl.pallas_call(
        _ssd_kernel,
        grid=(bsz * h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, chunk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, p, n), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz * h, nc, chunk, p), x.dtype),
            jax.ShapeDtypeStruct((bsz * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xb, dtb, ab, bb, cb)

    y = y.reshape(bsz, h, s, p)
    y = jnp.moveaxis(y, 1, 2)                              # (B,S,H,P)
    return y, s_fin.reshape(bsz, h, p, n)

"""Pallas compute kernels behind a backend dispatch layer.

The kernels accelerate the compute hot-spots of this repo — above all the
fused DP clip+noise update (paper Eq. 7a / Eq. 23), the per-step cost that
dominates DP-PASGD on a resource-constrained device — plus the model-side
flash attention, RWKV6 WKV scan, and Mamba2 SSD chunk scan.

Kernel backends
---------------
Every kernel is registered in :mod:`repro.kernels.dispatch` with its
pure-jnp oracle from :mod:`repro.kernels.ref` as a guaranteed-correct
fallback, and is selected by name + backend:

    from repro.kernels import get_kernel
    y, norm = get_kernel("dp_clip_noise")(g, noise, clip_norm, sigma)

========== ==============================================================
backend    meaning
========== ==============================================================
"pallas"   Mosaic-compiled Pallas (TPU only)
"interpret" ``pallas_call(interpret=True)`` — kernel body as jax ops (CPU)
"ref"      the pure-jnp oracle; always available
"auto"     ``KERNEL_BACKEND`` env var if set, else the best backend whose
           cached capability probe passes (pallas > interpret > ref)
========== ==============================================================

The capability probe runs each kernel once on tiny shapes against its
oracle, so a drifted jax/pallas API degrades to "ref" instead of erroring.
The training hot path selects its backend declaratively through
``FederationSpec(kernel_backend=...)``; :mod:`repro.kernels.ops` carries
the plain-function wrappers. ``register_kernel`` adds new kernels without
touching any call site.
"""
from repro.kernels.dispatch import (
    KERNEL_BACKENDS,
    available_backends,
    backend_works,
    get_kernel,
    kernel_names,
    register_kernel,
    resolve_backend,
)

__all__ = [
    "KERNEL_BACKENDS", "available_backends", "backend_works", "get_kernel",
    "kernel_names", "register_kernel", "resolve_backend",
]

"""Kernel backend dispatch: registry + capability-probed auto-selection.

Mirrors the round-engine registry of ``repro.api.engines`` one layer down:
every compute kernel is registered under a name with

  * its Pallas implementation (accepting an ``interpret`` kwarg), and
  * its pure-jnp oracle from :mod:`repro.kernels.ref` — guaranteed correct
    on any jax, so the suite degrades gracefully instead of erroring when
    the installed jax/pallas API drifts.

Backends
--------
``"pallas"``     Mosaic-compiled Pallas (requires a TPU backend).
``"interpret"``  ``pallas_call(interpret=True)`` — same kernel body executed
                 as jax ops; the CPU/CI path.
``"ref"``        the pure-jnp oracle; always available.
``"auto"``       resolve at first use: the ``KERNEL_BACKEND`` environment
                 variable if set, else the best backend whose cached
                 capability probe passes (pallas > interpret > ref).

A capability probe runs the registered smoke test (tiny shapes, allclose vs
the oracle) once per (kernel, backend) and caches the verdict, so a drifted
Pallas API costs one failed probe instead of a red suite.

Usage::

    from repro.kernels.dispatch import get_kernel
    y, norm = get_kernel("dp_clip_noise")(g, noise, clip_norm, sigma)
    fa = get_kernel("flash_attention", backend="interpret")

``register_kernel`` adds new kernels without touching call sites; the
engine hot path selects purely via ``FederationSpec.kernel_backend``.
"""
from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

KERNEL_BACKENDS = ("pallas", "interpret", "ref", "auto")
KERNEL_BACKEND_ENV = "KERNEL_BACKEND"
# comma-separated backends to report as unavailable (capability simulation:
# CI's oracle-only leg sets "pallas,interpret" to rehearse a broken pallas)
KERNEL_DISABLE_ENV = "KERNEL_DISPATCH_DISABLE"


@dataclass(frozen=True)
class KernelEntry:
    """One registered kernel: Pallas impl + oracle + capability probe."""
    name: str
    pallas_fn: Callable | None      # accepts interpret=... keyword
    ref_fn: Callable                # pure-jnp oracle (ignores tuning kwargs)
    probe: Callable[[Callable], bool] | None  # smoke test given a bound impl


_REGISTRY: dict[str, KernelEntry] = {}


def register_kernel(name: str, *, ref: Callable, pallas: Callable | None = None,
                    probe: Callable[[Callable], bool] | None = None) -> KernelEntry:
    """Register ``name`` with its oracle and (optionally) its Pallas impl.

    ``ref`` must share the Pallas impl's positional signature and swallow its
    tuning keywords (block sizes etc.) so callers can pass them uniformly.
    """
    entry = KernelEntry(name=name, pallas_fn=pallas, ref_fn=ref, probe=probe)
    _REGISTRY[name] = entry
    return entry


def kernel_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def _entry(name: str) -> KernelEntry:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown kernel {name!r}; registered: "
                       f"{kernel_names()}") from None


def _bind(entry: KernelEntry, backend: str) -> Callable:
    if backend == "ref":
        return entry.ref_fn
    if entry.pallas_fn is None:
        raise ValueError(f"kernel {entry.name!r} has no pallas implementation")
    return functools.partial(entry.pallas_fn,
                             interpret=(backend == "interpret"))


def _disabled_backends() -> frozenset[str]:
    raw = os.environ.get(KERNEL_DISABLE_ENV, "")
    return frozenset(b.strip() for b in raw.split(",") if b.strip())


@functools.lru_cache(maxsize=None)
def _probe_verdict(name: str, backend: str) -> bool:
    """The cached probe run. Only ever called from a clean trace state —
    see backend_works."""
    entry = _entry(name)
    if entry.probe is None:
        return False
    try:
        return bool(entry.probe(_bind(entry, backend)))
    except Exception:
        return False


def backend_works(name: str, backend: str) -> bool:
    """Capability probe (cached): does ``backend`` run ``name`` here?

    "ref" is always True. Backends named in ``KERNEL_DISPATCH_DISABLE``
    read as unavailable without probing (oracle-only rehearsal). "pallas"
    (Mosaic-compiled) additionally requires a TPU default backend before
    the probe is even attempted. Any exception from the probe — the
    drifted-API AttributeErrors included — reads as "unavailable", never
    as a test failure.

    Probes cannot run while an outer jax trace is active (the pallas smoke
    test would capture ambient tracers and spuriously fail); if the first
    resolution happens mid-trace, answer "unavailable" for that call
    WITHOUT caching the verdict, so a later eager resolution still probes
    for real. Engine builders resolve their backends eagerly at build time
    (clipping.make_dp_grad_fn, aggregation.make_compressor), so the hot
    path never takes this fallback.
    """
    if backend == "ref":
        return True
    if backend in _disabled_backends():
        return False
    entry = _entry(name)
    if entry.pallas_fn is None:
        return False
    if backend == "pallas" and jax.default_backend() != "tpu":
        return False
    if not jax.core.trace_state_clean():
        return False                   # uncached: retry eagerly later
    return _probe_verdict(name, backend)


# probe-cache reset for tests/tooling (the cache moved to _probe_verdict
# when the trace-state guard landed; keep the historic reset point)
backend_works.cache_clear = _probe_verdict.cache_clear


def available_backends(name: str) -> tuple[str, ...]:
    """Concrete backends (probe-verified) for ``name``, best first."""
    return tuple(b for b in ("pallas", "interpret", "ref")
                 if backend_works(name, b))


def resolve_backend(name: str, backend: str = "auto") -> str:
    """Map ``backend="auto"`` to a concrete backend for this process.

    Resolution order: an explicit non-auto argument wins untouched (callers
    get the real error if they force a broken backend); else the
    ``KERNEL_BACKEND`` env var if set; else the best probed backend for
    this process's default jax backend. "Best" is platform-aware: on TPU,
    ``pallas > interpret > ref``; everywhere else ``interpret`` is ranked
    BELOW the jnp oracle — ``pallas_call(interpret=True)`` executes the
    kernel body element-block by element-block as jax ops, ~100x slower
    than the fused oracle on CPU (measured in benchmarks/throughput.py).
    Interpret mode is a correctness rehearsal, not a fast path; it stays
    reachable explicitly (``backend="interpret"`` /
    ``KERNEL_BACKEND=interpret``) and via the parity suites.
    """
    if backend not in KERNEL_BACKENDS:
        raise ValueError(f"backend must be one of {KERNEL_BACKENDS}, "
                         f"got {backend!r}")
    if backend != "auto":
        return backend
    env = os.environ.get(KERNEL_BACKEND_ENV, "").strip()
    if env and env != "auto":
        if env not in KERNEL_BACKENDS:
            raise ValueError(f"${KERNEL_BACKEND_ENV}={env!r} is not one of "
                             f"{KERNEL_BACKENDS}")
        return env
    candidates = (("pallas", "interpret") if jax.default_backend() == "tpu"
                  else ("pallas",))       # off-TPU: ref outranks interpret
    for candidate in candidates:
        if backend_works(name, candidate):
            return candidate
    return "ref"


def get_kernel(name: str, backend: str = "auto") -> Callable:
    """The ``name`` kernel bound to a concrete backend.

    The returned callable has the kernel's public signature (oracle-compatible
    positional args; tuning kwargs accepted by every backend).
    """
    return _bind(_entry(name), resolve_backend(name, backend))


# ---------------------------------------------------------------------------
# built-in kernels: oracle adapters + smoke probes
# ---------------------------------------------------------------------------
# Adapters give every backend one signature: the oracle swallows the Pallas
# tuning kwargs. Probes run tiny shapes through the bound impl and compare
# against the oracle — cheap enough to pay once per process.

def _close(a, b, tol=1e-4) -> bool:
    return all(bool(jnp.allclose(jnp.asarray(x, jnp.float32),
                                 jnp.asarray(y, jnp.float32),
                                 rtol=tol, atol=tol))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _dp_clip_noise_oracle(g, noise, clip_norm, sigma, **_tuning):
    return _ref.dp_clip_noise_ref(g, noise, clip_norm, sigma)


def _dp_clip_noise_probe(impl) -> bool:
    g = jnp.linspace(-2.0, 3.0, 37, dtype=jnp.float32)
    noise = jnp.ones((37,), jnp.float32)
    got = impl(g, noise, 1.0, 0.25, block=16)
    return _close(got, _ref.dp_clip_noise_ref(g, noise, 1.0, 0.25))


def _quantize_decompress_oracle(x, u, bits, **_tuning):
    return _ref.quantize_decompress_ref(x, u, bits)


def _quantize_decompress_probe(impl) -> bool:
    x = jnp.linspace(-3.0, 2.0, 41, dtype=jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(3), (41,), jnp.float32)
    got = impl(x, u, 4, block=16)
    return _close(got, _ref.quantize_decompress_ref(x, u, 4))


def _flash_attention_oracle(q, k, v, *, causal=True, window=0, **_tuning):
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def _flash_attention_probe(impl) -> bool:
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (1, 1, 8, 8), jnp.float32) for kk in ks)
    got = impl(q, k, v, block_q=8, block_k=8)
    return _close(got, _ref.flash_attention_ref(q, k, v))


def _rwkv6_scan_oracle(r, k, v, w, u, s0=None, **_tuning):
    return _ref.rwkv6_scan_ref(r, k, v, w, u, s0)


def _rwkv6_scan_probe(impl) -> bool:
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r, k, v = (jax.random.normal(kk, (1, 1, 3, 4), jnp.float32)
               for kk in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (1, 1, 3, 4)))
    u = jax.random.normal(ks[4], (1, 4), jnp.float32)
    got = impl(r, k, v, w, u)
    return _close(got, _ref.rwkv6_scan_ref(r, k, v, w, u))


def _mamba2_ssd_oracle(x, dt, a, b_in, c_in, **_tuning):
    return _ref.mamba2_ssd_ref(x, dt, a, b_in, c_in)


def _mamba2_ssd_probe(impl) -> bool:
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (1, 4, 1, 2), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 4, 1)))
    a = -jnp.exp(jax.random.normal(ks[2], (1,)) * 0.3)
    b_in = jax.random.normal(ks[3], (1, 4, 2), jnp.float32)
    c_in = jax.random.normal(ks[4], (1, 4, 2), jnp.float32)
    got = impl(x, dt, a, b_in, c_in, chunk=4)
    return _close(got, _ref.mamba2_ssd_ref(x, dt, a, b_in, c_in), tol=1e-3)


def _cohort_gather_scatter_oracle(cache, slots, rows=None, **_tuning):
    return _ref.cohort_gather_scatter_ref(cache, slots, rows)


def _cohort_gather_scatter_probe(impl) -> bool:
    cache = jnp.arange(9 * 5, dtype=jnp.float32).reshape(9, 5)
    slots = jnp.asarray([7, 0, 4], jnp.int32)
    rows = -jnp.arange(3 * 5, dtype=jnp.float32).reshape(3, 5)
    got_g = impl(cache, slots)
    got_s = impl(cache, slots, rows)
    return (_close(got_g, _ref.cohort_gather_scatter_ref(cache, slots))
            and _close(got_s,
                       _ref.cohort_gather_scatter_ref(cache, slots, rows)))


def _register_builtins() -> None:
    from repro.kernels.cohort_gather import cohort_gather_scatter
    from repro.kernels.dp_clip_noise import dp_clip_noise
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.mamba2_ssd import mamba2_ssd
    from repro.kernels.quantize_decompress import quantize_decompress
    from repro.kernels.rwkv6_scan import rwkv6_scan

    register_kernel("dp_clip_noise", pallas=dp_clip_noise,
                    ref=_dp_clip_noise_oracle, probe=_dp_clip_noise_probe)
    register_kernel("quantize_decompress", pallas=quantize_decompress,
                    ref=_quantize_decompress_oracle,
                    probe=_quantize_decompress_probe)
    register_kernel("flash_attention", pallas=flash_attention,
                    ref=_flash_attention_oracle, probe=_flash_attention_probe)
    register_kernel("rwkv6_scan", pallas=rwkv6_scan,
                    ref=_rwkv6_scan_oracle, probe=_rwkv6_scan_probe)
    register_kernel("mamba2_ssd", pallas=mamba2_ssd,
                    ref=_mamba2_ssd_oracle, probe=_mamba2_ssd_probe)
    register_kernel("cohort_gather_scatter", pallas=cohort_gather_scatter,
                    ref=_cohort_gather_scatter_oracle,
                    probe=_cohort_gather_scatter_probe)


_register_builtins()

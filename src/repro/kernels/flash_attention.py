"""Flash attention Pallas kernel (TPU target; validated interpret=True).

Grid: (batch*heads, n_q_blocks). Each program streams kv blocks for one q
tile with the online-softmax recurrence; running max/denominator/accumulator
live in VMEM scratch. Causal blocks beyond the diagonal are skipped
(`hi = ceil((q_idx+1)*bq / bk)`), and with a sliding window the lower bound
is raised too — the block-sparsity that makes SWA O(S*W).

BlockSpec tiling: q tile (bq, hd), kv tiles (bk, hd); MXU-aligned when
bq, bk, hd are multiples of 128 (hd=128 for most assigned archs).

kv streaming uses the ref-indexing API (``ref[0, pl.dslice(...), :]``) —
the tuple-index ``pl.load`` form was dropped upstream. Selected through
``repro.kernels.dispatch`` (backend "pallas"/"interpret"), with
``ref.flash_attention_ref`` as the registered oracle fallback.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, s, window, scale):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale              # (bq, hd)
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)

    n_kv = s // bk
    hi = jnp.minimum((qi + 1) * bq + bk - 1, s) // bk     # causal upper bound
    if window:
        lo = jnp.maximum(qi * bq - window, 0) // bk
    else:
        lo = 0

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        k_pos = j * bk + jax.lax.iota(jnp.int32, bk)
        scores = q @ k.T                                   # (bq, bk)
        mask = q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, q.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q/k/v (B, H, S, hd) (GQA pre-expanded). Returns (B, H, S, hd)."""
    assert causal, "only causal supported (decoder stacks)"
    b, h, s, hd = q.shape
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(b * h, s, hd)
    kf = k.reshape(b * h, s, hd)
    vf = v.reshape(b * h, s, hd)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, s=s,
                               window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, hd)

"""RWKV6 WKV recurrence Pallas kernel (TPU target; validated interpret=True).

Grid: (B, H). Each program owns one head's (hd x hd) state in VMEM and walks
the sequence with a fori_loop:
    y_t = r_t . (S + u * k_t v_t^T);  S <- diag(w_t) S + k_t v_t^T
The state tile (hd, hd) = (64, 64) f32 = 16 KiB — deep in VMEM; inputs are
streamed per (b, h) as (S, hd) tiles.

Per-timestep rows are read/written with the ref-indexing API
(``ref[0, 0, pl.dslice(t, 1), :]``) — the tuple-index ``pl.load``/``pl.store``
form was dropped upstream. Selected through ``repro.kernels.dispatch``
(backend "pallas"/"interpret"), with ``ref.rwkv6_scan_ref`` as the
registered oracle fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                 *, seq_len):
    u = u_ref[0].astype(jnp.float32)                      # (hd,)
    state0 = s0_ref[0, 0].astype(jnp.float32)             # (hd, hd)

    def _load_t(ref, t):
        row = ref[0, 0, pl.dslice(t, 1), :]               # (1, hd)
        return row[0].astype(jnp.float32)                 # (hd,)

    def body(t, state):
        rt = _load_t(r_ref, t)
        kt = _load_t(k_ref, t)
        vt = _load_t(v_ref, t)
        wt = _load_t(w_ref, t)
        kv = kt[:, None] * vt[None, :]                    # (hd, hd)
        y = ((state + u[:, None] * kv) * rt[:, None]).sum(axis=0)
        y_ref[0, 0, pl.dslice(t, 1), :] = y[None].astype(y_ref.dtype)
        return state * wt[:, None] + kv

    state = jax.lax.fori_loop(0, seq_len, body, state0)
    sout_ref[0, 0] = state.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv6_scan(r, k, v, w, u, s0=None, *, interpret: bool = True):
    """r/k/v/w (B, H, S, hd); u (H, hd); s0 (B, H, hd, hd) or None.
    Returns (y (B, H, S, hd), final state (B, H, hd, hd))."""
    b, h, s, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    kernel = functools.partial(_wkv6_kernel, seq_len=s)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, s, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, hd), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, s, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, hd), r.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_out

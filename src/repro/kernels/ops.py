"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True unless running on a real TPU backend, so the
same call sites work in this CPU container (kernel body executed in Python)
and on the target hardware (Mosaic-compiled).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dp_clip_noise import dp_clip_noise as _dp_clip_noise
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.mamba2_ssd import mamba2_ssd as _mamba2_ssd
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6_scan
from repro.utils.tree import tree_split_keys


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def dp_clip_noise_flat(g, noise, clip_norm, sigma, block: int = 64 * 1024):
    return _dp_clip_noise(g, noise, clip_norm, sigma, block=block,
                          interpret=_interpret())


def dp_clip_noise_tree(grads, key, clip_norm, sigma, block: int = 64 * 1024):
    """Tree-level fused clip+noise: flatten -> kernel -> unflatten.
    Drop-in replacement for core.clipping clip_tree + tree_add_noise."""
    leaves, treedef = jax.tree.flatten(grads)
    sizes = [x.size for x in leaves]
    flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])
    noise = jax.random.normal(key, flat.shape, jnp.float32)
    out, norm = dp_clip_noise_flat(flat, noise, clip_norm, sigma, block)
    news = []
    off = 0
    for x, n in zip(leaves, sizes):
        news.append(out[off:off + n].reshape(x.shape).astype(x.dtype))
        off += n
    return jax.tree.unflatten(treedef, news), norm


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    return _flash_attention(q, k, v, causal=causal, window=window,
                            block_q=block_q, block_k=block_k,
                            interpret=_interpret())


def rwkv6_scan(r, k, v, w, u, s0=None):
    return _rwkv6_scan(r, k, v, w, u, s0, interpret=_interpret())


def mamba2_ssd(x, dt, a, b_in, c_in, *, chunk: int = 128):
    return _mamba2_ssd(x, dt, a, b_in, c_in, chunk=chunk,
                       interpret=_interpret())

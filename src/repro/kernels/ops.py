"""Public kernel wrappers, routed through the backend dispatch layer.

Every wrapper resolves its implementation via
:func:`repro.kernels.dispatch.get_kernel` (``backend="auto"`` by default):
Mosaic-compiled Pallas on TPU, ``interpret=True`` Pallas on CPU, and the
pure-jnp oracle when the installed jax/pallas API cannot run the kernel —
so the same call sites work in this CPU container, on the target hardware,
and on a drifted jax without erroring.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import get_kernel
from repro.kernels.dp_clip_noise import DEFAULT_BLOCK


def dp_clip_noise_flat(g, noise, clip_norm, sigma, block: int = DEFAULT_BLOCK,
                       backend: str = "auto"):
    """Fused clip+noise on flat (N,) arrays; returns (y, pre-clip norm)."""
    return get_kernel("dp_clip_noise", backend)(g, noise, clip_norm, sigma,
                                                block=block)


def dp_clip_noise_tree(grads, key, clip_norm, sigma,
                       block: int = DEFAULT_BLOCK, backend: str = "auto"):
    """Tree-level fused clip+noise: flatten -> kernel -> unflatten.

    Drop-in replacement for core.clipping ``clip_tree`` + ``tree_add_noise``;
    preserves each leaf's dtype. ``key=None`` skips the noise draw entirely
    (clip-only kernel lowering — no noise buffer materialized). The noise is
    drawn per leaf from split keys — the same stream structure as
    ``tree_add_noise`` — so swapping backends (or swapping the legacy path
    for this one) only changes arithmetic order, never the sampled noise.
    """
    leaves, treedef = jax.tree.flatten(grads)
    sizes = [x.size for x in leaves]
    flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])
    if key is None:
        noise = None
    else:
        keys = jax.random.split(key, len(leaves))
        noise = jnp.concatenate(
            [jax.random.normal(k, x.shape, jnp.float32).reshape(-1)
             for k, x in zip(keys, leaves)])
    out, norm = dp_clip_noise_flat(flat, noise, clip_norm, sigma,
                                   block=block, backend=backend)
    news = []
    off = 0
    for x, n in zip(leaves, sizes):
        news.append(out[off:off + n].reshape(x.shape).astype(x.dtype))
        off += n
    return jax.tree.unflatten(treedef, news), norm


def quantize_decompress_flat(x, u, bits: int, block: int = DEFAULT_BLOCK,
                             backend: str = "auto"):
    """Fused QSGD quantize->dequantize round trip on flat (N,) arrays.

    ``u ~ U[0,1)`` supplies the stochastic-rounding randomness (caller PRNG,
    like the noise operand of dp_clip_noise). Returns (y, scale)."""
    return get_kernel("quantize_decompress", backend)(x, u, bits, block=block)


def cohort_gather(cache, slots, backend: str = "auto"):
    """Gather the cohort's (K, D) rows out of the (S, D) resident cache."""
    return get_kernel("cohort_gather_scatter", backend)(cache, slots)


def cohort_scatter(cache, slots, rows, backend: str = "auto"):
    """Scatter the cohort's updated (K, D) rows back into the (S, D)
    resident cache (in place under jit: the pallas form aliases the cache
    operand, the oracle is a donated ``.at[slots].set``)."""
    return get_kernel("cohort_gather_scatter", backend)(cache, slots, rows)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    backend: str = "auto"):
    return get_kernel("flash_attention", backend)(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k)


def rwkv6_scan(r, k, v, w, u, s0=None, backend: str = "auto"):
    return get_kernel("rwkv6_scan", backend)(r, k, v, w, u, s0)


def mamba2_ssd(x, dt, a, b_in, c_in, *, chunk: int = 128,
               backend: str = "auto"):
    return get_kernel("mamba2_ssd", backend)(x, dt, a, b_in, c_in, chunk=chunk)

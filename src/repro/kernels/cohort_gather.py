"""Fused cohort gather/scatter over the device-resident shard cache.

The resident-cohort driver (:mod:`repro.population.resident`) keeps the
sticky per-client state of S "warm" virtual clients as a device block —
the (S, D) error-feedback residual cache — and draws a fresh cohort of K
slot indices every round inside the fused ``lax.scan``. The per-round
boundary then needs exactly two data movements, both expressed here as one
Pallas kernel pair instead of host numpy:

* **gather**: ``rows = cache[slots]`` — the cohort's K rows pulled into the
  round's (K, D) block;
* **scatter**: ``cache[slots] = rows`` — the round's updated rows written
  back in place (``input_output_aliases`` pins the cache buffer, so the
  scan carry never double-buffers the S-row cache).

Both are pure row copies — no arithmetic — so every backend (mosaic,
interpret, jnp oracle) is bit-identical by construction; the dispatch
probe checks exact equality, not tolerance.

Implementation: the slot vector rides as a *scalar-prefetch* operand
(``pltpu.PrefetchScalarGridSpec``), available to the BlockSpec index maps
before the body runs — the canonical TPU pattern for index-driven gathers
(the block for grid step i is ``cache[slots[i]]``, DMA'd directly; no
one-hot matmul, no full-cache stream). Grid is (K,): one row block per
sampled cohort slot, so the kernel touches K*D elements of the S*D cache.

TPU tiling caveat: row blocks are (1, D) with D padded to the 128-lane
boundary; sublane-1 blocks relayout on some mosaic versions — the
dispatch probe demotes to interpret/ref where the compiled form is
unavailable, which is also the expected CPU path in this container.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_LANES = 128


def _pad_lanes(x: jax.Array) -> jax.Array:
    """Pad the trailing dim up to the 128-lane boundary (zeros)."""
    d = x.shape[-1]
    rem = (-d) % _LANES
    if rem == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, rem)])


def _gather_kernel(slots_ref, cache_ref, out_ref):
    del slots_ref                  # consumed by the index maps
    out_ref[...] = cache_ref[...]


def _scatter_kernel(slots_ref, rows_ref, cache_ref, out_ref):
    del slots_ref, cache_ref       # cache is aliased into out
    out_ref[...] = rows_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def cohort_gather_scatter(cache, slots, rows=None, *, interpret: bool = False):
    """Gather (``rows=None``) or scatter rows of the (S, D) cohort cache.

    gather:  ``cohort_gather_scatter(cache, slots)`` -> (K, D) rows
    scatter: ``cohort_gather_scatter(cache, slots, rows)`` -> (S, D) cache'

    ``slots`` is the cohort's (K,) int32 cache-slot vector — unique by the
    cohort-sampler contract, so the scatter is order-independent. The
    scatter aliases the cache operand into the output: under jit/scan the
    S-row cache updates in place (§Perf opt — the whole point of keeping
    the warm set resident).
    """
    s, d = cache.shape
    k = slots.shape[0]
    slots = slots.astype(jnp.int32)
    padded = _pad_lanes(cache)
    dp = padded.shape[-1]
    if rows is None:
        out = pl.pallas_call(
            _gather_kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(k,),
                in_specs=[pl.BlockSpec((1, dp), lambda i, slots: (slots[i], 0))],
                out_specs=pl.BlockSpec((1, dp), lambda i, slots: (i, 0)),
            ),
            out_shape=jax.ShapeDtypeStruct((k, dp), cache.dtype),
            interpret=interpret,
        )(slots, padded)
        return out[:, :d]
    out = pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(k,),
            in_specs=[pl.BlockSpec((1, dp), lambda i, slots: (i, 0)),
                      pl.BlockSpec((1, dp), lambda i, slots: (slots[i], 0))],
            out_specs=pl.BlockSpec((1, dp), lambda i, slots: (slots[i], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((s, dp), cache.dtype),
        # operand order is (slots, rows, cache): alias the cache (input 2)
        # into the output so the resident block updates in place
        input_output_aliases={2: 0},
        interpret=interpret,
    )(slots, _pad_lanes(rows), padded)
    return out[:, :d]

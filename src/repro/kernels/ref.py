"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dp_clip_noise_ref(g, noise, clip_norm, sigma):
    """y = g * min(1, C/||g||_2) + sigma * noise ; returns (y, norm).
    ``noise=None`` -> clip only (mirrors the kernel's clip-only lowering)."""
    norm = jnp.sqrt(jnp.sum(jnp.square(g.astype(jnp.float32))))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))
    y = g.astype(jnp.float32) * scale
    if noise is not None:
        y = y + sigma * noise.astype(jnp.float32)
    return y.astype(g.dtype), norm


def quantize_decompress_ref(x, u, bits):
    """QSGD round trip: y = sign(x) * floor(|x|/scale + u) * scale with
    scale = max|x| / (2**bits - 1); u ~ U[0,1) drives the stochastic
    rounding. Returns (y, scale)."""
    levels = (1 << bits) - 1
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / levels
    level = jnp.floor(jnp.abs(xf) / scale + u.astype(jnp.float32))
    return (jnp.sign(xf) * level * scale).astype(x.dtype), scale


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q/k/v (B, H, S, hd) same head count (GQA expanded by caller)."""
    s = q.shape[2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)


def rwkv6_scan_ref(r, k, v, w, u, s0=None):
    """r/k/v/w (B, H, S, hd); u (H, hd). Returns (y, final state)."""
    b, h, s, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(state, t):
        rt, kt, vt, wt = (x[:, :, t].astype(jnp.float32)
                          for x in (r, k, v, w))
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj",
                       rt, state + u[None, :, :, None] * kv)
        return state * wt[..., None] + kv, y

    state, ys = jax.lax.scan(step, s0, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 2), state


def mamba2_ssd_ref(x, dt, a, b_in, c_in, h0=None):
    """Sequential SSD oracle. x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N).
    Returns (y (B,S,H,P), final state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, t):
        xt = x[:, t].astype(jnp.float32)              # (B,H,P)
        dtt = dt[:, t].astype(jnp.float32)            # (B,H)
        bt = b_in[:, t].astype(jnp.float32)           # (B,N)
        ct = c_in[:, t].astype(jnp.float32)
        decay = jnp.exp(dtt * a[None, :])
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, bt)
        new = state * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", new, ct)
        return new, y

    state, ys = jax.lax.scan(step, h0, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1), state


def cohort_gather_scatter_ref(cache, slots, rows=None):
    """Cohort row gather/scatter oracle (exact, no arithmetic).

    gather (``rows=None``): (S, D) cache x (K,) slots -> (K, D) rows.
    scatter: writes ``rows`` over the slot rows -> updated (S, D) cache.
    Slots are unique by the cohort contract, so the scatter order never
    matters and every backend is bit-identical.
    """
    slots = slots.astype(jnp.int32)
    if rows is None:
        return jnp.take(cache, slots, axis=0)
    return cache.at[slots].set(rows)

"""Pluggable round-engine registry.

A *round engine* is a builder that turns a :class:`FederationSpec` into the
unified round function

    round_fn(params, opt_state, batch, key, sigmas)
        -> (new_params, new_opt_state, metrics)

with params/opt_state carrying a leading client axis C, batch leaves shaped
(C, tau, B, ...), and sigmas (C,). When the spec configures an aggregation
pipeline (``participation`` < 1 or ``compressor`` != "none"), every engine
instead builds the pipeline form

    round_fn(params, opt_state, batch, key, sigmas, mask, residual)
        -> (new_params, new_opt_state, new_residual, metrics)

where ``mask`` is the per-round 0/1 participation mask (sampled by
``run_round`` from the FLState RNG) and ``residual`` is the (C, D)
error-feedback state carried on :class:`repro.api.FLState`. Three engines
ship by default:

    "vmap"      GSPMD engine, clients vmapped (core/fl.py) — the default on
                one device and the lowering used for pod-scale GSPMD runs.
    "map"       same math with ``lax.map`` over clients (sequential; low
                peak memory for big-model CPU simulations).
    "shard_map" explicit collective schedule (core/fl_shard_map.py): one
                ``lax.pmean`` over the client mesh axis per round.
    "mesh_2d"   2D client x model plane (repro.mesh): shard_map's client
                blocking plus GSPMD model sharding within each client slab
                — the engine for replicas too big for one device.

``register_engine`` adds new execution strategies without touching the
drivers: everything upstream selects purely via ``FederationSpec.engine``.
The buffered-async engine ("async_buffered", :mod:`repro.asyncfl`) is
registered here too, but its builder returns a flush/dispatch *executor*
rather than a round_fn — ``round_fn_for``/``chunked_round_fn_for`` refuse
async specs and point at the ``repro.asyncfl`` drivers, and
``engine="auto"`` never resolves to it (async execution is always an
explicit choice).

Every engine's Eq.-7a clip+noise step runs through the fused
``dp_clip_noise`` kernel of :mod:`repro.kernels.dispatch` — the backend is
selected by ``FederationSpec.kernel_backend`` and carried to the gradient
builder by ``spec.fl_config()``; it is part of ``spec.engine_key()``, so
switching backends recompiles rather than aliasing cached rounds.

Two jitted forms are cached: the single round (:func:`round_fn_for`, per
engine key) and the fused multi-round scan (:func:`chunked_round_fn_for`,
per engine key + participant count — the scan bakes the per-round mask
sampling in) that lowers a whole chunk of rounds into one XLA program. Both donate the
params/opt_state/residual operands — the input FLState's device buffers are
consumed and reused in place (§Perf opt: no double-buffered client
replicas); callers continue from the returned state.
"""
from __future__ import annotations

from typing import Any, Callable, Protocol

import jax
import numpy as np

from repro.api.spec import ENGINES, FederationSpec

RoundFn = Callable[..., tuple[Any, Any, dict]]


class RoundEngine(Protocol):
    """Builder protocol: spec -> round_fn (uncompiled; callers jit)."""

    def __call__(self, spec: FederationSpec) -> RoundFn: ...


_REGISTRY: dict[str, RoundEngine] = {}


def register_engine(name: str, builder: RoundEngine | None = None):
    """Register a round-engine builder under ``name``.

    Usable directly (``register_engine("x", build)``) or as a decorator
    (``@register_engine("x")``).
    """
    def _add(b: RoundEngine) -> RoundEngine:
        _REGISTRY[name] = b
        return b

    return _add if builder is None else _add(builder)


def available_engines() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_engine(spec: FederationSpec) -> str:
    """Map ``engine="auto"`` to a concrete engine for this process.

    The decision table lives in :mod:`repro.mesh.placement`: mesh_2d when
    the spec's ``replica_bytes`` footprint hint exceeds the per-device
    budget (a whole replica cannot fit, so the model axis must shard);
    shard_map when >1 device can each own a whole client block; otherwise
    the vmap GSPMD engine. Adversarial specs never place onto mesh_2d.
    """
    if spec.engine != "auto":
        return spec.engine
    from repro.mesh.placement import choose_engine
    return choose_engine(spec.n_clients, len(jax.devices()),
                         replica_bytes=spec.replica_bytes,
                         adversarial=spec.is_adversarial())


def get_engine(name_or_spec: str | FederationSpec) -> RoundEngine:
    """Look up an engine builder by name, or resolve it from a spec."""
    name = (resolve_engine(name_or_spec)
            if isinstance(name_or_spec, FederationSpec) else name_or_spec)
    if name == "auto":
        raise ValueError("pass a FederationSpec to resolve engine='auto'")
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; registered: "
                       f"{available_engines()}") from None


# ---------------------------------------------------------------------------
# built-in engines
# ---------------------------------------------------------------------------

def _n_client_shards(n_clients: int, n_devices: int) -> int:
    """Largest divisor of n_clients that fits in the local device count."""
    return max(d for d in range(1, min(n_clients, n_devices) + 1)
               if n_clients % d == 0)


@register_engine("vmap")
def build_vmap_engine(spec: FederationSpec) -> RoundFn:
    from repro.core.fl import make_round_step
    return make_round_step(spec.loss_fn, spec.optimizer,
                           spec.fl_config(vmap_clients=True),
                           topology=spec.topology,
                           pipeline=spec.aggregation_pipeline())


@register_engine("map")
def build_map_engine(spec: FederationSpec) -> RoundFn:
    from repro.core.fl import make_round_step
    return make_round_step(spec.loss_fn, spec.optimizer,
                           spec.fl_config(vmap_clients=False),
                           topology=spec.topology,
                           pipeline=spec.aggregation_pipeline())


@register_engine("async_buffered")
def build_async_engine(spec: FederationSpec):
    """Buffered-async engine (repro.asyncfl): returns the per-spec
    :class:`repro.asyncfl.engine.AsyncBufferedExecutor` — a flush/dispatch
    executor object, NOT a ``round_fn`` (async rounds have no single
    synchronous round function; ``round_fn_for`` refuses async specs and
    points at the ``repro.asyncfl`` drivers). Imported lazily: asyncfl
    builds on repro.api and a module-level import would cycle."""
    from repro.asyncfl.engine import AsyncBufferedExecutor
    return AsyncBufferedExecutor(spec)


@register_engine("shard_map")
def build_shard_map_engine(spec: FederationSpec) -> RoundFn:
    """Explicit-collective engine on a 1-D ("client",) mesh over the local
    devices; clients that outnumber devices are blocked per mesh slot."""
    from jax.sharding import Mesh

    from repro.core.fl_shard_map import make_shard_map_round
    n_shards = _n_client_shards(spec.n_clients, len(jax.devices()))
    mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("client",))
    return make_shard_map_round(spec.loss_fn, spec.optimizer,
                                spec.fl_config(vmap_clients=True), mesh,
                                topology=spec.topology,
                                pipeline=spec.aggregation_pipeline())


@register_engine("mesh_2d")
def build_mesh_2d_engine(spec: FederationSpec) -> RoundFn:
    """2D client x model plane (repro.mesh): clients block over the manual
    "client" mesh axis, model tensors shard 1/dm over the GSPMD-controlled
    "model" axis. Mesh shape comes from the spec or the placement default
    (which reads the ``replica_bytes`` footprint hint); clients that do not
    divide the client axis are padded inside the engine."""
    from repro.launch.mesh import make_mesh_2d
    from repro.mesh.engine import make_mesh_2d_round
    from repro.mesh.placement import default_mesh_shape
    shape = spec.mesh_shape or default_mesh_shape(
        spec.n_clients, len(jax.devices()),
        replica_bytes=spec.replica_bytes)
    mesh = make_mesh_2d(shape)
    rules = dict(spec.sharding_rules) if spec.sharding_rules else None
    return make_mesh_2d_round(spec.loss_fn, spec.optimizer,
                              spec.fl_config(vmap_clients=True), mesh,
                              rules=rules, topology=spec.topology,
                              pipeline=spec.aggregation_pipeline())


# compiled-round caches: keyed on the engine-relevant slice of the spec, so
# budget edits (spec.replace(eps_th=...)) reuse the compiled function.
# Bounded LRU: engine keys hold loss/optimizer closures and XLA executables,
# so an unbounded map would leak across spec sweeps.
_ROUND_FN_CACHE: dict[tuple, RoundFn] = {}
_CHUNKED_FN_CACHE: dict[tuple, RoundFn] = {}
_ROUND_FN_CACHE_MAX = 32


def _cached(cache: dict, key, build) -> RoundFn:
    fn = cache.pop(key, None)
    if fn is None:
        fn = build()
        while len(cache) >= _ROUND_FN_CACHE_MAX:
            cache.pop(next(iter(cache)))
    cache[key] = fn                # (re)insert at MRU position
    return fn


def round_fn_for(spec: FederationSpec) -> RoundFn:
    """The jitted round function for ``spec`` (cached per engine key).

    Donation: the params / opt_state / error-feedback-residual operands are
    donated to XLA, so big-model client replicas update in place instead of
    double-buffering every round. Callers must treat the input FLState's
    device buffers as CONSUMED on a successful call — ``run_round`` returns
    the successor state; keep using that. (Host-side copies, e.g. a
    checkpoint written before the call, are unaffected.)
    """
    if spec.is_async():
        raise ValueError(
            "engine='async_buffered' has no synchronous round function: "
            "drive it with repro.asyncfl (init_async_state / "
            "run_async_cycle / train_async), not run_round/run_rounds")
    donate = (0, 1, 6) if spec.has_pipeline() else (0, 1)
    return _cached(
        _ROUND_FN_CACHE, spec.engine_key(),
        lambda: jax.jit(get_engine(resolve_engine(spec))(spec),
                        donate_argnums=donate))


def chunked_round_fn_for(spec: FederationSpec) -> RoundFn:
    """The jitted fused-multi-round scan for ``spec``: the engine's round
    body wrapped by :func:`repro.core.fl.make_chunked_round`, with the same
    donation contract as :func:`round_fn_for` (params / opt_state /
    residual update in place). One wrapper serves every chunk length — the
    scan reads R from the batches operand at trace time, so jit's own
    shape-keyed cache holds one executable per R. Operand/return shapes are
    documented on ``make_chunked_round``; ``repro.api.state.run_rounds`` is
    the driver that feeds it."""
    from repro.core.fl import make_chunked_round

    if spec.is_async():
        raise ValueError(
            "engine='async_buffered' has no fused sync scan: drive it with "
            "repro.asyncfl.train_async (its chunking is host-paced over "
            "the simulated event schedule)")
    pipeline = spec.has_pipeline()

    def build():
        raw = get_engine(resolve_engine(spec))(spec)
        chunk = make_chunked_round(
            raw, pipeline=pipeline, n_clients=spec.n_clients,
            n_participants=spec.participants_per_round())
        return jax.jit(chunk, donate_argnums=(0, 1, 5) if pipeline
                       else (0, 1))

    # unlike round_fn_for — where the mask is a runtime operand and
    # engine_key() is the whole story — the chunk samples masks inside the
    # scan, so the participant count is baked into the closure and must key
    # the cache, or a participation sweep would reuse the wrong protocol
    return _cached(_CHUNKED_FN_CACHE,
                   (spec.engine_key(), spec.participants_per_round()),
                   build)


_RESIDENT_FN_CACHE: dict[tuple, RoundFn] = {}


def resident_chunked_round_fn_for(spec: FederationSpec,
                                  data_resident: bool = False) -> RoundFn:
    """The jitted fused scan for the *resident-cohort* population path: the
    engine's pipeline round body wrapped by
    :func:`repro.core.fl.make_resident_chunked_round` — per-round cohort
    slot indices threaded into the scan, error-feedback residual gathered
    from / scattered into the device-resident (S, D) cohort cache via the
    ``cohort_gather_scatter`` kernel. Signature::

        fn(params, opt_state, batches, slots, key, sigmas, cache)
            -> (params, opt_state, key, cache, metrics, masks)

    Donation covers params / opt_state / cache (argnums 0, 1, 6): the
    resident cache updates in place across chunks, like the dense path's
    residual. ``data_resident=True`` selects the stationary-population
    form — ``batches`` becomes the warm-shard (S, tau, B, ...) cache
    pytree, NOT donated (it persists across chunks), and each round's
    batch is gathered from it inside the scan. Cached per (engine key,
    participant count, data_resident) like :func:`chunked_round_fn_for`;
    jit's shape cache handles S and R. Non-pipeline population specs have
    no device-resident sticky state — their resident driver reuses
    :func:`chunked_round_fn_for` directly, so this builder refuses them
    rather than compile a dead cache operand.
    """
    from repro.core.fl import make_resident_chunked_round

    if spec.is_async():
        raise ValueError(
            "engine='async_buffered' has no fused sync scan: drive it with "
            "repro.asyncfl.train_async")
    if not spec.has_pipeline():
        raise ValueError(
            "resident_chunked_round_fn_for is the pipeline (compressed /"
            " partial-participation) form; without a pipeline there is no "
            "device-resident sticky state — use chunked_round_fn_for")

    def build():
        raw = get_engine(resolve_engine(spec))(spec)
        chunk = make_resident_chunked_round(
            raw, n_clients=spec.n_clients,
            n_participants=spec.participants_per_round(),
            kernel_backend=spec.kernel_backend,
            data_resident=data_resident)
        return jax.jit(chunk, donate_argnums=(0, 1, 6))

    return _cached(_RESIDENT_FN_CACHE,
                   (spec.engine_key(), spec.participants_per_round(),
                    data_resident),
                   build)


assert set(ENGINES) - {"auto"} == set(_REGISTRY), "built-in engines drifted"

"""Pluggable round-engine registry.

A *round engine* is a builder that turns a :class:`FederationSpec` into the
unified round function

    round_fn(params, opt_state, batch, key, sigmas)
        -> (new_params, new_opt_state, metrics)

with params/opt_state carrying a leading client axis C, batch leaves shaped
(C, tau, B, ...), and sigmas (C,). When the spec configures an aggregation
pipeline (``participation`` < 1 or ``compressor`` != "none"), every engine
instead builds the pipeline form

    round_fn(params, opt_state, batch, key, sigmas, mask, residual)
        -> (new_params, new_opt_state, new_residual, metrics)

where ``mask`` is the per-round 0/1 participation mask (sampled by
``run_round`` from the FLState RNG) and ``residual`` is the (C, D)
error-feedback state carried on :class:`repro.api.FLState`. Three engines
ship by default:

    "vmap"      GSPMD engine, clients vmapped (core/fl.py) — the default on
                one device and the lowering used for pod-scale GSPMD runs.
    "map"       same math with ``lax.map`` over clients (sequential; low
                peak memory for big-model CPU simulations).
    "shard_map" explicit collective schedule (core/fl_shard_map.py): one
                ``lax.pmean`` over the client mesh axis per round.

``register_engine`` adds new execution strategies (e.g. async or hierarchical
aggregation) without touching the drivers: everything upstream selects purely
via ``FederationSpec.engine``.

Every engine's Eq.-7a clip+noise step runs through the fused
``dp_clip_noise`` kernel of :mod:`repro.kernels.dispatch` — the backend is
selected by ``FederationSpec.kernel_backend`` and carried to the gradient
builder by ``spec.fl_config()``; it is part of ``spec.engine_key()``, so
switching backends recompiles rather than aliasing cached rounds.
"""
from __future__ import annotations

from typing import Any, Callable, Protocol

import jax
import numpy as np

from repro.api.spec import ENGINES, FederationSpec

RoundFn = Callable[..., tuple[Any, Any, dict]]


class RoundEngine(Protocol):
    """Builder protocol: spec -> round_fn (uncompiled; callers jit)."""

    def __call__(self, spec: FederationSpec) -> RoundFn: ...


_REGISTRY: dict[str, RoundEngine] = {}


def register_engine(name: str, builder: RoundEngine | None = None):
    """Register a round-engine builder under ``name``.

    Usable directly (``register_engine("x", build)``) or as a decorator
    (``@register_engine("x")``).
    """
    def _add(b: RoundEngine) -> RoundEngine:
        _REGISTRY[name] = b
        return b

    return _add if builder is None else _add(builder)


def available_engines() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_engine(spec: FederationSpec) -> str:
    """Map ``engine="auto"`` to a concrete engine for this process.

    shard_map when >1 device can each own a whole client block; otherwise
    the vmap GSPMD engine.
    """
    if spec.engine != "auto":
        return spec.engine
    n_dev = len(jax.devices())
    if n_dev > 1 and _n_client_shards(spec.n_clients, n_dev) > 1:
        return "shard_map"
    return "vmap"


def get_engine(name_or_spec: str | FederationSpec) -> RoundEngine:
    """Look up an engine builder by name, or resolve it from a spec."""
    name = (resolve_engine(name_or_spec)
            if isinstance(name_or_spec, FederationSpec) else name_or_spec)
    if name == "auto":
        raise ValueError("pass a FederationSpec to resolve engine='auto'")
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; registered: "
                       f"{available_engines()}") from None


# ---------------------------------------------------------------------------
# built-in engines
# ---------------------------------------------------------------------------

def _n_client_shards(n_clients: int, n_devices: int) -> int:
    """Largest divisor of n_clients that fits in the local device count."""
    return max(d for d in range(1, min(n_clients, n_devices) + 1)
               if n_clients % d == 0)


@register_engine("vmap")
def build_vmap_engine(spec: FederationSpec) -> RoundFn:
    from repro.core.fl import make_round_step
    return make_round_step(spec.loss_fn, spec.optimizer,
                           spec.fl_config(vmap_clients=True),
                           topology=spec.topology,
                           pipeline=spec.aggregation_pipeline())


@register_engine("map")
def build_map_engine(spec: FederationSpec) -> RoundFn:
    from repro.core.fl import make_round_step
    return make_round_step(spec.loss_fn, spec.optimizer,
                           spec.fl_config(vmap_clients=False),
                           topology=spec.topology,
                           pipeline=spec.aggregation_pipeline())


@register_engine("shard_map")
def build_shard_map_engine(spec: FederationSpec) -> RoundFn:
    """Explicit-collective engine on a 1-D ("client",) mesh over the local
    devices; clients that outnumber devices are blocked per mesh slot."""
    from jax.sharding import Mesh

    from repro.core.fl_shard_map import make_shard_map_round
    n_shards = _n_client_shards(spec.n_clients, len(jax.devices()))
    mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("client",))
    return make_shard_map_round(spec.loss_fn, spec.optimizer,
                                spec.fl_config(vmap_clients=True), mesh,
                                topology=spec.topology,
                                pipeline=spec.aggregation_pipeline())


# compiled-round cache: keyed on the engine-relevant slice of the spec, so
# budget edits (spec.replace(eps_th=...)) reuse the compiled function.
# Bounded LRU: engine keys hold loss/optimizer closures and XLA executables,
# so an unbounded map would leak across spec sweeps.
_ROUND_FN_CACHE: dict[tuple, RoundFn] = {}
_ROUND_FN_CACHE_MAX = 32


def round_fn_for(spec: FederationSpec) -> RoundFn:
    """The jitted round function for ``spec`` (cached per engine key)."""
    key = spec.engine_key()
    fn = _ROUND_FN_CACHE.pop(key, None)
    if fn is None:
        fn = jax.jit(get_engine(resolve_engine(spec))(spec))
        while len(_ROUND_FN_CACHE) >= _ROUND_FN_CACHE_MAX:
            _ROUND_FN_CACHE.pop(next(iter(_ROUND_FN_CACHE)))
    _ROUND_FN_CACHE[key] = fn      # (re)insert at MRU position
    return fn


assert set(ENGINES) - {"auto"} == set(_REGISTRY), "built-in engines drifted"

"""``repro.api`` — the single public entry point for running DP-PASGD.

Declarative spec, pluggable engines, pure functional state:

    from repro.api import FederationSpec, init_state, run_round, train

    spec = FederationSpec(n_clients=16, tau=8, loss_fn=loss, optimizer=sgd(0.3),
                          sigmas=sigmas, batch_sizes=batch_sizes,
                          eps_th=4.0, c_th=1000.0, engine="auto")
    state = init_state(spec, params0)
    state, out = train(spec, state, sampler, eval_fn=eval_fn)

or drive rounds yourself with ``run_round(spec, state, batch)`` — budget
checks (incremental ``peek_epsilon_fast``) raise :class:`BudgetExceeded`
before a round would overrun eps_th / C_th. ``run_rounds`` fuses a chunk of
R rounds into one jitted ``lax.scan`` (one dispatch, <=1 host sync per
chunk, bit-identical to the per-round loop); ``train(chunk_rounds=R)``
drives it with a double-buffered batch prefetcher. Engines ("vmap" | "map" |
"shard_map" | "auto") are selected purely via ``FederationSpec.engine``;
``register_engine`` plugs in new execution strategies. The mutable
:class:`Federation` is a back-compat wrapper over the functional core.
"""
from repro.api.engines import (
    RoundEngine,
    available_engines,
    chunked_round_fn_for,
    get_engine,
    register_engine,
    resolve_engine,
    round_fn_for,
)
from repro.api.federation import Federation
from repro.api.spec import COMPRESSORS, ENGINES, FederationSpec
from repro.api.state import (
    BudgetExceeded,
    FLState,
    accountant_view,
    collapse_clients,
    eval_params,
    exceeds_budgets,
    init_state,
    load_state,
    materialize_record,
    max_epsilon,
    peek_epsilon_fast,
    PrefetchFailed,
    round_batch,
    round_batches,
    round_rho_charges,
    rounds_within_budgets,
    run_round,
    run_rounds,
    save_state,
    sigmas_for,
    train,
)

__all__ = [
    "COMPRESSORS", "ENGINES", "FederationSpec",
    "RoundEngine", "available_engines", "chunked_round_fn_for", "get_engine",
    "register_engine", "resolve_engine", "round_fn_for",
    "BudgetExceeded", "FLState", "accountant_view", "collapse_clients",
    "eval_params",
    "exceeds_budgets", "init_state", "load_state", "materialize_record",
    "max_epsilon", "peek_epsilon_fast", "PrefetchFailed",
    "round_batch", "round_batches", "round_rho_charges",
    "rounds_within_budgets",
    "run_round", "run_rounds", "save_state", "sigmas_for", "train",
    "Federation",
]

"""``repro.api`` — the single public entry point for running DP-PASGD.

Declarative spec, pluggable engines, pure functional state:

    from repro.api import FederationSpec, init_state, run_round, train

    spec = FederationSpec(n_clients=16, tau=8, loss_fn=loss, optimizer=sgd(0.3),
                          sigmas=sigmas, batch_sizes=batch_sizes,
                          eps_th=4.0, c_th=1000.0, engine="auto")
    state = init_state(spec, params0)
    state, out = train(spec, state, sampler, eval_fn=eval_fn)

or drive rounds yourself with ``run_round(spec, state, batch)`` — budget
checks (``PrivacyAccountant.peek_epsilon``) raise :class:`BudgetExceeded`
before a round would overrun eps_th / C_th. Engines ("vmap" | "map" |
"shard_map" | "auto") are selected purely via ``FederationSpec.engine``;
``register_engine`` plugs in new execution strategies. The mutable
:class:`Federation` is a back-compat wrapper over the functional core.
"""
from repro.api.engines import (
    RoundEngine,
    available_engines,
    get_engine,
    register_engine,
    resolve_engine,
    round_fn_for,
)
from repro.api.federation import Federation
from repro.api.spec import COMPRESSORS, ENGINES, FederationSpec
from repro.api.state import (
    BudgetExceeded,
    FLState,
    accountant_view,
    collapse_clients,
    eval_params,
    exceeds_budgets,
    init_state,
    load_state,
    max_epsilon,
    round_batch,
    run_round,
    save_state,
    train,
)

__all__ = [
    "COMPRESSORS", "ENGINES", "FederationSpec",
    "RoundEngine", "available_engines", "get_engine", "register_engine",
    "resolve_engine", "round_fn_for",
    "BudgetExceeded", "FLState", "accountant_view", "collapse_clients",
    "eval_params",
    "exceeds_budgets", "init_state", "load_state", "max_epsilon",
    "round_batch", "run_round", "save_state", "train",
    "Federation",
]

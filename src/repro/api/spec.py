"""Declarative configuration of one DP-PASGD federation.

:class:`FederationSpec` is the single configuration surface of ``repro.api``:
it folds together the round structure (``FLConfig``), the privacy knobs
(eps_th / delta / per-client sigmas with auto Eq.-23 design), the resource
budgets (Eq. 8), the communication topology, and the execution engine. A
spec is frozen and hashable, so compiled round functions are cached per
spec and experiment sweeps are plain ``spec.replace(...)`` calls.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from repro.core.aggregation import (
    COMPRESSORS,
    compression_wire_ratio,
    validate_compression,
)
from repro.core.fl import TOPOLOGIES, Budgets, FLConfig, design_sigmas
from repro.kernels.dispatch import KERNEL_BACKENDS
from repro.optim.optimizers import Optimizer

ENGINES = ("vmap", "map", "shard_map", "mesh_2d", "async_buffered", "auto")


@dataclass(frozen=True)
class FederationSpec:
    """Everything needed to run DP-PASGD, in one frozen declarative object.

    ``loss_fn`` and ``optimizer`` are the only non-serializable fields — the
    model plugs in through them; every other field is a plain scalar/tuple.
    """
    # -- federation / round structure --------------------------------------
    n_clients: int
    tau: int                        # local steps per round (aggregation period)
    loss_fn: Callable[[Any, Any], Any]
    optimizer: Optimizer
    topology: str = "full_average"  # "full_average" | "local_only"
    engine: str = "auto"            # "vmap" | "map" | "shard_map" |
    #   "async_buffered" | "auto". "async_buffered" is the FedBuff-style
    #   buffered-async engine (repro.asyncfl): the server aggregates the
    #   first ``buffer_size`` arrivals per flush with staleness-weighted
    #   updates and redispatches immediately — driven by
    #   ``repro.asyncfl.train_async``, NOT by run_round/train (which raise
    #   for it). "auto" never resolves to it: async is always explicit.
    #   "mesh_2d" is the 2D client x model plane (repro.mesh): clients
    #   block over the mesh "client" axis exactly as under "shard_map",
    #   while model tensors shard 1/dm over the GSPMD-controlled "model"
    #   axis — the engine for replicas too big for one device. "auto"
    #   resolves to it when ``replica_bytes`` exceeds the per-device budget
    #   (repro.mesh.placement).
    kernel_backend: str = "auto"    # clip+noise kernel backend
    #   ("pallas" | "interpret" | "ref" | "auto"): every engine's Eq.-7a
    #   clip+noise step runs through kernels.dispatch get_kernel(
    #   "dp_clip_noise") on this backend; "auto" probes the installed
    #   jax/pallas and falls back to the jnp oracle

    # -- aggregation pipeline (Eq. 7b boundary; core/aggregation.py) -------
    participation: float = 1.0      # fraction q in (0,1], or an int count of
    #   clients sampled per round (without replacement, from the FLState
    #   RNG); non-participants neither upload nor spend privacy that round.
    #   NOTE the type dispatch: participation=1.0 means ALL clients,
    #   participation=1 (int) means ONE client per round.
    compressor: str = "none"        # "none" | "topk" | "randk" | "qsgd"
    compression_ratio: float = 0.1  # fraction of coords kept (topk/randk)
    compression_bits: int = 8       # bits per coordinate (qsgd)
    amplify_participation: bool = False  # True: account q-amplified
    #   per-step rho (privacy.subsampled_rho — the marginal
    #   subsampled-Gaussian bound, valid in expectation over the
    #   participation draw, NOT for a realized-heavy client; opt in when
    #   the subsampling-blind adversary model fits). Default False charges
    #   realized participants the full Lemma-2 rho: the worst-case
    #   conditional ledger, sound for the executed mechanism. In population
    #   mode the amplification composes with the cohort ratio K/M
    #   (privacy.composed_subsampling_q): a client realizes a step only if
    #   sampled into the cohort AND participating within it.
    #   Accounting-only: not part of engine_key(), editable via replace()
    #   without recompiling.

    # -- adversarial fleet (core/robust.py + core/secureagg.py) ------------
    aggregator: str = "mean"        # "mean" | "median" | "trimmed_mean" |
    #   "norm_bound": the Eq.-7b reduction over participant updates.
    #   "mean" is the exact PR-3 pipeline; the robust choices bound the
    #   pull of a byzantine minority (Yin et al. 2018). Part of
    #   engine_key() — and with a robust aggregator the participant count
    #   becomes static too (the row gather bakes P in), so participation
    #   sweeps recompile there (unlike under "mean").
    trim_fraction: float = 0.1      # per-end trim of "trimmed_mean", [0,.5)
    norm_bound_factor: float = 3.0  # "norm_bound" rejects updates with
    #   L2 norm > factor * median participant norm
    secure_agg: bool = False        # pairwise-mask secure-aggregation
    #   simulation (core/secureagg.py): updates are fixed-point encoded,
    #   pairwise-masked, and only their modular SUM is ever materialized —
    #   requires aggregator="mean" (the server cannot compute a median of
    #   updates it never sees). Non-participants are the round's dropout
    #   set; their pair masks are reconstructed and subtracted.
    secure_frac_bits: int = 16      # fixed-point fractional bits (the one
    #   lossy step: quantization to a 2^-frac_bits grid at encode time)
    dp_accounting: str = "local"    # "local" | "central". "central" (needs
    #   secure_agg) accounts against the aggregate-only observer: the
    #   masked sum pools P participants' Gaussian noises, scaling the
    #   per-step rho by 1/P (secureagg.central_rho_scale — see its
    #   caveats). Accounting-only: NOT part of engine_key().
    attack: str = "none"            # "none" | "sign_flip" | "scale":
    #   byzantine upload corruption applied at the server boundary by a
    #   static set of round(byzantine_fraction * C) clients drawn from
    #   (seed, fraction) — resident federations only (the set binds to
    #   stable client identities; data-level label_flip for populations
    #   lives in repro.population.attacks.malicious_population).
    byzantine_fraction: float = 0.0
    attack_scale: float = 10.0      # multiplier of the "scale" attack

    # -- virtual client population (repro.population; cohort execution) ----
    population: int | None = None   # M virtual clients behind a lazy
    #   ClientPopulation; None -> the resident dense path. In population
    #   mode ``n_clients`` IS the per-round cohort size K — the device
    #   block holds K replicas and the population drivers gather only the
    #   sampled cohort, so device memory is bounded by K independent of M.
    cohort_size: int | None = None  # K; defaults to n_clients and must
    #   equal it (the one device-block size there is). Accounting-only
    #   like ``population``: M is NOT part of engine_key(), so population
    #   sweeps at fixed K reuse one compiled round.

    # -- 2D mesh plane (repro.mesh; engine="mesh_2d" or "auto") ------------
    mesh_shape: tuple[int, int] | None = None  # (dc, dm) client blocks x
    #   model shards over the local devices; None -> repro.mesh.placement
    #   .default_mesh_shape (all devices to client blocks unless
    #   ``replica_bytes`` forces a model axis). Part of engine_key(): the
    #   shape is the compiled collective layout.
    sharding_rules: Any = None      # logical->mesh axis overrides for the
    #   model annotations inside the mesh_2d body (dict or (name, axis)
    #   pairs; normalized to a sorted tuple of pairs so specs stay
    #   hashable). None -> repro.models.sharding.mesh2d_rules().
    replica_bytes: int | None = None  # per-replica params+opt-state
    #   footprint hint (repro.configs.shapes.replica_footprint_bytes) that
    #   drives the mesh-aware engine="auto" placement: over the per-device
    #   budget -> "mesh_2d". None -> placement never picks mesh_2d.

    # -- buffered-async federation (repro.asyncfl; engine="async_buffered")
    buffer_size: int | None = None  # B: arrivals aggregated per flush.
    #   None -> n_clients (the degenerate buffer whose zero-latency-spread
    #   alpha=0 run is bit-for-bit the sync vmap path — the identity gate).
    #   Part of engine_key(): B is the flush/dispatch block shape.
    staleness_alpha: float = 0.0    # staleness-weight exponent: an arrival
    #   that trained on a model s versions old is folded in with weight
    #   w(s) = 1 / (1 + s)^alpha (alpha=0: every arrival counts fully).
    #   Runtime operand, NOT in engine_key() — alpha sweeps reuse one
    #   compiled flush.

    # -- DP mechanism (Eq. 7a) ---------------------------------------------
    dp: bool = True
    clip_norm: float = 1.0          # G (sensitivity bound)
    num_microbatches: int = 1
    vmap_microbatches: bool = True
    grad_accumulate: str = "stack"  # "stack" | "scan" (§Perf opt)
    average_opt_state: bool = True

    # -- privacy accounting (§5.2) -----------------------------------------
    sigmas: tuple[float, ...] | None = None  # per-client σ; None -> design
    batch_sizes: tuple[int, ...] = ()        # X_m per client; () -> all 1
    eps_th: float = math.inf
    delta: float = 1e-4
    total_steps: int | None = None  # planned K for auto sigma design (Eq. 23)

    # -- resource budget (Eq. 8) -------------------------------------------
    c_th: float = math.inf
    c1: float = 100.0               # comm cost per aggregation
    c2: float = 1.0                 # compute cost per local step

    seed: int = 0

    def __post_init__(self):
        if self.n_clients <= 0:
            raise ValueError(f"n_clients must be positive, got {self.n_clients}")
        if self.tau <= 0:
            raise ValueError(f"tau must be positive, got {self.tau}")
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"topology must be one of {TOPOLOGIES}, "
                             f"got {self.topology!r}")
        if self.engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {self.engine!r}")
        if self.kernel_backend not in KERNEL_BACKENDS:
            raise ValueError(f"kernel_backend must be one of "
                             f"{KERNEL_BACKENDS}, got {self.kernel_backend!r}")
        validate_compression(self.compressor, self.compression_ratio,
                             self.compression_bits)
        if isinstance(self.participation, bool) or not (
                isinstance(self.participation, (int, float))):
            raise ValueError(f"participation must be a fraction in (0, 1] or "
                             f"an int count, got {self.participation!r}")
        if isinstance(self.participation, int):
            if not 1 <= self.participation <= self.n_clients:
                raise ValueError(
                    f"participation count must be in [1, {self.n_clients}], "
                    f"got {self.participation}")
        elif not 0.0 < self.participation <= 1.0:
            raise ValueError(f"participation fraction must be in (0, 1], "
                             f"got {self.participation}")
        from repro.core.robust import validate_aggregator, validate_attack
        from repro.core.secureagg import validate_secure
        validate_aggregator(self.aggregator, self.trim_fraction,
                            self.norm_bound_factor)
        validate_attack(self.attack, self.byzantine_fraction,
                        self.attack_scale)
        validate_secure(self.secure_frac_bits)
        if self.secure_agg and self.aggregator != "mean":
            raise ValueError(
                f"secure_agg only composes with aggregator='mean': the "
                f"server materializes nothing but the masked SUM, so it "
                f"cannot compute a {self.aggregator!r} of updates it never "
                f"sees")
        if self.dp_accounting not in ("local", "central"):
            raise ValueError(f"dp_accounting must be 'local' or 'central', "
                             f"got {self.dp_accounting!r}")
        if self.dp_accounting == "central" and not self.secure_agg:
            raise ValueError(
                "dp_accounting='central' accounts the aggregate-only "
                "observer of the masked sum and therefore requires "
                "secure_agg=True — without secure aggregation the server "
                "sees individual updates and only the local ledger is "
                "sound")
        if self.attack != "none" and self.population is not None:
            raise ValueError(
                "update attacks (sign_flip/scale) bind a static byzantine "
                "set to resident client identities; population cohort "
                "slots host different virtual clients every round. Model "
                "malicious populations at the data level instead "
                "(repro.population.attacks.malicious_population)")
        if self.is_adversarial() and self.engine == "async_buffered":
            raise ValueError(
                "engine='async_buffered' aggregates with its own "
                "staleness-weighted flush and does not route through "
                "AggregationPipeline.aggregate — robust aggregators, "
                "secure_agg, and update attacks are sync-engine features")
        if self.has_pipeline() and self.topology != "full_average":
            raise ValueError(
                "participation/compression/robust-secure aggregation shape "
                "the Eq.-7b aggregation and require "
                "topology='full_average' (local_only never communicates)")
        if self.engine == "async_buffered":
            if self.population is not None:
                raise ValueError(
                    "engine='async_buffered' does not compose with "
                    "population mode yet: in-flight slot state (pending "
                    "rho, residual, dispatch versions) is per resident "
                    "client, not per virtual id. Model fleet heterogeneity "
                    "through the latency side instead "
                    "(repro.asyncfl.HeteroLatency over a "
                    "HeterogeneousCohort's availability rates)")
            if self.topology != "full_average":
                raise ValueError("engine='async_buffered' aggregates "
                                 "arrivals into one global model and "
                                 "requires topology='full_average'")
            if self.buffer_size is None:
                object.__setattr__(self, "buffer_size", self.n_clients)
            if not 1 <= self.buffer_size <= self.n_clients:
                raise ValueError(
                    f"buffer_size must be in [1, {self.n_clients}] "
                    f"(at most one in-flight upload per client slot), "
                    f"got {self.buffer_size}")
        else:
            if self.buffer_size is not None:
                raise ValueError("buffer_size only applies to "
                                 "engine='async_buffered'")
            if self.staleness_alpha != 0.0:
                raise ValueError("staleness_alpha only applies to "
                                 "engine='async_buffered'")
        if self.staleness_alpha < 0.0:
            raise ValueError(f"staleness_alpha must be >= 0, "
                             f"got {self.staleness_alpha}")
        if self.engine not in ("mesh_2d", "auto"):
            if self.mesh_shape is not None:
                raise ValueError("mesh_shape only applies to "
                                 "engine='mesh_2d' (or 'auto', which may "
                                 "resolve to it)")
            if self.sharding_rules is not None:
                raise ValueError("sharding_rules only apply to "
                                 "engine='mesh_2d' (or 'auto')")
        if self.mesh_shape is not None:
            ms = tuple(int(x) for x in self.mesh_shape)
            if len(ms) != 2 or ms[0] < 1 or ms[1] < 1:
                raise ValueError(f"mesh_shape must be two positive ints "
                                 f"(dc, dm), got {self.mesh_shape!r}")
            object.__setattr__(self, "mesh_shape", ms)
        if self.sharding_rules is not None:
            items = (self.sharding_rules.items()
                     if isinstance(self.sharding_rules, dict)
                     else self.sharding_rules)
            norm = tuple(sorted(
                (str(k), tuple(v) if isinstance(v, (list, tuple)) else v)
                for k, v in items))
            object.__setattr__(self, "sharding_rules", norm)
        if self.replica_bytes is not None:
            if int(self.replica_bytes) <= 0:
                raise ValueError(f"replica_bytes must be positive, "
                                 f"got {self.replica_bytes}")
            object.__setattr__(self, "replica_bytes", int(self.replica_bytes))
        if self.engine == "mesh_2d" and self.is_adversarial():
            raise ValueError(
                "engine='mesh_2d' does not support the adversarial "
                "extensions (robust aggregator / secure sum / update "
                "attack): their full-view reductions gather exactly "
                "n_clients rows and do not compose with the padded client "
                "axis. Use engine='shard_map'")
        if self.cohort_size is not None and self.population is None:
            raise ValueError("cohort_size only makes sense with a "
                             "population (FederationSpec(population=M))")
        if self.population is not None:
            if self.cohort_size is None:
                object.__setattr__(self, "cohort_size", self.n_clients)
            if self.cohort_size != self.n_clients:
                raise ValueError(
                    f"cohort_size ({self.cohort_size}) must equal n_clients "
                    f"({self.n_clients}): in population mode n_clients IS "
                    f"the device cohort block")
            if self.population < self.n_clients:
                raise ValueError(
                    f"population ({self.population}) must be >= cohort size "
                    f"({self.n_clients})")
            if self.topology != "full_average":
                raise ValueError("cohort execution re-broadcasts one global "
                                 "model and requires topology='full_average'")
            if self.batch_sizes and len(set(self.batch_sizes)) > 1:
                raise ValueError(
                    "population mode needs uniform batch_sizes: cohort "
                    "slots host different virtual clients every round, so "
                    "per-slot heterogeneity has no client to bind to")
            if self.sigmas is not None and len(set(self.sigmas)) > 1:
                raise ValueError(
                    "population mode needs uniform sigmas (cohort slots "
                    "are not stable client identities)")
        # normalize sequences to hashable tuples
        if self.sigmas is not None:
            object.__setattr__(self, "sigmas",
                               tuple(float(s) for s in np.asarray(self.sigmas)))
            if len(self.sigmas) != self.n_clients:
                raise ValueError(f"sigmas has {len(self.sigmas)} entries for "
                                 f"{self.n_clients} clients")
        if self.batch_sizes:
            object.__setattr__(self, "batch_sizes",
                               tuple(int(x) for x in self.batch_sizes))
            if len(self.batch_sizes) != self.n_clients:
                raise ValueError(
                    f"batch_sizes has {len(self.batch_sizes)} entries for "
                    f"{self.n_clients} clients")

    # -- derived views ------------------------------------------------------
    def replace(self, **changes) -> "FederationSpec":
        return dataclasses.replace(self, **changes)

    def fl_config(self, vmap_clients: bool = True) -> FLConfig:
        """The engine-level FLConfig view of this spec."""
        return FLConfig(
            n_clients=self.n_clients, tau=self.tau, clip_norm=self.clip_norm,
            dp=self.dp, num_microbatches=self.num_microbatches,
            vmap_microbatches=self.vmap_microbatches,
            grad_accumulate=self.grad_accumulate,
            average_opt_state=self.average_opt_state,
            vmap_clients=vmap_clients,
            kernel_backend=self.kernel_backend)

    def budgets(self) -> Budgets:
        return Budgets(c_th=self.c_th, eps_th=self.eps_th,
                       c1=self.c1, c2=self.c2)

    # -- aggregation-pipeline views -----------------------------------------
    def participants_per_round(self) -> int:
        """The fixed per-round participant count (fraction q rounded to a
        count, floored at one client so every round aggregates something)."""
        if isinstance(self.participation, int):
            return self.participation
        return max(1, min(self.n_clients,
                          round(self.participation * self.n_clients)))

    def participation_fraction(self) -> float:
        """Realized q = participants / n_clients (drives amplification)."""
        return self.participants_per_round() / self.n_clients

    # -- async views ---------------------------------------------------------
    def is_async(self) -> bool:
        """Buffered-async execution (repro.asyncfl drivers)."""
        return self.engine == "async_buffered"

    def resolved_buffer_size(self) -> int:
        """B — arrivals aggregated per flush (n_clients unless set)."""
        if self.buffer_size is not None:
            return self.buffer_size
        return self.n_clients

    # -- population views ----------------------------------------------------
    def is_population(self) -> bool:
        """Cohort-execution mode: n_clients is a per-round cohort of K
        drawn from ``population`` virtual clients (repro.population)."""
        return self.population is not None

    def cohort_fraction(self) -> float:
        """K/M — the cohort subsampling rate over the population (1.0 in
        the resident dense mode, where every client is in every round's
        block)."""
        if self.population is None:
            return 1.0
        return self.n_clients / self.population

    def accounting_q(self) -> float:
        """The q the privacy ledger charges per realized step: 1.0 (full
        Lemma-2 rho, the sound conditional ledger) by default; with
        ``amplify_participation``, the composed probability that a given
        client realizes a step in a given round — cohort sampling (K/M)
        times within-cohort participation
        (:func:`repro.core.privacy.composed_subsampling_q`). Under
        ``dp_accounting="central"`` (secure aggregation's aggregate-only
        observer) the charge additionally scales by
        :func:`repro.core.secureagg.central_rho_scale` — 1/P for the P
        pooled participant noises; the factors compose multiplicatively
        because subsampling and noise pooling amplify independently."""
        q = 1.0
        if self.amplify_participation:
            from repro.core.privacy import composed_subsampling_q
            q = composed_subsampling_q(self.cohort_fraction(),
                                       self.participation_fraction())
        if self.dp_accounting == "central":
            from repro.core.secureagg import central_rho_scale
            q *= central_rho_scale(self.participants_per_round())
        return q

    def wire_ratio(self) -> float:
        """Compressed-update bytes as a fraction of the dense fp32 update
        (see :func:`repro.core.aggregation.compression_wire_ratio`)."""
        return compression_wire_ratio(self.compressor, self.compression_ratio,
                                      self.compression_bits)

    def comm_scale(self) -> float:
        """Eq.-8 comm-cost multiplier of the pipeline: wire_ratio * q."""
        return self.wire_ratio() * self.participation_fraction()

    def is_adversarial(self) -> bool:
        """Any adversarial-fleet feature active (robust aggregator, secure
        aggregation, or an update attack)? These are full-view reductions
        on the pipeline seam — ``has_pipeline()`` includes them."""
        return (self.aggregator != "mean" or self.secure_agg
                or self.attack != "none")

    def resolved_byzantine_flags(self) -> tuple[int, ...] | None:
        """The static 0/1 byzantine membership over the C resident clients
        (None without an attack) — deterministic per (seed, fraction), see
        :func:`repro.core.robust.byzantine_flags`."""
        if self.attack == "none":
            return None
        from repro.core.robust import byzantine_flags
        return byzantine_flags(self.n_clients, self.byzantine_fraction,
                               self.seed)

    def has_pipeline(self) -> bool:
        """Does this spec leave the seed all-clients/dense-mean protocol?
        When False, rounds are bit-for-bit the pre-pipeline engines."""
        return (self.compressor != "none"
                or self.participants_per_round() < self.n_clients
                or self.is_adversarial())

    def aggregation_pipeline(self):
        """The AggregationPipeline for this spec, or None for the default
        (full participation, dense updates) path."""
        if not self.has_pipeline():
            return None
        from repro.core.aggregation import AggregationPipeline, make_compressor
        from repro.core.robust import make_aggregator, make_attack
        from repro.core.secureagg import SecureMaskedSum
        flags = self.resolved_byzantine_flags()
        return AggregationPipeline(
            n_clients=self.n_clients,
            compressor=make_compressor(self.compressor, self.compression_ratio,
                                       self.compression_bits,
                                       self.kernel_backend),
            average_opt_state=self.average_opt_state,
            aggregator=make_aggregator(self.aggregator, self.trim_fraction,
                                       self.norm_bound_factor),
            secure=(SecureMaskedSum(self.n_clients, self.secure_frac_bits)
                    if self.secure_agg else None),
            attack=(make_attack(self.attack, flags, self.attack_scale)
                    if flags is not None else None),
            n_participants=self.participants_per_round())

    def round_cost(self) -> float:
        """Eq. (8) per round: c1 * comm_scale + c2 * tau — the pipeline
        scales only the aggregation (communication) term."""
        return self.c1 * self.comm_scale() + self.c2 * self.tau

    def resolved_batch_sizes(self) -> tuple[int, ...]:
        return self.batch_sizes or (1,) * self.n_clients

    def resolved_sigmas(self) -> np.ndarray:
        """Per-client noise std: explicit > auto-designed (Eq. 23) > zero.

        Auto design needs a finite ``eps_th`` and a planned ``total_steps``
        (the K of Eq. 23); it yields the smallest noise meeting eps_th at K.
        """
        if self.sigmas is not None:
            return np.asarray(self.sigmas, np.float32)
        if not self.dp:
            return np.zeros((self.n_clients,), np.float32)
        if not math.isfinite(self.eps_th) or self.total_steps is None:
            raise ValueError(
                "FederationSpec needs explicit sigmas, or a finite eps_th "
                "plus total_steps so Eq. 23 can design them")
        return design_sigmas(self.total_steps, self.clip_norm,
                             list(self.resolved_batch_sizes()),
                             self.eps_th, self.delta)

    def ledger_key(self) -> tuple:
        """Hash key of everything that shapes the privacy ledger's per-step
        charges and the device-resident sigma vector. ``repro.api.state``
        caches both per ledger key (the cached-sigma transfer and the
        incremental budget probe of the fused driver), so budget edits via
        ``replace(eps_th=..., c_th=...)`` with explicit sigmas reuse the
        cached constants, while any change to the mechanism (clip norm,
        sigmas, batch sizes) repopulates them.

        Memoized on the (frozen) instance: probing it several times per
        round must not re-run the O(C) Eq.-23 sigma design. ``replace()``
        builds a fresh instance, so edits never see a stale key."""
        cached = self.__dict__.get("_ledger_key")
        if cached is None:
            cached = (self.clip_norm, self.dp,
                      tuple(float(s) for s in self.resolved_sigmas()),
                      self.resolved_batch_sizes())
            object.__setattr__(self, "_ledger_key", cached)
        return cached

    def engine_key(self) -> tuple:
        """Hash key of everything that shapes the compiled round function.

        Budget / accounting fields (eps_th, c_th, delta,
        amplify_participation, ...) are excluded — changing them must NOT
        retrace or recompile the engine. Participation enters only as
        ``has_pipeline()``: the participant count itself is a runtime
        operand (the mask), so q sweeps reuse one compiled round. The
        population size M is excluded too: the compiled round only ever
        sees the K-block, so sweeping M at fixed K reuses one XLA program
        (that exclusion is what makes cohort execution memory-bounded by
        K, and the M == C identity gate literally the same executable).

        Exception to the participation-is-runtime rule: a robust
        aggregator bakes the STATIC participant count P into its gathered
        (P, D) block shape, so the key includes P exactly when
        ``aggregator != "mean"`` — q sweeps under the default mean still
        share one executable. ``dp_accounting`` is accounting-only
        (rides :meth:`accounting_q`) and stays excluded; the byzantine
        flag vector is included because it is baked into the compiled
        attack select (and captures the seed/fraction dependence).
        """
        return (self.loss_fn, self.optimizer, self.n_clients, self.tau,
                self.clip_norm, self.dp, self.num_microbatches,
                self.vmap_microbatches, self.grad_accumulate,
                self.average_opt_state, self.topology, self.engine,
                self.kernel_backend, self.has_pipeline(), self.compressor,
                self.compression_ratio, self.compression_bits,
                # async: B shapes the flush/dispatch blocks; staleness_alpha
                # deliberately excluded (a runtime weight operand)
                self.buffer_size,
                # 2D mesh plane: the mesh shape and logical rules ARE the
                # compiled layout; replica_bytes steers what engine="auto"
                # resolves to, so it must key the cache even though the
                # resolved engine ignores it
                self.mesh_shape, self.sharding_rules, self.replica_bytes,
                # adversarial fleets (PR 7)
                self.aggregator, self.trim_fraction, self.norm_bound_factor,
                (self.participants_per_round()
                 if self.aggregator != "mean" else None),
                self.secure_agg, self.secure_frac_bits,
                self.attack, self.attack_scale,
                self.resolved_byzantine_flags())

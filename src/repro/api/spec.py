"""Declarative configuration of one DP-PASGD federation.

:class:`FederationSpec` is the single configuration surface of ``repro.api``:
it folds together the round structure (``FLConfig``), the privacy knobs
(eps_th / delta / per-client sigmas with auto Eq.-23 design), the resource
budgets (Eq. 8), the communication topology, and the execution engine. A
spec is frozen and hashable, so compiled round functions are cached per
spec and experiment sweeps are plain ``spec.replace(...)`` calls.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from repro.core.fl import TOPOLOGIES, Budgets, FLConfig, design_sigmas
from repro.kernels.dispatch import KERNEL_BACKENDS
from repro.optim.optimizers import Optimizer

ENGINES = ("vmap", "map", "shard_map", "auto")


@dataclass(frozen=True)
class FederationSpec:
    """Everything needed to run DP-PASGD, in one frozen declarative object.

    ``loss_fn`` and ``optimizer`` are the only non-serializable fields — the
    model plugs in through them; every other field is a plain scalar/tuple.
    """
    # -- federation / round structure --------------------------------------
    n_clients: int
    tau: int                        # local steps per round (aggregation period)
    loss_fn: Callable[[Any, Any], Any]
    optimizer: Optimizer
    topology: str = "full_average"  # "full_average" | "local_only"
    engine: str = "auto"            # "vmap" | "map" | "shard_map" | "auto"
    kernel_backend: str = "auto"    # clip+noise kernel backend
    #   ("pallas" | "interpret" | "ref" | "auto"): every engine's Eq.-7a
    #   clip+noise step runs through kernels.dispatch get_kernel(
    #   "dp_clip_noise") on this backend; "auto" probes the installed
    #   jax/pallas and falls back to the jnp oracle

    # -- DP mechanism (Eq. 7a) ---------------------------------------------
    dp: bool = True
    clip_norm: float = 1.0          # G (sensitivity bound)
    num_microbatches: int = 1
    vmap_microbatches: bool = True
    grad_accumulate: str = "stack"  # "stack" | "scan" (§Perf opt)
    average_opt_state: bool = True

    # -- privacy accounting (§5.2) -----------------------------------------
    sigmas: tuple[float, ...] | None = None  # per-client σ; None -> design
    batch_sizes: tuple[int, ...] = ()        # X_m per client; () -> all 1
    eps_th: float = math.inf
    delta: float = 1e-4
    total_steps: int | None = None  # planned K for auto sigma design (Eq. 23)

    # -- resource budget (Eq. 8) -------------------------------------------
    c_th: float = math.inf
    c1: float = 100.0               # comm cost per aggregation
    c2: float = 1.0                 # compute cost per local step

    seed: int = 0

    def __post_init__(self):
        if self.n_clients <= 0:
            raise ValueError(f"n_clients must be positive, got {self.n_clients}")
        if self.tau <= 0:
            raise ValueError(f"tau must be positive, got {self.tau}")
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"topology must be one of {TOPOLOGIES}, "
                             f"got {self.topology!r}")
        if self.engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {self.engine!r}")
        if self.kernel_backend not in KERNEL_BACKENDS:
            raise ValueError(f"kernel_backend must be one of "
                             f"{KERNEL_BACKENDS}, got {self.kernel_backend!r}")
        # normalize sequences to hashable tuples
        if self.sigmas is not None:
            object.__setattr__(self, "sigmas",
                               tuple(float(s) for s in np.asarray(self.sigmas)))
            if len(self.sigmas) != self.n_clients:
                raise ValueError(f"sigmas has {len(self.sigmas)} entries for "
                                 f"{self.n_clients} clients")
        if self.batch_sizes:
            object.__setattr__(self, "batch_sizes",
                               tuple(int(x) for x in self.batch_sizes))
            if len(self.batch_sizes) != self.n_clients:
                raise ValueError(
                    f"batch_sizes has {len(self.batch_sizes)} entries for "
                    f"{self.n_clients} clients")

    # -- derived views ------------------------------------------------------
    def replace(self, **changes) -> "FederationSpec":
        return dataclasses.replace(self, **changes)

    def fl_config(self, vmap_clients: bool = True) -> FLConfig:
        """The engine-level FLConfig view of this spec."""
        return FLConfig(
            n_clients=self.n_clients, tau=self.tau, clip_norm=self.clip_norm,
            dp=self.dp, num_microbatches=self.num_microbatches,
            vmap_microbatches=self.vmap_microbatches,
            grad_accumulate=self.grad_accumulate,
            average_opt_state=self.average_opt_state,
            vmap_clients=vmap_clients,
            kernel_backend=self.kernel_backend)

    def budgets(self) -> Budgets:
        return Budgets(c_th=self.c_th, eps_th=self.eps_th,
                       c1=self.c1, c2=self.c2)

    def round_cost(self) -> float:
        """Eq. (8) per round: c1 + c2 * tau."""
        return self.c1 + self.c2 * self.tau

    def resolved_batch_sizes(self) -> tuple[int, ...]:
        return self.batch_sizes or (1,) * self.n_clients

    def resolved_sigmas(self) -> np.ndarray:
        """Per-client noise std: explicit > auto-designed (Eq. 23) > zero.

        Auto design needs a finite ``eps_th`` and a planned ``total_steps``
        (the K of Eq. 23); it yields the smallest noise meeting eps_th at K.
        """
        if self.sigmas is not None:
            return np.asarray(self.sigmas, np.float32)
        if not self.dp:
            return np.zeros((self.n_clients,), np.float32)
        if not math.isfinite(self.eps_th) or self.total_steps is None:
            raise ValueError(
                "FederationSpec needs explicit sigmas, or a finite eps_th "
                "plus total_steps so Eq. 23 can design them")
        return design_sigmas(self.total_steps, self.clip_norm,
                             list(self.resolved_batch_sizes()),
                             self.eps_th, self.delta)

    def engine_key(self) -> tuple:
        """Hash key of everything that shapes the compiled round function.

        Budget / accounting fields (eps_th, c_th, delta, ...) are excluded —
        changing them must NOT retrace or recompile the engine.
        """
        return (self.loss_fn, self.optimizer, self.n_clients, self.tau,
                self.clip_norm, self.dp, self.num_microbatches,
                self.vmap_microbatches, self.grad_accumulate,
                self.average_opt_state, self.topology, self.engine,
                self.kernel_backend)

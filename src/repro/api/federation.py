"""Back-compat mutable driver over the pure functional core.

:class:`Federation` preserves the historic ``repro.core.fl.Federation``
surface (construct from FLConfig + arrays, ``.round()``, ``.train(budgets)``,
``.params`` / ``.accountant`` / ``.history`` attributes) while delegating
every round to ``repro.api.state.run_round``. New code should use
:class:`FederationSpec` + ``init_state`` / ``run_round`` / ``train`` directly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.api import state as api_state
from repro.api.spec import FederationSpec
from repro.core.fl import Budgets, FLConfig
from repro.core.privacy import PrivacyAccountant
from repro.optim.optimizers import Optimizer


@dataclass
class Federation:
    """Coordinates clients, the round engine, and the privacy accountant.

    ``sampler(client, tau, rng) -> batch pytree with leading axes (tau, B)``

    Thin wrapper: all state lives in ``self.state`` (an immutable
    :class:`FLState`); the attributes below are views over it.
    """
    cfg: FLConfig
    loss_fn: Callable
    optimizer: Optimizer
    params0: Any                              # single-replica init (no C axis)
    sampler: Callable[[int, int, np.random.Generator], Any]
    sigmas: np.ndarray                        # (C,) per-step noise std
    delta: float = 1e-4
    batch_sizes: list[int] = field(default_factory=list)  # X_m per client
    seed: int = 0
    engine: str | None = None                 # None -> derive from cfg
    topology: str = "full_average"

    def __post_init__(self):
        c = self.cfg.n_clients
        engine = self.engine or ("vmap" if self.cfg.vmap_clients else "map")
        self.spec = FederationSpec(
            n_clients=c, tau=self.cfg.tau, loss_fn=self.loss_fn,
            optimizer=self.optimizer, topology=self.topology, engine=engine,
            dp=self.cfg.dp, clip_norm=self.cfg.clip_norm,
            num_microbatches=self.cfg.num_microbatches,
            vmap_microbatches=self.cfg.vmap_microbatches,
            grad_accumulate=self.cfg.grad_accumulate,
            average_opt_state=self.cfg.average_opt_state,
            sigmas=tuple(float(s) for s in np.asarray(self.sigmas)),
            batch_sizes=tuple(self.batch_sizes) if self.batch_sizes
            else (1,) * c,
            delta=self.delta, seed=self.seed)
        self.state = api_state.init_state(self.spec, self.params0)
        self.accountant = api_state.accountant_view(self.spec, self.state)
        self._rng = np.random.default_rng(self.seed)
        self.history: list[dict] = []

    # -- state views ---------------------------------------------------------
    @property
    def params(self):
        return self.state.params

    @params.setter
    def params(self, value):
        self.state = self.state.replace(params=value)

    @property
    def opt_state(self):
        return self.state.opt_state

    @opt_state.setter
    def opt_state(self, value):
        self.state = self.state.replace(opt_state=value)

    @property
    def rounds_done(self) -> int:
        return self.state.rounds_done

    @property
    def resource_spent(self) -> float:
        return self.state.resource_spent

    def _sync_accountant(self) -> None:
        for m in range(self.spec.n_clients):
            self.accountant._rho[m] = float(self.state.rho[m])
        self.accountant.steps = self.state.steps

    def restore(self, state: api_state.FLState,
                history: list[dict] | None = None) -> None:
        """Adopt a checkpointed FLState (see repro.checkpoint)."""
        self.state = state
        if history is not None:
            self.history = list(history)
        self._sync_accountant()

    # -- training ------------------------------------------------------------
    def round(self) -> dict:
        """One unconditional round (no budget check).

        Historic semantics: resources are only charged inside ``train``,
        where the caller's Budgets set the prices — so the Eq.-8 cost
        accrued by run_round at the spec's default c1/c2 is rolled back.
        """
        batch = api_state.round_batch(self.spec, self.sampler, self._rng)
        spent = self.state.resource_spent
        self.state, rec = api_state.run_round(self.spec, self.state, batch,
                                              check_budgets=False)
        rec = api_state.materialize_record(rec)
        self.state = self.state.replace(resource_spent=spent)
        rec["resource_spent"] = spent
        self._sync_accountant()
        self.history.append(rec)
        return rec

    def round_cost(self, budgets: Budgets) -> float:
        """Eq. (8) per round: c1 + c2 * tau."""
        return budgets.c1 + budgets.c2 * self.cfg.tau

    def train(self, budgets: Budgets, max_rounds: int = 10_000,
              eval_fn: Callable | None = None, eval_every: int = 1) -> dict:
        """Run rounds until a budget (resource or privacy) would be exceeded.

        Tracks theta* = argmin of the evaluated loss (paper uses the best
        model among K iterations).
        """
        spec = self.spec.replace(c_th=budgets.c_th, eps_th=budgets.eps_th,
                                 c1=budgets.c1, c2=budgets.c2)
        self.state, out = api_state.train(
            spec, self.state, self.sampler, max_rounds=max_rounds,
            eval_fn=eval_fn, eval_every=eval_every, rng=self._rng,
            history=self.history)
        self._sync_accountant()
        return out

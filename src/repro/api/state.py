"""Pure functional DP-PASGD core: FLState + init_state / run_round / train.

The state of a federation is one immutable :class:`FLState` value — model
replicas, optimizer state, PRNG key, privacy-accountant snapshot, and spent
resources. ``run_round`` maps (spec, state, batch) -> (state', metrics),
which makes checkpoint/resume (``save_state`` / ``load_state``), budget
probing, and jit-friendly outer drivers trivial. The mutable
:class:`repro.api.Federation` is a thin wrapper over these functions.

DONATION CONTRACT (§Perf opt): the value semantics are linear, not
persistent — ``run_round`` / ``run_rounds`` donate the input state's
params / opt_state / residual device buffers to XLA (client replicas
update in place instead of double-buffering), so a successful call CONSUMES
the input FLState; always continue from the returned state. To fork one
state down two paths (what-if probing), copy the donated leaves first
(``state.replace(params=jax.tree.map(jnp.copy, state.params), ...)``) or
rebuild via ``init_state``. Host-side data (checkpoints on disk, the rho
snapshot, np views taken earlier) is never affected.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.engines import chunked_round_fn_for, round_fn_for
from repro.api.spec import FederationSpec
from repro.core.aggregation import participation_mask
from repro.core.privacy import (
    PrivacyAccountant,
    gaussian_zcdp,
    grad_sensitivity,
    per_step_charges,
    zcdp_to_dp,
)
from repro.utils.tree import tree_broadcast_axis0, tree_mean_over_axis0


class BudgetExceeded(RuntimeError):
    """Raised by run_round when the next round would break a budget."""

    def __init__(self, which: str, message: str):
        super().__init__(message)
        self.which = which          # "resource" | "privacy"


class PrefetchFailed(RuntimeError):
    """The ``prefetch`` callback of :func:`run_rounds` raised AFTER the
    chunk was dispatched. The chunk's DP releases already executed, so the
    completed successor state and records are attached — recover via
    ``.state`` / ``.records`` (as ``train`` does) instead of discarding a
    ledger that was physically spent. The original exception is chained as
    ``__cause__``."""

    def __init__(self, cause: BaseException, state: "FLState",
                 records: list):
        super().__init__(f"run_rounds prefetch callback failed: {cause!r}")
        self.state = state
        self.records = records


@dataclass(frozen=True)
class FLState:
    """Complete training state of one federation (immutable).

    params/opt_state carry the leading client axis C on every leaf. The
    accountant snapshot (rho, steps) lives host-side as plain numpy — the
    zCDP ledger is exact closed-form math, not traced computation.
    """
    params: Any
    opt_state: Any
    key: jax.Array                  # PRNG key consumed one split per round
    rho: np.ndarray                 # (C,) spent zCDP per client (Lemma 1)
    steps: int = 0                  # local iterations accounted so far
    resource_spent: float = 0.0     # accumulated Eq.-(8) cost
    rounds_done: int = 0
    residual: Any = None            # (C, D) error-feedback residual of the
    #   aggregation pipeline (core/aggregation.py); None unless the spec
    #   sets a compressor. Checkpointed alongside params/opt_state.

    def replace(self, **changes) -> "FLState":
        return dataclasses.replace(self, **changes)


def init_state(spec: FederationSpec, params0: Any,
               key: jax.Array | None = None) -> FLState:
    """Fresh FLState: params0 (no client axis) replicated C times."""
    params = tree_broadcast_axis0(params0, spec.n_clients)
    opt_state = tree_broadcast_axis0(spec.optimizer.init(params0),
                                     spec.n_clients)
    if key is None:
        key = jax.random.PRNGKey(spec.seed)
    pipe = spec.aggregation_pipeline()
    residual = pipe.init_residual(params0) if pipe is not None else None
    return FLState(params=params, opt_state=opt_state, key=key,
                   rho=np.zeros((spec.n_clients,), np.float64),
                   residual=residual)


# ---------------------------------------------------------------------------
# per-spec host/device ledger constants (cached — the per-round rebuild /
# re-transfer of these was the dominant host overhead of the old driver)
# ---------------------------------------------------------------------------

_SIGMA_CACHE: dict[tuple, jax.Array] = {}
_RHO_STEP_CACHE: dict[tuple, np.ndarray] = {}
_LEDGER_CACHE_MAX = 128


def _ledger_cached(cache: dict, key, build):
    val = cache.get(key)
    if val is None:
        if len(cache) >= _LEDGER_CACHE_MAX:
            cache.clear()          # tiny (C,) vectors; simple bound suffices
        val = cache[key] = build()
    return val


def sigmas_for(spec: FederationSpec) -> jax.Array:
    """The device-resident (C,) f32 sigma vector for ``spec``, cached per
    ``spec.ledger_key()`` so rounds stop paying a host->device transfer of
    the same constants every dispatch."""
    return _ledger_cached(
        _SIGMA_CACHE, spec.ledger_key(),
        lambda: jnp.asarray(spec.resolved_sigmas(), jnp.float32))


def _rho_steps(spec: FederationSpec) -> np.ndarray:
    """(C,) per-local-step zCDP charge per client at q=1 — Lemma 2 with the
    §5.2 sensitivity, exactly as ``PrivacyAccountant`` computes it on
    registered clients. Cached per ledger key: the incremental budget probe
    and the per-round ledger update reuse these host constants instead of
    re-registering all C clients on every probe."""
    def build():
        sig = spec.resolved_sigmas()
        return np.asarray(
            [gaussian_zcdp(grad_sensitivity(spec.clip_norm, x), float(s))
             for x, s in zip(spec.resolved_batch_sizes(), sig)], np.float64)

    return _ledger_cached(_RHO_STEP_CACHE, spec.ledger_key(), build)


def round_rho_charges(spec: FederationSpec) -> np.ndarray:
    """(C,) worst-case per-round rho increments: tau steps at the spec's
    accounting rate — the same expression ``PrivacyAccountant.step`` charges
    a realized participant (``n_steps * subsampled_rho(rho_step, q)``,
    via the shared :func:`repro.core.privacy.per_step_charges`). Public:
    the population drivers (``repro.population.runtime``) charge the
    per-virtual-client ledger with exactly this vector."""
    return spec.tau * per_step_charges(_rho_steps(spec), spec.accounting_q())


def accountant_view(spec: FederationSpec,
                    state: FLState | None = None) -> PrivacyAccountant:
    """A PrivacyAccountant materialized from spec (+ optional state snapshot)."""
    acc = PrivacyAccountant(clip_norm=spec.clip_norm, delta=spec.delta)
    sig = spec.resolved_sigmas()
    for m, x in enumerate(spec.resolved_batch_sizes()):
        acc.register_client(m, x, float(sig[m]))
    if state is not None:
        for m in range(spec.n_clients):
            acc._rho[m] = float(state.rho[m])
        acc.steps = state.steps
    return acc


def max_epsilon(spec: FederationSpec, state: FLState) -> float:
    return accountant_view(spec, state).max_epsilon()


def peek_epsilon_fast(spec: FederationSpec, state: FLState,
                      extra_steps: int) -> float:
    """Incremental budget probe: worst-client eps if every client took
    ``extra_steps`` more local iterations, computed from the state's rho
    snapshot plus the cached per-step charges — no O(C) accountant rebuild
    per probe. Bit-identical to
    ``accountant_view(spec, state).peek_epsilon(extra_steps,
    q=spec.accounting_q())`` (same per-element float expressions)."""
    extra = extra_steps * per_step_charges(_rho_steps(spec),
                                           spec.accounting_q())
    return zcdp_to_dp(float(np.max(state.rho + extra)), spec.delta)


def exceeds_budgets(spec: FederationSpec, state: FLState) -> str | None:
    """Would one more round break a budget? Returns "resource" / "privacy"
    or None. The privacy probe is the incremental
    :func:`peek_epsilon_fast` (identical math to
    ``PrivacyAccountant.peek_epsilon``), conservatively assuming the worst
    client participates next round (its per-step rho still carries the
    subsampling amplification factor)."""
    if state.resource_spent + spec.round_cost() > spec.c_th:
        return "resource"
    if peek_epsilon_fast(spec, state, spec.tau) > spec.eps_th:
        return "privacy"
    return None


def rounds_within_budgets(spec: FederationSpec, state: FLState,
                          limit: int) -> tuple[int, str | None]:
    """How many consecutive future rounds are CERTAIN to fit the budgets,
    capped at ``limit``, plus the budget ("resource" / "privacy" / None)
    that would bind next.

    Replays ``exceeds_budgets``'s per-round probes with worst-case ledger
    growth (every client charged every round). Exact for full
    participation — bit-identical decisions to the per-round driver; under
    partial participation the realized ledger grows no faster than the
    projection, so a chunk sized by this bound never contains a round the
    per-round driver would have refused (it may end early; the training
    loop re-probes on the realized ledger and continues)."""
    charges = round_rho_charges(spec)
    rho = state.rho
    spent = state.resource_spent
    cost = spec.round_cost()
    n = 0
    while n < limit:
        if spent + cost > spec.c_th:
            return n, "resource"
        if zcdp_to_dp(float(np.max(rho + charges)), spec.delta) > spec.eps_th:
            return n, "privacy"
        rho = rho + charges
        spent = spent + cost
        n += 1
    return n, None


def _raise_budget(which: str, spec: FederationSpec):
    if which == "resource":
        raise BudgetExceeded("resource", f"round cost {spec.round_cost()} "
                             f"would exceed C_th={spec.c_th}")
    raise BudgetExceeded("privacy", f"tau={spec.tau} more steps would "
                         f"exceed eps_th={spec.eps_th}")


def run_round(spec: FederationSpec, state: FLState, batch: Any,
              check_budgets: bool = True) -> tuple[FLState, dict]:
    """One DP-PASGD round (Eq. 7a-7b): tau local steps + topology collective.

    batch leaves are (C, tau, B, ...). Returns the successor state and a
    metrics record; raises :class:`BudgetExceeded` (state untouched) when
    ``check_budgets`` and the round would overrun ``spec.c_th``/``eps_th``.

    The input state's params/opt_state/residual device buffers are DONATED
    to the round (updated in place, see :func:`repro.api.engines
    .round_fn_for`) — continue from the returned state. The record's metric
    values stay device-resident 0-d arrays (no forced sync before the next
    round can dispatch); call :func:`materialize_record` — as ``train``
    does at history-append time — to force them to host floats.
    """
    if check_budgets:
        which = exceeds_budgets(spec, state)
        if which is not None:
            _raise_budget(which, spec)
    key, sub = jax.random.split(state.key)
    sig = sigmas_for(spec)
    per_round = round_rho_charges(spec)
    residual = state.residual
    if spec.has_pipeline():
        # pipeline round: sample this round's participant set from the
        # FLState RNG (host-visible — the ledger needs the realized set;
        # this mask fetch is the per-round driver's one blocking sync)
        sub, mask_key = jax.random.split(sub)
        mask = participation_mask(mask_key, spec.n_clients,
                                  spec.participants_per_round())
        mask_np = np.asarray(mask)
        new_p, new_s, residual, ms = round_fn_for(spec)(
            state.params, state.opt_state, batch, sub, sig, mask,
            state.residual)
        rho = state.rho + np.where(mask_np > 0, per_round, 0.0)
        n_participants = int(mask_np.sum())
    else:
        new_p, new_s, ms = round_fn_for(spec)(state.params, state.opt_state,
                                              batch, sub, sig)
        rho = state.rho + per_round
        n_participants = spec.n_clients
    new_state = state.replace(
        params=new_p, opt_state=new_s, key=key, residual=residual, rho=rho,
        steps=state.steps + spec.tau,
        resource_spent=state.resource_spent + spec.round_cost(),
        rounds_done=state.rounds_done + 1)
    rec = dict(ms)                 # lazy: 0-d device arrays, no sync
    rec["round"] = new_state.rounds_done
    rec["iterations"] = new_state.rounds_done * spec.tau
    rec["max_epsilon"] = zcdp_to_dp(float(np.max(rho)), spec.delta)
    rec["resource_spent"] = new_state.resource_spent
    rec["participants"] = float(n_participants)
    return new_state, rec


def run_rounds(spec: FederationSpec, state: FLState, batches: Any,
               n_rounds: int | None = None, check_budgets: bool = True,
               prefetch: Callable[[], None] | None = None,
               ) -> tuple[FLState, list[dict]]:
    """A fused chunk of R rounds as ONE jitted ``lax.scan`` (§Perf opt).

    ``batches`` leaves are (R, C, tau, B, ...) — see :func:`round_batches`;
    ``n_rounds`` defaults to the leading axis. Bit-identical to R sequential
    :func:`run_round` calls (params, opt_state, rho ledger, error-feedback
    residual, RNG key, resource_spent — guarded by the chunk/loop identity
    gate in tests/test_fused_rounds.py): participation masks are sampled
    INSIDE the scan from the carried key with run_round's exact split
    schedule, and the realized masks come back stacked so the host replays
    the conditional ledger once per chunk
    (:meth:`PrivacyAccountant.step_many`) instead of 4x per round.

    Host-sync model: the chunk blocks the host at most ONCE (fetching the
    stacked masks under a pipeline spec; never for the default protocol) —
    per-round records are returned lazily, metric values as 0-d device
    slices of the stacked metrics (:func:`materialize_record` forces them).
    ``prefetch()``, if given, runs after the chunk is dispatched and before
    that sync, so callers overlap building the next chunk's host batches
    with device compute (``train``'s double-buffered driver). If it raises,
    the chunk it overlapped is NOT lost: :class:`PrefetchFailed` carries
    the completed successor state and records (the donated inputs are
    already consumed and the DP releases executed — discarding the ledger
    would un-account spent privacy).

    Donation: like run_round, the input state's device buffers are consumed.
    Raises BudgetExceeded (state untouched) when ``check_budgets`` and any
    of the R rounds could overrun a budget, judged by the worst-case
    projection of :func:`rounds_within_budgets` (exact for full
    participation, conservative under partial participation).
    """
    lead = int(jax.tree.leaves(batches)[0].shape[0])
    if n_rounds is None:
        n_rounds = lead
    if n_rounds <= 0:
        raise ValueError(f"n_rounds must be positive, got {n_rounds}")
    if n_rounds != lead:
        # the scan length comes from the batches — a mismatch would train
        # lead rounds while charging the ledger for n_rounds
        raise ValueError(f"n_rounds={n_rounds} != stacked batches leading "
                         f"axis {lead}")
    if check_budgets:
        ok, which = rounds_within_budgets(spec, state, n_rounds)
        if ok < n_rounds:
            _raise_budget(which, spec)
    sig = sigmas_for(spec)
    fn = chunked_round_fn_for(spec)
    prefetch_exc = None

    def _prefetch():
        # a raising prefetch must not lose the already-dispatched chunk
        # (donated inputs are consumed, the DP releases execute): defer the
        # error until the successor state exists and attach it
        nonlocal prefetch_exc
        if prefetch is not None:
            try:
                prefetch()
            except Exception as e:        # noqa: BLE001 — re-raised below
                prefetch_exc = e

    if spec.has_pipeline():
        new_p, new_s, key, residual, ms, masks = fn(
            state.params, state.opt_state, batches, state.key, sig,
            state.residual)
        _prefetch()
        masks_np = np.asarray(masks)       # THE one blocking sync per chunk
        participants = masks_np.sum(axis=1)
    else:
        new_p, new_s, key, ms = fn(state.params, state.opt_state, batches,
                                   state.key, sig)
        residual = state.residual
        _prefetch()
        masks_np = None
        participants = np.full((n_rounds,), float(spec.n_clients))
    # exact ledger replay, hoisted to the chunk boundary: ONE accountant
    # materialization + one vectorized step_many over the realized masks
    acc = accountant_view(spec, state)
    worst_rho = acc.step_many([spec.tau] * n_rounds, masks=masks_np,
                              q=spec.accounting_q())
    rho = np.asarray([acc.rho(m) for m in range(spec.n_clients)], np.float64)
    recs = []
    spent = state.resource_spent
    for r in range(n_rounds):
        spent = spent + spec.round_cost()   # repeated add: bit-identical to
        #   the per-round driver's accumulation
        rec = {k: v[r] for k, v in ms.items()}      # lazy 0-d device slices
        rec["round"] = state.rounds_done + r + 1
        rec["iterations"] = (state.rounds_done + r + 1) * spec.tau
        rec["max_epsilon"] = zcdp_to_dp(float(worst_rho[r]), spec.delta)
        rec["resource_spent"] = spent
        rec["participants"] = float(participants[r])
        recs.append(rec)
    new_state = state.replace(
        params=new_p, opt_state=new_s, key=key, residual=residual, rho=rho,
        steps=state.steps + n_rounds * spec.tau,
        resource_spent=spent,
        rounds_done=state.rounds_done + n_rounds)
    if prefetch_exc is not None:
        raise PrefetchFailed(prefetch_exc, new_state, recs) from prefetch_exc
    return new_state, recs


def materialize_record(rec: dict) -> dict:
    """Force any device-resident metric values of a round record to host
    floats — the drivers' one deliberate sync point. ``run_round`` /
    ``run_rounds`` return records lazily (loss etc. stay 0-d device
    arrays) so recording a round never blocks the next dispatch; convert
    at history-append or read time via this helper."""
    return {k: (v if isinstance(v, (bool, int, float, str)) else float(v))
            for k, v in rec.items()}


# ---------------------------------------------------------------------------
# data plumbing + budget-aware driver
# ---------------------------------------------------------------------------

def round_batch(spec: FederationSpec, sampler: Callable, rng) -> Any:
    """Stack per-client samples into the (C, tau, B, ...) round batch.

    ``sampler(client, tau, rng)`` returns one client's pytree with leading
    axes (tau, B, ...).
    """
    per_client = [sampler(m, spec.tau, rng) for m in range(spec.n_clients)]
    return jax.tree.map(lambda *xs: np.stack(xs), *per_client)


def round_batches(spec: FederationSpec, sampler: Callable, rng,
                  n_rounds: int) -> Any:
    """Stack ``n_rounds`` round batches into the (R, C, tau, B, ...) chunk
    operand of :func:`run_rounds`, drawing from ``rng`` in exactly the
    order ``n_rounds`` sequential :func:`round_batch` calls would (so a
    chunked driver consumes the sampler stream identically to the
    per-round one)."""
    rounds = [round_batch(spec, sampler, rng) for _ in range(n_rounds)]
    return jax.tree.map(lambda *xs: np.stack(xs), *rounds)


def collapse_clients(params: Any, topology: str) -> Any:
    """Client-stacked params -> the single serving/eval model: any replica
    after full averaging, the cross-client mean under local_only. The one
    place the topology-collapse rule lives (eval_params and the serving
    driver both delegate here)."""
    if topology == "full_average":
        return jax.tree.map(lambda x: x[0], params)
    return tree_mean_over_axis0(params)


def eval_params(spec: FederationSpec, state: FLState) -> Any:
    """The single evaluation model for ``spec``'s topology."""
    return collapse_clients(state.params, spec.topology)


def budget_train_loop(*, state, max_rounds: int, eval_fn: Callable | None,
                      eval_every: int, history: list[dict],
                      chunk_rounds: int,
                      rounds_done: Callable[[Any], int],
                      exceeds: Callable[[Any], bool],
                      safe_rounds: Callable[[Any, int], int],
                      run_single: Callable[[Any], tuple],
                      build_chunk: Callable[[int, int], Any],
                      run_chunk: Callable[..., tuple],
                      run_tail: Callable[[Any, Any, int], tuple],
                      eval_model: Callable[[Any], Any]) -> tuple[Any, dict]:
    """THE budget-aware driver loop, shared by the dense :func:`train` and
    the cohort-execution ``repro.population.train_population`` (one copy of
    the double-buffered prefetch / tail-chunk / eval-boundary invariants;
    the two drivers differ only in how a round runs and how budgets probe).
    Parameterized over an opaque ``state`` and an opaque prepared ``chunk``:

        rounds_done(state) -> int          completed-round counter
        exceeds(state) -> bool             would one more round overrun?
        safe_rounds(state, cap) -> int     certain-to-fit round count
        run_single(state) -> (state, rec)  one round, building its own batch
        build_chunk(start, n) -> chunk     host-build + device_put n rounds
                                           starting at round index ``start``
        run_chunk(state, chunk, n, prefetch) -> (state, recs)
                                           fused scan; may raise
                                           PrefetchFailed carrying the
                                           completed state/records
        run_tail(state, chunk, r) -> (state, rec)
                                           row r of chunk via the per-round
                                           path
        eval_model(state) -> params        the eval_fn operand

    Tracks theta* = argmin of the evaluated loss (the paper uses the best
    model among K iterations); appends materialized records to ``history``;
    returns (state, best).
    """
    best = {"loss": float("inf"), "round": 0}

    def track_best(rec: dict, evaluated: bool):
        nonlocal best
        # theta* tracking: compare on eval loss when available, else train
        if eval_fn is None:
            crit = rec["loss"]
        elif evaluated:
            crit = rec["eval_loss"]
        else:
            crit = float("inf")
        if crit < best["loss"]:
            # rec AFTER the overrides: best["loss"] must stay the tracked
            # criterion (eval loss when eval_fn is given), not rec's train
            # loss, or a later genuinely-better eval never displaces it
            best = {**rec, "loss": crit, "round": rec["round"]}

    if chunk_rounds <= 1:
        while rounds_done(state) < max_rounds:
            if exceeds(state):
                break
            state, rec = run_single(state)
            rec = materialize_record(rec)
            history.append(rec)
            evaluated = False
            if eval_fn is not None and rounds_done(state) % eval_every == 0:
                rec.update(eval_fn(eval_model(state)))
                evaluated = True
            track_best(rec, evaluated)
        return state, best

    pending = None            # double buffer: (chunk, n) prefetched
    while rounds_done(state) < max_rounds:
        cap = min(2 * chunk_rounds, max_rounds - rounds_done(state))
        safe = safe_rounds(state, cap)
        if pending is not None:
            # prefetched chunks were sized by the post-chunk projection,
            # so they always fit (safe >= n); run them whole to keep the
            # sampler stream aligned with the per-round driver
            chunk, n = pending
            pending = None
        elif safe == 0:
            break
        else:
            n = min(chunk_rounds, safe)
            chunk = build_chunk(rounds_done(state), n)
        next_n = min(chunk_rounds, safe - n,
                     max_rounds - rounds_done(state) - n)
        next_start = rounds_done(state) + n

        def build_next(next_n=next_n, next_start=next_start):
            nonlocal pending
            if next_n > 0:
                pending = (build_chunk(next_start, next_n), next_n)

        deferred = None
        if n < chunk_rounds:
            # tail chunk (budget/max_rounds edge): drive the rows through
            # the per-round path — the single compiled round is reused for
            # any tail size, instead of paying a one-shot XLA compile of a
            # fresh n-round scan for a few rounds
            recs = []
            for r in range(n):
                state, rec = run_tail(state, chunk, r)
                recs.append(rec)
        else:
            try:
                state, recs = run_chunk(state, chunk, n, build_next)
            except PrefetchFailed as pf:
                # the sampler failed building the NEXT chunk; keep the
                # completed chunk's state/records, re-raise the original
                # error after recording them (the per-round driver raises
                # at the same point: after round r, before batch r+1)
                state, recs, deferred = pf.state, pf.records, pf.__cause__
        recs = [materialize_record(r) for r in recs]
        history.extend(recs)
        evaluated = False
        if eval_fn is not None and (
                rounds_done(state) // eval_every
                > (rounds_done(state) - n) // eval_every):
            # an eval was due mid-chunk: run it once, at the boundary
            recs[-1].update(eval_fn(eval_model(state)))
            evaluated = True
        for rec in recs[:-1]:
            track_best(rec, False)
        track_best(recs[-1], evaluated)
        if deferred is not None:
            raise deferred
    return state, best


def train(spec: FederationSpec, state: FLState, sampler: Callable,
          max_rounds: int = 10_000, eval_fn: Callable | None = None,
          eval_every: int = 1, rng=None,
          history: list[dict] | None = None,
          chunk_rounds: int = 1) -> tuple[FLState, dict]:
    """Run rounds until a budget (resource or privacy) would be exceeded.

    Tracks theta* = argmin of the evaluated loss (the paper uses the best
    model among K iterations). Returns (final_state, summary) where summary
    carries best/rounds/resource_spent/max_epsilon/history.

    ``chunk_rounds=R > 1`` drives training in fused :func:`run_rounds`
    chunks (§Perf opt): R rounds lower to one XLA dispatch with at most one
    host sync per chunk, and the next chunk's round batches are built and
    ``device_put`` while the current chunk computes (double-buffered
    prefetch). Budget semantics are preserved: chunks are sized by
    :func:`rounds_within_budgets`, so no round runs that the per-round
    driver would have refused (under partial participation the sizing is
    conservative — a chunk may come up short and the loop re-probes on the
    realized ledger). The one semantic difference: ``eval_fn`` runs at
    chunk boundaries only (mid-chunk models never exist on the host), so
    evaluation happens every ~max(eval_every, R) rounds; train-loss theta*
    tracking stays per-round via the stacked metrics.
    """
    if rng is None:
        rng = np.random.default_rng(spec.seed)
    history = [] if history is None else history
    state, best = budget_train_loop(
        state=state, max_rounds=max_rounds, eval_fn=eval_fn,
        eval_every=eval_every, history=history, chunk_rounds=chunk_rounds,
        rounds_done=lambda s: s.rounds_done,
        exceeds=lambda s: exceeds_budgets(spec, s) is not None,
        safe_rounds=lambda s, cap: rounds_within_budgets(spec, s, cap)[0],
        run_single=lambda s: run_round(
            spec, s, round_batch(spec, sampler, rng), check_budgets=False),
        build_chunk=lambda start, n: jax.device_put(
            round_batches(spec, sampler, rng, n)),
        run_chunk=lambda s, chunk, n, prefetch: run_rounds(
            spec, s, chunk, n, check_budgets=False, prefetch=prefetch),
        run_tail=lambda s, chunk, r: run_round(
            spec, s, jax.tree.map(lambda x: x[r], chunk),
            check_budgets=False),
        eval_model=lambda s: eval_params(spec, s))
    return state, {
        "best": best, "rounds": state.rounds_done,
        "resource_spent": state.resource_spent,
        "max_epsilon": max_epsilon(spec, state),
        "history": history,
    }


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------

def save_state(directory: str, state: FLState,
               extra: dict | None = None) -> None:
    """Persist an FLState (arrays + accountant snapshot) to ``directory``."""
    from repro.checkpoint import save_checkpoint
    meta = {
        "rho": [float(r) for r in state.rho],
        "steps": int(state.steps),
        "resource_spent": float(state.resource_spent),
        "rounds_done": int(state.rounds_done),
        **(extra or {}),
    }
    arrays = {"params": state.params, "opt_state": state.opt_state,
              "key": state.key}
    if state.residual is not None:
        arrays["residual"] = state.residual
    save_checkpoint(directory, arrays, step=state.rounds_done, extra=meta)


def load_state(directory: str, like: FLState) -> tuple[FLState, dict]:
    """Restore an FLState saved by :func:`save_state`.

    ``like`` supplies the pytree structure (e.g. a fresh ``init_state``).
    Returns (state, extra) with any caller metadata passed to save_state.
    """
    from repro.checkpoint import checkpoint_leaf_paths, load_checkpoint
    like_tree = {"params": like.params, "opt_state": like.opt_state,
                 "key": like.key}
    # ask for the residual only when BOTH sides have one: a dense-trained
    # checkpoint resumed under a compressor keeps like's zero residual, a
    # compressed checkpoint resumed dense drops it
    saved = checkpoint_leaf_paths(directory)
    has_residual = any(p == "residual" or p.startswith("residual/")
                       for p in saved)
    if like.residual is not None and has_residual:
        like_tree["residual"] = like.residual
    tree, _, extra = load_checkpoint(directory, like=like_tree)
    state = like.replace(
        params=tree["params"], opt_state=tree["opt_state"],
        key=jnp.asarray(tree["key"]),
        residual=(jnp.asarray(tree["residual"])
                  if "residual" in tree else like.residual),
        rho=np.asarray(extra["rho"], np.float64),
        steps=int(extra["steps"]),
        resource_spent=float(extra["resource_spent"]),
        rounds_done=int(extra["rounds_done"]))
    return state, extra

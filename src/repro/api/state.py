"""Pure functional DP-PASGD core: FLState + init_state / run_round / train.

The state of a federation is one immutable :class:`FLState` value — model
replicas, optimizer state, PRNG key, privacy-accountant snapshot, and spent
resources. ``run_round`` maps (spec, state, batch) -> (state', metrics) with
no hidden mutation, which makes checkpoint/resume (``save_state`` /
``load_state``), budget probing, and jit-friendly outer drivers trivial.
The mutable :class:`repro.api.Federation` is a thin wrapper over these
functions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.engines import round_fn_for
from repro.api.spec import FederationSpec
from repro.core.aggregation import participation_mask
from repro.core.privacy import PrivacyAccountant
from repro.utils.tree import tree_broadcast_axis0, tree_mean_over_axis0


class BudgetExceeded(RuntimeError):
    """Raised by run_round when the next round would break a budget."""

    def __init__(self, which: str, message: str):
        super().__init__(message)
        self.which = which          # "resource" | "privacy"


@dataclass(frozen=True)
class FLState:
    """Complete training state of one federation (immutable).

    params/opt_state carry the leading client axis C on every leaf. The
    accountant snapshot (rho, steps) lives host-side as plain numpy — the
    zCDP ledger is exact closed-form math, not traced computation.
    """
    params: Any
    opt_state: Any
    key: jax.Array                  # PRNG key consumed one split per round
    rho: np.ndarray                 # (C,) spent zCDP per client (Lemma 1)
    steps: int = 0                  # local iterations accounted so far
    resource_spent: float = 0.0     # accumulated Eq.-(8) cost
    rounds_done: int = 0
    residual: Any = None            # (C, D) error-feedback residual of the
    #   aggregation pipeline (core/aggregation.py); None unless the spec
    #   sets a compressor. Checkpointed alongside params/opt_state.

    def replace(self, **changes) -> "FLState":
        return dataclasses.replace(self, **changes)


def init_state(spec: FederationSpec, params0: Any,
               key: jax.Array | None = None) -> FLState:
    """Fresh FLState: params0 (no client axis) replicated C times."""
    params = tree_broadcast_axis0(params0, spec.n_clients)
    opt_state = tree_broadcast_axis0(spec.optimizer.init(params0),
                                     spec.n_clients)
    if key is None:
        key = jax.random.PRNGKey(spec.seed)
    pipe = spec.aggregation_pipeline()
    residual = pipe.init_residual(params0) if pipe is not None else None
    return FLState(params=params, opt_state=opt_state, key=key,
                   rho=np.zeros((spec.n_clients,), np.float64),
                   residual=residual)


def accountant_view(spec: FederationSpec,
                    state: FLState | None = None) -> PrivacyAccountant:
    """A PrivacyAccountant materialized from spec (+ optional state snapshot)."""
    acc = PrivacyAccountant(clip_norm=spec.clip_norm, delta=spec.delta)
    sig = spec.resolved_sigmas()
    for m, x in enumerate(spec.resolved_batch_sizes()):
        acc.register_client(m, x, float(sig[m]))
    if state is not None:
        for m in range(spec.n_clients):
            acc._rho[m] = float(state.rho[m])
        acc.steps = state.steps
    return acc


def max_epsilon(spec: FederationSpec, state: FLState) -> float:
    return accountant_view(spec, state).max_epsilon()


def exceeds_budgets(spec: FederationSpec, state: FLState) -> str | None:
    """Would one more round break a budget? Returns "resource" / "privacy"
    or None. The privacy probe is ``PrivacyAccountant.peek_epsilon(tau)``,
    conservatively assuming the worst client participates next round (its
    per-step rho still carries the subsampling amplification factor)."""
    if state.resource_spent + spec.round_cost() > spec.c_th:
        return "resource"
    probe = accountant_view(spec, state).peek_epsilon(
        spec.tau, q=spec.accounting_q())
    if probe > spec.eps_th:
        return "privacy"
    return None


def run_round(spec: FederationSpec, state: FLState, batch: Any,
              check_budgets: bool = True) -> tuple[FLState, dict]:
    """One DP-PASGD round (Eq. 7a-7b): tau local steps + topology collective.

    batch leaves are (C, tau, B, ...). Returns the successor state and a
    metrics record; raises :class:`BudgetExceeded` (state untouched) when
    ``check_budgets`` and the round would overrun ``spec.c_th``/``eps_th``.
    """
    if check_budgets:
        which = exceeds_budgets(spec, state)
        if which == "resource":
            raise BudgetExceeded("resource", f"round cost {spec.round_cost()} "
                                 f"would exceed C_th={spec.c_th}")
        if which == "privacy":
            raise BudgetExceeded("privacy", f"tau={spec.tau} more steps would "
                                 f"exceed eps_th={spec.eps_th}")
    key, sub = jax.random.split(state.key)
    sig = jnp.asarray(spec.resolved_sigmas(), jnp.float32)
    acc = accountant_view(spec, state)
    residual = state.residual
    if spec.has_pipeline():
        # pipeline round: sample this round's participant set from the
        # FLState RNG (host-visible — the accountant needs the realized set)
        sub, mask_key = jax.random.split(sub)
        mask = participation_mask(mask_key, spec.n_clients,
                                  spec.participants_per_round())
        participants = np.flatnonzero(np.asarray(mask))
        new_p, new_s, residual, ms = round_fn_for(spec)(
            state.params, state.opt_state, batch, sub, sig, mask,
            state.residual)
        acc.step(spec.tau, clients=participants, q=spec.accounting_q())
    else:
        participants = np.arange(spec.n_clients)
        new_p, new_s, ms = round_fn_for(spec)(state.params, state.opt_state,
                                              batch, sub, sig)
        acc.step(spec.tau)
    new_state = state.replace(
        params=new_p, opt_state=new_s, key=key, residual=residual,
        rho=np.asarray([acc.rho(m) for m in range(spec.n_clients)],
                       np.float64),
        steps=state.steps + spec.tau,
        resource_spent=state.resource_spent + spec.round_cost(),
        rounds_done=state.rounds_done + 1)
    rec = {k: float(v) for k, v in ms.items()}
    rec["round"] = new_state.rounds_done
    rec["iterations"] = new_state.rounds_done * spec.tau
    rec["max_epsilon"] = acc.max_epsilon()
    rec["resource_spent"] = new_state.resource_spent
    rec["participants"] = float(len(participants))
    return new_state, rec


# ---------------------------------------------------------------------------
# data plumbing + budget-aware driver
# ---------------------------------------------------------------------------

def round_batch(spec: FederationSpec, sampler: Callable, rng) -> Any:
    """Stack per-client samples into the (C, tau, B, ...) round batch.

    ``sampler(client, tau, rng)`` returns one client's pytree with leading
    axes (tau, B, ...).
    """
    per_client = [sampler(m, spec.tau, rng) for m in range(spec.n_clients)]
    return jax.tree.map(lambda *xs: np.stack(xs), *per_client)


def collapse_clients(params: Any, topology: str) -> Any:
    """Client-stacked params -> the single serving/eval model: any replica
    after full averaging, the cross-client mean under local_only. The one
    place the topology-collapse rule lives (eval_params and the serving
    driver both delegate here)."""
    if topology == "full_average":
        return jax.tree.map(lambda x: x[0], params)
    return tree_mean_over_axis0(params)


def eval_params(spec: FederationSpec, state: FLState) -> Any:
    """The single evaluation model for ``spec``'s topology."""
    return collapse_clients(state.params, spec.topology)


def train(spec: FederationSpec, state: FLState, sampler: Callable,
          max_rounds: int = 10_000, eval_fn: Callable | None = None,
          eval_every: int = 1, rng=None,
          history: list[dict] | None = None) -> tuple[FLState, dict]:
    """Run rounds until a budget (resource or privacy) would be exceeded.

    Tracks theta* = argmin of the evaluated loss (the paper uses the best
    model among K iterations). Returns (final_state, summary) where summary
    carries best/rounds/resource_spent/max_epsilon/history.
    """
    if rng is None:
        rng = np.random.default_rng(spec.seed)
    history = [] if history is None else history
    best = {"loss": float("inf"), "round": 0}
    while state.rounds_done < max_rounds:
        if exceeds_budgets(spec, state):
            break
        batch = round_batch(spec, sampler, rng)
        state, rec = run_round(spec, state, batch, check_budgets=False)
        history.append(rec)
        evaluated = False
        if eval_fn is not None and state.rounds_done % eval_every == 0:
            rec.update(eval_fn(eval_params(spec, state)))
            evaluated = True
        # theta* tracking: compare on eval loss when available, else train
        if eval_fn is None:
            crit = rec["loss"]
        elif evaluated:
            crit = rec["eval_loss"]
        else:
            crit = float("inf")
        if crit < best["loss"]:
            best = {"loss": crit, "round": state.rounds_done, **rec}
    return state, {
        "best": best, "rounds": state.rounds_done,
        "resource_spent": state.resource_spent,
        "max_epsilon": max_epsilon(spec, state),
        "history": history,
    }


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------

def save_state(directory: str, state: FLState,
               extra: dict | None = None) -> None:
    """Persist an FLState (arrays + accountant snapshot) to ``directory``."""
    from repro.checkpoint import save_checkpoint
    meta = {
        "rho": [float(r) for r in state.rho],
        "steps": int(state.steps),
        "resource_spent": float(state.resource_spent),
        "rounds_done": int(state.rounds_done),
        **(extra or {}),
    }
    arrays = {"params": state.params, "opt_state": state.opt_state,
              "key": state.key}
    if state.residual is not None:
        arrays["residual"] = state.residual
    save_checkpoint(directory, arrays, step=state.rounds_done, extra=meta)


def load_state(directory: str, like: FLState) -> tuple[FLState, dict]:
    """Restore an FLState saved by :func:`save_state`.

    ``like`` supplies the pytree structure (e.g. a fresh ``init_state``).
    Returns (state, extra) with any caller metadata passed to save_state.
    """
    from repro.checkpoint import checkpoint_leaf_paths, load_checkpoint
    like_tree = {"params": like.params, "opt_state": like.opt_state,
                 "key": like.key}
    # ask for the residual only when BOTH sides have one: a dense-trained
    # checkpoint resumed under a compressor keeps like's zero residual, a
    # compressed checkpoint resumed dense drops it
    saved = checkpoint_leaf_paths(directory)
    has_residual = any(p == "residual" or p.startswith("residual/")
                       for p in saved)
    if like.residual is not None and has_residual:
        like_tree["residual"] = like.residual
    tree, _, extra = load_checkpoint(directory, like=like_tree)
    state = like.replace(
        params=tree["params"], opt_state=tree["opt_state"],
        key=jnp.asarray(tree["key"]),
        residual=(jnp.asarray(tree["residual"])
                  if "residual" in tree else like.residual),
        rho=np.asarray(extra["rho"], np.float64),
        steps=int(extra["steps"]),
        resource_spent=float(extra["resource_spent"]),
        rounds_done=int(extra["rounds_done"]))
    return state, extra

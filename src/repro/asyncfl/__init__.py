"""repro.asyncfl — buffered asynchronous federation (FedBuff-style).

The execution-side answer to IoT fleet heterogeneity: clients train on
simulated device clocks (:mod:`repro.asyncfl.clock`), the server
aggregates the first B arrivals per flush with staleness-weighted updates
and immediately redispatches (:mod:`repro.asyncfl.engine`), and privacy
is pre-charged at dispatch time so the zCDP budget probe is never outrun
by a straggler (:mod:`repro.asyncfl.runtime`). Select it with
``FederationSpec(engine="async_buffered", buffer_size=B,
staleness_alpha=...)`` and drive with :func:`init_async_state` /
:func:`run_async_cycle` / :func:`train_async` (the sync ``run_round`` /
``train`` have no async form — see ``repro.api.engines.round_fn_for``).
"""
from repro.asyncfl.clock import (
    LATENCY_PROFILES,
    HeteroLatency,
    LatencyModel,
    LognormalLatency,
    UniformLatency,
    latency_profile,
    sync_round_duration,
)
from repro.asyncfl.engine import AsyncBufferedExecutor, executor_for
from repro.asyncfl.events import EventView, earliest_arrivals
from repro.asyncfl.runtime import (
    AsyncState,
    ScheduleRow,
    async_accountant_view,
    async_eval_params,
    async_flush_cost,
    async_flush_cost_bound,
    dispatched_epsilon,
    dispatched_rho,
    exceeds_async_budgets,
    flushes_within_budgets,
    init_async_state,
    load_async_state,
    polynomial_staleness,
    run_async_cycle,
    save_async_state,
    train_async,
)

__all__ = [
    "LATENCY_PROFILES",
    "AsyncBufferedExecutor",
    "AsyncState",
    "EventView",
    "HeteroLatency",
    "LatencyModel",
    "LognormalLatency",
    "ScheduleRow",
    "UniformLatency",
    "async_accountant_view",
    "async_eval_params",
    "async_flush_cost",
    "async_flush_cost_bound",
    "dispatched_epsilon",
    "dispatched_rho",
    "earliest_arrivals",
    "exceeds_async_budgets",
    "executor_for",
    "flushes_within_budgets",
    "init_async_state",
    "latency_profile",
    "load_async_state",
    "polynomial_staleness",
    "run_async_cycle",
    "save_async_state",
    "sync_round_duration",
    "train_async",
]

"""Simulated device clocks: pluggable per-client latency distributions.

Buffered-async federation (:mod:`repro.asyncfl`) measures its speedup in
**simulated seconds**, not host time: every dispatched client draws a
compute+upload latency from a :class:`LatencyModel`, the virtual clock
advances to each flush's B-th arrival, and a sync baseline for the same
fleet is the per-round barrier ``max`` over all clients
(:func:`sync_round_duration`).

Determinism contract (the same one ``repro.population.samplers`` uses for
cohorts): a draw depends ONLY on ``(model seed, vid, dispatch seq)`` via a
fresh ``np.random.default_rng((seed, _LATENCY_TAG, vid, seq))`` per
element — no sampler state, so checkpoint/resume replays the identical
arrival schedule from the counters carried on the
:class:`repro.asyncfl.runtime.AsyncState`, and the chunked driver can
project the event schedule ahead of execution
(:class:`repro.asyncfl.events.EventView`) without desyncing from the
per-cycle driver.

Three models ship (plus the :func:`latency_profile` CLI factory):

* :class:`UniformLatency` — compute ~ U(a, b) + upload ~ U(c, d). With
  zero spread (``a == b``, ``c == d``) every device is identical — the
  degenerate clock of the sync-equivalence identity gate.
* :class:`LognormalLatency` — heavy-tailed compute times
  (``median * lognormal(0, sigma)``), the classic straggler model.
* :class:`HeteroLatency` — per-vid means scaled by a
  :class:`repro.population.samplers.HeterogeneousCohort`'s availability
  rates: ``mean_v = base * (1 + slow_factor * (1 - rate_v))``, so flaky
  (low-availability) devices are also the slow ones — the correlation
  that makes staleness weighting matter.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol

import numpy as np

# integer stream tag (SeedSequence entropy): disjoint from the cohort /
# rates tags of repro.population.samplers
_LATENCY_TAG = 0x1A7E9C


class LatencyModel(Protocol):
    """``model(vids, seqs) -> (n,) float64 simulated seconds``: the total
    compute+upload latency of each (client vid, dispatch seq) pair. Must
    be a pure function of ``(self, vid, seq)`` — see the module
    determinism contract."""

    def __call__(self, vids: np.ndarray, seqs: np.ndarray) -> np.ndarray: ...


def _element_rngs(seed: int, vids, seqs):
    """One independent Generator per (vid, seq) element."""
    return [np.random.default_rng((int(seed), _LATENCY_TAG, int(v), int(s)))
            for v, s in zip(np.asarray(vids).ravel(), np.asarray(seqs).ravel())]


def _check_range(name: str, lo: float, hi: float) -> None:
    if not 0.0 <= lo <= hi:
        raise ValueError(f"{name} range must satisfy 0 <= lo <= hi, "
                         f"got ({lo}, {hi})")


@dataclass(frozen=True)
class UniformLatency:
    """compute ~ U(compute) + upload ~ U(upload), identical for every vid.

    ``compute=(c, c), upload=(u, u)`` is the zero-spread degenerate clock:
    every dispatch takes exactly ``c + u`` simulated seconds, so all B ==
    n_clients uploads arrive simultaneously and the async engine reduces
    to the sync barrier (the identity gate's setting)."""
    seed: int = 0
    compute: tuple[float, float] = (0.5, 1.5)
    upload: tuple[float, float] = (0.05, 0.15)

    def __post_init__(self):
        _check_range("compute", *self.compute)
        _check_range("upload", *self.upload)

    def __call__(self, vids, seqs) -> np.ndarray:
        out = np.empty(np.asarray(vids).size, np.float64)
        for i, rng in enumerate(_element_rngs(self.seed, vids, seqs)):
            out[i] = (rng.uniform(*self.compute) + rng.uniform(*self.upload))
        return out


@dataclass(frozen=True)
class LognormalLatency:
    """Heavy-tailed compute: ``median * lognormal(0, sigma)`` + U(upload).

    The classic straggler distribution — most devices cluster near the
    median, a long tail takes many multiples of it."""
    seed: int = 0
    median: float = 1.0
    sigma: float = 0.75
    upload: tuple[float, float] = (0.05, 0.15)

    def __post_init__(self):
        if self.median <= 0:
            raise ValueError(f"median must be positive, got {self.median}")
        if self.sigma < 0:
            raise ValueError(f"sigma must be >= 0, got {self.sigma}")
        _check_range("upload", *self.upload)

    def __call__(self, vids, seqs) -> np.ndarray:
        out = np.empty(np.asarray(vids).size, np.float64)
        for i, rng in enumerate(_element_rngs(self.seed, vids, seqs)):
            out[i] = (self.median * rng.lognormal(0.0, self.sigma)
                      + rng.uniform(*self.upload))
        return out


@dataclass(frozen=True)
class HeteroLatency:
    """Fleet-correlated latency: slow where the cohort model is flaky.

    Per-vid mean ``mean_v = base * (1 + slow_factor * (1 - rate_v))`` with
    ``rate_v`` the Beta availability rate of ``cohort``
    (:meth:`HeterogeneousCohort.rates`), jittered per draw by
    ``U(1 - jitter, 1 + jitter)``. A device with rate 1.0 runs at ``base``;
    a rate-0 device at ``base * (1 + slow_factor)``. Coupling compute
    speed to the availability model is the point: the devices most likely
    to miss rounds are also the ones whose uploads arrive late and stale,
    which is exactly the regime staleness-weighted buffered aggregation
    (and its dispatch-time privacy charging) is designed for."""
    seed: int = 0
    fleet: int = 0                  # number of client vids (rates vector size)
    cohort: object = None           # HeterogeneousCohort; None -> default
    base: float = 1.0
    slow_factor: float = 4.0
    jitter: float = 0.25
    upload: tuple[float, float] = (0.05, 0.15)
    _cohort: object = field(init=False, repr=False, compare=False,
                            default=None)

    def __post_init__(self):
        if self.fleet <= 0:
            raise ValueError(f"fleet size must be positive, got {self.fleet}")
        if self.base <= 0 or self.slow_factor < 0:
            raise ValueError("base must be > 0 and slow_factor >= 0, got "
                             f"base={self.base} slow_factor={self.slow_factor}")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")
        _check_range("upload", *self.upload)
        cohort = self.cohort
        if cohort is None:
            from repro.population.samplers import HeterogeneousCohort
            cohort = HeterogeneousCohort(seed=self.seed)
        object.__setattr__(self, "_cohort", cohort)

    def rates(self) -> np.ndarray:
        """The fleet's (fleet,) availability rates (shared with sampling)."""
        return self._cohort.rates(self.fleet)

    def mean_latency(self, vids) -> np.ndarray:
        """Expected compute seconds per vid (before jitter/upload) — the
        monotone-in-unreliability quantity the composition test pins."""
        rate = self.rates()[np.asarray(vids)]
        return self.base * (1.0 + self.slow_factor * (1.0 - rate.astype(
            np.float64)))

    def __call__(self, vids, seqs) -> np.ndarray:
        means = self.mean_latency(vids)
        out = np.empty(means.size, np.float64)
        for i, rng in enumerate(_element_rngs(self.seed, vids, seqs)):
            out[i] = (means[i] * rng.uniform(1.0 - self.jitter,
                                             1.0 + self.jitter)
                      + rng.uniform(*self.upload))
        return out


LATENCY_PROFILES = ("uniform", "lognormal", "hetero")


def latency_profile(name: str, seed: int = 0, fleet: int = 0,
                    scale: float = 1.0) -> LatencyModel:
    """CLI factory for ``launch/train --latency-profile``. ``scale`` sets
    the nominal per-dispatch seconds; ``fleet`` (the client count) is only
    needed by the hetero profile's rates vector."""
    if name == "uniform":
        return UniformLatency(seed, compute=(0.5 * scale, 1.5 * scale),
                              upload=(0.05 * scale, 0.15 * scale))
    if name == "lognormal":
        return LognormalLatency(seed, median=scale,
                                upload=(0.05 * scale, 0.15 * scale))
    if name == "hetero":
        return HeteroLatency(seed, fleet=fleet, base=scale,
                             upload=(0.05 * scale, 0.15 * scale))
    raise ValueError(f"latency profile must be one of {LATENCY_PROFILES}, "
                     f"got {name!r}")


def sync_round_duration(model: LatencyModel, fleet: int,
                        round_idx: int) -> float:
    """Simulated seconds one SYNC round takes on this fleet: the barrier
    waits for the slowest of all ``fleet`` clients (each drawing with
    ``seq = round_idx``). The sync side of the simulated-time-to-target
    comparison in ``benchmarks/throughput.py``."""
    vids = np.arange(fleet)
    return float(np.max(model(vids, np.full(fleet, int(round_idx)))))

"""Buffered-async flush/dispatch executor (FedBuff-style, Nguyen et al.).

One async *cycle* = one jitted XLA program that

1. **flushes** the B popped arrivals into the global model — a
   staleness-weighted masked mean of the arrivals' slot replicas (dense),
   or of their compressed error-fed deltas (pipeline with compressor) —
   and
2. **dispatches** replacements for exactly those B slots from the new
   global model: the same ``make_local_round`` per-client step the sync
   engines vmap (so every kernel backend and compressor composes
   unchanged), with updates *computed at dispatch*: the upload a slot
   will contribute to some future flush is fixed the moment it starts
   training, which is what lets the whole flush+dispatch pair fuse into
   one program with donated slot storage.

Numerical contract (the sync-equivalence identity gate): with
``buffer_size == n_clients``, a zero-spread latency model, and
``staleness_alpha == 0``, every flush pops ``idx == arange(C)`` with unit
weights and each cycle's expressions degenerate **bit-for-bit** to the
sync ``vmap`` engine's round (``core/fl.py`` + ``core/aggregation.py``):

* weights enter only as ``m = w * mask`` (``1.0 * x`` is bitwise ``x``)
  and as an anchor carry ``(sum(mask) - sum(m)) * anchor`` that is an
  exact float zero when ``w == 1``;
* the dense flush divides by ``sum(mask)`` exactly like
  ``AggregationPipeline._masked_mean_bcast`` / ``jnp.mean`` over the
  client axis;
* integer optimizer leaves (step counters) follow the same comb rules as
  the sync paths (``tree_mean_over_axis0(keep_dtype=True)`` outside a
  pipeline, the masked-mean ``astype`` inside one);
* the per-cycle PRNG schedule replicates ``run_round`` + ``round_step``:
  ``key, sub = split(key)``, then ``split(sub, B)`` or the pipeline's
  ``(mask_key, pipeline_round_keys)`` derivation over the B-block.

Staleness (``w(s) = 1/(1+s)^alpha`` by default, pluggable at the runtime
layer) mixes each stale arrival toward the *current* global model: the
flush is ``[sum(w_i m_i x_i) + (sum(m) - sum(w m)) * global] / sum(m)``
for dense updates, and a plain ``w``-scaled delta average for compressed
updates (deltas are already anchored at the global model).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.api.spec import FederationSpec
from repro.core.aggregation import (
    flatten_tree,
    participation_mask,
    unflatten_like,
)
from repro.core.fl import make_grad_fn, make_local_round, pipeline_round_keys
from repro.utils.tree import tree_broadcast_axis0


def block_participants(spec: FederationSpec, block: int) -> int:
    """Participants sampled for a dispatch block of ``block`` slots: the
    spec's exact per-round count when the block is the full cohort (the
    degenerate/identity case), else the participation fraction scaled to
    the block (floored at one so every dispatch trains something)."""
    if block == spec.n_clients:
        return spec.participants_per_round()
    return max(1, min(block, round(spec.participation_fraction() * block)))


def _take0(tree, idx):
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), tree)


def _scatter0(store, new, idx):
    return jax.tree.map(lambda s, n: s.at[idx].set(n), store, new)


class AsyncBufferedExecutor:
    """Per-spec compiled flush/dispatch cycle (+ the generation-0 dispatch).

    Three operand layouts exist (plain / pipeline-dense /
    pipeline-compressed); :meth:`init_dispatch` and :meth:`cycle` hide the
    layout behind keyword residual/sent operands. jit retraces per block
    shape (B vs tail sizes), which is the intended shape-keyed cache.

    Donation: ``cycle`` donates the global model/opt and every slot
    storage (params, opt, metrics, and sent/residual when compressed) —
    the runtime must continue from the returned
    :class:`repro.asyncfl.runtime.AsyncState`, mirroring the sync
    drivers' donation contract.
    """

    def __init__(self, spec: FederationSpec):
        if not spec.is_async():
            raise ValueError("AsyncBufferedExecutor needs "
                             "engine='async_buffered', got "
                             f"engine={spec.engine!r}")
        self.spec = spec
        cfg = spec.fl_config(vmap_clients=True)
        self._avg_opt = cfg.average_opt_state
        self._pipeline = spec.aggregation_pipeline()
        self._compressor = (self._pipeline.compressor
                            if self._pipeline is not None else None)
        self._local_round = make_local_round(
            make_grad_fn(spec.loss_fn, cfg), spec.optimizer, cfg.tau)
        if self._compressor is not None:
            donate = (0, 1, 2, 3, 4, 5, 6)
        else:
            donate = (0, 1, 2, 3, 4)
        self._cycle = jax.jit(self._build_cycle(),
                              donate_argnums=donate)
        self._init = jax.jit(self._build_init())

    # -- dispatch core (shared by init and cycle) ---------------------------

    def _dispatch(self, global_p, global_o, slot_o_src, batch, sub, sigmas_b,
                  residual_b):
        """Train one block of ``b`` slots from ``global_p``: replicates the
        sync round's key schedule and local rounds over the block, plus the
        at-dispatch compression of the update the block will upload.

        ``slot_o_src`` is the per-slot optimizer state the block resumes
        from when the spec keeps optimizer state local
        (``average_opt_state=False``); ignored (broadcast of ``global_o``)
        otherwise. Returns ``(new_p, new_s, ms, sent_b, residual_b, mask)``
        with ``sent_b``/``residual_b`` None for dense specs and ``mask``
        the block's participation mask (all-ones without a pipeline).
        """
        b = jax.tree.leaves(batch)[0].shape[0]
        if self._pipeline is not None:
            sub, mask_key = jax.random.split(sub)
            mask = participation_mask(mask_key, b,
                                      block_participants(self.spec, b))
            keys, agg_keys = pipeline_round_keys(sub, b)
        else:
            mask = jnp.ones((b,), jnp.float32)
            keys = jax.random.split(sub, b)
        base = tree_broadcast_axis0(global_p, b)
        opt_in = (tree_broadcast_axis0(global_o, b) if self._avg_opt
                  else slot_o_src)
        new_p, new_s, ms = jax.vmap(self._local_round)(base, opt_in, batch,
                                                       keys, sigmas_b)
        sent_b = None
        if self._compressor is not None:
            flat_prev = jax.vmap(flatten_tree)(base)
            flat_new = jax.vmap(flatten_tree)(new_p)
            corrected = (flat_new - flat_prev) + residual_b
            sent_b = jax.vmap(self._compressor)(corrected, agg_keys)
            sel = mask[:, None]
            residual_b = (sel * (corrected - sent_b)
                          + (1.0 - sel) * residual_b)
        if self._pipeline is not None and not self._avg_opt:
            # non-participants of this dispatch did not really train: same
            # masked mix as AggregationPipeline's average_opt_state=False
            def _mask_leaf(new, old):
                m = mask.reshape((-1,) + (1,) * (new.ndim - 1))
                return (m * new.astype(jnp.float32)
                        + (1.0 - m) * old.astype(jnp.float32)).astype(
                            new.dtype)
            new_s = jax.tree.map(_mask_leaf, new_s, opt_in)
        return new_p, new_s, ms, sent_b, residual_b, mask

    # -- generation-0 dispatch ---------------------------------------------

    def _build_init(self):
        def init_plain(global_p, global_o, batch, key, sigmas):
            key, sub = jax.random.split(key)
            new_p, new_s, ms, _, _, mask = self._dispatch(
                global_p, global_o, tree_broadcast_axis0(
                    global_o, self.spec.n_clients), batch, sub, sigmas, None)
            return new_p, new_s, ms, key, mask

        def init_compressed(global_p, global_o, residual, batch, key, sigmas):
            key, sub = jax.random.split(key)
            new_p, new_s, ms, sent, residual, mask = self._dispatch(
                global_p, global_o, tree_broadcast_axis0(
                    global_o, self.spec.n_clients), batch, sub, sigmas,
                residual)
            return new_p, new_s, ms, sent, residual, key, mask

        return (init_compressed if self._compressor is not None
                else init_plain)

    def init_dispatch(self, global_p, global_o, batch, key, sigmas,
                      residual=None):
        """Dispatch generation 0 (every slot, from the initial model).

        Returns a dict of the fresh slot storages + the advanced key and
        the block's participation mask.
        """
        if self._compressor is not None:
            p, s, ms, sent, res, key, mask = self._init(
                global_p, global_o, residual, batch, key, sigmas)
        else:
            p, s, ms, key, mask = self._init(global_p, global_o, batch, key,
                                             sigmas)
            sent = res = None
        return {"slot_params": p, "slot_opt": s, "slot_metrics": ms,
                "sent": sent, "residual": res, "key": key, "mask": mask}

    # -- the fused flush + dispatch cycle -----------------------------------

    def _flush(self, global_p, global_o, slot_p, slot_o, slot_ms, sent, idx,
               weights, arr_mask):
        """Fold the popped arrivals into the global model (staleness- and
        participation-weighted) and reduce their metrics. Returns
        ``(new_global_p, new_global_o, record_metrics)``."""
        m = weights * arr_mask
        den_sel = jnp.sum(arr_mask)
        den_w = jnp.sum(m)
        in_pipeline = self._pipeline is not None

        def _comb(new_b, anchor):
            # int leaves: lockstep counters outside a pipeline take a
            # replica (tree_mean_over_axis0 keep_dtype rule); inside one
            # they ride the masked-mean astype like the sync pipeline
            if (not in_pipeline
                    and jnp.issubdtype(new_b.dtype, jnp.integer)):
                return new_b[0]
            mm = m.reshape((-1,) + (1,) * (new_b.ndim - 1))
            s = jnp.sum(mm * new_b.astype(jnp.float32), axis=0)
            carry = (den_sel - den_w) * anchor.astype(jnp.float32)
            return ((s + carry) / den_sel).astype(new_b.dtype)

        if self._compressor is not None:
            sent_b = jnp.take(sent, idx, axis=0)
            avg_delta = jnp.sum(m[:, None] * sent_b, axis=0) / den_sel
            new_gp = unflatten_like(flatten_tree(global_p) + avg_delta,
                                    global_p)
        else:
            new_gp = jax.tree.map(_comb, _take0(slot_p, idx), global_p)
        if self._avg_opt:
            new_go = jax.tree.map(_comb, _take0(slot_o, idx), global_o)
        else:
            new_go = global_o
        rec_ms = jax.tree.map(lambda x: jnp.sum(arr_mask * x) / den_sel,
                              _take0(slot_ms, idx))
        return new_gp, new_go, rec_ms

    def _build_cycle(self):
        def cycle_plain(global_p, global_o, slot_p, slot_o, slot_ms, key,
                        sigmas, idx, weights, arr_mask, batch):
            new_gp, new_go, rec_ms = self._flush(
                global_p, global_o, slot_p, slot_o, slot_ms, None, idx,
                weights, arr_mask)
            key, sub = jax.random.split(key)
            new_p, new_s, ms_b, _, _, nmask = self._dispatch(
                new_gp, new_go, _take0(slot_o, idx), batch, sub,
                jnp.take(sigmas, idx), None)
            slot_p = _scatter0(slot_p, new_p, idx)
            slot_o = _scatter0(slot_o, new_s, idx)
            slot_ms = _scatter0(slot_ms, ms_b, idx)
            return (new_gp, new_go, slot_p, slot_o, slot_ms, key, nmask,
                    rec_ms)

        def cycle_compressed(global_p, global_o, slot_p, slot_o, slot_ms,
                             sent, residual, key, sigmas, idx, weights,
                             arr_mask, batch):
            new_gp, new_go, rec_ms = self._flush(
                global_p, global_o, slot_p, slot_o, slot_ms, sent, idx,
                weights, arr_mask)
            key, sub = jax.random.split(key)
            new_p, new_s, ms_b, sent_b, res_b, nmask = self._dispatch(
                new_gp, new_go, _take0(slot_o, idx), batch, sub,
                jnp.take(sigmas, idx), jnp.take(residual, idx, axis=0))
            slot_p = _scatter0(slot_p, new_p, idx)
            slot_o = _scatter0(slot_o, new_s, idx)
            slot_ms = _scatter0(slot_ms, ms_b, idx)
            sent = sent.at[idx].set(sent_b)
            residual = residual.at[idx].set(res_b)
            return (new_gp, new_go, slot_p, slot_o, slot_ms, sent, residual,
                    key, nmask, rec_ms)

        return (cycle_compressed if self._compressor is not None
                else cycle_plain)

    def cycle(self, global_p, global_o, slot_p, slot_o, slot_ms, key, sigmas,
              idx, weights, arr_mask, batch, sent=None, residual=None):
        """One fused flush+dispatch over the popped arrival block ``idx``.

        ``weights``/``arr_mask`` are the block's staleness weights and its
        dispatch-time participation mask ((B,) f32, host-computed);
        ``batch`` is the replacement dispatch's (B, tau, ...) round batch.
        Returns a dict with the new globals, updated slot storages, the
        advanced key, the NEW dispatch's participation mask (the one
        host sync of a cycle, fetched by the runtime for the ledger), and
        the flushed arrivals' reduced metrics.
        """
        if self._compressor is not None:
            (gp, go, sp, so, sm, sent, residual, key, nmask,
             rec_ms) = self._cycle(global_p, global_o, slot_p, slot_o,
                                   slot_ms, sent, residual, key, sigmas, idx,
                                   weights, arr_mask, batch)
        else:
            gp, go, sp, so, sm, key, nmask, rec_ms = self._cycle(
                global_p, global_o, slot_p, slot_o, slot_ms, key, sigmas,
                idx, weights, arr_mask, batch)
        return {"global_params": gp, "global_opt": go, "slot_params": sp,
                "slot_opt": so, "slot_metrics": sm, "sent": sent,
                "residual": residual, "key": key, "mask": nmask,
                "metrics": rec_ms}


# per-spec executor cache (mirrors engines._ROUND_FN_CACHE: bounded LRU —
# executors hold XLA executables). Keyed like the chunked cache: the
# participation count is baked into the traced dispatch.
_EXECUTOR_CACHE: dict[tuple, AsyncBufferedExecutor] = {}
_EXECUTOR_CACHE_MAX = 16


def executor_for(spec: FederationSpec) -> AsyncBufferedExecutor:
    """The cached :class:`AsyncBufferedExecutor` for ``spec`` (per engine
    key + participant count, LRU-bounded)."""
    key = (spec.engine_key(), spec.participants_per_round())
    ex = _EXECUTOR_CACHE.pop(key, None)
    if ex is None:
        ex = AsyncBufferedExecutor(spec)
        while len(_EXECUTOR_CACHE) >= _EXECUTOR_CACHE_MAX:
            _EXECUTOR_CACHE.pop(next(iter(_EXECUTOR_CACHE)))
    _EXECUTOR_CACHE[key] = ex
    return ex

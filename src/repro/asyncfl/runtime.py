"""Buffered-async training driver: AsyncState + init/cycle/train_async.

The async mirror of :mod:`repro.api.state`: one immutable
:class:`AsyncState` value per federation — the global model, the K slot
storages (one in-flight client per slot), the simulated arrival schedule,
and the dispatch-split privacy ledger — advanced one *flush cycle* at a
time by :func:`run_async_cycle` and driven to the budgets by
:func:`train_async` through the shared
:func:`repro.api.state.budget_train_loop` hooks (checkpoint/resume, eval
boundaries, theta* tracking, and double-buffered chunking are inherited,
not reimplemented).

Dispatch-time privacy accounting (the staleness-aware ledger): a client
is charged the full Lemma-2 per-round rho **when it is dispatched** — for
the model version it trains on — not when its upload lands. The charge
sits in ``pending_rho`` until the flush that consumes the upload moves it
into the landed ``fl.rho``; every budget probe reads the *dispatched*
view ``fl.rho + pending_rho``, so a straggler whose upload is still in
flight can never let the probe under-count: privacy is spent the moment
the (noised) local computation is committed, and the flush only changes
*which* ledger column holds it. With the degenerate schedule (B == K,
zero latency spread, alpha=0) the landed ledger is bit-for-bit the sync
``run_round`` ledger: same masks, same per-round charge vector, same
numpy accumulation order.

Resource accounting charges Eq. 8 *per flush*, scaled by what actually
moved: ``c1 * wire_ratio * (participating arrivals / C)`` for the
aggregation and ``c2 * tau * (B / C)`` for the compute the flush consumed
— exactly ``spec.round_cost()`` in the degenerate case.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import FederationSpec
from repro.api.state import (
    BudgetExceeded,
    FLState,
    accountant_view,
    budget_train_loop,
    round_batch,
    round_rho_charges,
    sigmas_for,
)
from repro.asyncfl.clock import LatencyModel, UniformLatency
from repro.asyncfl.engine import executor_for
from repro.asyncfl.events import EventView, earliest_arrivals
from repro.core.privacy import zcdp_to_dp


@dataclass(frozen=True)
class AsyncState:
    """Complete state of one buffered-async federation (immutable).

    ``fl`` reuses :class:`repro.api.FLState` with async readings: its
    params/opt_state/residual are the K *slot* storages (slot i = the
    in-flight dispatch of client i; what that client will upload, computed
    at dispatch), its ``rho`` is the LANDED ledger (flushed charges only
    — probe with ``+ pending_rho`` for the sound dispatched view), and
    ``rounds_done`` counts completed flushes (== the global model
    version). All schedule arrays are host numpy: the event loop is exact
    host math, like the zCDP ledger.
    """
    fl: FLState
    global_params: Any              # the single server model (no client axis)
    global_opt: Any                 # its optimizer state (average_opt_state)
    sent: Any                       # (K, D) at-dispatch compressed uploads
    #   (None for dense specs); the flush averages rows of this
    slot_metrics: Any               # pytree of (K,) per-slot local metrics
    slot_mask: np.ndarray           # (K,) f32 dispatch-time participation mask
    pending_rho: np.ndarray         # (K,) f64 in-flight dispatch pre-charges
    slot_version: np.ndarray        # (K,) i64 model version trained on
    slot_seq: np.ndarray            # (K,) i64 dispatch seq (latency stream id)
    arrival_time: np.ndarray        # (K,) f64 pending arrival timestamps
    arrivals: np.ndarray            # (K,) i64 landed uploads per slot
    clock: float = 0.0              # virtual seconds at the last flush
    next_seq: int = 0               # global dispatch counter

    def replace(self, **changes) -> "AsyncState":
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ScheduleRow:
    """One pre-projected flush cycle (see :func:`train_async` chunking)."""
    idx: np.ndarray                 # (B,) popped slots, pop order
    flush_time: float
    latency: np.ndarray             # (B,) replacement-dispatch latencies
    batch: Any                      # (B, tau, ...) device round batch


def polynomial_staleness(alpha: float) -> Callable[[np.ndarray], np.ndarray]:
    """The default staleness weight ``w(s) = 1 / (1 + s)^alpha`` (FedBuff /
    FedAsync polynomial damping). ``alpha=0`` returns exact 1.0 weights —
    the identity-gate setting."""
    def weight(s: np.ndarray) -> np.ndarray:
        return np.power(1.0 + np.asarray(s, np.float64),
                        -float(alpha)).astype(np.float32)
    return weight


# ---------------------------------------------------------------------------
# budget probes (dispatched view: landed + in-flight)
# ---------------------------------------------------------------------------

def dispatched_rho(state: AsyncState) -> np.ndarray:
    """(C,) zCDP each client has COMMITTED to spend: landed + in-flight.
    Every probe reads this, never the landed ledger alone — see the module
    docstring for why stragglers can't outrun it."""
    return state.fl.rho + state.pending_rho


def dispatched_epsilon(spec: FederationSpec, state: AsyncState) -> float:
    """Worst-client (eps, delta)-DP of the dispatched view."""
    return zcdp_to_dp(float(np.max(dispatched_rho(state))), spec.delta)


def async_flush_cost(spec: FederationSpec, n_arrivals: int,
                     n_participants: int) -> float:
    """Eq.-8 cost of one realized flush: comm for the participating
    arrivals' uploads + compute for the ``n_arrivals`` local rounds the
    flush consumed. Degenerates bit-for-bit to ``spec.round_cost()`` when
    the flush is a full sync round (n_arrivals == C, participants == the
    spec's per-round count)."""
    comm = spec.c1 * (spec.wire_ratio() * (n_participants / spec.n_clients))
    comp = spec.c2 * spec.tau * (n_arrivals / spec.n_clients)
    return comm + comp


def async_flush_cost_bound(spec: FederationSpec) -> float:
    """Upper bound on any flush's cost (all B arrivals participate) — the
    conservative per-flush increment the budget probes assume."""
    b = spec.resolved_buffer_size()
    return async_flush_cost(spec, b, b)


def exceeds_async_budgets(spec: FederationSpec,
                          state: AsyncState) -> str | None:
    """Would one more flush break a budget? "resource" / "privacy" / None.

    Conservative and sound: the privacy probe assumes every client may be
    redispatched once more on top of everything already committed
    (dispatched view + one worst-case round charge); the resource probe
    assumes a maximal flush. Because in-flight work is pre-charged, this
    is the async analogue of ``exceeds_budgets`` — it can stop one flush
    earlier than the landed ledger alone would, never later."""
    if state.fl.resource_spent + async_flush_cost_bound(spec) > spec.c_th:
        return "resource"
    probe = np.max(dispatched_rho(state) + round_rho_charges(spec))
    if zcdp_to_dp(float(probe), spec.delta) > spec.eps_th:
        return "privacy"
    return None


def flushes_within_budgets(spec: FederationSpec, state: AsyncState,
                           limit: int) -> tuple[int, str | None]:
    """How many consecutive flushes are CERTAIN to fit the budgets (the
    async ``rounds_within_budgets``): replays the conservative per-flush
    probes with worst-case ledger growth."""
    charges = round_rho_charges(spec)
    rho = dispatched_rho(state)
    spent = state.fl.resource_spent
    cost = async_flush_cost_bound(spec)
    n = 0
    while n < limit:
        if spent + cost > spec.c_th:
            return n, "resource"
        if zcdp_to_dp(float(np.max(rho + charges)), spec.delta) > spec.eps_th:
            return n, "privacy"
        rho = rho + charges
        spent = spent + cost
        n += 1
    return n, None


def _raise_async_budget(which: str, spec: FederationSpec):
    if which == "resource":
        raise BudgetExceeded(
            "resource", f"flush cost bound {async_flush_cost_bound(spec)} "
            f"would exceed C_th={spec.c_th}")
    raise BudgetExceeded(
        "privacy", f"dispatching {spec.resolved_buffer_size()} more clients "
        f"(tau={spec.tau} pre-charged steps each) could exceed "
        f"eps_th={spec.eps_th}")


def async_accountant_view(spec: FederationSpec, state: AsyncState):
    """A :class:`PrivacyAccountant` materialized at the dispatched view,
    with the dispatch/arrival split restored (``pending_rho``/
    ``landed_rho`` report per-client in-flight vs flushed charges)."""
    acc = accountant_view(spec)
    for m in range(spec.n_clients):
        acc._rho[m] = float(state.fl.rho[m] + state.pending_rho[m])
        if state.pending_rho[m] > 0.0:
            acc._pending[m] = float(state.pending_rho[m])
    acc.steps = state.fl.steps
    return acc


# ---------------------------------------------------------------------------
# init / cycle
# ---------------------------------------------------------------------------

def _block_batch(spec: FederationSpec, sampler: Callable, rng,
                 idx: np.ndarray) -> Any:
    """Stack the popped slots' round batches in pop order — with the
    degenerate ``idx == arange(C)`` this consumes the sampler rng stream
    exactly like :func:`repro.api.state.round_batch`."""
    per_slot = [sampler(int(m), spec.tau, rng) for m in idx]
    return jax.tree.map(lambda *xs: np.stack(xs), *per_slot)


def init_async_state(spec: FederationSpec, params0: Any, sampler: Callable,
                     *, rng=None, latency_model: LatencyModel | None = None,
                     key: jax.Array | None = None,
                     check_budgets: bool = True) -> AsyncState:
    """Fresh AsyncState: dispatch generation 0 (all K slots, from the
    initial model) and schedule its arrivals at the latency model's draws.

    The generation-0 dispatch consumes exactly the sync driver's round-1
    PRNG/batch schedule and is pre-charged in ``pending_rho`` — nothing
    has landed yet, so ``fl.rho`` starts zero and ``clock`` at 0.0.
    """
    if not spec.is_async():
        raise ValueError("init_async_state needs engine='async_buffered', "
                         f"got engine={spec.engine!r}")
    if rng is None:
        rng = np.random.default_rng(spec.seed)
    if latency_model is None:
        latency_model = UniformLatency(spec.seed)
    if key is None:
        key = jax.random.PRNGKey(spec.seed)
    charges = round_rho_charges(spec)
    if check_budgets:
        # the same first-round probe the sync driver runs, against the
        # conservative flush bound / the gen-0 dispatch charge
        if async_flush_cost_bound(spec) > spec.c_th:
            _raise_async_budget("resource", spec)
        if zcdp_to_dp(float(np.max(charges)), spec.delta) > spec.eps_th:
            _raise_async_budget("privacy", spec)
    k = spec.n_clients
    global_params = jax.tree.map(jnp.asarray, params0)
    global_opt = spec.optimizer.init(global_params)
    pipe = spec.aggregation_pipeline()
    residual0 = pipe.init_residual(params0) if pipe is not None else None
    batch = round_batch(spec, sampler, rng)
    out = executor_for(spec).init_dispatch(
        global_params, global_opt, batch, key, sigmas_for(spec),
        residual=residual0)
    mask_np = np.asarray(out["mask"])
    fl = FLState(params=out["slot_params"], opt_state=out["slot_opt"],
                 key=out["key"], rho=np.zeros((k,), np.float64),
                 residual=out["residual"])
    latency = np.asarray(latency_model(np.arange(k), np.arange(k)),
                         np.float64)
    return AsyncState(
        fl=fl, global_params=global_params, global_opt=global_opt,
        sent=out["sent"], slot_metrics=out["slot_metrics"],
        slot_mask=mask_np.astype(np.float32),
        pending_rho=np.where(mask_np > 0, charges, 0.0),
        slot_version=np.zeros((k,), np.int64),
        slot_seq=np.arange(k, dtype=np.int64),
        arrival_time=latency, arrivals=np.zeros((k,), np.int64),
        clock=0.0, next_seq=k)


def run_async_cycle(spec: FederationSpec, state: AsyncState,
                    sampler: Callable | None = None, rng=None, *,
                    latency_model: LatencyModel | None = None,
                    staleness_weight: Callable | None = None,
                    check_budgets: bool = True,
                    prebuilt: ScheduleRow | None = None,
                    ) -> tuple[AsyncState, dict]:
    """One flush cycle: pop the B earliest arrivals, fold them into the
    global model (staleness-weighted), land their privacy charges, and
    redispatch the popped slots from the new model (pre-charging them).

    Either pass ``sampler``/``rng``/``latency_model`` (the per-cycle
    driver builds its own schedule step) or a ``prebuilt``
    :class:`ScheduleRow` from the chunked driver's projection — the two
    are interchangeable cycle for cycle (the schedule is deterministic;
    a desynced projection raises rather than training on wrong slots).

    Donation: the input state's device buffers (global model/opt, all
    slot storages) are CONSUMED — continue from the returned state, like
    ``run_round``. The returned record's metric values stay lazy 0-d
    device arrays; ``materialize_record`` forces them.
    """
    if check_budgets:
        which = exceeds_async_budgets(spec, state)
        if which is not None:
            _raise_async_budget(which, spec)
    b = spec.resolved_buffer_size()
    if prebuilt is None:
        if sampler is None or rng is None or latency_model is None:
            raise ValueError("run_async_cycle needs sampler, rng and "
                             "latency_model (or a prebuilt ScheduleRow)")
        view = EventView(state.arrival_time, state.slot_seq, state.next_seq,
                         state.clock)
        idx, flush_time, new_seqs, new_latency = view.pop(b, latency_model)
        batch = _block_batch(spec, sampler, rng, idx)
    else:
        idx, flush_time = prebuilt.idx, prebuilt.flush_time
        new_latency, batch = prebuilt.latency, prebuilt.batch
        live = earliest_arrivals(state.arrival_time, state.slot_seq, b)
        if not np.array_equal(live, idx):
            raise RuntimeError(
                "prebuilt schedule desynced from the live event state "
                f"(expected pop {live}, row has {idx}) — rebuild the "
                "projection from the current AsyncState")
        new_seqs = state.next_seq + np.arange(b, dtype=np.int64)
    staleness = (state.fl.rounds_done
                 - state.slot_version[idx]).astype(np.int64)
    weight_fn = (staleness_weight
                 or polynomial_staleness(spec.staleness_alpha))
    weights = np.asarray(weight_fn(staleness), np.float32)
    arr_mask = state.slot_mask[idx].astype(np.float32)
    out = executor_for(spec).cycle(
        state.global_params, state.global_opt, state.fl.params,
        state.fl.opt_state, state.slot_metrics, state.fl.key,
        sigmas_for(spec), jnp.asarray(idx), jnp.asarray(weights),
        jnp.asarray(arr_mask), batch, sent=state.sent,
        residual=state.fl.residual)
    nmask = np.asarray(out["mask"])    # the cycle's one blocking host sync
    charges = round_rho_charges(spec)
    # land the popped arrivals' pre-charges, then pre-charge the redispatch
    landed = np.zeros((spec.n_clients,), np.float64)
    landed[idx] = state.pending_rho[idx]
    rho = state.fl.rho + landed
    pending = state.pending_rho.copy()
    pending[idx] = np.where(nmask > 0, charges[idx], 0.0)
    n_participants = int(arr_mask.sum())
    cost = async_flush_cost(spec, b, n_participants)
    slot_mask = state.slot_mask.copy()
    slot_mask[idx] = nmask.astype(np.float32)
    slot_version = state.slot_version.copy()
    slot_version[idx] = state.fl.rounds_done + 1   # trains on the new model
    arrival_time = state.arrival_time.copy()
    arrival_time[idx] = flush_time + new_latency
    slot_seq = state.slot_seq.copy()
    slot_seq[idx] = new_seqs
    arrivals = state.arrivals.copy()
    arrivals[idx] += 1
    fl = state.fl.replace(
        params=out["slot_params"], opt_state=out["slot_opt"],
        key=out["key"], residual=out["residual"], rho=rho,
        steps=state.fl.steps + spec.tau,
        resource_spent=state.fl.resource_spent + cost,
        rounds_done=state.fl.rounds_done + 1)
    new_state = state.replace(
        fl=fl, global_params=out["global_params"],
        global_opt=out["global_opt"], sent=out["sent"],
        slot_metrics=out["slot_metrics"], slot_mask=slot_mask,
        pending_rho=pending, slot_version=slot_version, slot_seq=slot_seq,
        arrival_time=arrival_time, arrivals=arrivals,
        clock=float(flush_time), next_seq=state.next_seq + b)
    rec = dict(out["metrics"])        # lazy 0-d device arrays, no sync
    rec["round"] = fl.rounds_done
    rec["iterations"] = fl.rounds_done * spec.tau
    rec["max_epsilon"] = zcdp_to_dp(float(np.max(rho)), spec.delta)
    rec["max_epsilon_dispatched"] = dispatched_epsilon(spec, new_state)
    rec["resource_spent"] = fl.resource_spent
    rec["participants"] = float(n_participants)
    rec["sim_seconds"] = new_state.clock
    rec["buffer_size"] = float(b)
    rec["staleness_mean"] = float(np.mean(staleness))
    rec["staleness_max"] = float(np.max(staleness))
    return new_state, rec


# ---------------------------------------------------------------------------
# budget-aware driver
# ---------------------------------------------------------------------------

def async_eval_params(spec: FederationSpec, state: AsyncState) -> Any:
    """The single evaluation/serving model: async topology is always
    full_average, and the server model is already collapsed."""
    del spec
    return state.global_params


def train_async(spec: FederationSpec, state: AsyncState, sampler: Callable,
                max_rounds: int = 10_000, eval_fn: Callable | None = None,
                eval_every: int = 1, rng=None,
                history: list[dict] | None = None, chunk_rounds: int = 1,
                latency_model: LatencyModel | None = None,
                staleness_weight: Callable | None = None,
                ) -> tuple[AsyncState, dict]:
    """Run flush cycles until a budget would be exceeded — the async
    :func:`repro.api.state.train`, built on the same
    :func:`budget_train_loop` (identical eval-boundary, theta*, and
    double-buffer semantics; "round" = flush).

    ``chunk_rounds=R > 1`` pre-projects R cycles of the (fully
    deterministic) event schedule host-side — pop indices, flush times,
    latency draws, and ``device_put`` batches — while the current chunk
    computes; cycles still execute one fused flush+dispatch program each.
    ``max_rounds`` caps completed flushes; the summary reports virtual
    ``sim_seconds`` alongside the budget totals.
    """
    if rng is None:
        rng = np.random.default_rng(spec.seed)
    if latency_model is None:
        latency_model = UniformLatency(spec.seed)
    history = [] if history is None else history
    b = spec.resolved_buffer_size()
    # the chunked driver's schedule cursor: an EventView replica advanced in
    # build order. budget_train_loop builds chunks in execution order, so
    # the cursor (like the sampler rng stream) stays aligned with the runs;
    # run_async_cycle re-derives the live pop and raises on any desync.
    cursor = EventView(state.arrival_time, state.slot_seq, state.next_seq,
                       state.clock)

    def build_chunk(start: int, n: int) -> list[ScheduleRow]:
        del start
        rows = []
        for _ in range(n):
            idx, t, _, latency = cursor.pop(b, latency_model)
            rows.append(ScheduleRow(
                idx=idx, flush_time=t, latency=latency,
                batch=jax.device_put(_block_batch(spec, sampler, rng, idx))))
        return rows

    def run_chunk(s, chunk, n, prefetch):
        recs = []
        for i in range(n):
            s, rec = run_async_cycle(spec, s, check_budgets=False,
                                     prebuilt=chunk[i],
                                     staleness_weight=staleness_weight)
            recs.append(rec)
            if i == 0:
                prefetch()     # overlap building the next chunk's schedule
        return s, recs

    state, best = budget_train_loop(
        state=state, max_rounds=max_rounds, eval_fn=eval_fn,
        eval_every=eval_every, history=history, chunk_rounds=chunk_rounds,
        rounds_done=lambda s: s.fl.rounds_done,
        exceeds=lambda s: exceeds_async_budgets(spec, s) is not None,
        safe_rounds=lambda s, cap: flushes_within_budgets(spec, s, cap)[0],
        run_single=lambda s: run_async_cycle(
            spec, s, sampler, rng, latency_model=latency_model,
            staleness_weight=staleness_weight, check_budgets=False),
        build_chunk=build_chunk,
        run_chunk=run_chunk,
        run_tail=lambda s, chunk, r: run_async_cycle(
            spec, s, check_budgets=False, prebuilt=chunk[r],
            staleness_weight=staleness_weight),
        eval_model=lambda s: async_eval_params(spec, s))
    return state, {
        "best": best, "rounds": state.fl.rounds_done,
        "resource_spent": state.fl.resource_spent,
        "max_epsilon": dispatched_epsilon(spec, state),
        "sim_seconds": state.clock,
        "history": history,
    }


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------

def save_async_state(directory: str, state: AsyncState,
                     extra: dict | None = None) -> None:
    """Persist an AsyncState (device trees + the host schedule/ledger)."""
    from repro.checkpoint import save_checkpoint
    meta = {
        "rho": [float(r) for r in state.fl.rho],
        "steps": int(state.fl.steps),
        "resource_spent": float(state.fl.resource_spent),
        "rounds_done": int(state.fl.rounds_done),
        "slot_mask": [float(x) for x in state.slot_mask],
        "pending_rho": [float(x) for x in state.pending_rho],
        "slot_version": [int(x) for x in state.slot_version],
        "slot_seq": [int(x) for x in state.slot_seq],
        "arrival_time": [float(x) for x in state.arrival_time],
        "arrivals": [int(x) for x in state.arrivals],
        "clock": float(state.clock),
        "next_seq": int(state.next_seq),
        **(extra or {}),
    }
    arrays = {"params": state.fl.params, "opt_state": state.fl.opt_state,
              "key": state.fl.key, "global_params": state.global_params,
              "global_opt": state.global_opt,
              "slot_metrics": state.slot_metrics}
    if state.fl.residual is not None:
        arrays["residual"] = state.fl.residual
    if state.sent is not None:
        arrays["sent"] = state.sent
    save_checkpoint(directory, arrays, step=state.fl.rounds_done, extra=meta)


def load_async_state(directory: str,
                     like: AsyncState) -> tuple[AsyncState, dict]:
    """Restore an AsyncState saved by :func:`save_async_state`; ``like``
    supplies structure (e.g. a fresh :func:`init_async_state`). Returns
    (state, extra). The restored schedule arrays replay the exact event
    stream — resuming mid-run realizes the same flush sequence as the
    uninterrupted run (pinned by the resume test)."""
    from repro.checkpoint import load_checkpoint
    like_tree = {"params": like.fl.params, "opt_state": like.fl.opt_state,
                 "key": like.fl.key, "global_params": like.global_params,
                 "global_opt": like.global_opt,
                 "slot_metrics": like.slot_metrics}
    if like.fl.residual is not None:
        like_tree["residual"] = like.fl.residual
    if like.sent is not None:
        like_tree["sent"] = like.sent
    tree, _, extra = load_checkpoint(directory, like=like_tree)
    fl = like.fl.replace(
        params=tree["params"], opt_state=tree["opt_state"],
        key=jnp.asarray(tree["key"]),
        residual=(jnp.asarray(tree["residual"])
                  if "residual" in tree else like.fl.residual),
        rho=np.asarray(extra["rho"], np.float64),
        steps=int(extra["steps"]),
        resource_spent=float(extra["resource_spent"]),
        rounds_done=int(extra["rounds_done"]))
    state = like.replace(
        fl=fl, global_params=tree["global_params"],
        global_opt=tree["global_opt"],
        sent=(jnp.asarray(tree["sent"]) if "sent" in tree else like.sent),
        slot_metrics=tree["slot_metrics"],
        slot_mask=np.asarray(extra["slot_mask"], np.float32),
        pending_rho=np.asarray(extra["pending_rho"], np.float64),
        slot_version=np.asarray(extra["slot_version"], np.int64),
        slot_seq=np.asarray(extra["slot_seq"], np.int64),
        arrival_time=np.asarray(extra["arrival_time"], np.float64),
        arrivals=np.asarray(extra["arrivals"], np.int64),
        clock=float(extra["clock"]), next_seq=int(extra["next_seq"]))
    return state, extra

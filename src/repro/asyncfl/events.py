"""Deterministic event schedule for buffered-async federation.

The async runtime is a discrete-event simulation over in-flight client
uploads. Each of the K slots (slot == client vid under the dense fleet)
carries a pending upload with a simulated ``arrival_time`` and the global
``seq`` number of its dispatch (the latency stream id — see
:mod:`repro.asyncfl.clock`). A *flush* pops the B earliest arrivals,
advances the virtual clock to the latest of them, aggregates, and
immediately redispatches those B slots with fresh latency draws.

Because latency draws are pure functions of ``(seed, vid, seq)`` and pops
are ordered by ``(arrival_time, seq)`` (seq breaks timestamp ties, so
simultaneous arrivals pop in dispatch order — this is what makes the
zero-latency-spread degenerate schedule pop ``idx == arange(K)`` and
reduce bit-for-bit to the sync barrier), the entire schedule is a
deterministic function of the initial state. :class:`EventView` exploits
that: a host-side replica of the schedule that the chunked driver rolls
forward to pre-build whole chunks of (idx, flush time, latencies) rows
ahead of execution, exactly like the sync driver pre-builds round
batches.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def earliest_arrivals(arrival_time: np.ndarray, seq: np.ndarray,
                      k: int) -> np.ndarray:
    """Indices of the ``k`` earliest pending uploads, orderd by
    ``(arrival_time, seq)``: earliest timestamp first, dispatch order
    among ties. Returned in pop order (ascending sort order)."""
    order = np.lexsort((np.asarray(seq), np.asarray(arrival_time)))
    return np.ascontiguousarray(order[:k])


@dataclass
class EventView:
    """Mutable host replica of the in-flight arrival schedule.

    ``pop(b, latency_model)`` advances it by one flush: selects the B
    earliest arrivals, moves the clock to the last of them, and replaces
    the popped slots with fresh dispatches (new seqs, new latency draws)
    timed from the flush instant. Rolling a view forward replays exactly
    the schedule the live runtime will realize, because nothing here
    depends on model state — only on the latency model and the counters.
    """
    arrival_time: np.ndarray   # (K,) float64 pending arrival timestamps
    seq: np.ndarray            # (K,) int64  dispatch seq of each pending upload
    next_seq: int              # global dispatch counter
    clock: float               # virtual time of the last flush

    def __post_init__(self):
        self.arrival_time = np.array(self.arrival_time, np.float64)
        self.seq = np.array(self.seq, np.int64)

    def copy(self) -> "EventView":
        return EventView(self.arrival_time.copy(), self.seq.copy(),
                         int(self.next_seq), float(self.clock))

    def pop(self, b: int, latency_model):
        """Advance by one flush of ``b`` arrivals.

        Returns ``(idx, flush_time, new_seqs, new_latency)``: the popped
        slot indices in pop order, the virtual-clock instant of the
        flush, and the seq numbers / latency draws of the replacement
        dispatches (whose arrivals are scheduled at
        ``flush_time + new_latency``).
        """
        if not 1 <= b <= self.arrival_time.size:
            raise ValueError(f"flush size must be in [1, "
                             f"{self.arrival_time.size}], got {b}")
        idx = earliest_arrivals(self.arrival_time, self.seq, b)
        flush_time = float(self.arrival_time[idx].max())
        new_seqs = self.next_seq + np.arange(b, dtype=np.int64)
        new_latency = np.asarray(latency_model(idx, new_seqs), np.float64)
        self.arrival_time[idx] = flush_time + new_latency
        self.seq[idx] = new_seqs
        self.next_seq = int(self.next_seq) + b
        self.clock = flush_time
        return idx, flush_time, new_seqs, new_latency

"""Loop-primitive escape hatch for the mesh_2d partial-auto region.

XLA's SPMD partitioner cannot propagate manual-subgroup shardings into
``while`` loops (``hlo_sharding_util.cc: Check failed:
sharding.IsManualSubgroup()``), so any ``lax.map``/``lax.scan`` that traces
inside a ``shard_map(..., auto={"model"})`` region hard-aborts the process
at compile time — even a single-iteration loop. The mesh_2d engine
(repro.mesh.engine) therefore requires every model loop to lower as
straight-line HLO: ``lax.scan`` calls take ``unroll=True`` and ``lax.map``
calls route through :func:`maybe_map`. ``ArchConfig.scan_unroll`` threads
the switch; everywhere else the loops stay rolled (compile time scales with
trip count when unrolled).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def maybe_map(f, xs, unroll: bool = False):
    """``jax.lax.map(f, xs)``, or the fully unrolled equivalent (Python
    loop over the leading axis + stack) when ``unroll``. ``xs`` may be any
    pytree with a common leading dimension; trip count must be static."""
    if not unroll:
        return jax.lax.map(f, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    outs = [f(jax.tree.map(lambda t: t[i], xs)) for i in range(n)]
    return jax.tree.map(lambda *o: jnp.stack(o), *outs)


def maybe_scan(f, init, xs, unroll: bool = False):
    """``jax.lax.scan(f, init, xs)``, or the straight-line equivalent when
    ``unroll``. A Python loop rather than ``lax.scan(unroll=True)`` because
    jax keeps the single-iteration ``while`` wrapper for length-1 scans even
    when fully unrolled — and one iteration is exactly what the reduced
    smoke configs produce."""
    if not unroll:
        return jax.lax.scan(f, init, xs)
    carry = init
    ys = []
    n = jax.tree.leaves(xs)[0].shape[0]
    for i in range(n):
        carry, y = f(carry, jax.tree.map(lambda t, i=i: t[i], xs))
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    return carry, jax.tree.map(lambda *o: jnp.stack(o), *ys)


def scan_unroll_arg(unroll: bool):
    """The ``lax.scan(..., unroll=)`` value for an unroll switch. Only safe
    for scans whose length is always > 1 — see :func:`maybe_scan`."""
    return True if unroll else 1

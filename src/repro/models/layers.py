"""Common transformer layers: RMSNorm, RoPE, SwiGLU MLP, embeddings.

Every init_* returns (params, logical_axes) where logical_axes mirrors the
param tree with tuples of logical axis names (see models/sharding.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.sharding import shard_hint


def _dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    scale = 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": (None,)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding (computed on the fly from positions)
# ---------------------------------------------------------------------------

def rope_angles(positions, head_dim: int, theta: float):
    """positions (...,) -> cos/sin (..., head_dim//2), float32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, hd); cos/sin (..., S, hd//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x32_1 * c - x32_2 * s, x32_2 * c + x32_1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP (dense FFN used by every assigned arch)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w_gate": _dense_init(k1, (d_model, d_ff), 0, dtype),
        "w_up": _dense_init(k2, (d_model, d_ff), 0, dtype),
        "w_down": _dense_init(k3, (d_ff, d_model), 0, dtype),
    }
    axes = {
        "w_gate": ("fsdp", "tp"),
        "w_up": ("fsdp", "tp"),
        "w_down": ("tp", "fsdp"),
    }
    return params, axes


def mlp(params, x):
    w_gate = shard_hint(params["w_gate"], "wg", "tp")
    w_up = shard_hint(params["w_up"], "wg", "tp")
    w_down = shard_hint(params["w_down"], "tp", "wg")
    h = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    h = shard_hint(h, "batch", "seq", "tp")
    return jnp.einsum("...f,fd->...d", h, w_down)


# ---------------------------------------------------------------------------
# Embedding + LM head
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int, tie_head: bool = True,
               dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    params = {"embedding": _dense_init(k1, (vocab, d_model), 1, dtype)}
    axes = {"embedding": ("tp", "fsdp")}
    if not tie_head:
        params["head"] = _dense_init(k2, (d_model, vocab), 0, dtype)
        axes["head"] = ("fsdp", "tp")
    return params, axes


def embed(params, tokens, impl: str = "gather"):
    if impl == "one_hot":
        # SPMD-friendly on TPU: the one-hot matmul contracts the sharded
        # vocab dim locally + one reduce, instead of the gather's
        # replicate-then-repartition pathology (§Perf optimization).
        table = params["embedding"]
        oh = jax.nn.one_hot(tokens, table.shape[0], dtype=table.dtype)
        out = jnp.einsum("bsv,vd->bsd", oh, table)
    else:
        out = jnp.take(params["embedding"], tokens, axis=0)
    return shard_hint(out, "batch", "seq", None)


def unembed(params, x):
    if "head" in params:
        logits = jnp.einsum("...d,dv->...v", x, params["head"])
    else:
        logits = jnp.einsum("...d,vd->...v", x, params["embedding"])
    return shard_hint(logits, "batch", "seq", "tp")


def cross_entropy(logits, labels, ignore_id: int = -1):
    """Mean token cross-entropy in fp32. labels (B, S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

"""RWKV6 ("Finch") mixer with data-dependent decay (paper-assigned ssm arch).

Time-mix (per head, state S of shape (hd, hd)):
    y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with w_t = exp(-exp(w0 + lora(x~_t))) data-dependent per channel.

Channel-mix: squared-ReLU MLP with token shift.

Training/prefill uses a lax.scan over the sequence (baseline; the Pallas
kernel in repro/kernels/rwkv6_scan.py is the TPU hot path); decode is the
one-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init
from repro.models.scan_utils import maybe_map, maybe_scan
from repro.models.sharding import shard_hint


def init_rwkv6_timemix(key, d_model: int, headdim: int = 64, lora_rank: int = 32,
                       dtype=jnp.float32):
    n_heads = d_model // headdim
    ks = jax.random.split(key, 8)
    params = {
        "mu_r": jnp.full((d_model,), 0.5, dtype),
        "mu_k": jnp.full((d_model,), 0.5, dtype),
        "mu_v": jnp.full((d_model,), 0.5, dtype),
        "mu_w": jnp.full((d_model,), 0.5, dtype),
        "mu_g": jnp.full((d_model,), 0.5, dtype),
        "w_r": _dense_init(ks[0], (d_model, d_model), 0, dtype),
        "w_k": _dense_init(ks[1], (d_model, d_model), 0, dtype),
        "w_v": _dense_init(ks[2], (d_model, d_model), 0, dtype),
        "w_g": _dense_init(ks[3], (d_model, d_model), 0, dtype),
        "w_o": _dense_init(ks[4], (d_model, d_model), 0, dtype),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x @ a) @ b))
        "decay_w0": jnp.full((d_model,), -6.0, jnp.float32),
        "decay_a": _dense_init(ks[5], (d_model, lora_rank), 0, dtype),
        "decay_b": (_dense_init(ks[6], (lora_rank, d_model), 0, dtype) * 0.1),
        "bonus_u": jnp.zeros((n_heads, headdim), jnp.float32),
        "ln_scale": jnp.ones((d_model,), dtype),
    }
    axes = {
        "mu_r": (None,), "mu_k": (None,), "mu_v": (None,), "mu_w": (None,),
        "mu_g": (None,),
        "w_r": ("fsdp", "tp"), "w_k": ("fsdp", "tp"), "w_v": ("fsdp", "tp"),
        "w_g": ("fsdp", "tp"), "w_o": ("tp", "fsdp"),
        "decay_w0": (None,), "decay_a": ("fsdp", None), "decay_b": (None, "tp"),
        "bonus_u": ("tp", None), "ln_scale": (None,),
    }
    return params, axes


def _token_shift(x, last=None):
    """x_{t-1} with zero (or cached) init. x (B,S,d) -> (B,S,d)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _tm_inputs(params, x, x_prev):
    mix = lambda mu: x + (x_prev - x) * mu
    r = jnp.einsum("bsd,df->bsf", mix(params["mu_r"]), params["w_r"])
    k = jnp.einsum("bsd,df->bsf", mix(params["mu_k"]), params["w_k"])
    v = jnp.einsum("bsd,df->bsf", mix(params["mu_v"]), params["w_v"])
    g = jnp.einsum("bsd,df->bsf", mix(params["mu_g"]), params["w_g"])
    xw = mix(params["mu_w"])
    lora = jnp.einsum("bsr,rd->bsd",
                      jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, params["decay_a"])),
                      params["decay_b"])
    log_decay = -jnp.exp(params["decay_w0"] + lora.astype(jnp.float32))
    w = jnp.exp(log_decay)                                 # (B,S,d) in (0,1)
    return r, k, v, g, w, log_decay


def wkv6_scan(r, k, v, w, u, s0=None, unroll: bool = False):
    """Sequential WKV6 recurrence. r/k/v/w (B,S,H,hd); u (H,hd).
    Returns (y (B,S,H,hd), final state (B,H,hd,hd))."""
    bsz, s, h, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((bsz, h, hd, hd), jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp                                # (B,H,hd) each
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt, state + u[None, :, :, None] * kv)
        new = state * wt[..., None] + kv
        return new, y

    seq = lambda t: jnp.moveaxis(t.astype(jnp.float32), 1, 0)
    s_final, ys = maybe_scan(step, s0, (seq(r), seq(k), seq(v), seq(w)),
                             unroll=unroll)
    return jnp.moveaxis(ys, 0, 1), s_final


def wkv6_chunked(r, k, v, log_decay, u, s0=None, chunk: int = 64,
                 unroll: bool = False):
    """Chunk-parallel WKV6 (fla-style): intra-chunk quadratic form + one
    state read/write per chunk instead of per token. Exact (all exponents
    are <= 0 under the causal mask, so no overflow).

    r/k/v/log_decay (B, S, H, hd); u (H, hd). Returns (y, final state)."""
    bsz, s, h, hd = r.shape
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} % rwkv chunk {chunk}")
    nc = s // chunk
    if s0 is None:
        s0 = jnp.zeros((bsz, h, hd, hd), jnp.float32)

    f32 = lambda t: t.astype(jnp.float32)
    shp = (bsz, nc, chunk, h, hd)
    rc, kc, vc = (f32(t).reshape(shp) for t in (r, k, v))
    ld = f32(log_decay).reshape(shp)
    lc = jnp.cumsum(ld, axis=2)                     # L_t = sum_{s<=t} log w_s
    lcm1 = lc - ld                                  # L_{t-1}
    lq = lc[:, :, -1:]                              # L_Q (chunk total)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)   # s < t

    # Intra-chunk quadratic form, streamed over (head x channel-block) so the
    # (Q, Q, hd_block) decay tensor never exceeds a small VMEM-sized tile.
    # y = (sum_i A_i) v decomposes as sum over channel blocks of (A_blk v).
    hd_blk = min(8, hd)
    nblk = hd // hd_blk

    def blocked(t):                                 # (B,nc,Q,H,hd) ->
        t = t.reshape(bsz, nc, chunk, h, nblk, hd_blk)
        return jnp.moveaxis(t, (3, 4), (0, 1)).reshape(
            h * nblk, bsz, nc, chunk, hd_blk)

    v_rep = jnp.broadcast_to(jnp.moveaxis(vc, 3, 0)[:, None],
                             (h, nblk, bsz, nc, chunk, hd)
                             ).reshape(h * nblk, bsz, nc, chunk, hd)

    def per_block(args):
        rh, kh, lch, lcm1h, vh = args               # (B, nc, Q, hd_blk)
        # A[t,s] = sum_i r_t k_s exp(L_{t-1} - L_s), s < t   (exponent <= 0)
        diff = lcm1h[:, :, :, None, :] - lch[:, :, None, :, :]
        diff = jnp.where(tri[None, None, :, :, None], diff, -jnp.inf)
        a = jnp.einsum("bcti,bcsi,bctsi->bcts", rh, kh, jnp.exp(diff))
        return jnp.einsum("bcts,bcsj->bctj", a, vh)

    parts = maybe_map(per_block,
                      (blocked(rc), blocked(kc), blocked(lc),
                       blocked(lcm1), v_rep), unroll)
    parts = parts.reshape(h, nblk, bsz, nc, chunk, hd).sum(axis=1)
    y_intra = jnp.moveaxis(parts, 0, 3)

    # bonus (diagonal) term: (r_t . u k_t) v_t
    bonus = jnp.einsum("bcthi,hi,bcthi->bcth", rc, u.astype(jnp.float32), kc)
    y_intra = y_intra + bonus[..., None] * vc

    # inter-chunk: state scan, one (hd, hd) read/write per chunk
    r_tilde = rc * jnp.exp(lcm1)                    # exponent <= 0
    k_hat = kc * jnp.exp(lq - lc)                   # exponent <= 0
    chunk_states = jnp.einsum("bcthi,bcthj->bchij", k_hat, vc)
    chunk_decay = jnp.exp(lq[:, :, 0])              # (B, nc, H, hd)

    def step(carry, inp):
        st, dcy = inp                               # (B,H,hd,hd), (B,H,hd)
        new = carry * dcy[..., None] + st
        return new, carry                           # emit state BEFORE chunk

    sw = lambda t: jnp.moveaxis(t, 1, 0)
    s_final, s_prev = maybe_scan(step, s0,
                                 (sw(chunk_states), sw(chunk_decay)),
                                 unroll=unroll)
    s_prev = jnp.moveaxis(s_prev, 0, 1)             # (B,nc,H,hd,hd)
    y_state = jnp.einsum("bcthi,bchij->bcthj", r_tilde, s_prev)
    y = (y_intra + y_state).reshape(bsz, s, h, hd)
    return y, s_final


def _tm_output(params, y, g, d_model):
    bsz, s = y.shape[:2]
    y = y.reshape(bsz, s, d_model).astype(jnp.float32)
    # per-head group norm approximated by full-layer RMS norm
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * params["ln_scale"].astype(jnp.float32)
    y = y * jax.nn.silu(g.astype(jnp.float32))
    w_o = shard_hint(params["w_o"], "tp", "fsdp")
    out = jnp.einsum("bsf,fd->bsd", y.astype(w_o.dtype), w_o)
    return shard_hint(out, "batch", "seq", None)


def rwkv6_timemix_forward(params, x, headdim: int = 64, chunk: int = 0,
                          unroll: bool = False):
    out, _ = rwkv6_timemix_forward_state(params, x, headdim, chunk,
                                         unroll=unroll)
    return out


def rwkv6_timemix_forward_state(params, x, headdim: int = 64,
                                chunk: int = 0, unroll: bool = False):
    """Full-sequence time-mix that also returns the decode cache.
    chunk == 0 -> per-token lax.scan (baseline); chunk > 0 -> chunk-parallel
    WKV6 (§Perf optimization)."""
    d_model = x.shape[-1]
    n_heads = d_model // headdim
    x_prev = _token_shift(x)
    r, k, v, g, w, log_decay = _tm_inputs(params, x, x_prev)
    heads = lambda t: t.reshape(t.shape[0], t.shape[1], n_heads, headdim)
    if chunk:
        y, s_final = wkv6_chunked(heads(r), heads(k), heads(v),
                                  heads(log_decay), params["bonus_u"],
                                  chunk=chunk, unroll=unroll)
    else:
        y, s_final = wkv6_scan(heads(r), heads(k), heads(v), heads(w),
                               params["bonus_u"], unroll=unroll)
    out = _tm_output(params, y.astype(x.dtype), g, d_model)
    return out, {"wkv": s_final, "tm_last": x[:, -1:]}


def init_rwkv6_channelmix(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "mu_k": jnp.full((d_model,), 0.5, dtype),
        "mu_r": jnp.full((d_model,), 0.5, dtype),
        "w_k": _dense_init(k1, (d_model, d_ff), 0, dtype),
        "w_v": _dense_init(k2, (d_ff, d_model), 0, dtype),
        "w_r": _dense_init(k3, (d_model, d_model), 0, dtype),
    }
    axes = {
        "mu_k": (None,), "mu_r": (None,),
        "w_k": ("fsdp", "tp"), "w_v": ("tp", "fsdp"), "w_r": ("fsdp", "tp"),
    }
    return params, axes


def rwkv6_channelmix_forward(params, x, x_prev=None):
    xp = _token_shift(x, x_prev)
    xk = x + (xp - x) * params["mu_k"]
    xr = x + (xp - x) * params["mu_r"]
    k = jnp.einsum("bsd,df->bsf", xk, params["w_k"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    k = shard_hint(k, "batch", "seq", "tp")
    kv = jnp.einsum("bsf,fd->bsd", k, params["w_v"])
    r = jax.nn.sigmoid(
        jnp.einsum("bsd,df->bsf", xr, params["w_r"]).astype(jnp.float32))
    return (r * kv.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_rwkv6_cache(batch: int, d_model: int, headdim: int,
                     dtype=jnp.float32):
    n_heads = d_model // headdim
    return {
        "wkv": jnp.zeros((batch, n_heads, headdim, headdim), jnp.float32),
        "tm_last": jnp.zeros((batch, 1, d_model), dtype),
        "cm_last": jnp.zeros((batch, 1, d_model), dtype),
    }


def rwkv6_timemix_decode(params, x, cache, headdim: int = 64):
    """x (B,1,d)."""
    d_model = x.shape[-1]
    n_heads = d_model // headdim
    r, k, v, g, w, _ = _tm_inputs(params, x, cache["tm_last"])
    heads = lambda t: t.reshape(t.shape[0], 1, n_heads, headdim)
    y, s_new = wkv6_scan(heads(r), heads(k), heads(v), heads(w),
                         params["bonus_u"], s0=cache["wkv"])
    out = _tm_output(params, y.astype(x.dtype), g, d_model)
    cache = dict(cache, wkv=s_new, tm_last=x)
    return out, cache


def rwkv6_channelmix_decode(params, x, cache):
    out = rwkv6_channelmix_forward(params, x, cache["cm_last"])
    return out, dict(cache, cm_last=x)

"""Logical-axis sharding helpers.

Models annotate tensors with *logical* axis names; a context installs the
active mesh plus a logical->mesh translation. Outside any context (CPU unit
tests) every helper is the identity, so the same model code runs on one
device and on the 512-chip production mesh.

Logical names used across the model stack:
  "client"  federated client axis (leading axis of FL-stacked params)
  "fsdp"    fully-sharded param dim            -> mesh "replica" (train)
                                                   or "data" (serve, optional)
  "tp"      tensor-parallel param/activation dim -> mesh "model"
  "batch"   data batch                          -> mesh "replica" / "data"
  "seq"     sequence dim (sharded only for long-context decode caches)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: dict[str, Any]):
    """Install mesh + logical->mesh rules for model code in this thread."""
    prev = _current()
    _state.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _state.ctx = prev


def train_rules() -> dict[str, Any]:
    return {"client": "client", "fsdp": "replica", "tp": "model",
            "batch": "replica", "seq": None, "act": None,
            # weight sharding at USE site; set to None to force a (loop-
            # hoistable) weight all-gather instead of per-microbatch
            # activation all-reduces (§Perf "gather_weights")
            "wg": "replica"}


def mesh2d_rules() -> dict[str, Any]:
    """Rules for the 2D ("client", "model") federation mesh (repro.mesh).

    The client axis is MANUAL inside the mesh_2d engine's shard_map body, so
    no logical name may map to it here — these rules only place the model
    axes. With a single model axis, "fsdp" and "tp" both map to "model" and
    :func:`resolve_spec` keeps whichever dim claims it first (a mesh axis
    may appear at most once per PartitionSpec), so every weight ends up
    1/dm-sharded along its first shardable logical dim. The "act" rule
    shards the d_model activation carry, bounding the in-body working set.
    """
    return {"client": None, "fsdp": "model", "tp": "model",
            "batch": None, "seq": None, "act": "model", "wg": None}


def serve_rules(fsdp_over_data: bool = False, shard_seq: bool = False) -> dict[str, Any]:
    return {"client": None, "fsdp": "data" if fsdp_over_data else None,
            "tp": "model", "batch": "data",
            "seq": "data" if shard_seq else None, "act": None,
            # decode-cache dims; lower_decode overrides per config:
            "kv_tp": "model", "cache_seq": "data" if shard_seq else None,
            "wg": "data" if fsdp_over_data else None}


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _atomic_axes(axis) -> tuple:
    return tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)


def resolve_spec(logical: tuple, shape: tuple[int, ...] | None = None) -> P:
    """Translate logical axis names to a PartitionSpec under active rules.

    If ``shape`` is given, any mesh axis that does not divide the dim size is
    dropped (GSPMD would pad; we prefer explicit replication). A mesh axis
    claimed by an earlier dim is dropped from later dims (first dim wins):
    a PartitionSpec may not repeat an axis, and on small meshes several
    logical names legitimately map to one physical axis (e.g. "fsdp" and
    "tp" both -> "model" under :func:`mesh2d_rules`)."""
    ctx = _current()
    if ctx is None:
        return P()
    mesh, rules = ctx
    out = []
    used: set = set()
    for i, name in enumerate(logical):
        axis = rules.get(name) if name is not None else None
        if axis is not None and any(a in used for a in _atomic_axes(axis)):
            axis = None
        if axis is not None and shape is not None:
            if shape[i] % _mesh_axis_size(mesh, axis) != 0:
                axis = None
        if axis is not None:
            used.update(_atomic_axes(axis))
        out.append(axis)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard_hint(x, *logical):
    """with_sharding_constraint under the active rules (identity if none)."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, _ = ctx
    spec = resolve_spec(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def spec_tree(logical_tree, shape_tree=None):
    """Map a pytree of logical-axis tuples to PartitionSpecs."""
    if shape_tree is None:
        return jax.tree.map(lambda lg: resolve_spec(lg), logical_tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(
        lambda lg, arr: resolve_spec(lg, arr.shape),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple))


def named_sharding_tree(mesh: Mesh, logical_tree, shape_tree=None):
    specs = spec_tree(logical_tree, shape_tree)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))

"""Transformer assembly: segments of scanned layer patterns, with train,
prefill, and decode paths. See configs/base.py for the segment machinery.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    _dense_init,
    cross_entropy,
    embed,
    init_embed,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
    unembed,
)
from repro.models.scan_utils import maybe_scan
from repro.models.sharding import shard_hint

AUX_WEIGHT = 0.01  # load-balance aux loss weight


def _prepend_axis(axes_tree, name=None):
    return jax.tree.map(lambda t: (name,) + t, axes_tree,
                        is_leaf=lambda t: isinstance(t, tuple))


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


class Transformer:
    """Functional model: params are explicit pytrees; methods are pure."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.has_shared = any(ls.mixer == "shared_attn"
                              for ls in cfg.layer_specs())

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------

    def _init_layer(self, spec: LayerSpec, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        d = cfg.d_model
        kmix, kffn = jax.random.split(key)
        params: dict[str, Any] = {}
        axes: dict[str, Any] = {}

        params["norm1"], axes["norm1"] = init_rmsnorm(d, dt)
        if spec.mixer == "attn":
            params["mixer"], axes["mixer"] = attn.init_attention(
                kmix, d, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim,
                cfg.qkv_bias, dt)
        elif spec.mixer == "mamba2":
            params["mixer"], axes["mixer"] = ssm_mod.init_mamba2(
                kmix, d, cfg.ssm_state, cfg.ssm_headdim, cfg.ssm_expand,
                cfg.conv_kernel, dt)
        elif spec.mixer == "rwkv6":
            params["mixer"], axes["mixer"] = rwkv_mod.init_rwkv6_timemix(
                kmix, d, cfg.rwkv_headdim, max(4, cfg.lora_rank or 32), dt)
        elif spec.mixer == "shared_attn":
            r = max(1, cfg.lora_rank)
            hd = cfg.resolved_head_dim
            ks = jax.random.split(kmix, 4)
            params["mixer"] = {
                "lora_q_a": _dense_init(ks[0], (d, r), 0, dt),
                "lora_q_b": jnp.zeros((r, cfg.n_heads * hd), dt),
                "lora_o_a": _dense_init(ks[1], (cfg.n_heads * hd, r), 0, dt),
                "lora_o_b": jnp.zeros((r, d), dt),
            }
            axes["mixer"] = {
                "lora_q_a": ("fsdp", None), "lora_q_b": (None, "tp"),
                "lora_o_a": ("tp", None), "lora_o_b": (None, "fsdp"),
            }
        else:
            raise ValueError(f"unknown mixer {spec.mixer}")

        if spec.ffn != "none":
            params["norm2"], axes["norm2"] = init_rmsnorm(d, dt)
        if spec.ffn == "mlp":
            params["ffn"], axes["ffn"] = init_mlp(kffn, d, cfg.d_ff, dt)
        elif spec.ffn == "moe":
            params["ffn"], axes["ffn"] = moe_mod.init_moe(
                kffn, d, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts, cfg.top_k,
                cfg.shared_expert, dt)
        elif spec.ffn == "rwkv_cm":
            params["ffn"], axes["ffn"] = rwkv_mod.init_rwkv6_channelmix(
                kffn, d, cfg.d_ff, dt)
        elif spec.ffn in ("none", "shared_mlp"):
            params["ffn"], axes["ffn"] = {}, {}
        else:
            raise ValueError(f"unknown ffn {spec.ffn}")
        return params, axes

    def _layer_axes(self, spec: LayerSpec):
        """Logical axes of one layer, without materializing params."""
        box = {}

        def probe(k):
            p, a = self._init_layer(spec, k)
            box["axes"] = a
            return p

        jax.eval_shape(probe, jax.random.PRNGKey(0))
        return box["axes"]

    def init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = jax.random.split(key, 3 + len(cfg.segments))
        params: dict[str, Any] = {}
        params["embed"], self._embed_axes = init_embed(
            keys[0], cfg.vocab, cfg.d_model, cfg.tie_head, dt)
        params["final_norm"], fn_axes = init_rmsnorm(cfg.d_model, dt)

        seg_params = []
        seg_axes = []
        for si, seg in enumerate(cfg.segments):
            layer_keys = jax.random.split(keys[2 + si],
                                          seg.n_steps * len(seg.pattern))
            layer_keys = layer_keys.reshape(seg.n_steps, len(seg.pattern), 2)
            pat_p: dict[str, Any] = {}
            pat_a: dict[str, Any] = {}
            for j, ls in enumerate(seg.pattern):
                stacked = jax.vmap(lambda k, ls=ls: self._init_layer(ls, k)[0]
                                   )(layer_keys[:, j])
                pat_p[str(j)] = stacked
                pat_a[str(j)] = _prepend_axis(self._layer_axes(ls), None)
            seg_params.append(pat_p)
            seg_axes.append(pat_a)
        params["segments"] = seg_params

        if self.has_shared:
            ks = jax.random.split(keys[1], 2)
            sh_attn, sh_attn_ax = attn.init_attention(
                ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.resolved_head_dim, cfg.qkv_bias, dt)
            sh_mlp, sh_mlp_ax = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt)
            params["shared"] = {"attn": sh_attn, "mlp": sh_mlp}
            self._shared_axes = {"attn": sh_attn_ax, "mlp": sh_mlp_ax}

        self._axes = {
            "embed": self._embed_axes,
            "final_norm": fn_axes,
            "segments": seg_axes,
        }
        if self.has_shared:
            self._axes["shared"] = self._shared_axes
        return params

    def param_axes(self):
        """Logical-axis tree matching init() output (init must run first,
        or call axes_only())."""
        if not hasattr(self, "_axes"):
            self.axes_only()
        return self._axes

    def axes_only(self):
        """Build the logical-axes tree without materializing params."""
        jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return self._axes

    # ------------------------------------------------------------------
    # layer application (full sequence)
    # ------------------------------------------------------------------

    def _merged_shared_attn(self, lora, shared):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        d = cfg.d_model
        dq = (lora["lora_q_a"] @ lora["lora_q_b"]).reshape(d, cfg.n_heads, hd)
        do = (lora["lora_o_a"] @ lora["lora_o_b"]).reshape(cfg.n_heads, hd, d)
        p = dict(shared["attn"])
        p["wq"] = p["wq"] + dq
        p["wo"] = p["wo"] + do
        return p

    def _apply_mixer(self, spec: LayerSpec, lparams, shared, h, positions):
        cfg = self.cfg
        if spec.mixer == "attn":
            return attn.attention_forward(
                lparams["mixer"], h, positions, kind=spec.attn_kind,
                window=cfg.window, chunk=cfg.chunk, use_rope=spec.use_rope,
                rope_theta=cfg.rope_theta, block_q=cfg.block_q,
                causal_buckets=cfg.causal_buckets, unroll=cfg.scan_unroll)
        if spec.mixer == "shared_attn":
            p = self._merged_shared_attn(lparams["mixer"], shared)
            return attn.attention_forward(
                p, h, positions, kind=spec.attn_kind, window=cfg.window,
                chunk=cfg.chunk, use_rope=spec.use_rope,
                rope_theta=cfg.rope_theta, block_q=cfg.block_q,
                causal_buckets=cfg.causal_buckets, unroll=cfg.scan_unroll)
        if spec.mixer == "mamba2":
            return ssm_mod.mamba2_forward(
                lparams["mixer"], h, d_state=cfg.ssm_state,
                headdim=cfg.ssm_headdim, expand=cfg.ssm_expand,
                chunk=cfg.ssd_chunk, unroll=cfg.scan_unroll)
        if spec.mixer == "rwkv6":
            return rwkv_mod.rwkv6_timemix_forward(lparams["mixer"], h,
                                                  cfg.rwkv_headdim,
                                                  cfg.rwkv_chunk,
                                                  unroll=cfg.scan_unroll)
        raise ValueError(spec.mixer)

    def _apply_ffn(self, spec: LayerSpec, lparams, shared, h):
        cfg = self.cfg
        if spec.ffn == "mlp":
            return mlp(lparams["ffn"], h), 0.0
        if spec.ffn == "moe":
            return moe_mod.moe_apply(
                lparams["ffn"], h, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, impl=cfg.moe_impl,
                iterative_topk=cfg.scan_unroll)
        if spec.ffn == "rwkv_cm":
            return rwkv_mod.rwkv6_channelmix_forward(lparams["ffn"], h), 0.0
        if spec.ffn == "shared_mlp":
            return mlp(shared["mlp"], h), 0.0
        return None, 0.0

    def _apply_layer(self, spec: LayerSpec, lparams, shared, x, positions):
        aux = jnp.zeros((), jnp.float32)
        h = rmsnorm(lparams["norm1"], x)
        x = x + self._apply_mixer(spec, lparams, shared, h, positions)
        if spec.ffn != "none":
            h2 = rmsnorm(lparams["norm2"], x)
            out, a = self._apply_ffn(spec, lparams, shared, h2)
            x = x + out
            aux = aux + a
        # layer-boundary carry: d_model sharded over the model axis in
        # training ("act" rule) so the remat-saved per-layer activations are
        # 1/TP the size; serving maps "act" to None.
        x = shard_hint(x, "batch", "seq", "act")
        return x, aux

    # ------------------------------------------------------------------
    # full forward (train / prefill logits)
    # ------------------------------------------------------------------

    def _embed_tokens(self, params, tokens, prefix):
        cfg = self.cfg
        x = embed(params["embed"], tokens, cfg.embed_impl)
        if cfg.embed_scale:
            x = x * math.sqrt(cfg.d_model)
        if prefix is not None:
            x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
        return x

    def forward(self, params, tokens, prefix=None, remat=None):
        """tokens (B, S) -> (logits (B, S, V), aux). prefix (B, P, d) stub
        embeddings are prepended (vlm / audio) and stripped from logits."""
        cfg = self.cfg
        remat = cfg.remat if remat is None else remat
        x = self._embed_tokens(params, tokens, prefix)
        positions = jnp.arange(x.shape[1])
        shared = params.get("shared")
        aux = jnp.zeros((), jnp.float32)

        for seg_params, seg in zip(params["segments"], cfg.segments):
            def step(carry, p_step, seg=seg):
                x, aux = carry
                for j, ls in enumerate(seg.pattern):
                    x, a = self._apply_layer(ls, p_step[str(j)], shared, x,
                                             positions)
                    aux = aux + a
                return (x, aux), None

            step_fn = jax.checkpoint(step) if remat else step
            (x, aux), _ = maybe_scan(step_fn, (x, aux), seg_params,
                                     unroll=cfg.scan_unroll)

        x = rmsnorm(params["final_norm"], x)
        if prefix is not None:
            x = x[:, prefix.shape[1]:]
        logits = unembed(params["embed"], x)
        return logits, aux

    def loss_fn(self, params, batch):
        """batch: {"tokens": (B,S), "labels": (B,S), ["prefix": (B,P,d)]}"""
        cfg = self.cfg
        prefix = batch.get("prefix")
        if cfg.loss_chunk:
            return self._chunked_loss(params, batch, prefix)
        logits, aux = self.forward(params, batch["tokens"], prefix)
        return cross_entropy(logits, batch["labels"]) + AUX_WEIGHT * aux

    def _chunked_loss(self, params, batch, prefix):
        """Cross-entropy computed per sequence chunk : never materializes the
        full (B, S, V) logits — default for large-vocab archs."""
        cfg = self.cfg
        x, aux = self._hidden_states(params, batch["tokens"], prefix)
        c = cfg.loss_chunk
        b, s, d = x.shape
        assert s % c == 0, f"seq {s} % loss_chunk {c} != 0"
        xs = jnp.moveaxis(x.reshape(b, s // c, c, d), 1, 0)
        ls = jnp.moveaxis(batch["labels"].reshape(b, s // c, c), 1, 0)

        def chunk_nll(carry, inp):
            xc, lc = inp
            logits = unembed(params["embed"], xc)
            return carry + cross_entropy(logits, lc) * c, None

        chunk_fn = jax.checkpoint(chunk_nll) if cfg.remat else chunk_nll
        total, _ = maybe_scan(chunk_fn, jnp.zeros((), jnp.float32),
                              (xs, ls), unroll=cfg.scan_unroll)
        return total / s + AUX_WEIGHT * aux

    def _hidden_states(self, params, tokens, prefix):
        cfg = self.cfg
        x = self._embed_tokens(params, tokens, prefix)
        positions = jnp.arange(x.shape[1])
        shared = params.get("shared")
        aux = jnp.zeros((), jnp.float32)
        for seg_params, seg in zip(params["segments"], cfg.segments):
            def step(carry, p_step, seg=seg):
                x, aux = carry
                for j, ls in enumerate(seg.pattern):
                    x, a = self._apply_layer(ls, p_step[str(j)], shared, x,
                                             positions)
                    aux = aux + a
                return (x, aux), None
            step_fn = jax.checkpoint(step) if cfg.remat else step
            (x, aux), _ = maybe_scan(step_fn, (x, aux), seg_params,
                                     unroll=cfg.scan_unroll)
        x = rmsnorm(params["final_norm"], x)
        if prefix is not None:
            x = x[:, prefix.shape[1]:]
        return x, aux

    # ------------------------------------------------------------------
    # serving: prefill + decode
    # ------------------------------------------------------------------

    def _layer_cache_shape(self, spec: LayerSpec, batch: int, max_len: int,
                           natural: bool = False):
        cfg = self.cfg
        dt = _dtype(cfg)
        cache: dict[str, Any] = {}
        if spec.mixer in ("attn", "shared_attn"):
            # natural: full-length position-ordered cache even for swa /
            # chunk layers (no ring truncation) — the layout the paged
            # serving pool ingests; visibility is enforced by masks
            cache["mixer"] = attn.init_kv_cache(
                batch, "full" if natural else spec.attn_kind, max_len,
                cfg.n_kv_heads, cfg.resolved_head_dim, cfg.window,
                cfg.chunk, dt)
        elif spec.mixer == "mamba2":
            cache["mixer"] = ssm_mod.init_mamba2_cache(
                batch, cfg.d_model, cfg.ssm_state, cfg.ssm_headdim,
                cfg.ssm_expand, cfg.conv_kernel, dt)
        elif spec.mixer == "rwkv6":
            n_heads = cfg.d_model // cfg.rwkv_headdim
            cache["mixer"] = {
                "wkv": jnp.zeros((batch, n_heads, cfg.rwkv_headdim,
                                  cfg.rwkv_headdim), jnp.float32),
                "tm_last": jnp.zeros((batch, 1, cfg.d_model), dt),
            }
        if spec.ffn == "rwkv_cm":
            cache["ffn"] = {"cm_last": jnp.zeros((batch, 1, cfg.d_model), dt)}
        else:
            cache["ffn"] = {}
        return cache

    def init_cache(self, batch: int, max_len: int, natural: bool = False):
        """Zeroed caches matching the segment structure. KV caches for swa /
        chunk layers are ring buffers of the window/chunk size (or full
        position-ordered buffers under ``natural``, the serving-ingest
        layout)."""
        caches = []
        for seg in self.cfg.segments:
            pat = {}
            for j, ls in enumerate(seg.pattern):
                one = self._layer_cache_shape(ls, batch, max_len, natural)
                pat[str(j)] = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (seg.n_steps,) + x.shape), one)
            caches.append(pat)
        return caches

    def init_paged_cache(self, n_slots: int, n_blocks: int, block_size: int):
        """Serving caches for a continuous-batching engine: attention
        layers get a physical block pool (block-table indexed, shared
        geometry across layers), recurrent layers keep per-slot state rows
        (their state is O(1) per slot — nothing to page)."""
        cfg = self.cfg
        caches = []
        for seg in cfg.segments:
            pat = {}
            for j, ls in enumerate(seg.pattern):
                one = self._layer_cache_shape(ls, n_slots, 1)
                if ls.mixer in ("attn", "shared_attn"):
                    one["mixer"] = attn.init_paged_kv_cache(
                        n_blocks, block_size, cfg.n_kv_heads,
                        cfg.resolved_head_dim, _dtype(cfg))
                pat[str(j)] = jax.tree.map(
                    lambda x: jnp.zeros((seg.n_steps,) + x.shape, x.dtype),
                    one)
            caches.append(pat)
        return caches

    def cache_axes(self):
        """Logical axes for cache arrays (KV caches: batch + heads + seq)."""
        def attn_axes(kind):
            return {"k": (None, "batch", "cache_seq", "kv_tp", None),
                    "v": (None, "batch", "cache_seq", "kv_tp", None)}
        axes = []
        for seg in self.cfg.segments:
            pat = {}
            for j, ls in enumerate(seg.pattern):
                c: dict[str, Any] = {}
                if ls.mixer in ("attn", "shared_attn"):
                    c["mixer"] = attn_axes(ls.attn_kind)
                elif ls.mixer == "mamba2":
                    c["mixer"] = {"h": (None, "batch", "tp", None, None),
                                  "conv": (None, "batch", None, "tp")}
                elif ls.mixer == "rwkv6":
                    c["mixer"] = {"wkv": (None, "batch", "tp", None, None),
                                  "tm_last": (None, "batch", None, None)}
                c["ffn"] = ({"cm_last": (None, "batch", None, None)}
                            if ls.ffn == "rwkv_cm" else {})
                pat[str(j)] = c
            axes.append(pat)
        return axes

    def _decode_layer(self, spec: LayerSpec, lparams, shared, cache, x, pos,
                      table=None):
        cfg = self.cfg
        h = rmsnorm(lparams["norm1"], x)
        new_cache = dict(cache)
        if spec.mixer in ("attn", "shared_attn"):
            p = (self._merged_shared_attn(lparams["mixer"], shared)
                 if spec.mixer == "shared_attn" else lparams["mixer"])
            if table is None:
                out, kv = attn.decode_attention(
                    p, h, cache["mixer"], pos, kind=spec.attn_kind,
                    window=cfg.window, chunk=cfg.chunk,
                    use_rope=spec.use_rope, rope_theta=cfg.rope_theta)
            else:
                out, kv = attn.paged_decode_attention(
                    p, h, cache["mixer"], table, pos, kind=spec.attn_kind,
                    window=cfg.window, chunk=cfg.chunk,
                    use_rope=spec.use_rope, rope_theta=cfg.rope_theta)
            new_cache["mixer"] = kv
        elif spec.mixer == "mamba2":
            out, mc = ssm_mod.mamba2_decode(
                lparams["mixer"], h, cache["mixer"], d_state=cfg.ssm_state,
                headdim=cfg.ssm_headdim, expand=cfg.ssm_expand)
            new_cache["mixer"] = mc
        elif spec.mixer == "rwkv6":
            out, rc = rwkv_mod.rwkv6_timemix_decode(
                lparams["mixer"], h,
                {**cache["mixer"], "cm_last": None}, cfg.rwkv_headdim)
            new_cache["mixer"] = {"wkv": rc["wkv"], "tm_last": rc["tm_last"]}
        else:
            raise ValueError(spec.mixer)
        x = x + out

        if spec.ffn != "none":
            h2 = rmsnorm(lparams["norm2"], x)
            if spec.ffn == "rwkv_cm":
                out2, fc = rwkv_mod.rwkv6_channelmix_decode(
                    lparams["ffn"], h2, cache["ffn"])
                new_cache["ffn"] = fc
            else:
                out2, _ = self._apply_ffn(spec, lparams, shared, h2)
            x = x + out2
        return x, new_cache

    def decode_step(self, params, caches, tokens, pos, table=None):
        """One decode step. tokens (B,) int32; pos () int32 = position of
        this token (prefix-inclusive). Returns (logits (B, V), new caches).

        With ``table`` (B, blocks_per_slot) int32, ``caches`` are the paged
        pools of :meth:`init_paged_cache` and ``pos`` is a per-slot (B,)
        vector — the continuous-batching decode where every slot sits at
        its own position."""
        cfg = self.cfg
        x = embed(params["embed"], tokens[:, None], cfg.embed_impl)
        if cfg.embed_scale:
            x = x * math.sqrt(cfg.d_model)
        shared = params.get("shared")
        new_caches = []
        for seg_params, seg_cache, seg in zip(params["segments"], caches,
                                              cfg.segments):
            def step(x, xs, seg=seg):
                p_step, c_step = xs
                new_c = {}
                for j, ls in enumerate(seg.pattern):
                    x, new_c[str(j)] = self._decode_layer(
                        ls, p_step[str(j)], shared, c_step[str(j)], x, pos,
                        table)
                return x, new_c

            x, new_seg_cache = jax.lax.scan(step, x, (seg_params, seg_cache))
            new_caches.append(new_seg_cache)
        x = rmsnorm(params["final_norm"], x)
        logits = unembed(params["embed"], x)[:, 0]
        return logits, new_caches

    def _prefill_states(self, params, tokens, prefix, max_len,
                        natural=False):
        """Shared prefill body: final-normed hidden states (B, S_total, d)
        plus the filled caches."""
        cfg = self.cfg
        x = self._embed_tokens(params, tokens, prefix)
        b, s_total = x.shape[:2]
        max_len = max_len or s_total
        positions = jnp.arange(s_total)
        shared = params.get("shared")
        caches = self.init_cache(b, max_len, natural)
        new_caches = []
        for seg_params, seg_cache, seg in zip(params["segments"], caches,
                                              cfg.segments):
            def step(x, xs, seg=seg):
                p_step, c_step = xs
                new_c = {}
                for j, ls in enumerate(seg.pattern):
                    x, new_c[str(j)] = self._prefill_layer(
                        ls, p_step[str(j)], shared, c_step[str(j)], x,
                        positions)
                return x, new_c

            x, new_seg_cache = jax.lax.scan(step, x, (seg_params, seg_cache))
            new_caches.append(new_seg_cache)
        x = rmsnorm(params["final_norm"], x)
        return x, new_caches, s_total

    def prefill(self, params, tokens, prefix=None, max_len=None):
        """Run the full prompt, building caches. Returns (last-token logits
        (B, V), caches, next position)."""
        x, new_caches, s_total = self._prefill_states(params, tokens,
                                                      prefix, max_len)
        logits = unembed(params["embed"], x[:, -1:])[:, 0]
        return logits, new_caches, jnp.asarray(s_total, jnp.int32)

    def prefill_at(self, params, tokens, lengths, prefix=None,
                   max_len=None):
        """Bucketed prefill for the serving engine: tokens (B, S) are
        right-padded to a common bucket length, lengths (B,) int32 are the
        true prompt lengths. Returns (per-row logits at each row's last
        TRUE token, natural-layout caches, per-row next position).

        Rows' cache entries beyond their true length hold pad garbage;
        paged decode overwrites position p before the ``p <= pos`` mask
        ever exposes it, so right-padding is exact for attention layers.
        Recurrent state (mamba2 / rwkv6 / rwkv_cm) consumes pad tokens,
        so engines must prefill those archs at exact lengths.
        """
        p_len = 0 if prefix is None else prefix.shape[1]
        x, new_caches, s_total = self._prefill_states(
            params, tokens, prefix, max_len, natural=True)
        b = x.shape[0]
        idx = p_len + lengths - 1
        xg = x[jnp.arange(b), idx][:, None]
        logits = unembed(params["embed"], xg)[:, 0]
        return logits, new_caches, (p_len + lengths).astype(jnp.int32)

    def insert_prefill(self, paged, pre, table_rows, slots):
        """Scatter one prefill batch's natural-layout caches into the
        paged pools / slot state rows.

        paged: pools from :meth:`init_paged_cache`; pre: caches from
        :meth:`prefill_at` (attention rows in position order, length n);
        table_rows (nb, bps) int32 physical blocks of the target slots;
        slots (nb,) int32 slot ids. Only the blocks the prompt span
        covers are written — later blocks keep stale garbage that decode
        overwrites before the position mask exposes it. Duplicate rows
        (admission padding) must carry identical data: scatters with
        repeated indices then commute."""
        def scatter_blocks(pool, rows):
            # pool (T, NB, bs, KV, hd); rows (T, nb, n, KV, hd)
            bs = pool.shape[2]
            n = rows.shape[2]
            nb_blocks = -(-n // bs)
            pad = nb_blocks * bs - n
            if pad:
                rows = jnp.pad(rows, ((0, 0), (0, 0), (0, pad), (0, 0),
                                      (0, 0)))
            blocks = rows.reshape(rows.shape[0], rows.shape[1], nb_blocks,
                                  bs, *rows.shape[3:])
            return pool.at[:, table_rows[:, :nb_blocks]].set(
                blocks.astype(pool.dtype))

        out = []
        for seg_pre, seg_paged, seg in zip(pre, paged, self.cfg.segments):
            pat = {}
            for j, ls in enumerate(seg.pattern):
                cp, cg = seg_pre[str(j)], seg_paged[str(j)]
                new = {}
                if ls.mixer in ("attn", "shared_attn"):
                    new["mixer"] = {
                        "k": scatter_blocks(cg["mixer"]["k"],
                                            cp["mixer"]["k"]),
                        "v": scatter_blocks(cg["mixer"]["v"],
                                            cp["mixer"]["v"]),
                    }
                else:
                    new["mixer"] = jax.tree.map(
                        lambda g, p: g.at[:, slots].set(p.astype(g.dtype)),
                        cg["mixer"], cp["mixer"])
                new["ffn"] = jax.tree.map(
                    lambda g, p: g.at[:, slots].set(p.astype(g.dtype)),
                    cg["ffn"], cp["ffn"])
                pat[str(j)] = new
            out.append(pat)
        return out

    def _prefill_layer(self, spec: LayerSpec, lparams, shared, cache, x,
                       positions):
        cfg = self.cfg
        h = rmsnorm(lparams["norm1"], x)
        new_cache = dict(cache)
        if spec.mixer in ("attn", "shared_attn"):
            p = (self._merged_shared_attn(lparams["mixer"], shared)
                 if spec.mixer == "shared_attn" else lparams["mixer"])
            out, (k, v) = attn.attention_forward_kv(
                p, h, positions, kind=spec.attn_kind, window=cfg.window,
                chunk=cfg.chunk, use_rope=spec.use_rope,
                rope_theta=cfg.rope_theta, block_q=cfg.block_q)
            new_cache["mixer"] = attn.fill_kv_cache(
                cache["mixer"], k, v, spec.attn_kind, cfg.window, cfg.chunk)
        elif spec.mixer == "mamba2":
            out, st = ssm_mod.mamba2_forward_state(
                lparams["mixer"], h, d_state=cfg.ssm_state,
                headdim=cfg.ssm_headdim, expand=cfg.ssm_expand,
                chunk=cfg.ssd_chunk)
            new_cache["mixer"] = st
        elif spec.mixer == "rwkv6":
            out, st = rwkv_mod.rwkv6_timemix_forward_state(
                lparams["mixer"], h, cfg.rwkv_headdim, cfg.rwkv_chunk)
            new_cache["mixer"] = st
        else:
            raise ValueError(spec.mixer)
        x = x + out
        if spec.ffn != "none":
            h2 = rmsnorm(lparams["norm2"], x)
            if spec.ffn == "rwkv_cm":
                out2 = rwkv_mod.rwkv6_channelmix_forward(lparams["ffn"], h2)
                new_cache["ffn"] = {"cm_last": h2[:, -1:]}
            else:
                out2, _ = self._apply_ffn(spec, lparams, shared, h2)
            x = x + out2
        return x, new_cache

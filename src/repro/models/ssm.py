"""Mamba2 (SSD) mixer — used by zamba2 (paper-assigned hybrid arch).

State-space recurrence per head h with state (P, N):
    H_t = exp(dt_t * A_h) * H_{t-1} + dt_t * x_t (P) outer B_t (N)
    y_t = H_t @ C_t + D_h * x_t
Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic form +
inter-chunk state scan); decode is the plain one-step recurrence.

Shapes: d_inner = expand * d_model; H = d_inner / headdim (P = headdim);
B/C shared across heads (single group), state size N = cfg.ssm_state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init
from repro.models.scan_utils import maybe_scan
from repro.models.sharding import shard_hint


def init_mamba2(key, d_model: int, d_state: int, headdim: int = 64,
                expand: int = 2, conv_kernel: int = 4, dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    ks = jax.random.split(key, 4)
    params = {
        # fused input projection: [z, x, B, C, dt]
        "w_in": _dense_init(ks[0], (d_model, 2 * d_inner + 2 * d_state + n_heads),
                            0, dtype),
        "conv_w": _dense_init(ks[1], (conv_kernel, d_inner + 2 * d_state), 0,
                              dtype),
        "a_log": jnp.zeros((n_heads,), jnp.float32),        # A = -exp(a_log)
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "w_out": _dense_init(ks[2], (d_inner, d_model), 0, dtype),
    }
    axes = {
        "w_in": ("fsdp", "tp"),
        "conv_w": (None, "tp"),
        "a_log": ("tp",),
        "dt_bias": ("tp",),
        "d_skip": ("tp",),
        "norm_scale": ("tp",),
        "w_out": ("tp", "fsdp"),
    }
    return params, axes


def _split_proj(proj, d_inner, d_state, n_heads):
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:2 * d_inner + 2 * d_state]
    dt = proj[..., 2 * d_inner + 2 * d_state:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w):
    """Depthwise causal conv over seq. xbc (B,S,C); conv_w (K,C)."""
    k = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(k))
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype)


def _gated_out(params, y, z, d_model):
    b, s = y.shape[:2]
    y = y.reshape(b, s, -1)
    # RMS-normed gating (Mamba2 uses grouped RMSNorm before out-proj)
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y32 = y32 * jax.lax.rsqrt(var + 1e-6) * params["norm_scale"].astype(jnp.float32)
    w_out = shard_hint(params["w_out"], "tp", "fsdp")
    out = jnp.einsum("bsf,fd->bsd", y32.astype(w_out.dtype), w_out)
    return shard_hint(out, "batch", "seq", None)


def ssd_chunked(x, dt, a, b_in, c_in, chunk: int = 128, h0=None,
                unroll: bool = False):
    """Chunked SSD scan.

    x (B,S,H,P); dt (B,S,H) (post-softplus); a (H,) negative;
    b_in/c_in (B,S,N). Returns (y (B,S,H,P), final state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by ssd chunk {chunk}")
    nc = s // chunk

    xs = x.reshape(bsz, nc, chunk, h, p)
    dts = dt.reshape(bsz, nc, chunk, h)
    bs = b_in.reshape(bsz, nc, chunk, n)
    cs = c_in.reshape(bsz, nc, chunk, n)

    # log-decay within chunk: l[t] = cumsum(dt * a)
    dta = dts * a[None, None, None, :]                     # (B,nc,Q,H)
    l = jnp.cumsum(dta, axis=2)
    l_last = l[:, :, -1:]                                  # (B,nc,1,H)

    # ---- intra-chunk (quadratic within chunk) -----------------------------
    scores = jnp.einsum("bctn,bcsn->bcts", cs, bs)         # (B,nc,Q,Q)
    decay = jnp.exp(l[:, :, :, None, :] - l[:, :, None, :, :])  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    m = scores[..., None] * decay * tri[None, None, :, :, None]
    y_intra = jnp.einsum("bctsh,bcsh,bcshp->bcthp", m, dts, xs)

    # ---- chunk states ------------------------------------------------------
    # state contribution of chunk c: sum_s exp(l_last - l_s) dt_s x_s (x) B_s
    w = jnp.exp(l_last - l) * dts                          # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcsh,bcshp,bcsn->bchpn", w, xs, bs)
    chunk_decay = jnp.exp(l_last[:, :, 0])                 # (B,nc,H)

    # ---- inter-chunk state scan -------------------------------------------
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(carry, inp):
        st, dcy = inp                                      # (B,H,P,N), (B,H)
        new = carry * dcy[:, :, None, None] + st
        return new, carry                                  # emit state BEFORE chunk

    states = jnp.moveaxis(chunk_state.astype(jnp.float32), 1, 0)
    decays = jnp.moveaxis(chunk_decay, 1, 0)
    h_final, h_prevs = maybe_scan(step, h0, (states, decays),
                                  unroll=unroll)
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # (B,nc,H,P,N)

    # ---- inter-chunk contribution to outputs ------------------------------
    y_inter = jnp.einsum("bcth,bctn,bchpn->bcthp",
                         jnp.exp(l), cs, h_prevs.astype(x.dtype))
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, h_final


def mamba2_forward(params, x, *, d_state: int, headdim: int, expand: int,
                   chunk: int = 128, unroll: bool = False):
    """Full-sequence Mamba2 mixer. x (B,S,d) -> (B,S,d)."""
    out, _ = mamba2_forward_state(params, x, d_state=d_state, headdim=headdim,
                                  expand=expand, chunk=chunk, unroll=unroll)
    return out


def mamba2_forward_state(params, x, *, d_state: int, headdim: int,
                         expand: int, chunk: int = 128,
                         unroll: bool = False):
    """Full-sequence Mamba2 that also returns the decode cache (final SSM
    state + conv window)."""
    d_model = x.shape[-1]
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    proj = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    z, xbc_raw, dt = _split_proj(proj, d_inner, d_state, n_heads)
    xbc = _causal_conv(xbc_raw, params["conv_w"])
    xin = xbc[..., :d_inner]
    b_in = xbc[..., d_inner:d_inner + d_state]
    c_in = xbc[..., d_inner + d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    bsz, s = x.shape[:2]
    xh = xin.reshape(bsz, s, n_heads, headdim)
    xh = shard_hint(xh, "batch", "seq", "tp", None)
    y, h_final = ssd_chunked(xh, dt, a, b_in, c_in,
                             chunk=min(chunk, s), unroll=unroll)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(y.dtype)
    out = _gated_out(params, y.astype(x.dtype), z, d_model)
    cache = {"h": h_final,                          # (B,H,P,N)
             "conv": xbc_raw[:, -(params["conv_w"].shape[0] - 1):]}
    return out, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_mamba2_cache(batch: int, d_model: int, d_state: int, headdim: int,
                      expand: int, conv_kernel: int, dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    return {
        "h": jnp.zeros((batch, n_heads, headdim, d_state), jnp.float32),
        "conv": jnp.zeros((batch, conv_kernel - 1, d_inner + 2 * d_state),
                          dtype),
    }


def mamba2_decode(params, x, cache, *, d_state: int, headdim: int,
                  expand: int):
    """One-token step. x (B,1,d)."""
    d_model = x.shape[-1]
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    proj = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    z, xbc, dt = _split_proj(proj, d_inner, d_state, n_heads)
    # conv over the cached window + this token
    win = jnp.concatenate([cache["conv"], xbc], axis=1)    # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", win, params["conv_w"])
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)[:, None]
    new_conv = win[:, 1:]
    xin = conv_out[..., :d_inner]
    b_in = conv_out[..., d_inner:d_inner + d_state]
    c_in = conv_out[..., d_inner + d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]
    a = -jnp.exp(params["a_log"])
    xh = xin[:, 0].reshape(-1, n_heads, headdim)
    decay = jnp.exp(dt * a[None, :])                       # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh.astype(jnp.float32),
                     b_in[:, 0].astype(jnp.float32))
    h_new = cache["h"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, c_in[:, 0].astype(jnp.float32))
    y = y + params["d_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y[:, None].astype(x.dtype)                         # (B,1,H,P)
    out = _gated_out(params, y, z, d_model)
    return out, {"h": h_new, "conv": new_conv}

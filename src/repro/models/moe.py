"""Mixture-of-Experts FFN (phi3.5-moe 16e top-2, llama4 128e top-1 + shared).

Token-choice top-k routing with per-row capacity. Two dispatch
implementations:

  "scatter" (baseline): scatter-add tokens into per-expert buffers, batched
      expert matmul, gather back. Memory O(E * capacity * d) — no (T, E, C)
      dispatch tensor is ever materialized.
  "dense" (GShard-style): one-hot dispatch einsum — simple, used as the
      reference oracle in tests.

Experts are sharded over the "tp" mesh axis (expert parallelism); GSPMD
inserts the token all-to-all at the dispatch/combine boundaries.
Aux losses: switch load-balance loss + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, init_mlp, mlp
from repro.models.sharding import shard_hint


def init_moe(key, d_model: int, d_ff: int, n_experts: int, top_k: int,
             shared_expert: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    ex = jax.random.split(ks[0], 3)
    params = {
        "router": _dense_init(ks[1], (d_model, n_experts), 0, jnp.float32),
        "w_gate": _dense_init(ex[0], (n_experts, d_model, d_ff), 1, dtype),
        "w_up": _dense_init(ex[1], (n_experts, d_model, d_ff), 1, dtype),
        "w_down": _dense_init(ex[2], (n_experts, d_ff, d_model), 1, dtype),
    }
    axes = {
        "router": (None, None),
        "w_gate": ("tp", "fsdp", None),
        "w_up": ("tp", "fsdp", None),
        "w_down": ("tp", None, "fsdp"),
    }
    if shared_expert:
        sp, sa = init_mlp(ks[2], d_model, d_ff, dtype)
        params["shared"] = sp
        axes["shared"] = sa
    return params, axes


def _expert_ffn(params, xe):
    """xe (E, C, d) -> (E, C, d), batched over experts."""
    w_gate = shard_hint(params["w_gate"], "tp", "fsdp", None)
    w_up = shard_hint(params["w_up"], "tp", "fsdp", None)
    w_down = shard_hint(params["w_down"], "tp", None, "fsdp")
    h = jnp.einsum("ecd,edf->ecf", xe, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xe, w_up)
    h = jax.nn.silu(h.astype(jnp.float32)).astype(xe.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _iterative_top_k(probs, k):
    """top_k via k argmax passes — no sort/top_k primitive. XLA's partial-auto
    SPMD partitioner (mesh_2d engine region) aborts on sort-family HLOs, so
    the routed path swaps this in when ArchConfig.scan_unroll is set. Ties
    resolve to the lowest index, matching jax.lax.top_k."""
    masked = probs
    vals, ids = [], []
    for _ in range(k):
        v = jnp.max(masked, axis=-1)
        i = jnp.argmax(masked, axis=-1)
        vals.append(v)
        ids.append(i)
        masked = jnp.where(jax.nn.one_hot(i, probs.shape[-1], dtype=bool),
                           -jnp.inf, masked)
    return jnp.stack(vals, axis=-1), jnp.stack(ids, axis=-1)


def _route(params, x, top_k: int, iterative_topk: bool = False):
    """x (T, d) -> weights (T, K), ids (T, K), aux losses."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    select = _iterative_top_k if iterative_topk else jax.lax.top_k
    weights, ids = select(probs, top_k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # switch load-balance loss: E * sum_e f_e * p_e
    e = params["router"].shape[1]
    f = jnp.mean(jax.nn.one_hot(ids[:, 0], e), axis=0)
    p = jnp.mean(probs, axis=0)
    lb = e * jnp.sum(f * p)
    z = jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))
    return weights, ids, lb + 1e-3 * z


def capacity(tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(tokens * top_k * factor / n_experts)
    return max(8, (c + 7) // 8 * 8)


def _regroup(x):
    """Dispatch groups: per batch row for long sequences; the whole batch as
    one group for decode (S=1), where per-row capacity would pad each row's
    single token to a full min-capacity expert buffer (128x waste)."""
    bsz, s, d = x.shape
    if s <= 8:
        return x.reshape(1, bsz * s, d)
    return x


def moe_scatter(params, x, *, top_k: int, capacity_factor: float = 1.25,
                iterative_topk: bool = False):
    """x (B, S, d) -> (y, aux). Scatter/gather dispatch, per group."""
    orig_shape = x.shape
    x = _regroup(x)
    bsz, s, d = x.shape
    e = params["router"].shape[1]
    cap = capacity(s, e, top_k, capacity_factor)

    def per_row(xr):                                     # xr (S, d)
        weights, ids, aux = _route(params, xr, top_k,
                                   iterative_topk=iterative_topk)
        flat_ids = ids.reshape(-1)                       # (S*K,)
        flat_w = weights.reshape(-1)
        # rank of each (token, k) within its expert, in token order
        oh = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)     # (S*K, E)
        ranks = jnp.cumsum(oh, axis=0) - oh
        rank = jnp.sum(ranks * oh, axis=-1)              # (S*K,)
        keep = rank < cap
        slot = jnp.where(keep, flat_ids * cap + rank, e * cap)  # overflow slot
        xr_rep = jnp.repeat(xr, top_k, axis=0)           # (S*K, d)
        buf = jnp.zeros((e * cap + 1, d), xr.dtype)
        buf = buf.at[slot].add(xr_rep)
        ye = _expert_ffn(params, buf[:-1].reshape(e, cap, d))
        y_tok = ye.reshape(e * cap, d)
        y_tok = jnp.concatenate([y_tok, jnp.zeros((1, d), y_tok.dtype)])
        gathered = y_tok[slot] * (flat_w * keep)[:, None].astype(y_tok.dtype)
        y = jnp.sum(gathered.reshape(s, top_k, d), axis=1)
        return y, aux

    y, aux = jax.vmap(per_row)(x)
    y = y.reshape(orig_shape)
    x = x.reshape(orig_shape)
    y = shard_hint(y, "batch", "seq", None)
    if "shared" in params:
        y = y + mlp(params["shared"], x)
    return y, jnp.mean(aux)


def moe_dense(params, x, *, top_k: int, capacity_factor: float = 1.25,
              iterative_topk: bool = False):
    """Reference GShard-style dense-dispatch implementation (oracle)."""
    orig_shape = x.shape
    x = _regroup(x)
    bsz, s, d = x.shape
    e = params["router"].shape[1]
    cap = capacity(s, e, top_k, capacity_factor)

    def per_row(xr):
        weights, ids, aux = _route(params, xr, top_k,
                                   iterative_topk=iterative_topk)
        flat_ids = ids.reshape(-1)
        flat_w = weights.reshape(-1)
        oh = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)
        ranks = jnp.cumsum(oh, axis=0) - oh
        rank = jnp.sum(ranks * oh, axis=-1)
        keep = rank < cap
        disp = (jax.nn.one_hot(flat_ids, e)[..., None] *
                jax.nn.one_hot(rank, cap)[..., None, :]) * keep[:, None, None]
        xr_rep = jnp.repeat(xr, top_k, axis=0)           # (S*K, d)
        xe = jnp.einsum("tec,td->ecd", disp, xr_rep)
        ye = _expert_ffn(params, xe)
        comb = disp * flat_w[:, None, None]
        y = jnp.einsum("tec,ecd->td", comb, ye)
        return jnp.sum(y.reshape(s, top_k, d), axis=1), aux

    y, aux = jax.vmap(per_row)(x)
    y = y.reshape(orig_shape)
    x = x.reshape(orig_shape)
    if "shared" in params:
        y = y + mlp(params["shared"], x)
    return y, jnp.mean(aux)


def moe_apply(params, x, *, top_k: int, capacity_factor: float = 1.25,
              impl: str = "scatter", iterative_topk: bool = False):
    fn = moe_scatter if impl == "scatter" else moe_dense
    return fn(params, x, top_k=top_k, capacity_factor=capacity_factor,
              iterative_topk=iterative_topk)

"""The paper's convex models: logistic regression (Adult) and linear SVM
(Vehicle), with the loss functions used in §8.1 (softmax cross-entropy and
hinge loss). Both are G-Lipschitz on unit-ball data, matching §4."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import shard_hint


def init_linear(dim: int, n_classes: int = 2, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(scale=0.01, size=(dim, n_classes)),
                         jnp.float32),
        "b": jnp.zeros((n_classes,), jnp.float32),
    }


def logits(params, x):
    # "fsdp" resolves to the 2D mesh's "model" axis under mesh2d_rules();
    # identity outside an axis_rules() context, so vmap/map paths see no-op.
    w = shard_hint(params["w"], "fsdp", "tp")
    return x @ w + params["b"]


def logreg_loss(params, batch, l2: float = 1e-4):
    """Softmax cross-entropy (paper: Adult logistic regression)."""
    z = logits(params, batch["x"])
    logp = jax.nn.log_softmax(z, axis=-1)
    y = batch["y"]
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    reg = 0.5 * l2 * (jnp.sum(params["w"] ** 2))
    return jnp.mean(nll) + reg


def svm_loss(params, batch, l2: float = 1e-4):
    """Binary hinge loss (paper: Vehicle linear SVM). Uses the margin of the
    positive-class score minus negative-class score."""
    z = logits(params, batch["x"])
    margin = z[:, 1] - z[:, 0]
    y_pm = 2.0 * batch["y"].astype(jnp.float32) - 1.0
    hinge = jnp.maximum(0.0, 1.0 - y_pm * margin)
    reg = 0.5 * l2 * (jnp.sum(params["w"] ** 2))
    return jnp.mean(hinge) + reg


def accuracy(params, x, y):
    pred = jnp.argmax(logits(params, x), axis=-1)
    return jnp.mean((pred == y).astype(jnp.float32))


def make_eval_fn(loss_fn, x, y):
    x = jnp.asarray(x)
    y = jnp.asarray(y)

    @jax.jit
    def _eval(params):
        return {
            "eval_loss": loss_fn(params, {"x": x, "y": y}),
            "eval_acc": accuracy(params, x, y),
        }

    def eval_fn(params):
        return {k: float(v) for k, v in _eval(params).items()}

    return eval_fn

"""Attention: GQA/MQA/MHA with full-causal, sliding-window, and chunked
variants; blockwise (flash-style) lax implementation for train/prefill and a
cached single-token path for decode.

The blockwise path is the *compiled* baseline (works on any backend and keeps
the S x S score matrix tiled); the Pallas kernel in repro/kernels/flash_attention.py
is the TPU hot path and is validated against the same math.

Shapes: x (B, S, d); q (B, S, H, hd); k/v (B, S, KV, hd).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, apply_rope, rope_angles
from repro.models.scan_utils import maybe_map
from repro.models.sharding import shard_hint

NEG_INF = -1e30


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qkv_bias: bool = False, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "wq": _dense_init(k1, (d_model, n_heads, head_dim), 0, dtype),
        "wk": _dense_init(k2, (d_model, n_kv_heads, head_dim), 0, dtype),
        "wv": _dense_init(k3, (d_model, n_kv_heads, head_dim), 0, dtype),
        "wo": _dense_init(k4, (n_heads, head_dim, d_model), 2, dtype),
    }
    axes = {
        "wq": ("fsdp", "tp", None),
        "wk": ("fsdp", "tp", None),
        "wv": ("fsdp", "tp", None),
        "wo": ("tp", None, "fsdp"),
    }
    if qkv_bias:
        params["bq"] = jnp.zeros((n_heads, head_dim), dtype)
        params["bk"] = jnp.zeros((n_kv_heads, head_dim), dtype)
        params["bv"] = jnp.zeros((n_kv_heads, head_dim), dtype)
        axes["bq"] = ("tp", None)
        axes["bk"] = ("tp", None)
        axes["bv"] = ("tp", None)
    return params, axes


def _project_qkv(params, x, positions, use_rope: bool, rope_theta: float):
    wq = shard_hint(params["wq"], "wg", "tp", None)
    wk = shard_hint(params["wk"], "wg", "tp", None)
    wv = shard_hint(params["wv"], "wg", "tp", None)
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if use_rope:
        cos, sin = rope_angles(positions, q.shape[-1], rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = shard_hint(q, "batch", "seq", "tp", None)
    k = shard_hint(k, "batch", "seq", "tp", None)
    v = shard_hint(v, "batch", "seq", "tp", None)
    return q, k, v


def _sdpa(q, k, v, mask):
    """Grouped scaled-dot-product attention on one (q-block, kv-block).

    q (B, Sq, H, hd); k/v (B, Skv, KV, hd); mask broadcastable to
    (B, 1, 1, Sq, Skv). Softmax in fp32.
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, hd)


def blocked_causal_attention(q, k, v, *, window: int = 0, block_q: int = 512,
                             q_start: int = 0, causal_buckets: bool = False,
                             unroll: bool = False):
    """Causal (optionally sliding-window) attention, tiled over q blocks.

    window == 0 -> full causal. window == W -> attend to the last W positions
    (inclusive of self). q_start offsets q positions relative to k positions
    (used when a prefix occupies the head of the kv sequence).

    causal_buckets: group q blocks into power-of-two buckets so bucket b only
    reads kv[0 : 2^(b+1) * block_q] — skips ~1/3 of the above-diagonal work
    with fully static shapes (§Perf optimization).
    """
    if causal_buckets and not window and q_start == 0:
        return _bucketed_causal_attention(q, k, v, block_q=block_q,
                                          unroll=unroll)
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    bq = min(block_q, sq)
    n_blocks = (sq + bq - 1) // bq
    pad = n_blocks * bq - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))

    kv_positions = jnp.arange(skv)

    # checkpointed per-q-block body: the backward pass recomputes the block's
    # scores/probs instead of saving an S x S softmax across all blocks
    @jax.checkpoint
    def one_block(i):
        qs = i * bq
        qb = jax.lax.dynamic_slice_in_dim(q, qs, bq, axis=1)
        q_pos = q_start + qs + jnp.arange(bq)
        if window and window + bq < skv:
            # only the last (window + bq) keys can be visible to this block
            kv_len = window + bq
            start = jnp.clip(q_start + qs + bq - kv_len, 0, skv - kv_len)
            kb = jax.lax.dynamic_slice_in_dim(k, start, kv_len, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, kv_len, axis=1)
            k_pos = start + jnp.arange(kv_len)
        else:
            kb, vb = k, v
            k_pos = kv_positions
        mask = q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask = mask[None, None, None]
        return _sdpa(qb, kb, vb, mask)

    out = maybe_map(one_block, jnp.arange(n_blocks), unroll)  # (nb,B,bq,H,hd)
    out = jnp.moveaxis(out, 0, 1).reshape(b, n_blocks * bq, h, hd)
    return out[:, :sq]


def _bucketed_causal_attention(q, k, v, *, block_q: int,
                               unroll: bool = False):
    """Causal attention with power-of-two kv buckets (static shapes).

    q block i needs kv[0 : (i+1) * bq]. Blocks with i+1 in (2^b/2, 2^b] share
    the padded kv span kv[0 : 2^b * bq]; each bucket runs its own lax.map.
    FLOPs = sum_b 2^(b-1) * 2^b * bq^2 ~ (2/3) S^2 vs S^2 for the full grid.
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    bq = min(block_q, sq)
    assert sq % bq == 0, (sq, bq)
    nb = sq // bq

    outs = []
    start = 0
    span = 1
    while start < nb:
        count = min(span - start, nb - start)     # blocks in this bucket
        kv_len = min(span * bq, skv)
        kb, vb = k[:, :kv_len], v[:, :kv_len]
        k_pos = jnp.arange(kv_len)

        def one_block(i, kb=kb, vb=vb, k_pos=k_pos, start=start):
            qs = (start + i) * bq
            qb = jax.lax.dynamic_slice_in_dim(q, qs, bq, axis=1)
            q_pos = qs + jnp.arange(bq)
            mask = (q_pos[:, None] >= k_pos[None, :])[None, None, None]
            return _sdpa(qb, kb, vb, mask)

        one_block = jax.checkpoint(one_block)
        out = maybe_map(one_block, jnp.arange(count), unroll)
        outs.append(jnp.moveaxis(out, 0, 1).reshape(b, count * bq, h, hd))
        start += count
        span *= 2
    return jnp.concatenate(outs, axis=1)


def chunked_causal_attention(q, k, v, chunk: int, unroll: bool = False):
    """Llama4-style chunked attention: tokens attend causally only within
    their own chunk. O(S * chunk)."""
    b, s, h, hd = q.shape
    if s <= chunk:
        pos = jnp.arange(s)
        mask = (pos[:, None] >= pos[None, :])[None, None, None]
        return _sdpa(q, k, v, mask)
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    n = s // chunk
    kv_h = k.shape[2]

    @jax.checkpoint
    def per_chunk(args):
        qc, kc, vc = args
        pos = jnp.arange(chunk)
        mask = (pos[:, None] >= pos[None, :])[None, None, None]
        return _sdpa(qc, kc, vc, mask)

    qc = jnp.moveaxis(q.reshape(b, n, chunk, h, hd), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, n, chunk, kv_h, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n, chunk, kv_h, hd), 1, 0)
    out = maybe_map(per_chunk, (qc, kc, vc), unroll)
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)


def attention_forward(params, x, positions, *, kind: str = "full",
                      window: int = 0, chunk: int = 0, use_rope: bool = True,
                      rope_theta: float = 1e4, block_q: int = 512,
                      causal_buckets: bool = False, unroll: bool = False):
    """Full-sequence attention (train / prefill). Returns (B, S, d)."""
    out, _ = attention_forward_kv(
        params, x, positions, kind=kind, window=window, chunk=chunk,
        use_rope=use_rope, rope_theta=rope_theta, block_q=block_q,
        causal_buckets=causal_buckets, unroll=unroll)
    return out


def attention_forward_kv(params, x, positions, *, kind: str = "full",
                         window: int = 0, chunk: int = 0,
                         use_rope: bool = True, rope_theta: float = 1e4,
                         block_q: int = 512, causal_buckets: bool = False,
                         unroll: bool = False):
    """Like attention_forward but also returns the (k, v) pair for prefill
    cache construction."""
    q, k, v = _project_qkv(params, x, positions, use_rope, rope_theta)
    if kind == "full":
        ctxv = blocked_causal_attention(q, k, v, window=0, block_q=block_q,
                                        causal_buckets=causal_buckets,
                                        unroll=unroll)
    elif kind == "swa":
        ctxv = blocked_causal_attention(q, k, v, window=window,
                                        block_q=block_q, unroll=unroll)
    elif kind == "chunk":
        ctxv = chunked_causal_attention(q, k, v, chunk=chunk, unroll=unroll)
    else:
        raise ValueError(f"unknown attention kind {kind}")
    wo = shard_hint(params["wo"], "tp", None, "fsdp")
    out = jnp.einsum("bshk,hkd->bsd", ctxv, wo)
    return shard_hint(out, "batch", "seq", None), (k, v)


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

def cache_len(kind: str, max_len: int, window: int, chunk: int) -> int:
    if kind == "swa":
        return min(window, max_len)
    if kind == "chunk":
        return min(chunk, max_len)
    return max_len


def init_kv_cache(batch: int, kind: str, max_len: int, n_kv_heads: int,
                  head_dim: int, window: int = 0, chunk: int = 0,
                  dtype=jnp.bfloat16):
    n = cache_len(kind, max_len, window, chunk)
    shape = (batch, n, n_kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def fill_kv_cache(cache, k, v, kind: str, window: int = 0, chunk: int = 0):
    """Write a full prefill sequence into the cache (possibly ring-truncated).

    k/v (B, S, KV, hd). For swa/chunk caches only the tail that remains
    visible is stored, laid out in ring order (slot = pos % cache_len)."""
    n = cache["k"].shape[1]
    s = k.shape[1]
    if s <= n:
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1),
        }
        return cache
    tail_k, tail_v = k[:, s - n:], v[:, s - n:]
    # ring layout: position p lives at slot p % n
    slots = (jnp.arange(s - n, s)) % n
    order = jnp.argsort(slots)
    return {"k": tail_k[:, order], "v": tail_v[:, order]}


def init_paged_kv_cache(n_blocks: int, block_size: int, n_kv_heads: int,
                        head_dim: int, dtype=jnp.bfloat16):
    """Preallocated block pool for the paged serving cache.

    Unlike the dense per-sequence cache of :func:`init_kv_cache`, the pool
    is indexed by *physical block id*: a slot owns an arbitrary set of
    blocks through an engine-managed ``(slots, blocks_per_slot)`` block
    table, so recycled slots reuse whatever blocks are free rather than a
    fixed contiguous span. Layout inside a slot's span is natural
    (position ``p`` lives at logical offset ``p``; no ring truncation —
    swa/chunk visibility is enforced by the decode mask instead), which
    makes the pool literally the dense full-attention cache when one
    block spans ``max_len`` and the table is the identity."""
    shape = (n_blocks, block_size, n_kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def paged_decode_attention(params, x, cache, table, pos, *,
                           kind: str = "full", window: int = 0,
                           chunk: int = 0, use_rope: bool = True,
                           rope_theta: float = 1e4):
    """One-token decode over B independent slots of a paged KV cache.

    x (B, 1, d); cache {"k"/"v": (NB, bs, KV, hd)} block pool; table
    (B, bps) int32 maps each slot's logical block l to a physical block;
    pos (B,) int32 per-slot position of this token. Writes each slot's
    k/v at (table[b, pos_b // bs], pos_b % bs), gathers the slot's full
    logical span back in position order, and masks entries beyond pos_b
    (plus the sliding-window / chunk visibility rule). With one block
    spanning the span and an identity table the gathered reads are
    bit-identical to the dense :func:`decode_attention` cache reads; with
    more blocks they are the same values in the same position order, so
    full-attention outputs stay bit-identical to the dense path.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(params, x, pos[:, None], use_rope, rope_theta)
    bs = cache["k"].shape[1]
    phys = table[jnp.arange(b), pos // bs]
    off = pos % bs
    ck = cache["k"].at[phys, off].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[phys, off].set(v[:, 0].astype(cache["v"].dtype))
    span = table.shape[1] * bs
    kb = ck[table].reshape(b, span, *ck.shape[2:])
    vb = cv[table].reshape(b, span, *cv.shape[2:])
    p = jnp.arange(span)
    valid = p[None, :] <= pos[:, None]
    if kind == "swa":
        valid &= p[None, :] > pos[:, None] - window
    elif kind == "chunk":
        valid &= p[None, :] >= (pos[:, None] // chunk) * chunk
    mask = valid[:, None, None, None, :]
    ctxv = _sdpa(q, kb, vb, mask)
    wo = shard_hint(params["wo"], "tp", None, "fsdp")
    out = jnp.einsum("bshk,hkd->bsd", ctxv, wo)
    return shard_hint(out, "batch", "seq", None), {"k": ck, "v": cv}


def decode_attention(params, x, cache, pos, *, kind: str = "full",
                     window: int = 0, chunk: int = 0, use_rope: bool = True,
                     rope_theta: float = 1e4):
    """One-token decode. x (B, 1, d); pos scalar int32 = index of this token.
    Returns (out (B,1,d), updated cache)."""
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = _project_qkv(params, x, positions, use_rope, rope_theta)
    n = cache["k"].shape[1]
    slot = pos % n
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    # entry at slot i currently holds position: the largest p <= pos with
    # p % n == i  ->  p = pos - ((pos - i) % n)
    slots = jnp.arange(n)
    entry_pos = pos - jnp.mod(pos - slots, n)
    valid = entry_pos >= 0
    if kind == "swa":
        valid &= entry_pos > pos - window
    elif kind == "chunk":
        valid &= entry_pos >= (pos // chunk) * chunk
    mask = valid[None, None, None, None, :]
    ctxv = _sdpa(q, ck, cv, mask)
    wo = shard_hint(params["wo"], "tp", None, "fsdp")
    out = jnp.einsum("bshk,hkd->bsd", ctxv, wo)
    return shard_hint(out, "batch", "seq", None), {"k": ck, "v": cv}

"""Admission scheduler, serving clocks, and the static-batch baseline.

:func:`serve_continuous` drives a :class:`SlotEngine` over an open-loop
workload: each loop iteration either admits a prefill group (requests
join at decode-step granularity — prefill is length-bucketed to bound
recompiles) or runs one decode wavefront; when the engine is empty and
nothing has arrived yet, the clock jumps to the next arrival (open-loop
semantics — arrivals never wait for the server).

Two clocks implement the :class:`ServeClock` protocol. ``WallClock``
advances by the measured host seconds of each unit of work and jumps
idle gaps instantly — real engine speed against simulated arrivals, the
benchmark configuration. ``StepClock`` charges fixed costs per decode
step / prefill token — fully deterministic, the test configuration (the
same role the zero-spread UniformLatency plays for the async engine).

:func:`serve_static` is the pre-engine baseline as a scheduler: FIFO
batches of same-length prompts, the whole batch decoded to its largest
generation budget (the convoy penalty), new arrivals wait for the batch
to drain. Greedy static and continuous serving emit byte-identical
tokens per request; the benchmark measures what the convoy + same-length
grouping cost under mixed-length load.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.requests import Request


class ServeClock:
    """Protocol: ``work(kind, wall_s, amount)`` charges one unit of
    server work (kind 'decode' | 'prefill'); ``jump(t)`` advances the
    idle clock to an arrival; ``now`` is simulated seconds."""

    now: float


@dataclass
class WallClock:
    """Simulated time = accumulated measured wall seconds of server work;
    idle gaps are skipped by jumping to the next arrival."""
    now: float = 0.0

    def work(self, kind: str, wall_s: float, amount: int = 1) -> None:
        self.now += wall_s

    def jump(self, t: float) -> None:
        self.now = max(self.now, t)


@dataclass
class StepClock:
    """Deterministic clock: every decode wavefront costs ``dt_decode``,
    prefill costs ``dt_prefill_token`` per padded prompt token."""
    dt_decode: float = 1.0
    dt_prefill_token: float = 0.125
    now: float = 0.0

    def work(self, kind: str, wall_s: float, amount: int = 1) -> None:
        if kind == "decode":
            self.now += self.dt_decode
        else:
            self.now += self.dt_prefill_token * amount

    def jump(self, t: float) -> None:
        self.now = max(self.now, t)


@dataclass
class ServeReport:
    """Everything the benchmark plots: completed requests (tokens +
    per-token emission times), aggregate tokens/s in simulated seconds,
    and backpressure stats sampled every loop iteration."""
    requests: list = field(default_factory=list)
    duration_s: float = 0.0
    tokens_out: int = 0
    queue_depth: list = field(default_factory=list)
    occupancy: list = field(default_factory=list)
    engine_stats: dict = field(default_factory=dict)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.duration_s if self.duration_s else 0.0

    def latencies(self) -> np.ndarray:
        if not self.requests:
            return np.zeros(0)
        return np.concatenate([r.token_latencies() for r in self.requests])

    def summary(self) -> dict:
        lat = self.latencies()
        # engine stats first: they are cumulative over the engine's whole
        # lifetime (warmup + every serve run on a reused engine), so the
        # per-run report fields must win on any shared key
        return {
            **self.engine_stats,
            "requests": len(self.requests),
            "tokens_out": self.tokens_out,
            "duration_s": round(self.duration_s, 4),
            "tokens_per_s": round(self.tokens_per_s, 2),
            "p50_latency_s": round(float(np.percentile(lat, 50)), 5)
            if lat.size else None,
            "p99_latency_s": round(float(np.percentile(lat, 99)), 5)
            if lat.size else None,
            "max_queue_depth": max(self.queue_depth, default=0),
            "occupancy_mean": round(float(np.mean(self.occupancy)), 3)
            if self.occupancy else 0.0,
        }


def _take_group(ready: deque, engine) -> list[Request]:
    """Head-of-line prefill group: the head request's bucket, plus every
    other ready request sharing it, up to free slots / prefill batch."""
    limit = min(engine.free_slots, engine.prefill_batch)
    head_bucket = engine.bucket_len(ready[0].prompt_len)
    group, keep = [], []
    for r in ready:
        if (len(group) < limit
                and engine.bucket_len(r.prompt_len) == head_bucket):
            group.append(r)
        else:
            keep.append(r)
    ready.clear()
    ready.extend(keep)
    return group


def serve_continuous(engine, workload: list[Request],
                     clock: ServeClock | None = None,
                     swap_at: float | None = None,
                     swap_params=None) -> ServeReport:
    """Run the engine over an arrival-ordered workload until every
    request completes. Admission has priority over decode (a free slot
    never idles while a bucketed group is ready). ``swap_at`` hot-swaps
    ``swap_params`` in at the first loop boundary past that simulated
    time — in-flight slots keep running."""
    clock = clock or WallClock()
    for r in workload:
        if r.prompt_len + r.max_gen > engine.max_len:
            raise ValueError(f"request {r.rid} needs {r.prompt_len}+"
                             f"{r.max_gen} tokens; engine max_len="
                             f"{engine.max_len}")
    pending = deque(sorted(workload, key=lambda r: (r.arrival, r.rid)))
    ready: deque[Request] = deque()
    report = ServeReport()
    t_start = clock.now
    swapped = swap_params is None

    while pending or ready or engine.n_active:
        if not swapped and clock.now >= swap_at:
            engine.swap_params(swap_params)
            swapped = True
        while pending and pending[0].arrival <= clock.now:
            ready.append(pending.popleft())
        report.queue_depth.append(len(ready))
        report.occupancy.append(engine.n_active / engine.n_slots)

        if ready and engine.free_slots:
            group = _take_group(ready, engine)
            bucket = engine.bucket_len(group[0].prompt_len)
            t0 = time.perf_counter()
            engine.admit(group)
            jax.block_until_ready(engine._state["logits"])
            clock.work("prefill", time.perf_counter() - t0,
                       amount=bucket * len(group))
        elif engine.n_active:
            t0 = time.perf_counter()
            emitted, finished = engine.step()
            clock.work("decode", time.perf_counter() - t0)
            for r in emitted:
                r.emit_times.append(clock.now)
            for r in finished:
                r.finished = clock.now
                report.requests.append(r)
                report.tokens_out += len(r.out)
        elif pending:
            clock.jump(pending[0].arrival)
        else:  # pragma: no cover - loop condition excludes this
            break

    report.duration_s = clock.now - t_start
    report.engine_stats = engine.stats()
    report.requests.sort(key=lambda r: r.rid)
    return report


def serve_static(model, params, workload: list[Request],
                 clock: ServeClock | None = None, batch: int = 4,
                 temperature: float = 0.0, seed: int = 0,
                 max_len: int = 0) -> ServeReport:
    """Static-batch baseline: FIFO groups of same-prompt-length arrived
    requests (up to ``batch``), prefilled together and decoded to the
    group's largest generation budget; arrivals during a batch wait.
    Shares the fused sample+decode step with ``launch.serve.generate``,
    so greedy tokens match the engine byte-for-byte."""
    from repro.launch.serve import _decode_fns

    clock = clock or WallClock()
    pending = deque(sorted(workload, key=lambda r: (r.arrival, r.rid)))
    report = ServeReport()
    t_start = clock.now
    span = max_len or max(r.prompt_len + r.max_gen for r in workload)
    prefill_c, step_c = _decode_fns(model, temperature, span)

    while pending:
        if pending[0].arrival > clock.now:
            clock.jump(pending[0].arrival)
        head_len = pending[0].prompt_len
        group, keep = [], []
        for r in pending:
            if len(group) < batch and r.prompt_len == head_len \
                    and r.arrival <= clock.now:
                group.append(r)
            else:
                keep.append(r)
        pending = deque(keep)

        # pad the prefill batch to a fixed row count by repeating row 0,
        # so each distinct prompt length compiles exactly once
        toks = np.stack([r.tokens for r in group]
                        + [group[0].tokens] * (batch - len(group)))
        t0 = time.perf_counter()
        logits, caches, pos = prefill_c(params, jnp.asarray(toks), None)
        jax.block_until_ready(logits)
        clock.work("prefill", time.perf_counter() - t0,
                   amount=head_len * len(group))
        key = jax.random.PRNGKey(seed)
        gen = max(r.max_gen for r in group)  # convoy: all decode to max
        for i in range(gen):
            t0 = time.perf_counter()
            logits, caches, tok, key = step_c(params, caches, logits,
                                              pos + i, key)
            tok_np = np.asarray(tok)
            clock.work("decode", time.perf_counter() - t0)
            for j, r in enumerate(group):
                if len(r.out) < r.max_gen:
                    r.out.append(int(tok_np[j]))
                    r.emit_times.append(clock.now)
        for r in group:
            r.finished = clock.now
            report.requests.append(r)
            report.tokens_out += len(r.out)
        report.queue_depth.append(len(pending))
        report.occupancy.append(len(group) / batch)

    report.duration_s = clock.now - t_start
    report.requests.sort(key=lambda r: r.rid)
    return report

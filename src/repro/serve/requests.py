"""Serving requests and deterministic open-loop workloads.

A :class:`Request` is one decode job: a prompt, an arrival time in
simulated seconds, and a generation budget. :func:`poisson_workload`
builds an open-loop Poisson arrival stream with mixed prompt/generation
lengths under the same determinism contract as
:mod:`repro.asyncfl.clock`: every per-request draw comes from a fresh
``np.random.default_rng((seed, _SERVE_TAG, rid))`` — no sampler state,
so a workload is a pure function of ``(seed, rid)`` and any slice of it
can be regenerated independently of execution order.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# integer stream tag (SeedSequence entropy): disjoint from the latency /
# cohort tags of repro.asyncfl.clock and repro.population.samplers
_SERVE_TAG = 0x5E12F3


@dataclass
class Request:
    """One serving job: ``tokens`` (S,) int32 prompt, ``arrival`` in
    simulated seconds, ``max_gen`` tokens to decode. ``out`` /
    ``emit_times`` are filled by the scheduler as tokens stream out."""
    rid: int
    arrival: float
    tokens: np.ndarray
    max_gen: int
    out: list = field(default_factory=list)
    emit_times: list = field(default_factory=list)
    finished: float | None = None

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    def token_latencies(self) -> np.ndarray:
        """Per-token latency (s): first token measured from arrival
        (TTFT, includes queueing), the rest from the previous emission
        (inter-token time)."""
        times = np.asarray(self.emit_times, np.float64)
        prev = np.concatenate([[self.arrival], times[:-1]])
        return times - prev


def poisson_workload(n_requests: int, rate: float, vocab: int, *,
                     seed: int = 0,
                     prompt_lens=(8, 16, 32),
                     gen_lens=(8, 16)) -> list[Request]:
    """Open-loop Poisson arrivals: inter-arrival gaps ~ Exp(1/rate),
    prompt length and generation budget drawn uniformly from the choice
    sets, prompt tokens uniform over the vocab. ``rate`` is requests per
    simulated second. Deterministic per ``(seed, rid)``."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    reqs = []
    t = 0.0
    for rid in range(n_requests):
        rng = np.random.default_rng((int(seed), _SERVE_TAG, rid))
        t += float(rng.exponential(1.0 / rate))
        p_len = int(rng.choice(np.asarray(prompt_lens)))
        g_len = int(rng.choice(np.asarray(gen_lens)))
        toks = rng.integers(0, vocab, size=(p_len,)).astype(np.int32)
        reqs.append(Request(rid=rid, arrival=t, tokens=toks, max_gen=g_len))
    return reqs
